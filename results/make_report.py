"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md tables."""
import glob
import json
import sys

PEAK = 197e12


def mfu_like(r):
    """roofline fraction: ideal model time / dominant derived term."""
    ideal = (r["model_gflops"] / r["chips"]) * 1e9 / PEAK
    dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return ideal / dom if dom > 0 else 0.0


def row(r):
    gb = r.get("memory_analysis", {}).get("bytes_per_chip", 0) / 1e9
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
            f"{gb:.1f} | {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | {r['bottleneck'][:4]} | "
            f"{100*r['useful_flops_frac']:.0f}% | {100*mfu_like(r):.1f}% |")


def main(pattern="results/dryrun/*.json"):
    rows = []
    for f in sorted(glob.glob(pattern)):
        with open(f) as fh:
            rows.append(json.load(fh))
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("| arch | shape | mesh | kind | GB/chip | compute ms | memory ms "
          "| coll ms | bound | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(row(r))


if __name__ == "__main__":
    main(*sys.argv[1:])
