"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Physical mesh axes:

- ``pod``   (multi-pod only): pure data parallelism across pods (DCI).
- ``data``  : data parallelism within a pod; also hosts FSDP (ZeRO-3) param
              sharding and sequence parallelism for long-context cells.
- ``model`` : tensor/expert parallelism within a pod (ICI-adjacent).

Rules are *name + shape* based: ``param_pspec`` inspects the param path (e.g.
``stack/scanned/0/mixer/wq``) and the array rank, returns a PartitionSpec, and
silently falls back to replication for any dim not divisible by its axis size
(e.g. kv-heads < model-axis on GQA archs — those weights are replicated inside
the TP group exactly like Megatron does).

Everything here is pure metadata: no jax device state is touched, so importing
is safe before ``XLA_FLAGS`` is set by the dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 1


def _fit(dim: int, axis, mesh: Mesh):
    """Return ``axis`` if ``dim`` is divisible by its mesh size, else None."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 \
        and _axis_size(mesh, axis) > 1 else None


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The pure-DP axes, outermost first: ('pod','data') or ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_parallel_mesh(devices=None) -> Optional[Mesh]:
    """A 1-D ('data',) mesh over the local devices — the mesh the panel-sweep
    engine (``repro.core.sweep``) shards over.  Returns None when only one
    device is visible, which every ``mesh=`` consumer treats as the
    sequential single-device fallback."""
    import numpy as np
    devices = jax.devices() if devices is None else list(devices)
    if len(devices) <= 1:
        return None
    return Mesh(np.asarray(devices), ("data",))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

_REPLICATED_KEYS = ("norm", "scale", "router", "q_norm", "k_norm", "kv_norm",
                    "a_param", "conv", "gates", "offset")


def _is_stacked(parts) -> bool:
    """Does this leaf carry a leading layers dim?

    - 'xattn' subtrees (whisper) are always vmap-stacked.
    - scanned mode:   stack/scanned/<slot>/...        (ONE numeric)  stacked
    - unrolled mode:  stack/scanned/<rep>/<slot>/...  (TWO numerics) flat
    """
    if "xattn" in parts:
        return True
    if "scanned" not in parts:
        return False
    i = parts.index("scanned")
    numerics = 0
    for p in parts[i + 1:]:
        if p.lstrip("-").isdigit():
            numerics += 1
        else:
            break
    return numerics <= 1


def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                fsdp: bool = False, moe_ep2d: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    ``path`` is '/'-joined dict keys (ints for scanned stacks). The leading
    scan dim (layers) of stacked params is never sharded.  ``moe_ep2d``
    spreads expert banks over ('data','model') — the shard_map EP layout
    (one deepseek expert per chip; no ZeRO gather for expert weights).
    """
    parts = path.strip("/").split("/")
    key = parts[-1]
    nd = len(shape)
    off = 1 if (_is_stacked(parts) and nd >= 2) else 0   # leading layer dim

    def spec(*axes):
        full = [None] * nd
        for i, ax in enumerate(axes):
            full[off + i] = _fit(shape[off + i], ax, mesh)
        return P(*full)

    fs = "data" if fsdp else None                 # ZeRO-3 axis

    # ---- norms / small vectors -------------------------------------------
    if any(k in key for k in _REPLICATED_KEYS) and nd - off <= 2:
        return P(*([None] * nd))

    # ---- embeddings -------------------------------------------------------
    # vocab -> model only: co-sharding d over 'data' makes the token gather
    # un-partitionable (SPMD falls back to full rematerialization)
    if key == "embedding":                        # (V, d): vocab -> model
        return spec("model", None)
    if key == "unembed":                          # (d, V): vocab -> model
        return spec(None, "model")
    if key == "frontend_proj":                    # (d_front, d)
        return spec(None, "model")

    # ---- MoE expert banks -------------------------------------------------
    if "moe" in parts and key in ("wi_gate", "wi_up", "wo") \
            and "shared" not in parts and nd - off == 3:
        # (E, d, ff) / (E, ff, d): experts -> model (EP); when the expert
        # count doesn't divide the axis (qwen2's 60) fall back to TP inside
        # each expert on the ff dim
        if moe_ep2d and _fit(shape[off], ("data", "model"), mesh):
            return spec(("data", "model"), None, None)
        if _fit(shape[off], "model", mesh):
            return spec("model", fs, None)
        if key == "wo":                       # (E, ff, d)
            return spec(None, "model", fs)
        return spec(None, fs, "model")        # (E, d, ff)

    # ---- attention --------------------------------------------------------
    if key == "wq" and nd - off == 3:             # (d, H, hd): heads -> model
        return spec(fs, "model", None)
    if key in ("wk", "wv") and nd - off == 3:     # (d, KV, hd)
        return spec(fs, "model", None)
    if key == "wo" and nd - off == 3:             # (H, hd, d): heads -> model
        return spec("model", None, fs)

    # ---- MLA (deepseek) ---------------------------------------------------
    if key == "wq_a":                             # (d, q_rank)
        return spec(fs, "model")
    if key == "wq_b":                             # (q_rank, H, k)
        return spec(fs, "model", None)
    if key == "wkv_a":                            # (d, R+dr)
        return spec(fs, None)
    if key == "wkv_b":                            # (R, H, k)
        return spec(fs, "model", None)

    # ---- dense MLP --------------------------------------------------------
    if key in ("wi_gate", "wi_up") and nd - off == 2:   # (d, ff): ff -> model
        return spec(fs, "model")
    if key == "wo" and nd - off == 2:                   # (ff, d)
        return spec("model", fs)

    # ---- recurrent mixers (rglru / mlstm / slstm) -------------------------
    if key in ("wx", "wy"):                       # rglru in/out (d, W)/(W, d)
        return spec(fs, "model") if key == "wx" else spec("model", fs)
    if key in ("wqkv", "wi", "wf", "wz", "wout", "wproj", "wup", "wdown"):
        # generic wide projections: shard the widest non-d dim over model
        full = [None] * nd
        if nd - off >= 2:
            widest = max(range(off, nd), key=lambda i: shape[i])
            full[widest] = _fit(shape[widest], "model", mesh)
        return P(*full)

    # ---- fallback: shard the largest dim over model if it fits ------------
    if nd - off >= 2 and max(shape[off:]) >= 1024:
        full = [None] * nd
        widest = max(range(off, nd), key=lambda i: shape[i])
        full[widest] = _fit(shape[widest], "model", mesh)
        return P(*full)
    return P(*([None] * nd))


def param_shardings(params, mesh: Mesh, fsdp: bool = False,
                    moe_ep2d: bool = False):
    """NamedSharding pytree matching ``params`` (works on ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append(NamedSharding(mesh, param_pspec(
            pstr, leaf.shape, mesh, fsdp=fsdp, moe_ep2d=moe_ep2d)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# batch / activation / cache rules
# ---------------------------------------------------------------------------

def batch_pspec(shape: Tuple[int, ...], mesh: Mesh,
                seq_axis: Optional[int] = None) -> P:
    """Shard the batch dim over as much of (pod, data) as divides it; for
    unshardable batch (e.g. long_500k B=1) shard ``seq_axis`` over 'data'."""
    if not shape:
        return P()
    B = shape[0]
    dp = data_axes(mesh)
    full = [None] * len(shape)
    if dp and B % _axis_size(mesh, dp) == 0:
        full[0] = dp
    elif "data" in mesh.shape and B % mesh.shape["data"] == 0 \
            and mesh.shape["data"] > 1:
        full[0] = "data"
    elif seq_axis is not None and len(shape) > seq_axis \
            and shape[seq_axis] % _axis_size(mesh, "data") == 0:
        full[seq_axis] = "data"
    return P(*full)


def batch_shardings(batch, mesh: Mesh):
    """Shardings for a train/prefill/decode input batch dict."""
    def one(leaf):
        return NamedSharding(mesh, batch_pspec(leaf.shape, mesh))
    return jax.tree.map(one, batch)


def cache_shardings(cache, mesh: Mesh):
    """Decode caches, keyed by leaf name (the cache layout contract):

    - k/v/enc_kv  (L?, B, S, KV, hd): batch -> DP; KV heads -> 'model' when
      divisible (TP-style KV sharding), else the sequence -> 'model'
      (sequence-parallel cache: the softmax reduction becomes a collective,
      visible in the roofline's collective term).
    - ckv/krope   (L?, B, S, R): MLA latent cache — batch -> DP, seq -> model.
    - k_land/uv/u1/offset: landmark factors (O(c), tiny) — batch -> DP only.
    - recurrent states (C/n/m/c/h/conv): batch -> DP; the widest state dim
      -> 'model' when divisible (mirrors the mixer's head/width sharding).
    - long-context fallback (B not shardable): the sequence dim takes every
      axis it divides: ('pod','data','model') -> S/512 per chip.
    """
    dp = data_axes(mesh)

    def one(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        key = keys[-1] if keys else ""
        shape = leaf.shape
        nd = len(shape)
        full = [None] * nd

        if key in ("k", "v") or "enc_kv" in keys:
            off = nd - 4                       # (B, S, KV, hd) trailing
            b, s, kvh = off, off + 1, off + 2
            if shape[b] > 1 and shape[b] % _axis_size(mesh, dp) == 0 \
                    and _axis_size(mesh, dp) > 1:
                full[b] = dp
                leaf_bytes = 2
                for d in shape:
                    leaf_bytes *= d
                local_bytes = leaf_bytes // _axis_size(mesh, dp)
                if _fit(shape[kvh], "model", mesh):
                    full[kvh] = "model"
                elif local_bytes > 2e9 and shape[s] >= 1024 \
                        and _fit(shape[s], "model", mesh):
                    # only sequence-shard caches too big to replicate over
                    # 'model': S-sharding forces a distributed softmax
                    # (all-gathers per decode step, §Perf-C iteration 2)
                    full[s] = "model"
            else:
                # B=1 long-context: sequence takes all axes it divides
                axes = tuple(a for a in ("pod", "data", "model")
                             if a in mesh.shape)
                if shape[s] % _axis_size(mesh, axes) == 0 and shape[s] >= 1024:
                    full[s] = axes
                elif _fit(shape[s], "data", mesh):
                    full[s] = "data"
        elif key in ("ckv", "krope"):
            off = nd - 3                       # (B, S, R)
            b, s = off, off + 1
            if shape[b] > 1 and shape[b] % _axis_size(mesh, dp) == 0 \
                    and _axis_size(mesh, dp) > 1:
                full[b] = dp
                if shape[s] >= 1024 and _fit(shape[s], "model", mesh):
                    full[s] = "model"
            elif shape[s] >= 1024:
                axes = tuple(a for a in ("pod", "data", "model")
                             if a in mesh.shape)
                if shape[s] % _axis_size(mesh, axes) == 0:
                    full[s] = axes
        elif key in ("k_land", "uv", "u1", "offset"):
            # landmark factors: (L?, B, KV, [c, [hd]])
            base_nd = {"k_land": 4, "uv": 4, "u1": 3, "offset": 2}[key]
            b = nd - base_nd                   # 1 when scanned, else 0
            if b < nd and shape[b] > 1 and _axis_size(mesh, dp) > 1 \
                    and shape[b] % _axis_size(mesh, dp) == 0:
                full[b] = dp
        else:
            # recurrent states: batch is the first DP-divisible dim among
            # the first two; widest trailing dim -> model
            for b in range(min(2, nd)):
                if shape[b] > 1 and _axis_size(mesh, dp) > 1 \
                        and shape[b] % _axis_size(mesh, dp) == 0:
                    full[b] = dp
                    break
            if nd >= 2:
                widest = max(range(nd), key=lambda i: shape[i])
                if full[widest] is None and shape[widest] >= 128 \
                        and _fit(shape[widest], "model", mesh):
                    full[widest] = "model"
        return NamedSharding(mesh, P(*full))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def tree_shardings(tree, mesh: Mesh, pspec_fn):
    """Generic: one PartitionSpec per leaf from ``pspec_fn(path, shape)``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append(NamedSharding(mesh, pspec_fn(pstr, leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# in-graph helpers (used by model code under an ambient `with mesh:`)
# ---------------------------------------------------------------------------

def ambient_axis_size(name: str) -> int:
    """Size of a mesh axis in the ambient (context-manager) mesh, else 1."""
    try:
        from jax._src import mesh as _mesh_lib
        shape = _mesh_lib.thread_resources.env.physical_mesh.shape
        return dict(shape).get(name, 1)
    except Exception:                                         # noqa: BLE001
        return 1


def constrain(x, spec: P):
    """with_sharding_constraint when an ambient mesh can resolve it."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:                                         # noqa: BLE001
        return x
