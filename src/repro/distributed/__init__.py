from repro.distributed.sharding import (  # noqa: F401
    batch_pspec,
    cache_shardings,
    param_pspec,
    param_shardings,
    tree_shardings,
)
