from repro.distributed.sharding import (  # noqa: F401
    batch_pspec,
    cache_shardings,
    data_axes,
    data_parallel_mesh,
    param_pspec,
    param_shardings,
    tree_shardings,
)
