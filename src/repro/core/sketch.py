"""Matrix sketching operators (paper §3.1, Lemma 2 toolbox).

Five families, all TPU-native:

- uniform column sampling        (gather)
- leverage-score column sampling (gather; scaled or paper-§4.5 unscaled)
- Gaussian projection            (GEMM)
- SRHT                           (fast Walsh-Hadamard transform + gather)
- CountSketch                    (segment-sum)

A sketch ``S ∈ R^{n×s}`` is never materialized; we expose the three products the
paper needs: ``S^T A`` (rows), ``A S`` (cols), and the symmetric form ``S^T K S``.
Column-selection sketches additionally expose their index set so SPSD/CUR code can
read *blocks* of an implicit kernel matrix (Fig. 1's memory trick).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Column selection sketches (one nonzero per column of S)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ColumnSketch:
    """S with S[i_j, j] = scale_j (Eq. 1).  ``indices``: (s,), ``scales``: (s,)."""

    indices: jnp.ndarray
    scales: jnp.ndarray
    n: int

    def tree_flatten(self):
        return (self.indices, self.scales), (self.n,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def s(self) -> int:
        return int(self.indices.shape[0])

    # S^T A : select + scale rows of A
    def left(self, A: jnp.ndarray) -> jnp.ndarray:
        return jnp.take(A, self.indices, axis=0) * self.scales[:, None]

    # A S : select + scale columns of A
    def right(self, A: jnp.ndarray) -> jnp.ndarray:
        return jnp.take(A, self.indices, axis=1) * self.scales[None, :]

    # S^T K S for an explicit K
    def sym(self, K: jnp.ndarray) -> jnp.ndarray:
        blk = jnp.take(jnp.take(K, self.indices, axis=0), self.indices, axis=1)
        return blk * (self.scales[:, None] * self.scales[None, :])


def uniform_column_sketch(key: jax.Array, n: int, s: int,
                          scale: bool = True,
                          mask: Optional[jnp.ndarray] = None) -> ColumnSketch:
    """Uniform sampling without replacement (p_i = 1/n).

    ``mask`` (n,) restricts sampling to valid rows of a padded operator
    (p_i = 1/n_valid on the mask, 0 elsewhere) — see ``MaskedSketch``.

    When ``s`` exceeds the number of valid rows, sampling without replacement
    is impossible and ``jax.random.choice(replace=False, p=...)`` silently
    falls back to zero-weight entries — junk padding columns of K would enter
    the sketch.  A concrete mask raises ``ValueError`` instead; a traced mask
    (vmapped ragged batches, where the overflow may affect only some batch
    items) clamps the overflowing picks back onto valid rows (sampled with
    replacement), so the sketch degenerates to duplicated valid columns but
    never observes padding.
    """
    if mask is None:
        idx = jax.random.choice(key, n, shape=(s,), replace=False)
        sc = jnp.full((s,), jnp.sqrt(n / s) if scale else 1.0,
                      dtype=jnp.float32)
    else:
        m = mask.astype(jnp.float32)
        nv = jnp.sum(m)
        traced = isinstance(nv, jax.core.Tracer)
        if not traced and int(nv) < s:
            raise ValueError(
                f"uniform_column_sketch: s={s} exceeds the {int(nv)} valid "
                f"rows of the mask; sampling without replacement would pull "
                f"in padding rows")
        p = m / nv
        idx = jax.random.choice(key, n, shape=(s,), replace=False, p=p)
        if traced:
            # traced-mask overflow guard (the raise above already proved a
            # concrete mask cannot overflow): remap any zero-weight pick onto
            # a valid row (categorical sampling never selects zero-prob ones)
            repl = jax.random.choice(jax.random.fold_in(key, 1), n,
                                     shape=(s,), replace=True, p=p)
            idx = jnp.where(jnp.take(m, idx) > 0, idx, repl)
        one = jnp.sqrt(nv / s) if scale else jnp.float32(1.0)
        sc = jnp.full((s,), 1.0, jnp.float32) * one
    return ColumnSketch(idx, sc, n)


def leverage_column_sketch(key: jax.Array, lev: jnp.ndarray, s: int,
                           scale: bool = False) -> ColumnSketch:
    """Leverage-score sampling (Algorithm 2).

    ``lev``: (n,) row leverage scores of C (sum = rank(C)).  Sampling is with
    replacement, p_i ∝ lev_i.  Default is the paper's §4.5 *unscaled* variant
    (better numerical stability); ``scale=True`` gives the theory-exact scaling
    1/sqrt(s·p_i).
    """
    n = lev.shape[0]
    p = lev / jnp.sum(lev)
    idx = jax.random.choice(key, n, shape=(s,), replace=True, p=p)
    if scale:
        sc = 1.0 / jnp.sqrt(s * jnp.take(p, idx))
    else:
        sc = jnp.ones((s,), dtype=jnp.float32)
    return ColumnSketch(idx, sc.astype(jnp.float32), n)


def subset_union_sketch(base: ColumnSketch, extra_indices: jnp.ndarray,
                        n: int) -> ColumnSketch:
    """Enforce P ⊂ S (Corollary 5): prepend the P indices with scale 1."""
    idx = jnp.concatenate([extra_indices, base.indices])
    sc = jnp.concatenate(
        [jnp.ones((extra_indices.shape[0],), jnp.float32), base.scales])
    return ColumnSketch(idx, sc, n)


# ---------------------------------------------------------------------------
# Gaussian projection
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GaussianSketch:
    """S = G/sqrt(s), G_ij ~ N(0,1).  Materialized lazily row-block-wise."""

    key: jax.Array
    n: int
    s: int

    def tree_flatten(self):
        return (self.key,), (self.n, self.s)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0], aux[1])

    def _mat(self, dtype=jnp.float32) -> jnp.ndarray:
        g = jax.random.normal(self.key, (self.n, self.s), dtype=dtype)
        return g / jnp.sqrt(self.s).astype(dtype)

    def left(self, A: jnp.ndarray) -> jnp.ndarray:   # S^T A : (s, d)
        return self._mat(A.dtype).T @ A

    def right(self, A: jnp.ndarray) -> jnp.ndarray:  # A S : (m, s)
        return A @ self._mat(A.dtype)

    def sym(self, K: jnp.ndarray) -> jnp.ndarray:
        S = self._mat(K.dtype)
        return S.T @ K @ S


# ---------------------------------------------------------------------------
# SRHT
# ---------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Fast Walsh-Hadamard transform along axis 0 (length must be a power of 2).

    Unnormalized: result = H_n @ x with H entries ±1.
    """
    n = x.shape[0]
    shape_rest = x.shape[1:]
    h = 1
    y = x
    while h < n:
        y = y.reshape((n // (2 * h), 2, h) + shape_rest)
        a = y[:, 0]
        b = y[:, 1]
        y = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    return y.reshape((n,) + shape_rest)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SRHTSketch:
    """S = sqrt(n/s) * (1/sqrt(n)) D H P  (paper §3.1.2).

    Applied in O(n log n) per column via the FWHT; n is zero-padded to the next
    power of two (rademacher signs drawn for the padded length).
    """

    signs: jnp.ndarray        # (n_pad,)
    indices: jnp.ndarray      # (s,) rows kept after the transform
    n: int

    def tree_flatten(self):
        return (self.signs, self.indices), (self.n,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def s(self) -> int:
        return int(self.indices.shape[0])

    def left(self, A: jnp.ndarray) -> jnp.ndarray:
        n_pad = self.signs.shape[0]
        s = self.s
        pad = [(0, n_pad - A.shape[0])] + [(0, 0)] * (A.ndim - 1)
        Ap = jnp.pad(A, pad)
        y = fwht(self.signs.reshape((-1,) + (1,) * (A.ndim - 1)) * Ap)
        y = y / jnp.sqrt(n_pad).astype(A.dtype)          # orthonormal H D
        y = jnp.take(y, self.indices, axis=0)
        return y * jnp.sqrt(n_pad / s).astype(A.dtype)   # sampling scale

    def right(self, A: jnp.ndarray) -> jnp.ndarray:
        return self.left(A.T).T

    def sym(self, K: jnp.ndarray) -> jnp.ndarray:
        return self.left(self.left(K).T).T


def srht_sketch(key: jax.Array, n: int, s: int) -> SRHTSketch:
    kd, kp = jax.random.split(key)
    n_pad = _next_pow2(n)
    signs = jax.random.rademacher(kd, (n_pad,), dtype=jnp.float32)
    idx = jax.random.choice(kp, n_pad, shape=(s,), replace=False)
    return SRHTSketch(signs, idx, n)


# ---------------------------------------------------------------------------
# CountSketch
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CountSketch:
    """One nonzero ±1 per *row* of S; S^T A is a signed segment-sum: O(nnz(A))."""

    hashes: jnp.ndarray   # (n,) in [0, s)
    signs: jnp.ndarray    # (n,) ±1
    s: int

    def tree_flatten(self):
        return (self.hashes, self.signs), (self.s,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def n(self) -> int:
        return int(self.hashes.shape[0])

    def left(self, A: jnp.ndarray) -> jnp.ndarray:
        signed = A * self.signs.reshape((-1,) + (1,) * (A.ndim - 1))
        return jax.ops.segment_sum(signed, self.hashes, num_segments=self.s)

    def right(self, A: jnp.ndarray) -> jnp.ndarray:
        return self.left(A.T).T

    def sym(self, K: jnp.ndarray) -> jnp.ndarray:
        return self.left(self.left(K).T).T


def count_sketch(key: jax.Array, n: int, s: int) -> CountSketch:
    kh, ks = jax.random.split(key)
    hashes = jax.random.randint(kh, (n,), 0, s)
    signs = jax.random.rademacher(ks, (n,), dtype=jnp.float32)
    return CountSketch(hashes, signs, s)


# ---------------------------------------------------------------------------
# Row masking (ragged / padded batches)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MaskedSketch:
    """diag(mask) · S — a sketch restricted to the valid rows of a padded op.

    Stacking ragged kernels (different n per item) to a common shape leaves
    junk padding rows in K; masking the sketch rows makes every product
    identical to the unpadded one: Sᵀ M K M S only ever touches valid
    entries, so Sᵀ K S is unbiased by construction.
    """

    base: object
    mask: jnp.ndarray           # (n,) 1.0 on valid rows, 0.0 on padding

    def tree_flatten(self):
        return (self.base, self.mask), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.base.n

    @property
    def s(self) -> int:
        return self.base.s

    def left(self, A: jnp.ndarray) -> jnp.ndarray:      # Sᵀ M A
        m = self.mask.astype(A.dtype)
        return self.base.left(A * m.reshape((-1,) + (1,) * (A.ndim - 1)))

    def right(self, A: jnp.ndarray) -> jnp.ndarray:     # A M S (A: (b, n))
        m = self.mask.astype(A.dtype)
        return self.base.right(A * m[None, :])

    def sym(self, K: jnp.ndarray) -> jnp.ndarray:       # Sᵀ M K M S
        m = self.mask.astype(K.dtype)
        return self.base.sym(K * (m[:, None] * m[None, :]))


# ---------------------------------------------------------------------------
# Streaming application against implicit operators (Fig. 1 at scale)
# ---------------------------------------------------------------------------

def plan_for_sketch(S):
    """K S as a panel plan for the sweep engine (``SPSDOperator.sweep``).

    Gaussian sketches materialize their n×s matrix once — the same O(n·s)
    budget as the output — so the panel loop never redraws it; every other
    family applies ``S.right`` to each panel.
    """
    from repro.core import sweep as sweep_lib
    base, mask = (S.base, S.mask) if isinstance(S, MaskedSketch) else (S, None)
    if isinstance(base, GaussianSketch):
        M = base._mat()
        if mask is not None:
            M = M * mask.astype(M.dtype)[:, None]
        return sweep_lib.MatmulPlan(M)
    return sweep_lib.SketchRightPlan(S, S.s)


def right_streaming(S, Kop, block_size: Optional[int] = None,
                    mesh=None) -> jnp.ndarray:
    """K S (n × s) through blocked row panels of an ``SPSDOperator``.

    One sweep of the panel engine; peak memory is O(b·n + n·s) and the n×n
    kernel is never materialized.  Pass a ``mesh`` to shard the panels over
    its data axis.
    """
    (KS,) = Kop.sweep([plan_for_sketch(S)], block_size=block_size, mesh=mesh)
    return KS


def sym_streaming(S, Kop, block_size: Optional[int] = None,
                  mesh=None) -> jnp.ndarray:
    """S^T K S (s × s) via blocked K @ S then one ``S.left`` — streaming
    counterpart of ``S.sym(K_dense)`` for implicit operators."""
    KS = right_streaming(S, Kop, block_size, mesh=mesh)
    return S.left(KS)


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

SKETCH_KINDS = ("uniform", "leverage", "gaussian", "srht", "countsketch")


def make_sketch(kind: str, key: jax.Array, n: int, s: int,
                lev: Optional[jnp.ndarray] = None, scale: bool = False):
    """Build any of the paper's five sketches (Table 4 row names)."""
    if kind == "uniform":
        return uniform_column_sketch(key, n, s, scale=scale)
    if kind == "leverage":
        if lev is None:
            raise ValueError("leverage sketch needs leverage scores")
        return leverage_column_sketch(key, lev, s, scale=scale)
    if kind == "gaussian":
        return GaussianSketch(key, n, s)
    if kind == "srht":
        return srht_sketch(key, n, s)
    if kind == "countsketch":
        return count_sketch(key, n, s)
    raise ValueError(f"unknown sketch kind {kind!r}; one of {SKETCH_KINDS}")
