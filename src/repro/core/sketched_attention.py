"""Landmark (sketched) attention — the paper's fast CUR applied to attention.

Full attention computes ``softmax(QK^T/√d) V``.  Write ``G = exp(QK^T/√d)``
(m × n, entrywise-positive Gram-like matrix).  Then

    out = (G V) / (G 1).

We approximate G once with the paper's fast CUR (Eq. 9) and reuse the factors
for both the numerator and the normalizer:

    G ≈ Ĉ Ũ R̂,   Ĉ = exp(Q K_P^T/√d) (m×c),   R̂ = exp(Q_P K^T/√d) (c×n),
    Ũ = (S_q^T Ĉ)† (S_q^T G S_k) (R̂ S_k)†        — fast-CUR U, s = θ·c.

``P`` are c landmark positions; sketches satisfy P ⊂ S (§4.5).  Plain
Nyströmformer is the degenerate S = P case (exactly the paper's reading of
Nyström as a crude sketched solve); the prototype-quality solve is S = I.

Cost: O(m·c + n·c + s²c) instead of O(m·n) — sub-quadratic for s = O(c√(n/ε)).
For autoregressive decode with a fixed context the factors ``Ũ (R̂ V)`` and
``Ũ (R̂ 1)`` are cached (c×d_v and c×1), making per-token cost O(c·d).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.cur import fast_U_cur
from repro.core.leverage import pinv


class LandmarkState(NamedTuple):
    """Decode-time cache: everything that depends only on the context K/V."""
    k_land: jnp.ndarray    # (c, d)   landmark keys
    UV: jnp.ndarray        # (c, d_v) Ũ @ (R̂ V)
    U1: jnp.ndarray        # (c,)     Ũ @ (R̂ 1)
    scale: jnp.ndarray     # ()       max-logit offset used inside exp


def _exp_scores(Q: jnp.ndarray, K: jnp.ndarray, inv_sqrt_d: float,
                offset: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp((Q @ K.T).astype(jnp.float32) * inv_sqrt_d - offset)


def landmark_indices(key: jax.Array, n: int, c: int) -> jnp.ndarray:
    """Uniform landmarks (paper §6: uniform ≈ leverage for S; C uniform)."""
    seg = n // c
    base = jnp.arange(c) * seg
    jitter = jax.random.randint(key, (c,), 0, max(seg, 1))
    return jnp.clip(base + jitter, 0, n - 1)


def sketched_attention(
    Q: jnp.ndarray,               # (m, d)
    K: jnp.ndarray,               # (n, d)
    V: jnp.ndarray,               # (n, d_v)
    key: jax.Array,
    c: int,
    theta: int = 4,               # s = θ·c, paper's Fig. 3/4 sweep
    mode: str = "fast",           # fast | nystrom | prototype
) -> jnp.ndarray:
    """Non-causal sketched attention over a full context."""
    m, d = Q.shape
    n = K.shape[0]
    inv_sqrt_d = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kp, kq, kk = jax.random.split(key, 3)

    p_idx = landmark_indices(kp, n, c)
    Kp = jnp.take(K, p_idx, axis=0)
    Qp = jnp.take(Q, p_idx, axis=0) if m == n else jnp.take(K, p_idx, axis=0)

    # stabilization offset: max landmark logit (global max is close for RBF-ish G)
    offset = jnp.max((Qp @ Kp.T).astype(jnp.float32)) * inv_sqrt_d

    Chat = _exp_scores(Q, Kp, inv_sqrt_d, offset)       # (m, c)
    Rhat = _exp_scores(Qp, K, inv_sqrt_d, offset)       # (c, n)

    if mode == "prototype":                              # S = I (exact solve)
        G = _exp_scores(Q, K, inv_sqrt_d, offset)
        U = pinv(Chat) @ G @ pinv(Rhat)
    elif mode == "nystrom":                              # S = P
        W = _exp_scores(Qp, Kp, inv_sqrt_d, offset)
        U = pinv(W)
    else:                                                # fast CUR (Eq. 9)
        s = min(theta * c, n)
        sq = jnp.concatenate([p_idx if m == n else jnp.arange(c),
                              jax.random.choice(kq, m, (s - c,), replace=True)])
        skx = jnp.concatenate([p_idx,
                               jax.random.choice(kk, n, (s - c,), replace=True)])
        ScC = jnp.take(Chat, sq, axis=0)                 # (s, c)
        RSr = jnp.take(Rhat, skx, axis=1)                # (c, s)
        G_blk = _exp_scores(jnp.take(Q, sq, axis=0),
                            jnp.take(K, skx, axis=0), inv_sqrt_d, offset)
        U = fast_U_cur(ScC, G_blk, RSr)

    num = Chat @ (U @ (Rhat @ V.astype(jnp.float32)))    # (m, d_v)
    den = Chat @ (U @ jnp.sum(Rhat, axis=1))             # (m,)
    den = jnp.maximum(den, 1e-6)[:, None]
    return (num / den).astype(V.dtype)


# ---------------------------------------------------------------------------
# Decode path: O(c) per token against a 500k context
# ---------------------------------------------------------------------------

def build_landmark_state(K: jnp.ndarray, V: jnp.ndarray, key: jax.Array,
                         c: int, theta: int = 4) -> LandmarkState:
    """Precompute the context-side factors once (prefill)."""
    n, d = K.shape
    inv_sqrt_d = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kp, ks = jax.random.split(key)
    p_idx = landmark_indices(kp, n, c)
    Kp = jnp.take(K, p_idx, axis=0)
    offset = jnp.max((Kp @ Kp.T).astype(jnp.float32)) * inv_sqrt_d

    Rhat = _exp_scores(Kp, K, inv_sqrt_d, offset)        # (c, n)
    s = min(theta * c, n)
    skx = jnp.concatenate(
        [p_idx, jax.random.choice(ks, n, (s - c,), replace=True)])
    # queries at the sketched rows are the landmark keys themselves (self-Gram)
    ScC = _exp_scores(jnp.take(K, skx, axis=0), Kp, inv_sqrt_d, offset)
    G_blk = _exp_scores(jnp.take(K, skx, axis=0), jnp.take(K, skx, axis=0),
                        inv_sqrt_d, offset)
    RSr = jnp.take(Rhat, skx, axis=1)
    U = fast_U_cur(ScC, G_blk, RSr)

    RV = Rhat @ V.astype(jnp.float32)                    # (c, d_v)
    R1 = jnp.sum(Rhat, axis=1)                           # (c,)
    return LandmarkState(k_land=Kp, UV=U @ RV, U1=U @ R1, scale=offset)


def landmark_decode(state: LandmarkState, q: jnp.ndarray) -> jnp.ndarray:
    """One-token attention read: (d,) query -> (d_v,) output, O(c·d)."""
    d = q.shape[-1]
    inv_sqrt_d = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = (state.k_land @ q.astype(jnp.float32)) * inv_sqrt_d - state.scale
    cvec = jnp.exp(logits)                               # (c,)
    num = cvec @ state.UV                                # (d_v,)
    den = jnp.maximum(cvec @ state.U1, 1e-6)
    return (num / den).astype(q.dtype)
