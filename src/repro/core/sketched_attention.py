"""Landmark (sketched) attention — the paper's fast CUR applied to attention.

Full attention computes ``softmax(QK^T/√d) V``.  Write ``G = exp(QK^T/√d)``
(m × n, entrywise-positive Gram-like matrix).  Then

    out = (G V) / (G 1).

We approximate G once with the paper's fast CUR (Eq. 9) and reuse the factors
for both the numerator and the normalizer:

    G ≈ Ĉ Ũ R̂,   Ĉ = exp(Q K_P^T/√d) (m×c),   R̂ = exp(Q_P K^T/√d) (c×n),
    Ũ = (S_q^T Ĉ)† (S_q^T G S_k) (R̂ S_k)†        — fast-CUR U, s = θ·c.

``P`` are c landmark positions; sketches satisfy P ⊂ S (§4.5).  Plain
Nyströmformer is the degenerate S = P case (exactly the paper's reading of
Nyström as a crude sketched solve); the prototype-quality solve is S = I.

Cost: O(m·c + n·c + s²c) instead of O(m·n) — sub-quadratic for s = O(c√(n/ε)).
For autoregressive decode with a fixed context the factors ``Ũ (R̂ V)`` and
``Ũ (R̂ 1)`` are cached (c×d_v and c×1), making per-token cost O(c·d).

Landmark positions default to strided-with-jitter (``selection="strided"``),
but any registered :class:`~repro.core.selection.SelectionPolicy` name picks
landmarks from the context's own softmax Gram ``exp(K Kᵀ/√d)`` — the same
streaming column-selection machinery the SPSD models use (leverage /
adaptive² landmarks, every kernel access through the operator protocol).
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.cur import fast_U_cur
from repro.core.leverage import pinv


class LandmarkState(NamedTuple):
    """Decode-time cache: everything that depends only on the context K/V."""
    k_land: jnp.ndarray    # (c, d)   landmark keys
    UV: jnp.ndarray        # (c, d_v) Ũ @ (R̂ V)
    U1: jnp.ndarray        # (c,)     Ũ @ (R̂ 1)
    scale: jnp.ndarray     # ()       max-logit offset used inside exp


def _exp_scores(Q: jnp.ndarray, K: jnp.ndarray, inv_sqrt_d: float,
                offset: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp((Q @ K.T).astype(jnp.float32) * inv_sqrt_d - offset)


def signed_den_floor(den: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Floor ``|den|`` at ``eps`` *preserving sign*.

    The normalizer ``Ĉ Ũ R̂ 1`` can go negative through an indefinite fast/
    Nyström ``Ũ`` even though the exact ``G 1`` is positive; a plain
    ``maximum(den, eps)`` silently flips the sign of the whole output row.
    Keeping the sign makes ``num/den`` invariant to a global sign flip of
    ``Ũ`` (both factors flip) and only guards against division blow-up.
    """
    return jnp.where(den < 0.0, -1.0, 1.0) * jnp.maximum(jnp.abs(den), eps)


def landmark_indices(key: jax.Array, n: int, c: int) -> jnp.ndarray:
    """Uniform landmarks (paper §6: uniform ≈ leverage for S; C uniform).

    Strided base + per-segment jitter gives c *distinct* positions for
    c < n.  A request of c >= n landmarks is degenerate (the old
    ``seg = n // c == 0`` path collapsed every index to 0): clamp to all n
    positions, distinct, with a warning.
    """
    if c >= n:
        if c > n:
            warnings.warn(
                f"landmark_indices: requested c={c} >= n={n}; clamping to "
                "all n distinct positions", stacklevel=2)
        return jax.random.permutation(key, n)
    seg = n // c
    base = jnp.arange(c) * seg
    jitter = jax.random.randint(key, (c,), 0, max(seg, 1))
    return jnp.clip(base + jitter, 0, n - 1)


@functools.lru_cache(maxsize=None)
def _softmax_gram_spec(inv_sqrt_d: float, offset: float):
    """Unregistered KernelSpec for the context softmax Gram exp(KKᵀ/√d − off).

    Built directly (NOT through ``register_kernel``) so the conformance /
    parity suites that parametrize over ``registered_kernels()`` are
    unaffected; cached per (scale, offset) because specs hash by field
    identity, keeping one jit entry per parameter set.
    """
    from repro.kernels.pairwise.specs import KernelSpec
    return KernelSpec(
        "softmax_gram", "dot",
        lambda t: jnp.exp(t * inv_sqrt_d - offset),
        params=(("inv_sqrt_d", inv_sqrt_d), ("offset", offset)))


def select_landmarks(K: jnp.ndarray, key: jax.Array, c: int,
                     selection: str = "strided",
                     block_size: int | None = None) -> jnp.ndarray:
    """Pick c landmark key positions.

    ``"strided"`` is the classic Nyströmformer layout
    (:func:`landmark_indices`).  Any other name resolves through the
    :mod:`repro.core.selection` registry and selects columns of the
    context's softmax Gram operator ``exp(K Kᵀ/√d − offset)`` — an SPSD
    ``PairwiseKernel`` with an (unregistered) exp-dot spec, so leverage /
    adaptive² landmark choice streams through the sweep engine exactly like
    the kernel models (no n×n materialization).
    """
    n, d = K.shape
    if selection == "strided":
        return landmark_indices(key, n, c)
    from repro.core import selection as selection_lib
    from repro.core.kernelop import PairwiseKernel
    inv_sqrt_d = 1.0 / float(d) ** 0.5
    if isinstance(K, jax.core.Tracer):
        offset = 0.0                      # traced context: no concrete max
    else:                                 # stabilize exp: diag logits <= 0
        offset = round(
            float(jnp.max(jnp.sum(K.astype(jnp.float32) ** 2, axis=1)))
            * inv_sqrt_d, 3)
    spec = _softmax_gram_spec(inv_sqrt_d, offset)
    op = PairwiseKernel(K.astype(jnp.float32), spec)
    policy = selection_lib.get_policy(selection)
    return policy.select(op, key, min(c, n), block_size=block_size)


def _extend_without_replacement(key: jax.Array, base: jnp.ndarray, s: int,
                                n: int) -> jnp.ndarray:
    """``base`` plus (s − |base|) distinct indices from its complement.

    The sketch sets must be duplicate-free: sampling the extension with
    replacement (or without excluding ``base``) lands repeated rows in
    ``S_qᵀĈ`` / ``R̂ S_k``, biasing the fast-CUR solve exactly like the PR-5
    with-replacement adaptive-sampling bug.
    """
    extra = s - base.shape[0]
    if extra <= 0:
        return base[:s]
    w = jnp.ones((n,), jnp.float32).at[base].set(0.0)
    ext = jax.random.choice(key, n, (extra,), replace=False, p=w / jnp.sum(w))
    return jnp.concatenate([base, ext])


def _sketch_indices(kq: jax.Array, kk: jax.Array, p_idx: jnp.ndarray,
                    m: int, n: int, c: int,
                    theta: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Row (queries) and column (keys) sketch index sets for Eq. 9.

    The column sketch always extends the landmarks (P ⊂ S, §4.5) with
    distinct non-landmark columns.  The row sketch mirrors it when the Gram
    is square (m == n); for rectangular attention it is a plain
    without-replacement row sample of [0, m) — the old code gathered
    ``arange(c)`` rows of Q there, which clamp-duplicates out-of-bounds rows
    whenever m < c.
    """
    s_k = min(theta * c, n)
    skx = _extend_without_replacement(kk, p_idx, s_k, n)
    if m == n:
        sq = _extend_without_replacement(kq, p_idx, s_k, m)
    else:
        s_q = min(theta * c, m)
        sq = jax.random.choice(kq, m, (s_q,), replace=False)
    return sq, skx


def sketched_attention(
    Q: jnp.ndarray,               # (m, d)
    K: jnp.ndarray,               # (n, d)
    V: jnp.ndarray,               # (n, d_v)
    key: jax.Array,
    c: int,
    theta: int = 4,               # s = θ·c, paper's Fig. 3/4 sweep
    mode: str = "fast",           # fast | nystrom | prototype
    selection: str = "strided",   # or any SelectionPolicy registry name
) -> jnp.ndarray:
    """Non-causal sketched attention over a full context."""
    m, d = Q.shape
    n = K.shape[0]
    inv_sqrt_d = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kp, kq, kk = jax.random.split(key, 3)

    p_idx = select_landmarks(K, kp, c, selection=selection)
    c = p_idx.shape[0]            # may have been clamped to n
    Kp = jnp.take(K, p_idx, axis=0)
    Qp = jnp.take(Q, p_idx, axis=0) if m == n else jnp.take(K, p_idx, axis=0)

    # stabilization offset: max landmark logit (global max is close for RBF-ish G)
    offset = jnp.max((Qp @ Kp.T).astype(jnp.float32)) * inv_sqrt_d

    Chat = _exp_scores(Q, Kp, inv_sqrt_d, offset)       # (m, c)
    Rhat = _exp_scores(Qp, K, inv_sqrt_d, offset)       # (c, n)

    if mode == "prototype":                              # S = I (exact solve)
        G = _exp_scores(Q, K, inv_sqrt_d, offset)
        U = pinv(Chat) @ G @ pinv(Rhat)
    elif mode == "nystrom":                              # S = P
        W = _exp_scores(Qp, Kp, inv_sqrt_d, offset)
        U = pinv(W)
    else:                                                # fast CUR (Eq. 9)
        sq, skx = _sketch_indices(kq, kk, p_idx, m, n, c, theta)
        ScC = jnp.take(Chat, sq, axis=0)                 # (s_q, c)
        RSr = jnp.take(Rhat, skx, axis=1)                # (c, s_k)
        G_blk = _exp_scores(jnp.take(Q, sq, axis=0),
                            jnp.take(K, skx, axis=0), inv_sqrt_d, offset)
        U = fast_U_cur(ScC, G_blk, RSr)

    num = Chat @ (U @ (Rhat @ V.astype(jnp.float32)))    # (m, d_v)
    den = Chat @ (U @ jnp.sum(Rhat, axis=1))             # (m,)
    den = signed_den_floor(den)[:, None]
    return (num / den).astype(V.dtype)


# ---------------------------------------------------------------------------
# Decode path: O(c) per token against a 500k context
# ---------------------------------------------------------------------------

def build_landmark_state(K: jnp.ndarray, V: jnp.ndarray, key: jax.Array,
                         c: int, theta: int = 4,
                         selection: str = "strided") -> LandmarkState:
    """Precompute the context-side factors once (prefill)."""
    n, d = K.shape
    inv_sqrt_d = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kp, ks = jax.random.split(key)
    p_idx = select_landmarks(K, kp, c, selection=selection)
    c = p_idx.shape[0]            # may have been clamped to n
    Kp = jnp.take(K, p_idx, axis=0)
    offset = jnp.max((Kp @ Kp.T).astype(jnp.float32)) * inv_sqrt_d

    Rhat = _exp_scores(Kp, K, inv_sqrt_d, offset)        # (c, n)
    s = min(theta * c, n)
    skx = _extend_without_replacement(ks, p_idx, s, n)
    # queries at the sketched rows are the landmark keys themselves (self-Gram)
    ScC = _exp_scores(jnp.take(K, skx, axis=0), Kp, inv_sqrt_d, offset)
    G_blk = _exp_scores(jnp.take(K, skx, axis=0), jnp.take(K, skx, axis=0),
                        inv_sqrt_d, offset)
    RSr = jnp.take(Rhat, skx, axis=1)
    U = fast_U_cur(ScC, G_blk, RSr)

    RV = Rhat @ V.astype(jnp.float32)                    # (c, d_v)
    R1 = jnp.sum(Rhat, axis=1)                           # (c,)
    return LandmarkState(k_land=Kp, UV=U @ RV, U1=U @ R1, scale=offset)


def landmark_decode(state: LandmarkState, q: jnp.ndarray) -> jnp.ndarray:
    """One-token attention read: (d,) query -> (d_v,) output, O(c·d)."""
    d = q.shape[-1]
    inv_sqrt_d = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = (state.k_land @ q.astype(jnp.float32)) * inv_sqrt_d - state.scale
    cvec = jnp.exp(logits)                               # (c,)
    num = cvec @ state.UV                                # (d_v,)
    den = signed_den_floor(cvec @ state.U1)
    return (num / den).astype(q.dtype)
