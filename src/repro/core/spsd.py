"""SPSD matrix approximation models (paper §3.2 & §4).

All three models produce ``K ≈ C U C^T`` with the same sketch ``C = K P`` and
differ only in U (Table 1):

- prototype:  U* = C† K (C†)^T                    O(n²c), sees all of K
- Nyström:    U  = (P^T K P)†                      O(c³),  sees n·c entries
- fast:       U  = (S^T C)† (S^T K S) (C^T S)†     O(nc² + s²c), nc + (s-c)² entries

``fast_spsd`` is Algorithm 1 end-to-end (with the §4.5 tricks: P ⊂ S and
unscaled leverage sampling by default).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.kernelop import SPSDOperator, as_operator
from repro.core.leverage import pinv, row_leverage_scores


class SPSDApprox(NamedTuple):
    """K ≈ C U C^T."""
    C: jnp.ndarray          # (n, c)
    U: jnp.ndarray          # (c, c)
    P_indices: Optional[jnp.ndarray] = None   # columns of K forming C (if sampled)

    def dense(self) -> jnp.ndarray:
        return self.C @ self.U @ self.C.T

    def matmat(self, V: jnp.ndarray) -> jnp.ndarray:
        return self.C @ (self.U @ (self.C.T @ V))


# ---------------------------------------------------------------------------
# U matrices
# ---------------------------------------------------------------------------

def prototype_U(K: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """U* = argmin_U ||K - C U C^T||_F = C† K (C†)^T  (Eq. 4)."""
    Cp = pinv(C)
    return Cp @ K.astype(Cp.dtype) @ Cp.T


def nystrom_U(W: jnp.ndarray) -> jnp.ndarray:
    """U^nys = W† with W = P^T K P (Eq. 3)."""
    Wsym = 0.5 * (W + W.T)
    return pinv(Wsym)


def fast_U(StC: jnp.ndarray, StKS: jnp.ndarray) -> jnp.ndarray:
    """U^fast = (S^T C)† (S^T K S) (C^T S)†  (Eq. 5).

    StC: (s, c), StKS: (s, s).  Cost O(s²c) — independent of n.
    """
    StCp = pinv(StC)                      # (c, s)
    return StCp @ StKS.astype(StCp.dtype) @ StCp.T


# ---------------------------------------------------------------------------
# End-to-end models
# ---------------------------------------------------------------------------

def sample_C(Kop: SPSDOperator, key: jax.Array, c: int) -> SPSDApprox:
    """Uniformly sample c columns of K to form C (the sketch this paper fixes)."""
    idx = jax.random.choice(key, Kop.n, shape=(c,), replace=False)
    C = Kop.columns(idx)
    return SPSDApprox(C=C, U=jnp.eye(c, dtype=C.dtype), P_indices=idx)


def prototype_model(K, C: jnp.ndarray, P_indices=None) -> SPSDApprox:
    Kop = as_operator(K)
    U = prototype_U(Kop.full(), C)
    return SPSDApprox(C=C, U=U, P_indices=P_indices)


def nystrom_model(K, key: jax.Array, c: int) -> SPSDApprox:
    Kop = as_operator(K)
    idx = jax.random.choice(key, Kop.n, shape=(c,), replace=False)
    C = Kop.columns(idx)
    W = Kop.block(idx, idx)
    return SPSDApprox(C=C, U=nystrom_U(W), P_indices=idx)


def fast_model_from_C(
    K,
    C: jnp.ndarray,
    key: jax.Array,
    s: int,
    P_indices: Optional[jnp.ndarray] = None,
    s_sketch: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
) -> SPSDApprox:
    """Algorithm 1 given a fixed C (any provenance).

    ``s_sketch`` ∈ {uniform, leverage, gaussian, srht, countsketch}.
    Column-selection sketches read only an s×s block of K (Fig. 1);
    projection sketches need K (or an operator able to form K S).
    """
    Kop = as_operator(K)
    n = Kop.n

    if s_sketch in ("uniform", "leverage"):
        if s_sketch == "leverage":
            lev = row_leverage_scores(C)
            S = sk.leverage_column_sketch(key, lev, s, scale=scale)
        else:
            S = sk.uniform_column_sketch(key, n, s, scale=scale)
        if enforce_subset and P_indices is not None:
            S = sk.subset_union_sketch(S, P_indices, n)     # Corollary 5
        StC = S.left(C)
        blk = Kop.block(S.indices, S.indices)
        StKS = blk * (S.scales[:, None] * S.scales[None, :])
    else:
        S = sk.make_sketch(s_sketch, key, n, s)
        StC = S.left(C)
        StKS = S.sym(Kop.full())

    U = fast_U(StC, StKS)
    return SPSDApprox(C=C, U=U, P_indices=P_indices)


def fast_model(
    K,
    key: jax.Array,
    c: int,
    s: int,
    s_sketch: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
) -> SPSDApprox:
    """Algorithm 1 end-to-end: uniform C = KP, then the fast U."""
    Kop = as_operator(K)
    kc, ks = jax.random.split(key)
    base = sample_C(Kop, kc, c)
    return fast_model_from_C(
        Kop, base.C, ks, s,
        P_indices=base.P_indices, s_sketch=s_sketch,
        enforce_subset=enforce_subset, scale=scale)


# ---------------------------------------------------------------------------
# Error metric used throughout the paper's §6
# ---------------------------------------------------------------------------

def relative_error(K, approx: SPSDApprox) -> jnp.ndarray:
    """||K - C U C^T||_F² / ||K||_F²  (Fig. 3/4 y-axis)."""
    Kd = as_operator(K).full().astype(jnp.float32)
    R = Kd - approx.dense().astype(jnp.float32)
    return jnp.sum(R * R) / jnp.sum(Kd * Kd)


def error_vs_best_rank_k(K, approx: SPSDApprox, k: int) -> jnp.ndarray:
    """||K - CUC^T||_F² / ||K - K_k||_F²  (the 1+ε target of Thm 3/Remark 4)."""
    Kd = as_operator(K).full().astype(jnp.float32)
    evals = jnp.linalg.eigvalsh(Kd)
    tail = jnp.sum(jnp.sort(evals ** 2)[: Kd.shape[0] - k])
    R = Kd - approx.dense().astype(jnp.float32)
    return jnp.sum(R * R) / tail
