"""SPSD matrix approximation models (paper §3.2 & §4).

All three models produce ``K ≈ C U C^T`` with the same sketch ``C = K P`` and
differ only in U (Table 1):

- prototype:  U* = C† K (C†)^T                    O(n²c), sees all of K
- Nyström:    U  = (P^T K P)†                      O(c³),  sees n·c entries
- fast:       U  = (S^T C)† (S^T K S) (C^T S)†     O(nc² + s²c), nc + (s-c)² entries

``fast_spsd`` is Algorithm 1 end-to-end (with the §4.5 tricks: P ⊂ S and
unscaled leverage sampling by default).

Every large-n path streams through the blockwise operator protocol
(``SPSDOperator.map_row_panels`` / ``matmat``): projection sketches, the
prototype U, and the error metrics all run at n ≫ 10⁴ without ever
allocating an n×n array.  ``fast_model_batched`` vmaps Algorithm 1 over a
stacked batch of same-shape kernels.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.kernelop import DenseSPSD, SPSDOperator, as_operator
from repro.core.leverage import pinv, row_leverage_scores

# Below this n the dense error metrics are cheap and exact; above it the
# "auto" policy switches to the streaming estimators.
_DENSE_N_CUTOFF = 2048


class SPSDApprox(NamedTuple):
    """K ≈ C U C^T."""
    C: jnp.ndarray          # (n, c)
    U: jnp.ndarray          # (c, c)
    P_indices: Optional[jnp.ndarray] = None   # columns of K forming C (if sampled)

    def dense(self) -> jnp.ndarray:
        return self.C @ self.U @ self.C.T

    def matmat(self, V: jnp.ndarray) -> jnp.ndarray:
        return self.C @ (self.U @ (self.C.T @ V))


# ---------------------------------------------------------------------------
# U matrices
# ---------------------------------------------------------------------------

def prototype_U(K, C: jnp.ndarray,
                block_size: Optional[int] = None) -> jnp.ndarray:
    """U* = argmin_U ||K - C U C^T||_F = C† K (C†)^T  (Eq. 4).

    K may be dense or any ``SPSDOperator``; K (C†)^T is streamed through
    ``matmat`` so implicit kernels are never densified.
    """
    Kop = as_operator(K)
    Cp = pinv(C)                                          # (c, n) f32
    KCpT = Kop.matmat(Cp.T, block_size=block_size)        # (n, c)
    return Cp @ KCpT.astype(Cp.dtype)


def nystrom_U(W: jnp.ndarray) -> jnp.ndarray:
    """U^nys = W† with W = P^T K P (Eq. 3)."""
    Wsym = 0.5 * (W + W.T)
    return pinv(Wsym)


def fast_U(StC: jnp.ndarray, StKS: jnp.ndarray) -> jnp.ndarray:
    """U^fast = (S^T C)† (S^T K S) (C^T S)†  (Eq. 5).

    StC: (s, c), StKS: (s, s).  Cost O(s²c) — independent of n.
    """
    StCp = pinv(StC)                      # (c, s)
    return StCp @ StKS.astype(StCp.dtype) @ StCp.T


# ---------------------------------------------------------------------------
# End-to-end models
# ---------------------------------------------------------------------------

def sample_C(Kop: SPSDOperator, key: jax.Array, c: int) -> SPSDApprox:
    """Uniformly sample c columns of K to form C (the sketch this paper fixes)."""
    idx = jax.random.choice(key, Kop.n, shape=(c,), replace=False)
    C = Kop.columns(idx)
    return SPSDApprox(C=C, U=jnp.eye(c, dtype=C.dtype), P_indices=idx)


def prototype_model(K, C: jnp.ndarray, P_indices=None,
                    block_size: Optional[int] = None) -> SPSDApprox:
    Kop = as_operator(K)
    U = prototype_U(Kop, C, block_size=block_size)
    return SPSDApprox(C=C, U=U, P_indices=P_indices)


def nystrom_model(K, key: jax.Array, c: int) -> SPSDApprox:
    Kop = as_operator(K)
    idx = jax.random.choice(key, Kop.n, shape=(c,), replace=False)
    C = Kop.columns(idx)
    W = Kop.block(idx, idx)
    return SPSDApprox(C=C, U=nystrom_U(W), P_indices=idx)


def fast_model_from_C(
    K,
    C: jnp.ndarray,
    key: jax.Array,
    s: int,
    P_indices: Optional[jnp.ndarray] = None,
    s_sketch: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
    streaming: Optional[bool] = None,
    block_size: Optional[int] = None,
) -> SPSDApprox:
    """Algorithm 1 given a fixed C (any provenance).

    ``s_sketch`` ∈ {uniform, leverage, gaussian, srht, countsketch}.
    Column-selection sketches read only an s×s block of K (Fig. 1).
    Projection sketches form S^T K S through blocked K @ S
    (``sketch.sym_streaming``) unless ``streaming=False`` forces the dense
    route; default is streaming for every implicit operator, dense only for
    an already-materialized ``DenseSPSD``.
    """
    Kop = as_operator(K)
    n = Kop.n

    if s_sketch in ("uniform", "leverage"):
        if s_sketch == "leverage":
            lev = row_leverage_scores(C)
            S = sk.leverage_column_sketch(key, lev, s, scale=scale)
        else:
            S = sk.uniform_column_sketch(key, n, s, scale=scale)
        if enforce_subset and P_indices is not None:
            S = sk.subset_union_sketch(S, P_indices, n)     # Corollary 5
        StC = S.left(C)
        blk = Kop.block(S.indices, S.indices)
        StKS = blk * (S.scales[:, None] * S.scales[None, :])
    else:
        S = sk.make_sketch(s_sketch, key, n, s)
        StC = S.left(C)
        if streaming is None:
            streaming = not isinstance(Kop, DenseSPSD)
        if streaming:
            StKS = sk.sym_streaming(S, Kop, block_size=block_size)
        else:
            StKS = S.sym(Kop.full())

    U = fast_U(StC, StKS)
    return SPSDApprox(C=C, U=U, P_indices=P_indices)


def fast_model(
    K,
    key: jax.Array,
    c: int,
    s: int,
    s_sketch: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
    streaming: Optional[bool] = None,
    block_size: Optional[int] = None,
) -> SPSDApprox:
    """Algorithm 1 end-to-end: uniform C = KP, then the fast U."""
    Kop = as_operator(K)
    kc, ks = jax.random.split(key)
    base = sample_C(Kop, kc, c)
    return fast_model_from_C(
        Kop, base.C, ks, s,
        P_indices=base.P_indices, s_sketch=s_sketch,
        enforce_subset=enforce_subset, scale=scale,
        streaming=streaming, block_size=block_size)


def fast_model_batched(
    Ks,
    keys: jax.Array,
    c: int,
    s: int,
    s_sketch: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
    streaming: Optional[bool] = None,
    block_size: Optional[int] = None,
) -> SPSDApprox:
    """Algorithm 1 vmapped over a batch of kernels.

    ``Ks`` is one operator pytree whose leaves carry a leading batch axis —
    e.g. ``RBFKernel(X_batch)`` with ``X_batch`` of shape (B, n, d), or
    ``DenseSPSD(K_batch)`` with (B, n, n) — and ``keys`` has shape (B, 2) as
    produced by ``jax.random.split``.  Returns an ``SPSDApprox`` whose fields
    are stacked along the batch axis.  Whole-batch work runs in one XLA
    computation, so many moderate kernels (hyperparameter sweeps, per-class
    Gram matrices) amortize compilation and saturate the accelerator.
    """
    if not isinstance(Ks, SPSDOperator):
        Ks = DenseSPSD(jnp.asarray(Ks))

    def one(op, key):
        return fast_model(op, key, c=c, s=s, s_sketch=s_sketch,
                          enforce_subset=enforce_subset, scale=scale,
                          streaming=streaming, block_size=block_size)

    return jax.vmap(one)(Ks, keys)


# ---------------------------------------------------------------------------
# Error metrics used throughout the paper's §6
#
# Three evaluation methods, selected by ``method``:
#   dense       exact, materializes K — small n only.
#   blocked     exact, accumulates ||K - CUC^T||_F² over row panels; O(b·n)
#               memory, reads each kernel entry once.
#   hutchinson  stochastic: ||R||_F² = E_z ||R z||² over Rademacher probes;
#               one streaming K @ Z pass serves numerator and denominator.
#   auto        dense below _DENSE_N_CUTOFF (or for DenseSPSD), else blocked.
# ---------------------------------------------------------------------------

def _resolve_error_method(Kop: SPSDOperator, method: str) -> str:
    if method != "auto":
        return method
    if isinstance(Kop, DenseSPSD) or Kop.n <= _DENSE_N_CUTOFF:
        return "dense"
    # "blocked" is exact with the same O(b·n) memory guarantee, so the default
    # never silently trades accuracy; the stochastic estimator is opt-in.
    return "blocked"


def _blocked_residual_fro2(Kop: SPSDOperator, approx: SPSDApprox,
                           block_size: Optional[int]):
    """(||K - CUC^T||_F², ||K||_F²) in one streaming pass."""
    C32 = approx.C.astype(jnp.float32)
    M = approx.U.astype(jnp.float32) @ C32.T              # (c, n)

    def fn(panel, idx, valid):
        p32 = panel.astype(jnp.float32)
        resid = p32 - jnp.take(C32, idx, axis=0) @ M
        v = valid.astype(jnp.float32)[:, None]
        return (jnp.sum(resid * resid * v), jnp.sum(p32 * p32 * v))

    num_parts, den_parts = Kop.map_row_panels(fn, block_size)
    return jnp.sum(num_parts), jnp.sum(den_parts)


def _hutchinson_residual_fro2(Kop: SPSDOperator, approx: SPSDApprox,
                              probes: int, key: jax.Array,
                              block_size: Optional[int]):
    """Rademacher estimates of (||K - CUC^T||_F², ||K||_F²)."""
    Z = jax.random.rademacher(key, (Kop.n, probes), dtype=jnp.float32)
    KZ = Kop.matmat(Z, block_size=block_size).astype(jnp.float32)
    RZ = KZ - approx.matmat(Z).astype(jnp.float32)
    return jnp.sum(RZ * RZ) / probes, jnp.sum(KZ * KZ) / probes


def relative_error(K, approx: SPSDApprox, method: str = "auto",
                   block_size: Optional[int] = None, probes: int = 64,
                   key: Optional[jax.Array] = None) -> jnp.ndarray:
    """||K - C U C^T||_F² / ||K||_F²  (Fig. 3/4 y-axis)."""
    Kop = as_operator(K)
    method = _resolve_error_method(Kop, method)
    if method == "dense":
        Kd = Kop.full().astype(jnp.float32)
        R = Kd - approx.dense().astype(jnp.float32)
        return jnp.sum(R * R) / jnp.sum(Kd * Kd)
    if method == "blocked":
        num, den = _blocked_residual_fro2(Kop, approx, block_size)
        return num / den
    if method == "hutchinson":
        key = jax.random.PRNGKey(0) if key is None else key
        num, den = _hutchinson_residual_fro2(Kop, approx, probes, key,
                                             block_size)
        return num / den
    raise ValueError(f"unknown error method {method!r}")


def streaming_topk_eigvals(K, k: int, key: Optional[jax.Array] = None,
                           oversample: int = 8, power_iters: int = 2,
                           block_size: Optional[int] = None) -> jnp.ndarray:
    """Top-k eigenvalues of an SPSD operator via randomized subspace iteration.

    Halko-Martinsson-Tropp: Y = K Ω, a few power passes, then the Rayleigh
    quotient Q^T K Q — every K application streams through ``matmat``, so the
    cost is (2 + power_iters) blocked passes and O(n·(k+p)) memory.
    """
    Kop = as_operator(K)
    key = jax.random.PRNGKey(0) if key is None else key
    q = min(Kop.n, k + oversample)
    Y = Kop.matmat(jax.random.normal(key, (Kop.n, q), dtype=jnp.float32),
                   block_size=block_size)
    for _ in range(power_iters):
        Q, _ = jnp.linalg.qr(Y)
        Y = Kop.matmat(Q, block_size=block_size)
    Q, _ = jnp.linalg.qr(Y)
    B = Q.T @ Kop.matmat(Q, block_size=block_size)
    B = 0.5 * (B + B.T)
    lam = jnp.linalg.eigvalsh(B)[::-1]
    return lam[:k]


def error_vs_best_rank_k(K, approx: SPSDApprox, k: int, method: str = "auto",
                         block_size: Optional[int] = None, probes: int = 64,
                         key: Optional[jax.Array] = None) -> jnp.ndarray:
    """||K - CUC^T||_F² / ||K - K_k||_F²  (the 1+ε target of Thm 3/Remark 4).

    Streaming methods use ||K - K_k||_F² = ||K||_F² - Σ_{i≤k} λ_i² (K SPSD)
    with the top spectrum from ``streaming_topk_eigvals``.
    """
    Kop = as_operator(K)
    method = _resolve_error_method(Kop, method)
    if method == "dense":
        Kd = Kop.full().astype(jnp.float32)
        evals = jnp.linalg.eigvalsh(Kd)
        tail = jnp.sum(jnp.sort(evals ** 2)[: Kd.shape[0] - k])
        R = Kd - approx.dense().astype(jnp.float32)
        return jnp.sum(R * R) / tail
    key = jax.random.PRNGKey(0) if key is None else key
    keig, kprobe = jax.random.split(key)
    lam = streaming_topk_eigvals(Kop, k, keig, block_size=block_size)
    if method == "blocked":
        num, fro2 = _blocked_residual_fro2(Kop, approx, block_size)
    elif method == "hutchinson":
        num, fro2 = _hutchinson_residual_fro2(Kop, approx, probes, kprobe,
                                              block_size)
    else:
        raise ValueError(f"unknown error method {method!r}")
    tail = jnp.maximum(fro2 - jnp.sum(lam ** 2), 1e-12 * fro2)
    return num / tail
