"""SPSD matrix approximation models (paper §3.2 & §4).

All three models produce ``K ≈ C U C^T`` with the same sketch ``C = K P`` and
differ only in U (Table 1):

- prototype:  U* = C† K (C†)^T                    O(n²c), sees all of K
- Nyström:    U  = (P^T K P)†                      O(c³),  sees n·c entries
- fast:       U  = (S^T C)† (S^T K S) (C^T S)†     O(nc² + s²c), nc + (s-c)² entries

``fast_spsd`` is Algorithm 1 end-to-end (with the §4.5 tricks: P ⊂ S and
unscaled leverage sampling by default).

Every large-n path streams through the single-sweep panel engine
(``SPSDOperator.sweep`` / ``matmat``): ``fast_model`` gathers C = K P and
applies the projection sketch from ONE pass over the kernel row panels, and
``fast_model_with_error`` folds the Hutchinson error probes into the same
pass — model + error for one evaluation of each kernel entry, the Table-3
"#Entries" economy at its floor.  Pass ``mesh=`` (a Mesh with a ``data``
axis, see ``distributed/sharding.py``) to shard every sweep across devices.
``fast_model_batched`` vmaps Algorithm 1 over a stacked batch of kernels;
ragged batches are handled by ``n_valid`` padding masks.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import selection as selection_lib
from repro.core import sketch as sk
from repro.core import sweep as sweep_lib
from repro.core.kernelop import DenseSPSD, SPSDOperator, as_operator
from repro.core.leverage import pinv, row_leverage_scores

# Below this n the dense error metrics are cheap and exact; above it the
# "auto" policy switches to the streaming estimators.
_DENSE_N_CUTOFF = 2048

# Seed used when a randomized estimator (Hutchinson probes, subspace
# iteration) is called with ``key=None``.  Deliberate and documented: the
# default path is deterministic across runs/processes so error trajectories
# are comparable, and callers who want fresh probes pass an explicit key —
# see the regression test that two distinct keys give distinct estimates.
DEFAULT_PROBE_SEED = 0


def default_probe_key() -> jax.Array:
    """The documented deterministic key for ``key=None`` estimator calls."""
    return jax.random.PRNGKey(DEFAULT_PROBE_SEED)


class SPSDApprox(NamedTuple):
    """K ≈ C U C^T."""
    C: jnp.ndarray          # (n, c)
    U: jnp.ndarray          # (c, c)
    P_indices: Optional[jnp.ndarray] = None   # columns of K forming C (if sampled)

    def dense(self) -> jnp.ndarray:
        return self.C @ self.U @ self.C.T

    def matmat(self, V: jnp.ndarray) -> jnp.ndarray:
        return self.C @ (self.U @ (self.C.T @ V))


# ---------------------------------------------------------------------------
# U matrices
# ---------------------------------------------------------------------------

def prototype_U(K, C: jnp.ndarray, block_size: Optional[int] = None,
                mesh=None) -> jnp.ndarray:
    """U* = argmin_U ||K - C U C^T||_F = C† K (C†)^T  (Eq. 4).

    K may be dense or any ``SPSDOperator``; K (C†)^T is streamed through
    ``matmat`` (one panel sweep, shardable via ``mesh``) so implicit kernels
    are never densified.
    """
    Kop = as_operator(K)
    Cp = pinv(C)                                          # (c, n) f32
    KCpT = Kop.matmat(Cp.T, block_size=block_size, mesh=mesh)  # (n, c)
    return Cp @ KCpT.astype(Cp.dtype)


def nystrom_U(W: jnp.ndarray) -> jnp.ndarray:
    """U^nys = W† with W = P^T K P (Eq. 3)."""
    Wsym = 0.5 * (W + W.T)
    return pinv(Wsym)


def fast_U(StC: jnp.ndarray, StKS: jnp.ndarray) -> jnp.ndarray:
    """U^fast = (S^T C)† (S^T K S) (C^T S)†  (Eq. 5).

    StC: (s, c), StKS: (s, s).  Cost O(s²c) — independent of n.
    """
    StCp = pinv(StC)                      # (c, s)
    return StCp @ StKS.astype(StCp.dtype) @ StCp.T


# ---------------------------------------------------------------------------
# End-to-end models
# ---------------------------------------------------------------------------

def sample_C(Kop: SPSDOperator, key: jax.Array, c: int) -> SPSDApprox:
    """Uniformly sample c columns of K to form C (the sketch this paper fixes)."""
    idx = jax.random.choice(key, Kop.n, shape=(c,), replace=False)
    C = Kop.columns(idx)
    return SPSDApprox(C=C, U=jnp.eye(c, dtype=C.dtype), P_indices=idx)


def prototype_model(K, C: jnp.ndarray, P_indices=None,
                    block_size: Optional[int] = None) -> SPSDApprox:
    Kop = as_operator(K)
    U = prototype_U(Kop, C, block_size=block_size)
    return SPSDApprox(C=C, U=U, P_indices=P_indices)


def nystrom_model(K, key: jax.Array, c: int) -> SPSDApprox:
    Kop = as_operator(K)
    idx = jax.random.choice(key, Kop.n, shape=(c,), replace=False)
    C = Kop.columns(idx)
    W = Kop.block(idx, idx)
    return SPSDApprox(C=C, U=nystrom_U(W), P_indices=idx)


def _column_sketch_for_C(Kop: SPSDOperator, C: jnp.ndarray, key: jax.Array,
                         s: int, s_sketch: str, P_indices, enforce_subset: bool,
                         scale: bool, mask: Optional[jnp.ndarray]):
    """The uniform/leverage S plus its S^T K S block (s² entries, no sweep)."""
    n = Kop.n
    if s_sketch == "leverage":
        # padding rows of a masked C are exactly zero -> leverage 0 -> never
        # sampled, so no extra masking is needed here.
        lev = row_leverage_scores(C)
        S = sk.leverage_column_sketch(key, lev, s, scale=scale)
    else:
        S = sk.uniform_column_sketch(key, n, s, scale=scale, mask=mask)
    if enforce_subset and P_indices is not None:
        S = sk.subset_union_sketch(S, P_indices, n)         # Corollary 5
    StC = S.left(C)
    blk = Kop.block(S.indices, S.indices)
    StKS = blk * (S.scales[:, None] * S.scales[None, :])
    return S, StC, StKS


def fast_model_from_C(
    K,
    C: jnp.ndarray,
    key: jax.Array,
    s: int,
    P_indices: Optional[jnp.ndarray] = None,
    s_sketch: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
    streaming: Optional[bool] = None,
    block_size: Optional[int] = None,
    mesh=None,
    n_valid=None,
) -> SPSDApprox:
    """Algorithm 1 given a fixed C (any provenance).

    ``s_sketch`` ∈ {uniform, leverage, gaussian, srht, countsketch}.
    Column-selection sketches read only an s×s block of K (Fig. 1).
    Projection sketches form S^T K S through one panel sweep
    (``sketch.sym_streaming``, shardable via ``mesh``) unless
    ``streaming=False`` forces the dense route; default is streaming for
    every implicit operator, dense only for an already-materialized
    ``DenseSPSD``.  ``n_valid`` marks the true size of a padded operator
    (rows ≥ n_valid are masked out of every product).
    """
    Kop = as_operator(K)
    n = Kop.n
    mask = None if n_valid is None else \
        (jnp.arange(n) < n_valid).astype(jnp.float32)

    if s_sketch in ("uniform", "leverage"):
        _, StC, StKS = _column_sketch_for_C(
            Kop, C, key, s, s_sketch, P_indices, enforce_subset, scale, mask)
    else:
        S = sk.make_sketch(s_sketch, key, n, s)
        if mask is not None:
            S = sk.MaskedSketch(S, mask)
        StC = S.left(C)
        if streaming is None:
            streaming = not isinstance(Kop, DenseSPSD)
        if streaming:
            StKS = sk.sym_streaming(S, Kop, block_size=block_size, mesh=mesh)
        else:
            StKS = S.sym(Kop.full())  # repro: allow-dense(caller forced streaming=False — explicit dense opt-out)

    U = fast_U(StC, StKS)
    return SPSDApprox(C=C, U=U, P_indices=P_indices)


def fast_model(
    K,
    key: jax.Array,
    c: int,
    s: int,
    s_sketch: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
    streaming: Optional[bool] = None,
    block_size: Optional[int] = None,
    mesh=None,
    n_valid=None,
    selection="uniform",
) -> SPSDApprox:
    """Algorithm 1 end-to-end: select C = KP columns, then the fast U.

    ``selection`` names a registered ``SelectionPolicy`` (``uniform``,
    ``leverage``, ``uniform_adaptive2``, or a policy instance) that picks
    WHICH columns form C; every policy meets a declared kernel-sweep budget
    and streams through the operator protocol (``repro.core.selection``).
    With a projection ``s_sketch`` on a streaming operator, the C gather and
    the K @ S product ride the SAME panel sweep — every kernel row panel is
    evaluated exactly once for the whole model (PR-1 paid one extra n×c
    evaluation plus a separate sweep).  ``mesh`` shards every sweep the model
    AND the selection policy run; ``n_valid`` handles padded (ragged-batch)
    operators — the mask restricts the policy to valid rows too.
    """
    Kop = as_operator(K)
    n = Kop.n
    kc, ks = jax.random.split(key)
    mask = None if n_valid is None else \
        (jnp.arange(n) < n_valid).astype(jnp.float32)
    pol = selection_lib.get_policy(selection)
    idx = pol.select(Kop, kc, c, block_size=block_size, mesh=mesh, mask=mask)

    if streaming is None:
        streaming = not isinstance(Kop, DenseSPSD)
    if s_sketch in ("uniform", "leverage") or not streaming:
        C = Kop.columns(idx)
        if mask is not None:
            C = C * mask[:, None]
        return fast_model_from_C(
            Kop, C, ks, s,
            P_indices=idx, s_sketch=s_sketch,
            enforce_subset=enforce_subset, scale=scale,
            streaming=streaming, block_size=block_size, mesh=mesh,
            n_valid=n_valid)

    # fused path: C = K P and K S from ONE sweep over the row panels
    S = sk.make_sketch(s_sketch, ks, n, s)
    if mask is not None:
        S = sk.MaskedSketch(S, mask)
    C, KS = Kop.sweep(
        [sweep_lib.ColumnGatherPlan(idx), sk.plan_for_sketch(S)],
        block_size=block_size, mesh=mesh)
    if mask is not None:
        C = C * mask[:, None]
    U = fast_U(S.left(C), S.left(KS))
    return SPSDApprox(C=C, U=U, P_indices=idx)


def fast_model_with_error(
    K,
    key: jax.Array,
    c: int,
    s: int,
    s_sketch: str = "gaussian",
    probes: int = 64,
    enforce_subset: bool = True,
    scale: bool = False,
    block_size: Optional[int] = None,
    mesh=None,
    error_key: Optional[jax.Array] = None,
    selection="uniform",
) -> Tuple[SPSDApprox, jnp.ndarray]:
    """Algorithm 1 + its Hutchinson relative error in ONE panel sweep.

    The error probes Z are independent of the model, so K @ Z joins the same
    sweep that gathers C and applies the projection sketch: the whole
    model-plus-evaluation pipeline reads each kernel row panel exactly once
    (PR 1 used one sweep for the model and another for the error — plus two
    more per adaptive round).  ``selection`` picks the policy that chooses
    C's columns (its declared sweeps are the only addition to the budget).
    Returns ``(approx, relative_error)`` with the same estimator as
    ``relative_error(method="hutchinson")``.
    """
    Kop = as_operator(K)
    n = Kop.n
    kc, ks = jax.random.split(key)
    kz = jax.random.fold_in(key, 777) if error_key is None else error_key
    pol = selection_lib.get_policy(selection)
    idx = pol.select(Kop, kc, c, block_size=block_size, mesh=mesh)
    Z = jax.random.rademacher(kz, (n, probes), dtype=jnp.float32)

    if s_sketch in ("uniform", "leverage"):
        C, KZ = Kop.sweep(
            [sweep_lib.ColumnGatherPlan(idx), sweep_lib.MatmulPlan(Z)],
            block_size=block_size, mesh=mesh)
        _, StC, StKS = _column_sketch_for_C(
            Kop, C, ks, s, s_sketch, idx, enforce_subset, scale, None)
    else:
        S = sk.make_sketch(s_sketch, ks, n, s)
        C, KS, KZ = Kop.sweep(
            [sweep_lib.ColumnGatherPlan(idx), sk.plan_for_sketch(S),
             sweep_lib.MatmulPlan(Z)],
            block_size=block_size, mesh=mesh)
        StC, StKS = S.left(C), S.left(KS)

    approx = SPSDApprox(C=C, U=fast_U(StC, StKS), P_indices=idx)
    RZ = KZ.astype(jnp.float32) - approx.matmat(Z).astype(jnp.float32)
    err = jnp.sum(RZ * RZ) / jnp.sum(KZ * KZ)
    return approx, err


def fast_model_batched(
    Ks,
    keys: jax.Array,
    c: int,
    s: int,
    s_sketch: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
    streaming: Optional[bool] = None,
    block_size: Optional[int] = None,
    n_valid: Optional[jnp.ndarray] = None,
    selection="uniform",
) -> SPSDApprox:
    """Algorithm 1 vmapped over a batch of kernels.

    ``Ks`` is one operator pytree whose leaves carry a leading batch axis —
    e.g. ``RBFKernel(X_batch)`` with ``X_batch`` of shape (B, n, d), or
    ``DenseSPSD(K_batch)`` with (B, n, n) — and ``keys`` has shape (B, 2) as
    produced by ``jax.random.split``.  Returns an ``SPSDApprox`` whose fields
    are stacked along the batch axis.  Whole-batch work runs in one XLA
    computation, so many moderate kernels (hyperparameter sweeps, per-class
    Gram matrices) amortize compilation and saturate the accelerator.
    ``selection`` picks the C-column policy per item (the whole policy —
    pilot gathers, residual-norm sweeps — traces under the vmap).

    Ragged batches: zero-pad each kernel's data to a common n and pass
    ``n_valid`` of shape (B,) with the true sizes.  Sampling is restricted to
    valid rows, C's padding rows are zeroed, and projection sketches are
    row-masked (``sketch.MaskedSketch``), so Sᵀ K S never observes a padding
    entry and the per-item results match unpadded runs.  ``fast_model_ragged``
    adds automatic size-bucketing on top so wildly mixed sizes don't all pay
    the largest item's padding.
    """
    if not isinstance(Ks, SPSDOperator):
        Ks = DenseSPSD(jnp.asarray(Ks))

    def one(op, key, nv):
        return fast_model(op, key, c=c, s=s, s_sketch=s_sketch,
                          enforce_subset=enforce_subset, scale=scale,
                          streaming=streaming, block_size=block_size,
                          n_valid=nv, selection=selection)

    if n_valid is None:
        return jax.vmap(lambda op, key: one(op, key, None))(Ks, keys)
    return jax.vmap(one)(Ks, keys, jnp.asarray(n_valid))


def bucket_by_size(sizes, waste: float = 0.25):
    """Greedy size-bucketing for ragged batches: index groups whose padded
    height stays within ``(1 + waste)×`` each member's true size.

    Items are visited in descending size order and join the current bucket
    while the bucket's padded height (its largest member) costs them at most
    a ``waste`` fraction of padding rows; otherwise a new bucket opens.  So
    every item's padding overhead is bounded by ``waste`` and the number of
    vmapped computations stays minimal for that bound.
    """
    order = sorted(range(len(sizes)), key=lambda i: -int(sizes[i]))
    buckets, cur, cap = [], [], 0
    for i in order:
        n_i = int(sizes[i])
        if cur and cap > n_i * (1.0 + waste):
            buckets.append(cur)
            cur = []
        if not cur:
            cap = n_i
        cur.append(i)
    if cur:
        buckets.append(cur)
    return buckets


def fast_model_ragged(
    Xs,
    make_operator,
    keys: jax.Array,
    c: int,
    s: int,
    waste: float = 0.25,
    **kwargs,
):
    """Algorithm 1 over a ragged list of datasets with automatic bucketing.

    ``Xs`` is a list of (n_i, d) data arrays (different n_i), and
    ``make_operator`` maps a stacked (B, n_pad, d) array to a batched
    operator pytree (e.g. ``lambda Xb: RBFKernel(Xb, sigma=1.5)``).  Items
    are grouped by ``bucket_by_size(..., waste)``, zero-padded only to their
    bucket's height, and each bucket runs one ``fast_model_batched`` call
    with the true sizes as ``n_valid`` — bounding padding waste at ``waste``
    instead of padding everything to the global maximum.  Extra ``kwargs``
    (``s_sketch``, ``selection``, …) pass through.  Returns a list of
    per-item ``SPSDApprox`` with C trimmed back to each item's true n,
    ordered like ``Xs``.
    """
    sizes = [int(x.shape[0]) for x in Xs]
    out = [None] * len(Xs)
    for bucket in bucket_by_size(sizes, waste):
        npad = max(sizes[i] for i in bucket)
        Xb = jnp.stack([jnp.pad(jnp.asarray(Xs[i]),
                                ((0, npad - sizes[i]), (0, 0)))
                        for i in bucket])
        kb = jnp.stack([keys[i] for i in bucket])
        nv = jnp.asarray([sizes[i] for i in bucket])
        bat = fast_model_batched(make_operator(Xb), kb, c=c, s=s,
                                 n_valid=nv, **kwargs)
        for j, i in enumerate(bucket):
            P = None if bat.P_indices is None else bat.P_indices[j]
            out[i] = SPSDApprox(C=bat.C[j][: sizes[i]], U=bat.U[j],
                                P_indices=P)
    return out


# ---------------------------------------------------------------------------
# Error metrics used throughout the paper's §6
#
# Three evaluation methods, selected by ``method``:
#   dense       exact, materializes K — small n only.
#   blocked     exact, accumulates ||K - CUC^T||_F² over row panels; O(b·n)
#               memory, reads each kernel entry once.
#   hutchinson  stochastic: ||R||_F² = E_z ||R z||² over Rademacher probes;
#               one streaming K @ Z pass serves numerator and denominator.
#   auto        dense below _DENSE_N_CUTOFF (or for DenseSPSD), else blocked.
# ---------------------------------------------------------------------------

def _resolve_error_method(Kop: SPSDOperator, method: str) -> str:
    if method != "auto":
        return method
    if isinstance(Kop, DenseSPSD) or Kop.n <= _DENSE_N_CUTOFF:
        return "dense"
    # "blocked" is exact with the same O(b·n) memory guarantee, so the default
    # never silently trades accuracy; the stochastic estimator is opt-in.
    return "blocked"


def _blocked_residual_fro2(Kop: SPSDOperator, approx: SPSDApprox,
                           block_size: Optional[int], mesh=None,
                           extra_plans=()):
    """(||K - CUC^T||_F², ||K||_F², extra results) in ONE panel sweep.

    ``extra_plans`` ride the same pass (e.g. the subspace-iteration K Ω of
    ``error_vs_best_rank_k``); their results come back in order.
    """
    C32 = approx.C.astype(jnp.float32)
    M = approx.U.astype(jnp.float32) @ C32.T              # (c, n)
    *extras, (num, den) = Kop.sweep(
        [*extra_plans, sweep_lib.ResidualFroPlan(C32, M)],
        block_size=block_size, mesh=mesh)
    return num, den, extras


def _hutchinson_residual_fro2(Kop: SPSDOperator, approx: SPSDApprox,
                              probes: int, key: jax.Array,
                              block_size: Optional[int], mesh=None,
                              extra_plans=()):
    """Rademacher estimates of (||K - CUC^T||_F², ||K||_F²), plus the
    results of any ``extra_plans`` fused into the same probe sweep."""
    Z = jax.random.rademacher(key, (Kop.n, probes), dtype=jnp.float32)
    *extras, KZ = Kop.sweep([*extra_plans, sweep_lib.MatmulPlan(Z)],
                            block_size=block_size, mesh=mesh)
    KZ = KZ.astype(jnp.float32)
    RZ = KZ - approx.matmat(Z).astype(jnp.float32)
    return jnp.sum(RZ * RZ) / probes, jnp.sum(KZ * KZ) / probes, extras


def relative_error(K, approx: SPSDApprox, method: str = "auto",
                   block_size: Optional[int] = None, probes: int = 64,
                   key: Optional[jax.Array] = None, mesh=None) -> jnp.ndarray:
    """||K - C U C^T||_F² / ||K||_F²  (Fig. 3/4 y-axis).

    The streaming methods cost exactly ONE sweep over the kernel row panels
    (shardable via ``mesh``); together with the fused ``fast_model`` that
    bounds model + error at two evaluations of each kernel entry — or one,
    via ``fast_model_with_error``.
    """
    Kop = as_operator(K)
    method = _resolve_error_method(Kop, method)
    if method == "dense":
        Kd = Kop.full().astype(jnp.float32)  # repro: allow-dense(exact f32 oracle, auto-gated to n<=2048)
        R = Kd - approx.dense().astype(jnp.float32)  # repro: allow-dense(same oracle branch)
        return jnp.sum(R * R) / jnp.sum(Kd * Kd)
    if method == "blocked":
        num, den, _ = _blocked_residual_fro2(Kop, approx, block_size, mesh)
        return num / den
    if method == "hutchinson":
        key = default_probe_key() if key is None else key
        num, den, _ = _hutchinson_residual_fro2(Kop, approx, probes, key,
                                                block_size, mesh)
        return num / den
    raise ValueError(f"unknown error method {method!r}")


def _subspace_eigvals_from_Y(Kop: SPSDOperator, Y: jnp.ndarray, k: int,
                             power_iters: int,
                             block_size: Optional[int], mesh=None):
    """Finish subspace iteration given the first product Y = K Ω.

    The remaining cost is ``power_iters`` power passes plus the Rayleigh
    quotient — (1 + power_iters) sweeps.  Factored out so callers that
    already have a sweep in flight (``error_vs_best_rank_k``) can fold the
    Y = K Ω pass into it instead of paying a dedicated one.
    """
    for _ in range(power_iters):
        Q, _ = jnp.linalg.qr(Y)
        Y = Kop.matmat(Q, block_size=block_size, mesh=mesh)
    Q, _ = jnp.linalg.qr(Y)
    B = Q.T @ Kop.matmat(Q, block_size=block_size, mesh=mesh)
    B = 0.5 * (B + B.T)
    lam = jnp.linalg.eigvalsh(B)[::-1]
    return lam[:k]


def streaming_topk_eigvals(K, k: int, key: Optional[jax.Array] = None,
                           oversample: int = 8, power_iters: int = 2,
                           block_size: Optional[int] = None,
                           mesh=None) -> jnp.ndarray:
    """Top-k eigenvalues of an SPSD operator via randomized subspace iteration.

    Halko-Martinsson-Tropp: Y = K Ω, a few power passes, then the Rayleigh
    quotient Q^T K Q — every K application streams through ``matmat``, so the
    cost is (2 + power_iters) blocked passes and O(n·(k+p)) memory.
    """
    Kop = as_operator(K)
    key = default_probe_key() if key is None else key
    q = min(Kop.n, k + oversample)
    Y = Kop.matmat(jax.random.normal(key, (Kop.n, q), dtype=jnp.float32),
                   block_size=block_size, mesh=mesh)
    return _subspace_eigvals_from_Y(Kop, Y, k, power_iters, block_size, mesh)


def error_vs_best_rank_k(K, approx: SPSDApprox, k: int, method: str = "auto",
                         block_size: Optional[int] = None, probes: int = 64,
                         key: Optional[jax.Array] = None,
                         mesh=None) -> jnp.ndarray:
    """||K - CUC^T||_F² / ||K - K_k||_F²  (the 1+ε target of Thm 3/Remark 4).

    Streaming methods use ||K - K_k||_F² = ||K||_F² - Σ_{i≤k} λ_i² (K SPSD)
    with the top spectrum by randomized subspace iteration — whose FIRST
    product Y = K Ω rides the same panel sweep as the residual accumulation
    (blocked) or the Hutchinson probes, so the whole metric costs
    (2 + power_iters) sweeps instead of (3 + power_iters).
    """
    Kop = as_operator(K)
    method = _resolve_error_method(Kop, method)
    if method == "dense":
        Kd = Kop.full().astype(jnp.float32)  # repro: allow-dense(exact eigen-tail oracle, auto-gated to n<=2048)
        evals = jnp.linalg.eigvalsh(Kd)
        # A kernel of rank ≤ k has an exactly-zero tail; floor it the same
        # way the streaming branch does (1e-12·||K||_F²) so the ratio stays
        # finite instead of inf/nan.
        fro2 = jnp.sum(evals ** 2)
        tail = jnp.sum(jnp.sort(evals ** 2)[: Kd.shape[0] - k])
        tail = jnp.maximum(tail, 1e-12 * fro2)
        R = Kd - approx.dense().astype(jnp.float32)  # repro: allow-dense(same oracle branch)
        return jnp.sum(R * R) / tail
    key = default_probe_key() if key is None else key
    keig, kprobe = jax.random.split(key)
    n = Kop.n
    q = min(n, k + 8)                       # streaming_topk_eigvals defaults
    power_iters = 2
    omega_plan = sweep_lib.MatmulPlan(
        jax.random.normal(keig, (n, q), dtype=jnp.float32))
    if method == "blocked":
        num, fro2, (Y,) = _blocked_residual_fro2(
            Kop, approx, block_size, mesh, extra_plans=[omega_plan])
    elif method == "hutchinson":
        num, fro2, (Y,) = _hutchinson_residual_fro2(
            Kop, approx, probes, kprobe, block_size, mesh,
            extra_plans=[omega_plan])
    else:
        raise ValueError(f"unknown error method {method!r}")
    lam = _subspace_eigvals_from_Y(Kop, Y, k, power_iters, block_size, mesh)
    tail = jnp.maximum(fro2 - jnp.sum(lam ** 2), 1e-12 * fro2)
    return num / tail
