"""Implicit SPSD operators.

The paper's efficiency story depends on *never* materializing the n×n kernel
matrix (Fig. 1, Table 3 "#Entries" column).  ``KernelOperator`` exposes exactly
the access patterns the fast model needs:

- ``columns(idx)``   -> K[:, idx]           (n × c)    for C = K P
- ``block(ri, ci)``  -> K[ri][:, ci]        (|ri|×|ci|) for S^T K S
- ``diag()``                                            for RBF trace tricks
- ``full()``         -> K                   (prototype model / tests only)

``RBFKernel`` computes entries on the fly from the d-dimensional data; on TPU the
block computation is backed by the fused Pallas kernel in
``repro.kernels.rbf_sketch`` (see ``use_pallas``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


class SPSDOperator:
    n: int

    def columns(self, idx: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def block(self, row_idx: jnp.ndarray, col_idx: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def full(self) -> jnp.ndarray:
        raise NotImplementedError

    def diag(self) -> jnp.ndarray:
        raise NotImplementedError

    def matmat(self, V: jnp.ndarray) -> jnp.ndarray:     # K @ V
        return self.full() @ V


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseSPSD(SPSDOperator):
    K: jnp.ndarray

    def tree_flatten(self):
        return (self.K,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self) -> int:
        return int(self.K.shape[0])

    def columns(self, idx):
        return jnp.take(self.K, idx, axis=1)

    def block(self, row_idx, col_idx):
        return jnp.take(jnp.take(self.K, row_idx, axis=0), col_idx, axis=1)

    def full(self):
        return self.K

    def diag(self):
        return jnp.diagonal(self.K)

    def matmat(self, V):
        return self.K @ V


def _sqdist(X: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared distances, MXU-friendly: |x|^2 + |y|^2 - 2 x.y."""
    xx = jnp.sum(X * X, axis=1)
    yy = jnp.sum(Y * Y, axis=1)
    cross = X @ Y.T
    return jnp.maximum(xx[:, None] + yy[None, :] - 2.0 * cross, 0.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RBFKernel(SPSDOperator):
    """K_ij = exp(-|x_i - x_j|^2 / (2 sigma^2)) computed from X (n × d)."""

    X: jnp.ndarray
    sigma: float
    use_pallas: bool = False

    def tree_flatten(self):
        return (self.X,), (self.sigma, self.use_pallas)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0], aux[1])

    @property
    def n(self) -> int:
        return int(self.X.shape[0])

    def _gamma(self):
        return 1.0 / (2.0 * self.sigma ** 2)

    def columns(self, idx):
        return self.block(jnp.arange(self.n), idx)

    def block(self, row_idx, col_idx):
        Xr = jnp.take(self.X, row_idx, axis=0)
        Xc = jnp.take(self.X, col_idx, axis=0)
        if self.use_pallas:
            from repro.kernels.rbf_sketch import ops as rbf_ops
            return rbf_ops.rbf_block(Xr, Xc, self.sigma)
        return jnp.exp(-self._gamma() * _sqdist(Xr, Xc))

    def full(self):
        return jnp.exp(-self._gamma() * _sqdist(self.X, self.X))

    def diag(self):
        return jnp.ones((self.n,), self.X.dtype)

    def matmat(self, V, block: int = 2048):
        """Blocked K @ V without materializing K (footnote-2 memory trick)."""
        n = self.n

        def body(i, acc):
            idx = i * block + jnp.arange(block)
            idx = jnp.clip(idx, 0, n - 1)
            rows = self.block(idx, jnp.arange(n))      # (block, n)
            return acc.at[i * block:(i + 1) * block].set(rows @ V)

        nblocks = (n + block - 1) // block
        out = jnp.zeros((nblocks * block, V.shape[1]), V.dtype)
        out = jax.lax.fori_loop(0, nblocks, body, out)
        return out[:n]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LinearKernel(SPSDOperator):
    """K = X X^T (n × n) from X (n × d)."""

    X: jnp.ndarray

    def tree_flatten(self):
        return (self.X,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self) -> int:
        return int(self.X.shape[0])

    def columns(self, idx):
        return self.X @ jnp.take(self.X, idx, axis=0).T

    def block(self, row_idx, col_idx):
        return jnp.take(self.X, row_idx, axis=0) @ jnp.take(self.X, col_idx, axis=0).T

    def full(self):
        return self.X @ self.X.T

    def diag(self):
        return jnp.sum(self.X * self.X, axis=1)

    def matmat(self, V):
        return self.X @ (self.X.T @ V)


def as_operator(K) -> SPSDOperator:
    if isinstance(K, SPSDOperator):
        return K
    return DenseSPSD(jnp.asarray(K))
