"""Implicit SPSD operators with a streaming blockwise access protocol.

The paper's efficiency story depends on *never* materializing the n×n kernel
matrix (Fig. 1, Table 3 "#Entries" column).  ``SPSDOperator`` exposes exactly
the access patterns the fast model needs:

- ``columns(idx)``   -> K[:, idx]           (n × c)    for C = K P
- ``block(ri, ci)``  -> K[ri][:, ci]        (|ri|×|ci|) for S^T K S
- ``diag()``                                            for RBF trace tricks
- ``full()``         -> K                   (small-n tests only)

plus the *streaming* protocol every large-n code path is built on:

- ``sweep(plans)``        -> the single-pass multi-product panel engine
  (``repro.core.sweep``): every plan consumes each (b × n) row panel from ONE
  materialization, and a non-trivial ``mesh`` partitions the panels over the
  data axis with ``shard_map`` (psum-reduced partial products).
- ``map_row_panels(fn)``  -> fn applied to (b × n) row panels, ``jax.lax.map``
  over row blocks; peak memory O(b·n), never O(n²).
- ``matmat(V)``           -> K @ V streamed through row panels.
- ``frobenius_norm_sq()`` -> ||K||_F² accumulated panel-by-panel.

``RBFKernel`` computes entries on the fly from the d-dimensional data; on TPU
both the block computation and the streaming matmat are backed by the fused
Pallas kernels in ``repro.kernels.rbf_sketch`` (see ``use_pallas``), and
matmul-shaped sweeps collapse into one multi-right-hand-side Pallas launch
whose kernel tiles never leave VMEM.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import sweep as sweep_lib

# Back-compat aliases; the canonical definitions live in repro.core.sweep.
_PANEL_ELEMENT_BUDGET = sweep_lib.PANEL_ELEMENT_BUDGET
_panel_block_size = sweep_lib.panel_block_size


class SPSDOperator:
    n: int

    # -- pointwise access ---------------------------------------------------

    def block(self, row_idx: jnp.ndarray, col_idx: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def columns(self, idx: jnp.ndarray) -> jnp.ndarray:
        return self.block(jnp.arange(self.n), idx)

    def full(self) -> jnp.ndarray:
        raise NotImplementedError

    def diag(self) -> jnp.ndarray:
        raise NotImplementedError

    # -- streaming protocol -------------------------------------------------

    def sweep(self, plans: Sequence, block_size: Optional[int] = None,
              mesh=None):
        """Run the multi-product panel engine over this operator's rows.

        Each kernel row panel is materialized exactly once and fed to every
        plan (``repro.core.sweep``), so a whole bundle of products — K @ S,
        column gathers for C, Hutchinson probes, residual norms — costs one
        evaluation of each kernel tile.  A non-trivial ``mesh`` shards the
        panels over its data axes via ``shard_map`` (single-device meshes and
        ``mesh=None`` fall back to the sequential scan).
        """
        cols = jnp.arange(self.n)
        return sweep_lib.sweep_panels(
            lambda idx: self.block(idx, cols), self.n, self.n, plans,
            block_size=block_size, mesh=mesh)

    def map_row_panels(self, fn, block_size: Optional[int] = None):
        """Apply ``fn(panel, row_idx, valid)`` to consecutive (b × n) row panels.

        ``panel`` is K[row_idx, :] (tail panels are padded by clamping to the
        last row; ``valid`` masks the padding).  Results are stacked along a
        leading block axis — reductions sum over it, matmats reshape it away.
        Runs under ``jax.lax.map`` so only one panel is live at a time.
        """
        n = self.n
        bs = sweep_lib.resolved_block_size(n, n, block_size)
        nblocks = -(-n // bs)
        starts = jnp.arange(nblocks) * bs
        cols = jnp.arange(n)

        def body(start):
            idx = start + jnp.arange(bs)
            valid = idx < n
            idx = jnp.clip(idx, 0, n - 1)
            return fn(self.block(idx, cols), idx, valid)

        return jax.lax.map(body, starts)

    def matmat(self, V: jnp.ndarray, block_size: Optional[int] = None,
               mesh=None) -> jnp.ndarray:
        """K @ V without materializing K (footnote-2 memory trick)."""
        V2 = V if V.ndim == 2 else V[:, None]
        (out,) = self.sweep([sweep_lib.MatmulPlan(V2)],
                            block_size=block_size, mesh=mesh)
        return out if V.ndim == 2 else out[:, 0]

    def frobenius_norm_sq(self, block_size: Optional[int] = None,
                          mesh=None) -> jnp.ndarray:
        """||K||_F² accumulated over row panels (never forms K)."""
        (out,) = self.sweep([sweep_lib.FrobeniusPlan()],
                            block_size=block_size, mesh=mesh)
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseSPSD(SPSDOperator):
    K: jnp.ndarray

    def tree_flatten(self):
        return (self.K,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self) -> int:
        return int(self.K.shape[0])

    def columns(self, idx):
        return jnp.take(self.K, idx, axis=1)

    def block(self, row_idx, col_idx):
        return jnp.take(jnp.take(self.K, row_idx, axis=0), col_idx, axis=1)

    def full(self):
        return self.K

    def diag(self):
        return jnp.diagonal(self.K)

    def matmat(self, V, block_size: Optional[int] = None, mesh=None):
        return self.K @ V

    def frobenius_norm_sq(self, block_size: Optional[int] = None, mesh=None):
        K32 = self.K.astype(jnp.float32)
        return jnp.sum(K32 * K32)


def _sqdist(X: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared distances, MXU-friendly: |x|^2 + |y|^2 - 2 x.y."""
    xx = jnp.sum(X * X, axis=1)
    yy = jnp.sum(Y * Y, axis=1)
    cross = X @ Y.T
    return jnp.maximum(xx[:, None] + yy[None, :] - 2.0 * cross, 0.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RBFKernel(SPSDOperator):
    """K_ij = exp(-|x_i - x_j|^2 / (2 sigma^2)) computed from X (n × d)."""

    X: jnp.ndarray
    sigma: float
    use_pallas: bool = False

    def tree_flatten(self):
        return (self.X,), (self.sigma, self.use_pallas)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0], aux[1])

    @property
    def n(self) -> int:
        return int(self.X.shape[0])

    def _gamma(self):
        return 1.0 / (2.0 * self.sigma ** 2)

    def block(self, row_idx, col_idx):
        Xr = jnp.take(self.X, row_idx, axis=0)
        Xc = jnp.take(self.X, col_idx, axis=0)
        if self.use_pallas:
            from repro.kernels.rbf_sketch import ops as rbf_ops
            return rbf_ops.rbf_block(Xr, Xc, self.sigma)
        return jnp.exp(-self._gamma() * _sqdist(Xr, Xc))

    def full(self):
        return jnp.exp(-self._gamma() * _sqdist(self.X, self.X))

    def diag(self):
        return jnp.ones((self.n,), self.X.dtype)

    def matmat(self, V, block_size: Optional[int] = None, mesh=None):
        if self.use_pallas and sweep_lib.mesh_data_size(mesh) <= 1:
            from repro.kernels.rbf_sketch import ops as rbf_ops
            return rbf_ops.rbf_matmat(self.X, V, self.sigma)
        return SPSDOperator.matmat(self, V, block_size, mesh=mesh)

    def _fused_rhs(self, plans: Sequence):
        """Dense f32 right-hand sides for a matmul-shaped plan bundle.

        Column gathers ride along as one-hot right-hand sides (exact: each
        output entry is one K entry times 1.0).
        """
        n = self.n
        return tuple(
            p.V.astype(jnp.float32) if isinstance(p, sweep_lib.MatmulPlan)
            else jax.nn.one_hot(p.col_idx, n, dtype=jnp.float32).T
            for p in plans)

    def sweep(self, plans: Sequence, block_size: Optional[int] = None,
              mesh=None):
        """Matmul-shaped sweeps fuse into ONE multi-RHS Pallas launch per
        device.

        When every plan is a matmat or a column gather (the fast-model
        bundle: C = K P plus K @ S plus probes), the whole sweep lowers to
        ``rbf_matmat_multi`` calls whose kernel tiles are computed once in
        VMEM and contracted against all right-hand sides before being
        discarded — no kernel entry is ever evaluated twice or staged in HBM.
        On a trivial mesh that is one square launch; on a non-trivial mesh
        the bundle is *claimed per shard* through the sweep engine's
        ``slab_fn`` hook: each device gathers its contiguous local row slab
        and runs one rectangular ``rbf_matmat_multi_rows`` launch, with the
        partial carries psum-reduced exactly like the panel route.  The
        route taken is recorded on ``self._last_sweep_route``
        ('pallas_fused' | 'pallas_fused_sharded' | 'panel') so
        instrumentation can assert the fast path stays engaged.
        """
        plans = list(plans)
        fused = self.use_pallas and plans and all(
            isinstance(p, (sweep_lib.MatmulPlan, sweep_lib.ColumnGatherPlan))
            for p in plans)
        if fused and sweep_lib.mesh_data_size(mesh) <= 1:
            self._last_sweep_route = "pallas_fused"
            from repro.kernels.rbf_sketch import ops as rbf_ops
            return list(rbf_ops.rbf_matmat_multi(self.X,
                                                 self._fused_rhs(plans),
                                                 self.sigma))
        if fused:
            self._last_sweep_route = "pallas_fused_sharded"
            from repro.kernels.rbf_sketch import ops as rbf_ops
            n = self.n
            Vs = self._fused_rhs(plans)

            def slab_fn(row_idx, valid):
                # One rectangular launch for this shard's row slab: only the
                # slab's kernel tiles are evaluated, each exactly once.
                Xr = jnp.take(self.X, row_idx, axis=0)
                outs = rbf_ops.rbf_matmat_multi_rows(Xr, self.X, Vs,
                                                     self.sigma)
                v = valid.astype(jnp.float32)[:, None]
                return tuple(p.init(n, n).at[row_idx].add(o * v)
                             for p, o in zip(plans, outs))

            # panel_fn=None: the claim is unconditional, the scan never runs
            return sweep_lib.sweep_panels(
                None, n, n, plans,
                block_size=block_size, mesh=mesh, slab_fn=slab_fn)
        self._last_sweep_route = "panel"
        return SPSDOperator.sweep(self, plans, block_size, mesh=mesh)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LinearKernel(SPSDOperator):
    """K = X X^T (n × n) from X (n × d)."""

    X: jnp.ndarray

    def tree_flatten(self):
        return (self.X,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self) -> int:
        return int(self.X.shape[0])

    def columns(self, idx):
        return self.X @ jnp.take(self.X, idx, axis=0).T

    def block(self, row_idx, col_idx):
        return jnp.take(self.X, row_idx, axis=0) @ jnp.take(self.X, col_idx, axis=0).T

    def full(self):
        return self.X @ self.X.T

    def diag(self):
        return jnp.sum(self.X * self.X, axis=1)

    def matmat(self, V, block_size: Optional[int] = None, mesh=None):
        return self.X @ (self.X.T @ V)

    def frobenius_norm_sq(self, block_size: Optional[int] = None, mesh=None):
        # ||X X^T||_F² = ||X^T X||_F² — a d×d Gram, O(nd²) and O(d²) memory.
        G = self.X.astype(jnp.float32)
        G = G.T @ G
        return jnp.sum(G * G)


def as_operator(K) -> SPSDOperator:
    if isinstance(K, SPSDOperator):
        return K
    return DenseSPSD(jnp.asarray(K))
