"""Implicit SPSD operators with a streaming blockwise access protocol.

The paper's efficiency story depends on *never* materializing the n×n kernel
matrix (Fig. 1, Table 3 "#Entries" column).  ``SPSDOperator`` exposes exactly
the access patterns the fast model needs:

- ``columns(idx)``   -> K[:, idx]           (n × c)    for C = K P
- ``block(ri, ci)``  -> K[ri][:, ci]        (|ri|×|ci|) for S^T K S
- ``diag()``                                            for RBF trace tricks
- ``full()``         -> K                   (small-n tests only)

plus the *streaming* protocol every large-n code path is built on:

- ``map_row_panels(fn)``  -> fn applied to (b × n) row panels, ``jax.lax.map``
  over row blocks; peak memory O(b·n), never O(n²).
- ``matmat(V)``           -> K @ V streamed through row panels.
- ``frobenius_norm_sq()`` -> ||K||_F² accumulated panel-by-panel.

``RBFKernel`` computes entries on the fly from the d-dimensional data; on TPU
both the block computation and the streaming matmat are backed by the fused
Pallas kernels in ``repro.kernels.rbf_sketch`` (see ``use_pallas``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# Row panels are capped at roughly this many f32 elements (b·n), so the
# streaming paths stay ~128 MB regardless of n.
_PANEL_ELEMENT_BUDGET = 1 << 25


def _panel_block_size(n: int, block_size: Optional[int]) -> int:
    if block_size is not None:
        return max(1, int(block_size))
    return max(128, min(4096, _PANEL_ELEMENT_BUDGET // max(n, 1)))


class SPSDOperator:
    n: int

    # -- pointwise access ---------------------------------------------------

    def block(self, row_idx: jnp.ndarray, col_idx: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def columns(self, idx: jnp.ndarray) -> jnp.ndarray:
        return self.block(jnp.arange(self.n), idx)

    def full(self) -> jnp.ndarray:
        raise NotImplementedError

    def diag(self) -> jnp.ndarray:
        raise NotImplementedError

    # -- streaming protocol -------------------------------------------------

    def map_row_panels(self, fn, block_size: Optional[int] = None):
        """Apply ``fn(panel, row_idx, valid)`` to consecutive (b × n) row panels.

        ``panel`` is K[row_idx, :] (tail panels are padded by clamping to the
        last row; ``valid`` masks the padding).  Results are stacked along a
        leading block axis — reductions sum over it, matmats reshape it away.
        Runs under ``jax.lax.map`` so only one panel is live at a time.
        """
        n = self.n
        bs = _panel_block_size(n, block_size)
        nblocks = -(-n // bs)
        starts = jnp.arange(nblocks) * bs
        cols = jnp.arange(n)

        def body(start):
            idx = start + jnp.arange(bs)
            valid = idx < n
            idx = jnp.clip(idx, 0, n - 1)
            return fn(self.block(idx, cols), idx, valid)

        return jax.lax.map(body, starts)

    def matmat(self, V: jnp.ndarray, block_size: Optional[int] = None) -> jnp.ndarray:
        """K @ V without materializing K (footnote-2 memory trick)."""
        V2 = V if V.ndim == 2 else V[:, None]
        out = self.map_row_panels(lambda panel, idx, valid: panel @ V2,
                                  block_size)
        out = out.reshape(-1, V2.shape[1])[: self.n]
        return out if V.ndim == 2 else out[:, 0]

    def frobenius_norm_sq(self, block_size: Optional[int] = None) -> jnp.ndarray:
        """||K||_F² accumulated over row panels (never forms K)."""
        def fn(panel, idx, valid):
            p32 = panel.astype(jnp.float32)
            return jnp.sum(p32 * p32 * valid.astype(jnp.float32)[:, None])

        return jnp.sum(self.map_row_panels(fn, block_size))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseSPSD(SPSDOperator):
    K: jnp.ndarray

    def tree_flatten(self):
        return (self.K,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self) -> int:
        return int(self.K.shape[0])

    def columns(self, idx):
        return jnp.take(self.K, idx, axis=1)

    def block(self, row_idx, col_idx):
        return jnp.take(jnp.take(self.K, row_idx, axis=0), col_idx, axis=1)

    def full(self):
        return self.K

    def diag(self):
        return jnp.diagonal(self.K)

    def matmat(self, V, block_size: Optional[int] = None):
        return self.K @ V

    def frobenius_norm_sq(self, block_size: Optional[int] = None):
        K32 = self.K.astype(jnp.float32)
        return jnp.sum(K32 * K32)


def _sqdist(X: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared distances, MXU-friendly: |x|^2 + |y|^2 - 2 x.y."""
    xx = jnp.sum(X * X, axis=1)
    yy = jnp.sum(Y * Y, axis=1)
    cross = X @ Y.T
    return jnp.maximum(xx[:, None] + yy[None, :] - 2.0 * cross, 0.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RBFKernel(SPSDOperator):
    """K_ij = exp(-|x_i - x_j|^2 / (2 sigma^2)) computed from X (n × d)."""

    X: jnp.ndarray
    sigma: float
    use_pallas: bool = False

    def tree_flatten(self):
        return (self.X,), (self.sigma, self.use_pallas)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0], aux[1])

    @property
    def n(self) -> int:
        return int(self.X.shape[0])

    def _gamma(self):
        return 1.0 / (2.0 * self.sigma ** 2)

    def block(self, row_idx, col_idx):
        Xr = jnp.take(self.X, row_idx, axis=0)
        Xc = jnp.take(self.X, col_idx, axis=0)
        if self.use_pallas:
            from repro.kernels.rbf_sketch import ops as rbf_ops
            return rbf_ops.rbf_block(Xr, Xc, self.sigma)
        return jnp.exp(-self._gamma() * _sqdist(Xr, Xc))

    def full(self):
        return jnp.exp(-self._gamma() * _sqdist(self.X, self.X))

    def diag(self):
        return jnp.ones((self.n,), self.X.dtype)

    def matmat(self, V, block_size: Optional[int] = None):
        if self.use_pallas:
            from repro.kernels.rbf_sketch import ops as rbf_ops
            return rbf_ops.rbf_matmat(self.X, V, self.sigma)
        return SPSDOperator.matmat(self, V, block_size)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LinearKernel(SPSDOperator):
    """K = X X^T (n × n) from X (n × d)."""

    X: jnp.ndarray

    def tree_flatten(self):
        return (self.X,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self) -> int:
        return int(self.X.shape[0])

    def columns(self, idx):
        return self.X @ jnp.take(self.X, idx, axis=0).T

    def block(self, row_idx, col_idx):
        return jnp.take(self.X, row_idx, axis=0) @ jnp.take(self.X, col_idx, axis=0).T

    def full(self):
        return self.X @ self.X.T

    def diag(self):
        return jnp.sum(self.X * self.X, axis=1)

    def matmat(self, V, block_size: Optional[int] = None):
        return self.X @ (self.X.T @ V)

    def frobenius_norm_sq(self, block_size: Optional[int] = None):
        # ||X X^T||_F² = ||X^T X||_F² — a d×d Gram, O(nd²) and O(d²) memory.
        G = self.X.astype(jnp.float32)
        G = G.T @ G
        return jnp.sum(G * G)


def as_operator(K) -> SPSDOperator:
    if isinstance(K, SPSDOperator):
        return K
    return DenseSPSD(jnp.asarray(K))
