"""Implicit SPSD operators with a streaming blockwise access protocol.

The paper's efficiency story depends on *never* materializing the n×n kernel
matrix (Fig. 1, Table 3 "#Entries" column).  ``SPSDOperator`` exposes exactly
the access patterns the fast model needs:

- ``columns(idx)``   -> K[:, idx]           (n × c)    for C = K P
- ``block(ri, ci)``  -> K[ri][:, ci]        (|ri|×|ci|) for S^T K S
- ``diag()``                                            for trace tricks
- ``full()``         -> K                   (small-n tests only)

plus the *streaming* protocol every large-n code path is built on:

- ``sweep(plans)``        -> the single-pass multi-product panel engine
  (``repro.core.sweep``): every plan consumes each (b × n) row panel from ONE
  materialization, and a non-trivial ``mesh`` partitions the panels over the
  data axis with ``shard_map`` (psum-reduced partial products).
- ``map_row_panels(fn)``  -> fn applied to (b × n) row panels, ``jax.lax.map``
  over row blocks; peak memory O(b·n), never O(n²).
- ``matmat(V)``           -> K @ V streamed through row panels.
- ``frobenius_norm_sq()`` -> ||K||_F² accumulated panel-by-panel.

Route selection lives in the sweep engine (``sweep.sweep_operator``) behind a
small capability protocol — ``supports_fused_matmat()`` / ``fused_rows()`` —
so any capable operator gets the fused Pallas fast paths at every call site.

``PairwiseKernel`` computes entries on the fly from the d-dimensional data
for ANY registered ``KernelSpec`` (rbf, laplacian, matern32, polynomial,
linear, or user-registered — see ``repro.kernels.pairwise.specs``); with
``use_pallas=True`` blocks and matmul-shaped sweeps run the fused pairwise
Pallas template, whose kernel tiles never leave VMEM.  ``RBFKernel`` and
``LinearKernel`` survive as thin back-compat constructors over it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import sweep as sweep_lib
from repro.kernels.pairwise import specs as pairwise_specs
from repro.kernels.pairwise.specs import KernelSpec

# Back-compat aliases; the canonical definitions live in repro.core.sweep.
_PANEL_ELEMENT_BUDGET = sweep_lib.PANEL_ELEMENT_BUDGET
_panel_block_size = sweep_lib.panel_block_size


class SPSDOperator:
    n: int

    # -- pointwise access ---------------------------------------------------

    def block(self, row_idx: jnp.ndarray, col_idx: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def columns(self, idx: jnp.ndarray) -> jnp.ndarray:
        """K[:, idx] through a ``ColumnGatherPlan`` sweep over the selected
        columns.

        The default deliberately does NOT call ``block(arange(n), idx)``:
        that would eagerly build an n-length row index (and for most
        implementations gather a full copy of the backing data) on every
        gather.  Instead the panel engine walks row panels of the n × c
        *selected-column view* ``block(rows, idx)`` — row indices only ever
        exist per-panel inside the scan, peak memory is O(b·c), and exactly
        the n·c requested entries are evaluated (the entry count
        ``CountingOperator`` meters for a gather).  Implementations with a
        cheaper direct form (dense K, factored or pairwise kernels)
        override this.
        """
        idx = jnp.asarray(idx)
        c = idx.shape[0]
        (C,) = sweep_lib.sweep_panels(
            lambda rows: self.block(rows, idx), self.n, c,
            [sweep_lib.ColumnGatherPlan(jnp.arange(c))])
        return C

    def full(self) -> jnp.ndarray:
        raise NotImplementedError

    def diag(self) -> jnp.ndarray:
        raise NotImplementedError

    # -- fused-sweep capability protocol (see sweep.sweep_operator) ---------

    @property
    def precision(self) -> str:
        """Tile-evaluation precision policy of this operator's launches
        (``'f32'`` unless the backing spec says otherwise) — recorded on
        ``_last_sweep_route`` by the sweep engine."""
        return "f32"

    def supports_fused_matmat(self) -> bool:
        """True when ``fused_rows`` answers matmul-shaped plan bundles."""
        return False

    def fused_rows(self, row_idx: Optional[jnp.ndarray], Vs):
        """[K[row_idx, :] @ V for V in Vs] in one fused launch (row_idx=None
        -> all rows).  Only called when ``supports_fused_matmat()``."""
        raise NotImplementedError

    def supports_prefetch_slab(self) -> bool:
        """True when ``fused_slab`` can answer a contiguous row slab with a
        scalar-prefetch launch (no gathered row copy)."""
        return False

    def fused_slab(self, start_row, slab_len: int, Vs):
        """[K[start:start+slab_len, :] @ V for V in Vs] with the slab
        addressed inside the launch (``start_row`` may be traced).  Rows at
        indices ≥ n are clamp duplicates the caller must mask.  Only called
        when ``supports_prefetch_slab()``."""
        raise NotImplementedError

    def cross(self, Xq: jnp.ndarray, Vs):
        """[K(Xq, ·) @ V for V in Vs] for OUT-OF-SAMPLE query points Xq.

        The query-time primitive of the serving path (``repro.serve``): one
        rectangular launch between new points and this operator's data,
        contracted against every right-hand side.  Only data-backed operators
        (``PairwiseKernel``) can extend the kernel to unseen points; index-
        backed operators (``DenseSPSD``) have no notion of a query point.
        """
        raise NotImplementedError(
            f"{type(self).__name__} is not data-backed; out-of-sample "
            f"queries need a PairwiseKernel (or another operator that can "
            f"evaluate K(x_query, x_data) from raw points)")

    # -- streaming protocol -------------------------------------------------

    def sweep(self, plans: Sequence, block_size: Optional[int] = None,
              mesh=None):
        """Run the multi-product panel engine over this operator's rows.

        Each kernel row panel is materialized exactly once and fed to every
        plan (``repro.core.sweep``), so a whole bundle of products — K @ S,
        column gathers for C, Hutchinson probes, residual norms — costs one
        evaluation of each kernel tile.  A non-trivial ``mesh`` shards the
        panels over its data axes via ``shard_map`` (single-device meshes and
        ``mesh=None`` fall back to the sequential scan).  Route selection —
        fused Pallas launches for matmul-shaped bundles on capable
        operators, the blocked panel scan otherwise — happens in
        ``sweep.sweep_operator`` and is recorded on ``_last_sweep_route``.
        """
        return sweep_lib.sweep_operator(self, plans, block_size=block_size,
                                        mesh=mesh)

    def map_row_panels(self, fn, block_size: Optional[int] = None):
        """Apply ``fn(panel, row_idx, valid)`` to consecutive (b × n) row panels.

        ``panel`` is K[row_idx, :] (tail panels are padded by clamping to the
        last row; ``valid`` masks the padding).  Results are stacked along a
        leading block axis — reductions sum over it, matmats reshape it away.
        Runs under ``jax.lax.map`` so only one panel is live at a time.
        """
        n = self.n
        bs = sweep_lib.resolved_block_size(n, n, block_size)
        nblocks = -(-n // bs)
        starts = jnp.arange(nblocks) * bs
        cols = jnp.arange(n)

        def body(start):
            idx = start + jnp.arange(bs)
            valid = idx < n
            idx = jnp.clip(idx, 0, n - 1)
            return fn(self.block(idx, cols), idx, valid)

        return jax.lax.map(body, starts)

    def matmat(self, V: jnp.ndarray, block_size: Optional[int] = None,
               mesh=None) -> jnp.ndarray:
        """K @ V without materializing K (footnote-2 memory trick)."""
        V2 = V if V.ndim == 2 else V[:, None]
        (out,) = self.sweep([sweep_lib.MatmulPlan(V2)],
                            block_size=block_size, mesh=mesh)
        return out if V.ndim == 2 else out[:, 0]

    def frobenius_norm_sq(self, block_size: Optional[int] = None,
                          mesh=None) -> jnp.ndarray:
        """||K||_F² accumulated over row panels (never forms K)."""
        (out,) = self.sweep([sweep_lib.FrobeniusPlan()],
                            block_size=block_size, mesh=mesh)
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseSPSD(SPSDOperator):
    K: jnp.ndarray

    def tree_flatten(self):
        return (self.K,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self) -> int:
        return int(self.K.shape[0])

    def columns(self, idx):
        return jnp.take(self.K, idx, axis=1)

    def block(self, row_idx, col_idx):
        return jnp.take(jnp.take(self.K, row_idx, axis=0), col_idx, axis=1)

    def full(self):
        return self.K

    def diag(self):
        return jnp.diagonal(self.K)

    def matmat(self, V, block_size: Optional[int] = None, mesh=None):
        return self.K @ V

    def frobenius_norm_sq(self, block_size: Optional[int] = None, mesh=None):
        K32 = self.K.astype(jnp.float32)
        return jnp.sum(K32 * K32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PairwiseKernel(SPSDOperator):
    """K_ij = entry_fn(stat(x_i, x_j)) for ANY registered ``KernelSpec``.

    One operator class for the whole kernel family: the spec supplies the
    pairwise statistic + elementwise entry function
    (``repro.kernels.pairwise.specs``), and this class supplies the operator
    protocol around it — on-the-fly blocks, the O(n·d) ``diag()`` shortcut,
    the direct n×c column gather, and the fused-sweep capability hooks
    (``supports_fused_matmat`` / ``fused_rows``) the sweep engine routes
    through, so every kernel rides the same single-launch multi-RHS Pallas
    sweeps and shard_map row-slab claims that PR 2/3 built for RBF::

        from repro.kernels.pairwise import specs
        K = PairwiseKernel(X, specs.get_spec("laplacian", gamma=0.5),
                           use_pallas=True)
        ap = spsd.fast_model(K, key, c=100, s=400, s_sketch="gaussian")
    """

    X: jnp.ndarray
    spec: KernelSpec
    use_pallas: bool = False

    def tree_flatten(self):
        return (self.X,), (self.spec, self.use_pallas)

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = object.__new__(cls)          # skip subclass back-compat inits
        obj.X, obj.spec, obj.use_pallas = children[0], aux[0], aux[1]
        return obj

    @property
    def n(self) -> int:
        return int(self.X.shape[0])

    @property
    def precision(self) -> str:
        return self.spec.precision

    def with_precision(self, precision: str) -> "PairwiseKernel":
        """This operator under another tile-precision policy (same data,
        same routing; the spec variant is cached so jit keys stay stable)."""
        return PairwiseKernel(self.X, self.spec.with_precision(precision),
                              self.use_pallas)

    def l1_edges(self) -> Optional[jnp.ndarray]:
        """Sign-split segment table for the MXU l1dist route, or None.

        Built lazily (one host-side pass over X) and cached on the instance.
        None — the VPU reference route — for non-l1dist statistics, traced
        X (unflattened inside jit; such instances are ephemeral, nothing is
        cached), and data whose per-feature cardinality exceeds the segment
        budget (``signsplit.MAX_SEGMENTS``).
        """
        if self.spec.stat != "l1dist":
            return None
        if not hasattr(self, "_l1_edges_cache"):
            from repro.kernels.pairwise import signsplit
            plan = signsplit.build_plan(self.X)
            edges = None if plan is None else plan.edges
            if isinstance(self.X, jax.core.Tracer):
                return edges
            self._l1_edges_cache = edges
        return self._l1_edges_cache

    def l1_route(self, Xq=None) -> Optional[str]:
        """Which l1dist route this operator's launches take
        ('mxu_signsplit' | 'vpu_loop'; None for non-l1dist statistics) —
        surfaced in bench metadata so perf regressions are attributable.

        With ``Xq`` given, reports the QUERY-side routing decision for a
        ``cross(Xq, ...)`` launch: 'mxu_signsplit' only when a plan exists
        AND every query value lies on the plan's lattice
        (``signsplit.query_in_plan`` — the exactness contract for
        out-of-sample points), 'vpu_loop' otherwise.  After a ``cross``
        call the decision actually taken is recorded on
        ``_last_cross_l1_route``."""
        if self.spec.stat != "l1dist":
            return None
        if self.l1_edges() is None:
            return "vpu_loop"
        if Xq is None:
            return "mxu_signsplit"
        from repro.kernels.pairwise import signsplit
        return ("mxu_signsplit" if signsplit.query_in_plan(self.X, Xq)
                else "vpu_loop")

    def block(self, row_idx, col_idx):
        Xr = jnp.take(self.X, row_idx, axis=0)
        Xc = jnp.take(self.X, col_idx, axis=0)
        if self.use_pallas:
            from repro.kernels.pairwise import ops as pw_ops
            return pw_ops.kernel_block(self.spec, Xr, Xc,
                                       edges=self.l1_edges())
        return pairwise_specs.apply(self.spec, Xr, Xc, self.l1_edges())

    def columns(self, idx):
        # n·c entries straight from the data: no n-length row index, no row
        # gather — the columns ARE a (all-rows × selected-points) block.
        Xc = jnp.take(self.X, idx, axis=0)
        if self.use_pallas:
            from repro.kernels.pairwise import ops as pw_ops
            return pw_ops.kernel_block(self.spec, self.X, Xc,
                                       edges=self.l1_edges())
        return pairwise_specs.apply(self.spec, self.X, Xc, self.l1_edges())

    def full(self):
        return pairwise_specs.apply(self.spec, self.X, self.X,
                                    self.l1_edges())

    def diag(self):
        # O(n·d), touches no off-diagonal entry (constant for distance
        # statistics, row norms through entry_fn for the dot statistic).
        return pairwise_specs.diag(self.spec, self.X)

    def stat_operator(self) -> "PairwiseKernel":
        """Operator over the RAW pairwise statistic (identity entry
        function) — what per-spec bandwidth calibration quantiles stream
        from (``repro.kernels.pairwise.calibrate``).  Shares this operator's
        data, Pallas routing, and sweep machinery."""
        return PairwiseKernel(self.X, pairwise_specs.stat_only(self.spec),
                              self.use_pallas)

    # -- fused-sweep capability (sweep.sweep_operator routes through these) --

    def supports_fused_matmat(self) -> bool:
        return bool(self.use_pallas)

    def fused_rows(self, row_idx, Vs):
        """One rectangular multi-RHS Pallas launch for a contiguous row slab:
        the slab's kernel tiles are computed once in VMEM and contracted
        against every right-hand side (``row_idx=None`` -> the square
        all-rows launch)."""
        from repro.kernels.pairwise import ops as pw_ops
        Xr = self.X if row_idx is None else jnp.take(self.X, row_idx, axis=0)
        return pw_ops.kernel_matmat_multi_rows(self.spec, Xr, self.X, Vs,
                                               edges=self.l1_edges())

    def supports_prefetch_slab(self) -> bool:
        return bool(self.use_pallas)

    def fused_slab(self, start_row, slab_len, Vs):
        """The scalar-prefetch slab launch: the shard's contiguous row range
        is addressed inside the kernel via a prefetched row-block offset
        (``ops.kernel_matmat_multi_slab``), so no per-device row-slice copy
        of X is ever gathered."""
        from repro.kernels.pairwise import ops as pw_ops
        return pw_ops.kernel_matmat_multi_slab(
            self.spec, self.X, start_row, int(slab_len), Vs,
            edges=self.l1_edges())

    def cross(self, Xq, Vs):
        """[K(Xq, X) @ V for V in Vs] — the serving-path query launch.

        Exactly the ``fused_rows`` row-slab template with the slab rows
        replaced by the query points: the (n_q × n) rectangular kernel block
        is computed tile-by-tile in VMEM (``use_pallas``) and contracted
        against every head matrix in ONE launch, so a whole heterogeneous
        query bucket (KRR predictions + KPCA projections + feature maps)
        costs one evaluation of each cross-kernel entry.  The route — and
        the precision policy, as a ``+bf16_f32acc`` suffix — is recorded on
        ``_last_sweep_route`` like every sweep (``pallas_fused_rows`` /
        ``dense_rows``).

        The sign-split l1 route IS used for on-lattice queries: the plan's
        exactness contract covers out-of-sample points whose values all lie
        on this operator's own per-feature value lattice
        (``signsplit.query_in_plan`` — appended rows from the training
        pipeline are the common case), in which case the launch takes the
        MXU form (``+mxu_signsplit`` route suffix); off-lattice queries
        keep the VPU reference loop.  The decision is recorded on
        ``_last_cross_l1_route`` and queryable up front via
        ``l1_route(Xq)``.
        """
        from repro.kernels.pairwise import ops as pw_ops
        edges = None
        self._last_cross_l1_route = None
        if self.spec.stat == "l1dist":
            q_route = self.l1_route(Xq)
            self._last_cross_l1_route = q_route
            if q_route == "mxu_signsplit":
                edges = self.l1_edges()
        route = "pallas_fused_rows" if self.use_pallas else "dense_rows"
        if edges is not None:
            route += "+mxu_signsplit"
        if self.precision != "f32":
            route += "+" + self.precision
        self._last_sweep_route = route
        return pw_ops.kernel_matmat_multi_rows(
            self.spec, jnp.asarray(Xq), self.X, tuple(Vs),
            use_pallas=self.use_pallas, edges=edges)


@jax.tree_util.register_pytree_node_class
class RBFKernel(PairwiseKernel):
    """K_ij = exp(-|x_i - x_j|^2 / (2 sigma^2)) computed from X (n × d).

    Thin back-compat constructor over ``PairwiseKernel`` with the registry's
    ``rbf`` spec; all routing/streaming behavior lives in the base class.
    """

    def __init__(self, X: jnp.ndarray, sigma: float,
                 use_pallas: bool = False):
        PairwiseKernel.__init__(self, X, pairwise_specs.rbf(sigma),
                                use_pallas)

    @property
    def sigma(self) -> float:
        return self.spec.param("sigma")


@jax.tree_util.register_pytree_node_class
class LinearKernel(PairwiseKernel):
    """K = X X^T (n × n) from X (n × d).

    The ``linear`` spec through ``PairwiseKernel``, plus the factored
    O(n·d)-per-product fast paths the explicit X Xᵀ structure allows (a
    fused entry-wise sweep could never beat (Xᵀ V) first).
    """

    def __init__(self, X: jnp.ndarray, use_pallas: bool = False):
        PairwiseKernel.__init__(self, X, pairwise_specs.linear(), use_pallas)

    def columns(self, idx):
        return self.X @ jnp.take(self.X, idx, axis=0).T

    def matmat(self, V, block_size: Optional[int] = None, mesh=None):
        return self.X @ (self.X.T @ V)

    def frobenius_norm_sq(self, block_size: Optional[int] = None, mesh=None):
        # ||X X^T||_F² = ||X^T X||_F² — a d×d Gram, O(nd²) and O(d²) memory.
        G = self.X.astype(jnp.float32)
        G = G.T @ G
        return jnp.sum(G * G)


def as_operator(K) -> SPSDOperator:
    if isinstance(K, SPSDOperator):
        return K
    return DenseSPSD(jnp.asarray(K))
