"""Single-sweep multi-product panel engine (the Table-3 "#Entries" workhorse).

The paper's linear-in-n claim hinges on how few kernel entries are ever
*evaluated*.  PR 1's streaming substrate evaluated each (b × n) row panel once
per product — once for C = K P, once per S^T K S, once per error estimator —
so on-the-fly kernels paid 4-6× the entry cost the model actually needs.

This module fixes that with *panel plans*: small accumulator objects that all
consume the same panel.  ``sweep_panels`` walks the row panels exactly once
under ``jax.lax.scan`` and feeds every plan from the single materialization,
so one sweep yields an arbitrary set of products (K @ S for each sketch,
column gathers for C, diag/trace/Frobenius accumulators, Hutchinson probes,
adaptive residual norms) for one evaluation of each kernel tile.

A plan implements three methods::

    init(nrows, ncols)            -> carry (f32 pytree of zeros)
    update(carry, panel, idx, valid) -> carry   # MUST mask by ``valid``
    finalize(carry)               -> result

All carries are pure sums of per-panel contributions (row-indexed outputs are
scatter-added into zero-initialized buffers), which makes the engine
data-parallel for free: with a ``Mesh`` carrying a ``data`` axis
(``distributed/sharding.py``), the panel starts are partitioned across
devices with ``shard_map`` and the per-device partial carries are reduced
with ``psum``.  On a trivial (single-device / absent) mesh the engine falls
back to the plain sequential scan — bit-identical results either way, up to
float reassociation across devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.4.35 re-export; fall back to the experimental home
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map

# Row panels are capped at roughly this many f32 elements (b·ncols), so the
# streaming paths stay ~128 MB regardless of problem size.
PANEL_ELEMENT_BUDGET = 1 << 25


def panel_block_size(ncols: int, block_size: Optional[int]) -> int:
    if block_size is not None:
        return max(1, int(block_size))
    return max(128, min(4096, PANEL_ELEMENT_BUDGET // max(ncols, 1)))


def resolved_block_size(nrows: int, ncols: int, block_size: Optional[int],
                        data_parallel: int = 1) -> int:
    """The panel height a sweep actually uses.

    The budgeted (or requested) size, clamped to ``nrows`` so short operators
    pay no clamp padding.  With ``data_parallel`` > 1 the size is shrunk so
    the panel count is (as nearly as possible) a multiple of the device
    count — sentinel padding panels would each evaluate a full b×ncols block
    of throwaway kernel entries, so balancing by *resizing* keeps the sharded
    sweep's evaluated-entry count within one thin panel of the sequential
    sweep's.
    """
    bs = min(panel_block_size(ncols, block_size), max(nrows, 1))
    if data_parallel > 1:
        nblocks = -(-nrows // bs)
        target = data_parallel * (-(-nblocks // data_parallel))
        bs = -(-nrows // target)
    return bs


def num_panels(nrows: int, ncols: int, block_size: Optional[int],
               data_parallel: int = 1) -> int:
    """How many panels one sweep over ``nrows`` rows touches."""
    return -(-nrows // resolved_block_size(nrows, ncols, block_size,
                                           data_parallel))


def local_slab_rows(nrows: int, ncols: int, block_size: Optional[int],
                    data_parallel: int = 1) -> int:
    """Rows of the per-device slab a sharded sweep covers (panels · b).

    This is the height a ``slab_fn`` claim is invoked with on each shard —
    the contiguous local row range, including the ≤ one thin panel of clamp /
    sentinel padding the panel route would also evaluate.
    """
    bs = resolved_block_size(nrows, ncols, block_size, data_parallel)
    nblocks = -(-nrows // bs)
    if data_parallel > 1:
        nblocks += (-nblocks) % data_parallel
    return (nblocks // data_parallel) * bs


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MatmulPlan:
    """A @ V for V (ncols × m): the streaming matmat as a plan."""

    V: jnp.ndarray

    def tree_flatten(self):
        return (self.V,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    def init(self, nrows: int, ncols: int):
        return jnp.zeros((nrows, self.V.shape[1]), jnp.float32)

    def update(self, carry, panel, idx, valid):
        y = panel.astype(jnp.float32) @ self.V.astype(jnp.float32)
        return carry.at[idx].add(y * valid.astype(jnp.float32)[:, None])

    def finalize(self, carry):
        return carry


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ColumnGatherPlan:
    """A[:, col_idx] — the C = K P gather, free once the panel exists."""

    col_idx: jnp.ndarray

    def tree_flatten(self):
        return (self.col_idx,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    def init(self, nrows: int, ncols: int):
        return jnp.zeros((nrows, self.col_idx.shape[0]), jnp.float32)

    def update(self, carry, panel, idx, valid):
        y = jnp.take(panel, self.col_idx, axis=1).astype(jnp.float32)
        return carry.at[idx].add(y * valid.astype(jnp.float32)[:, None])

    def finalize(self, carry):
        return carry


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SketchRightPlan:
    """A S for a sketch object exposing ``S.right`` (SRHT / CountSketch)."""

    S: object
    s: int

    def tree_flatten(self):
        return (self.S,), (self.s,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])

    def init(self, nrows: int, ncols: int):
        return jnp.zeros((nrows, self.s), jnp.float32)

    def update(self, carry, panel, idx, valid):
        y = self.S.right(panel.astype(jnp.float32))
        return carry.at[idx].add(y * valid.astype(jnp.float32)[:, None])

    def finalize(self, carry):
        return carry


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FrobeniusPlan:
    """||A||_F² accumulated panel-by-panel."""

    def tree_flatten(self):
        return (), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls()

    def init(self, nrows: int, ncols: int):
        return jnp.zeros((), jnp.float32)

    def update(self, carry, panel, idx, valid):
        p32 = panel.astype(jnp.float32)
        return carry + jnp.sum(p32 * p32 * valid.astype(jnp.float32)[:, None])

    def finalize(self, carry):
        return carry


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DiagPlan:
    """diag(A) (square operators): one gather per panel row."""

    def tree_flatten(self):
        return (), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls()

    def init(self, nrows: int, ncols: int):
        return jnp.zeros((nrows,), jnp.float32)

    def update(self, carry, panel, idx, valid):
        d = jnp.take_along_axis(panel, idx[:, None], axis=1)[:, 0]
        return carry.at[idx].add(d.astype(jnp.float32)
                                 * valid.astype(jnp.float32))

    def finalize(self, carry):
        return carry


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ResidualFroPlan:
    """(||K - C M||_F², ||K||_F²) for a low-rank C M (M = U C^T) in one pass.

    ``C``: (nrows, c) f32, ``M``: (c, ncols) f32.
    """

    C: jnp.ndarray
    M: jnp.ndarray

    def tree_flatten(self):
        return (self.C, self.M), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def init(self, nrows: int, ncols: int):
        return (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

    def update(self, carry, panel, idx, valid):
        p32 = panel.astype(jnp.float32)
        resid = p32 - jnp.take(self.C, idx, axis=0) @ self.M
        v = valid.astype(jnp.float32)[:, None]
        return (carry[0] + jnp.sum(resid * resid * v),
                carry[1] + jnp.sum(p32 * p32 * v))

    def finalize(self, carry):
        return carry


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ProjResidualColNormPlan:
    """Adaptive-sampling residual column norms ||(I − Q Qᵀ) K||² in ONE pass.

    With Q an orthonormal basis of range(C) (zero-σ columns masked to 0),
    ||(I − QQᵀ) K e_j||² = ||K e_j||² − ||Qᵀ K e_j||², so one sweep
    accumulating per-column norms of K alongside the (q × ncols) product
    Qᵀ K replaces PR 1's matmat pass + residual pass per adaptive round.

    ``mask`` (optional, (nrows,)) row-masks the statistics so padded
    (ragged-batch) operators never leak padding rows into the norms.
    """

    Q: jnp.ndarray           # (nrows, q) f32, orthonormal (masked) columns
    mask: Optional[jnp.ndarray] = None   # (nrows,) 1.0 valid / 0.0 padding

    def tree_flatten(self):
        return (self.Q, self.mask), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def init(self, nrows: int, ncols: int):
        return (jnp.zeros((ncols,), jnp.float32),
                jnp.zeros((self.Q.shape[1], ncols), jnp.float32))

    def update(self, carry, panel, idx, valid):
        colnorms, QtK = carry
        rowm = valid.astype(jnp.float32)
        if self.mask is not None:
            rowm = rowm * jnp.take(self.mask.astype(jnp.float32), idx)
        p32 = panel.astype(jnp.float32) * rowm[:, None]
        colnorms = colnorms + jnp.sum(p32 * p32, axis=0)
        QtK = QtK + jnp.take(self.Q, idx, axis=0).T @ p32
        return (colnorms, QtK)

    def finalize(self, carry):
        colnorms, QtK = carry
        return jnp.maximum(colnorms - jnp.sum(QtK * QtK, axis=0), 0.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GramPlan:
    """Σ panelᵀ panel — the blocked Gram pass (R Rᵀ over column panels)."""

    dim: int

    def tree_flatten(self):
        return (), (self.dim,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0])

    def init(self, nrows: int, ncols: int):
        return jnp.zeros((self.dim, self.dim), jnp.float32)

    def update(self, carry, panel, idx, valid):
        p32 = panel.astype(jnp.float32) * valid.astype(jnp.float32)[:, None]
        return carry + p32.T @ p32

    def finalize(self, carry):
        return carry


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RowQuadFormPlan:
    """q_i = panel_i W panel_iᵀ per row — blocked leverage-score scoring."""

    W: jnp.ndarray           # (ncols, ncols) f32 (small: r × r)

    def tree_flatten(self):
        return (self.W,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    def init(self, nrows: int, ncols: int):
        return jnp.zeros((nrows,), jnp.float32)

    def update(self, carry, panel, idx, valid):
        p32 = panel.astype(jnp.float32)
        q = jnp.sum((p32 @ self.W) * p32, axis=1)
        return carry.at[idx].add(q * valid.astype(jnp.float32))

    def finalize(self, carry):
        return carry


# ---------------------------------------------------------------------------
# route selection (the operator capability protocol)
# ---------------------------------------------------------------------------
#
# PR 3 hardwired the fused-Pallas routing decision inside ``RBFKernel.sweep``;
# it now lives here, behind two small capability hooks any operator may
# implement:
#
#     supports_fused_matmat() -> bool
#         True when the operator can answer a whole matmul-shaped plan bundle
#         with one fused launch (e.g. a Pallas-backed ``PairwiseKernel``).
#     fused_rows(row_idx, Vs) -> tuple[jnp.ndarray, ...]
#         [A[row_idx, :] @ V for V in Vs] for a contiguous row slab
#         (``row_idx=None`` means all rows — the square single-device case).
#
# Every sweep consumer (``fast_model``, ``fast_cur``, eig/error metrics,
# adaptive sampling) goes through ``sweep_operator`` and therefore gets the
# fast path for every capable operator with zero per-call-site changes.  The
# chosen route is recorded on ``op._last_sweep_route`` ('pallas_fused' |
# 'pallas_fused_sharded' | 'panel') for instrumentation
# (``CountingOperator.last_route``).

def is_matmul_shaped(plans: Sequence) -> bool:
    """True when every plan reduces to A @ V for some dense right-hand side
    (matmats as-is; column gathers as one-hot columns)."""
    plans = list(plans)
    return bool(plans) and all(
        isinstance(p, (MatmulPlan, ColumnGatherPlan)) for p in plans)


def fused_right_hand_sides(plans: Sequence, ncols: int):
    """Dense f32 right-hand sides for a matmul-shaped plan bundle.

    Column gathers ride along as one-hot right-hand sides (exact: each
    output entry is one A entry times 1.0).
    """
    return tuple(
        p.V.astype(jnp.float32) if isinstance(p, MatmulPlan)
        else jax.nn.one_hot(p.col_idx, ncols, dtype=jnp.float32).T
        for p in plans)


def sweep_operator(op, plans: Sequence, block_size: Optional[int] = None,
                   mesh: Optional[Mesh] = None):
    """Run a plan bundle over a square operator's rows, fastest route first.

    Matmul-shaped bundles on a capable operator collapse into ONE fused
    multi-RHS launch per device: a single square launch on a trivial mesh
    ('pallas_fused'), or — on a non-trivial mesh — a per-shard claim through
    the engine's ``slab_fn`` hook, where each device runs one rectangular
    row-slab launch and the partial carries are psum-reduced exactly like the
    panel route ('pallas_fused_sharded').  Everything else walks the blocked
    panel scan over ``op.block`` ('panel').
    """
    plans = list(plans)
    n = op.n
    fused = op.supports_fused_matmat() and is_matmul_shaped(plans)
    # the precision policy rides the route string as a suffix ('pallas_fused'
    # stays 'pallas_fused' under the default f32 policy, so route assertions
    # and startswith-based metering are unchanged)
    prec = getattr(op, "precision", "f32")
    suffix = "" if prec == "f32" else "+" + prec
    op._last_slab_mode = None          # only sharded fused claims set this
    if fused and mesh_data_size(mesh) <= 1:
        op._last_sweep_route = "pallas_fused" + suffix
        return list(op.fused_rows(None, fused_right_hand_sides(plans, n)))
    if fused:
        op._last_sweep_route = "pallas_fused_sharded" + suffix
        Vs = fused_right_hand_sides(plans, n)
        use_slab = op.supports_prefetch_slab()
        op._last_slab_mode = "prefetch" if use_slab else "gather"

        def slab_fn(row_idx, valid):
            # One rectangular launch for this shard's row slab: only the
            # slab's kernel tiles are evaluated, each exactly once.  The
            # scalar-prefetch claim addresses the slab inside the launch
            # (row_idx[0] is the slab start — clamped starts only occur on
            # all-sentinel shards, whose contributions ``valid`` zeroes);
            # the gather claim materializes the row slice.
            if use_slab:
                outs = op.fused_slab(row_idx[0], row_idx.shape[0], Vs)
            else:
                outs = op.fused_rows(row_idx, Vs)
            v = valid.astype(jnp.float32)[:, None]
            return tuple(p.init(n, n).at[row_idx].add(o * v)
                         for p, o in zip(plans, outs))

        # panel_fn=None: the claim is unconditional, the scan never runs
        return sweep_panels(None, n, n, plans,
                            block_size=block_size, mesh=mesh, slab_fn=slab_fn)
    op._last_sweep_route = "panel"
    cols = jnp.arange(n)
    return sweep_panels(lambda idx: op.block(idx, cols), n, n, plans,
                        block_size=block_size, mesh=mesh)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _mesh_data_axes(mesh: Optional[Mesh]):
    """The ('pod','data') subset present in ``mesh`` — lazy import so this
    module stays importable without the distributed package."""
    if mesh is None:
        return ()
    from repro.distributed.sharding import data_axes
    return data_axes(mesh)


def mesh_data_size(mesh: Optional[Mesh]) -> int:
    """Total data-parallel width of ``mesh`` (1 for None / trivial meshes)."""
    axes = _mesh_data_axes(mesh)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def sweep_panels(panel_fn, nrows: int, ncols: int, plans: Sequence,
                 block_size: Optional[int] = None,
                 mesh: Optional[Mesh] = None,
                 slab_fn=None):
    """Apply every plan to each (b × ncols) row panel in a single pass.

    ``panel_fn(idx)`` materializes rows ``idx`` (a (b,) int array; tail panels
    are clamped to the last row and masked via ``valid``).  Returns
    ``[plan.finalize(carry) for plan in plans]``.  ``panel_fn`` may be None
    when ``slab_fn`` is provided (an unconditional claim — the panel scan is
    then unreachable).

    With a non-trivial ``mesh`` the panel starts are partitioned over the
    mesh's data axes via ``shard_map``; each device scans its local panels and
    the additive carries are ``psum``-reduced, so results match the
    single-device sweep to float-reassociation accuracy.

    ``slab_fn`` is the per-shard fast-path hook: an operator that can produce
    a whole contiguous row slab's worth of carries in one shot (e.g. the
    fused multi-RHS Pallas launch of ``PairwiseKernel``) claims the plan
    bundle by
    passing ``slab_fn(row_idx, valid) -> tuple(carry per plan)``.  ``row_idx``
    is the shard's full local row range — ``local_slab_rows`` rows, clamped
    into ``[0, nrows)`` with ``valid`` masking clamp/sentinel padding — and
    the returned carries must equal what the panel scan would have produced
    (row-indexed outputs scatter-added into ``plan.init`` zeros, masked by
    ``valid``).  The psum reduction and finalize step are shared with the
    panel route, so a claim changes the schedule, never the contract.
    """
    plans = list(plans)
    dp = mesh_data_size(mesh)
    bs = resolved_block_size(nrows, ncols, block_size, dp)
    nblocks = -(-nrows // bs)

    def local_sweep(starts):
        def body(carry, start):
            idx = start + jnp.arange(bs)
            valid = idx < nrows
            idx = jnp.clip(idx, 0, nrows - 1)
            panel = panel_fn(idx)
            carry = tuple(p.update(c, panel, idx, valid)
                          for p, c in zip(plans, carry))
            return carry, None
        init = tuple(p.init(nrows, ncols) for p in plans)
        carry, _ = jax.lax.scan(body, init, starts)
        return carry

    def local_carry(starts_local, npanels_local):
        if slab_fn is None:
            return local_sweep(starts_local)
        # starts are contiguous ascending multiples of bs (sentinels == nrows
        # sort last), so the shard's panels tile exactly the row range
        # [starts_local[0], starts_local[0] + npanels_local·bs) ∩ [0, nrows).
        idx = starts_local[0] + jnp.arange(npanels_local * bs)
        valid = idx < nrows
        idx = jnp.clip(idx, 0, nrows - 1)
        return tuple(slab_fn(idx, valid))

    starts = jnp.arange(nblocks) * bs
    if dp > 1:
        axes = _mesh_data_axes(mesh)
        # resolved_block_size already rebalanced the panel count to (near) a
        # multiple of dp; any remainder is padded with sentinel starts == n
        # (``valid`` all-False -> exact zero contributions, ≤ dp-1 thin
        # panels of waste).
        pad = (-nblocks) % dp
        if pad:
            starts = jnp.concatenate(
                [starts, jnp.full((pad,), nrows, starts.dtype)])
        per_dev = starts.shape[0] // dp

        def sharded(starts_local):
            carry = local_carry(starts_local, per_dev)
            return jax.tree_util.tree_map(
                lambda x: jax.lax.psum(x, axes), carry)

        carry = _shard_map(sharded, mesh=mesh,
                           in_specs=P(axes), out_specs=P(),
                           check_rep=False)(starts)
    else:
        carry = local_carry(starts, nblocks)
    return [p.finalize(c) for p, c in zip(plans, carry)]
