"""Leverage scores and coherence (paper §2)."""
from __future__ import annotations

import jax.numpy as jnp


def _default_rcond(shape) -> float:
    """numpy-style cutoff: max(m, n) * eps(f32).  1e-10 keeps numerically-zero
    singular values in f32 and destroys the pinv — see tests/test_spsd_properties."""
    return max(shape) * float(jnp.finfo(jnp.float32).eps)


def row_leverage_scores(A: jnp.ndarray, rcond: float = None) -> jnp.ndarray:
    """l_i = ||u_i:||^2 where A = U Σ V^T is the condensed SVD.

    Computed from the thin SVD in f32.  Sum of scores equals rank(A).
    """
    rcond = _default_rcond(A.shape) if rcond is None else rcond
    A32 = A.astype(jnp.float32)
    u, s, _ = jnp.linalg.svd(A32, full_matrices=False)
    cutoff = rcond * jnp.max(s)
    mask = (s > cutoff).astype(jnp.float32)
    return jnp.sum((u * mask[None, :]) ** 2, axis=1)


def column_leverage_scores(A: jnp.ndarray, rcond: float = None) -> jnp.ndarray:
    return row_leverage_scores(A.T, rcond)


def _gram_leverage(panel_fn, nrows: int, dim: int, block_size, mesh):
    """l_i = p_i (Σ panelsᵀ panels)† p_iᵀ over (b × dim) panels: a blocked
    Gram pass then a blocked quadratic-form pass through the sweep engine
    (``repro.core.sweep``) — peak memory O(b·dim + dim²), shardable."""
    from repro.core.sweep import GramPlan, RowQuadFormPlan, sweep_panels
    (G,) = sweep_panels(panel_fn, nrows, dim, [GramPlan(dim)],
                        block_size=block_size, mesh=mesh)
    W = pinv(0.5 * (G + G.T))
    (lev,) = sweep_panels(panel_fn, nrows, dim, [RowQuadFormPlan(W)],
                          block_size=block_size, mesh=mesh)
    return lev


def row_leverage_scores_gram(A: jnp.ndarray, block_size: int = None,
                             mesh=None) -> jnp.ndarray:
    """Row leverage scores of a tall A (m × c) via a blocked Gram AᵀA pass.

    l_i = a_i (AᵀA)† a_iᵀ — identical to the SVD route (for σ > 0 masked
    consistently) but no m×c transposed copy or O(m·c²) SVD workspace is
    ever staged.
    """
    m, cdim = A.shape
    return _gram_leverage(lambda idx: jnp.take(A, idx, axis=0), m, cdim,
                          block_size, mesh)


def column_leverage_scores_gram(R: jnp.ndarray, block_size: int = None,
                                mesh=None) -> jnp.ndarray:
    """Column (row-space) leverage scores of a wide R (r × n), streamed.

    The CUR R-side scores: l_j = R_:jᵀ (R Rᵀ)† R_:j.  PR 1 densified the
    n × r transpose and ran an SVD — fine at paper scale, not at n ≫ 10⁵;
    here the Gram R Rᵀ accumulates over (b × r) column panels instead.
    """
    r, n = R.shape
    return _gram_leverage(lambda idx: jnp.take(R, idx, axis=1).T, n, r,
                          block_size, mesh)


def row_coherence(A: jnp.ndarray) -> jnp.ndarray:
    """mu(A) = (m / rank) * max_i l_i  in [1, m]."""
    lev = row_leverage_scores(A)
    rank = jnp.sum(lev)
    return A.shape[0] / rank * jnp.max(lev)


def pinv(A: jnp.ndarray, rcond: float = None) -> jnp.ndarray:
    """Moore-Penrose inverse via f32 SVD (small s×c / c×c blocks only)."""
    rcond = _default_rcond(A.shape) if rcond is None else rcond
    A32 = A.astype(jnp.float32)
    u, s, vt = jnp.linalg.svd(A32, full_matrices=False)
    cutoff = rcond * jnp.max(s)
    sinv = jnp.where(s > cutoff, 1.0 / s, 0.0)
    return (vt.T * sinv[None, :]) @ u.T


def orthonormal_basis(A: jnp.ndarray, rcond: float = None) -> jnp.ndarray:
    """Orthonormal basis of range(A) (Algorithm 1, step 3 'optional')."""
    A32 = A.astype(jnp.float32)
    u, s, _ = jnp.linalg.svd(A32, full_matrices=False)
    return u  # zero-singular-value columns contribute nothing downstream
