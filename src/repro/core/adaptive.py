"""uniform+adaptive² column selection (Wang, Luo, Zhang 2016), used by Fig. 4.

Round 0: c/3 columns uniformly.  Rounds 1-2: c/3 columns each, sampled with
probability proportional to the squared residual column norms
||k_:j − C C† k_:j||² of the current sketch — ONE panel sweep per round via
the projection identity (see ``repro.core.selection``).

The implementation lives in the pluggable selection subsystem
(``selection.UniformAdaptive2Policy``); this module keeps the historical
entry points.  Since PR 5 the adaptive draws zero out already-selected
indices and sample without replacement, so the returned index set is always
duplicate-free (the old ``replace=True`` draw could duplicate a dominant
residual column into C — wasted budget, rank-deficient C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import selection as selection_lib
from repro.core.selection import (_masked_orthonormal_basis,  # noqa: F401
                                  residual_column_norms)


def _residual_column_norms(Kop, idx: jnp.ndarray, block_size=None,
                           mesh=None) -> jnp.ndarray:
    """||(I − C C†) K||² column norms in one panel sweep (back-compat name)."""
    return residual_column_norms(Kop, idx, block_size=block_size, mesh=mesh)


def uniform_adaptive2_indices(K, key: jax.Array, c: int, block_size=None,
                              mesh=None) -> jnp.ndarray:
    """Return c distinct column indices via uniform + two adaptive rounds."""
    pol = selection_lib.UniformAdaptive2Policy()
    return pol.select(K, key, c, block_size=block_size, mesh=mesh)
