"""uniform+adaptive² column selection (Wang, Luo, Zhang 2016), used by Fig. 4.

Round 0: c/3 columns uniformly.  Rounds 1-2: c/3 columns each, sampled with
probability proportional to the squared residual column norms
||k_:j − C C† k_:j||² of the current sketch.  Needs K (or an operator whose
columns/matmat are cheap) — hence Fig. 4's caveat that adaptive sampling gives
up the fast model's time advantage but improves C itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernelop import as_operator
from repro.core.leverage import pinv


def _residual_column_norms(Kop, idx: jnp.ndarray,
                           block_size=None) -> jnp.ndarray:
    """||(I − C C†) K||² column norms, accumulated over row panels.

    C† K = (K (C†)^T)^T by symmetry of K, so one streaming ``matmat`` plus one
    ``map_row_panels`` pass computes the norms without materializing K.
    """
    C = Kop.columns(idx).astype(jnp.float32)
    Cp = pinv(C)                                       # (c, n)
    CpK = Kop.matmat(Cp.T, block_size=block_size).T    # (c, n) == C† K

    def fn(panel, ridx, valid):
        resid = panel.astype(jnp.float32) - jnp.take(C, ridx, axis=0) @ CpK
        v = valid.astype(jnp.float32)[:, None]
        return jnp.sum(resid * resid * v, axis=0)      # per-column partials

    parts = Kop.map_row_panels(fn, block_size)         # (nblocks, n)
    return jnp.sum(parts, axis=0)


def uniform_adaptive2_indices(K, key: jax.Array, c: int) -> jnp.ndarray:
    """Return c column indices via uniform + two adaptive rounds."""
    Kop = as_operator(K)
    n = Kop.n
    c0 = c - 2 * (c // 3)
    c1 = c // 3
    k0, k1, k2 = jax.random.split(key, 3)

    idx = jax.random.choice(k0, n, shape=(c0,), replace=False)
    for kk, extra in ((k1, c1), (k2, c1)):
        if extra == 0:
            continue
        norms = _residual_column_norms(Kop, idx)
        p = norms / jnp.maximum(jnp.sum(norms), 1e-30)
        new = jax.random.choice(kk, n, shape=(extra,), replace=True, p=p)
        idx = jnp.concatenate([idx, new])
    return idx
