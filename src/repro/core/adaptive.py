"""uniform+adaptive² column selection (Wang, Luo, Zhang 2016), used by Fig. 4.

Round 0: c/3 columns uniformly.  Rounds 1-2: c/3 columns each, sampled with
probability proportional to the squared residual column norms
||k_:j − C C† k_:j||² of the current sketch.

Each adaptive round costs ONE sweep of the panel engine: with Q an
orthonormal basis of range(C) (an O(n·c²) SVD that touches no kernel
entries), the residual norms decompose as

    ||(I − Q Qᵀ) K e_j||² = ||K e_j||² − ||Qᵀ K e_j||²,

so a single pass accumulating the per-column norms of K alongside Qᵀ K
replaces PR 1's two passes per round (a streaming C† K matmat plus a
residual-norm pass).  Pass a ``mesh`` to shard the sweep across devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernelop import as_operator
from repro.core.sweep import ProjResidualColNormPlan


def _masked_orthonormal_basis(C: jnp.ndarray) -> jnp.ndarray:
    """Left singular vectors of C with zero-σ columns zeroed out, so Q Qᵀ is
    the orthogonal projector onto range(C) even when C is rank-deficient."""
    C32 = C.astype(jnp.float32)
    u, s, _ = jnp.linalg.svd(C32, full_matrices=False)
    cutoff = max(C.shape) * jnp.finfo(jnp.float32).eps * jnp.max(s)
    return u * (s > cutoff).astype(jnp.float32)[None, :]


def _residual_column_norms(Kop, idx: jnp.ndarray, block_size=None,
                           mesh=None) -> jnp.ndarray:
    """||(I − C C†) K||² column norms in one panel sweep."""
    C = Kop.columns(idx)                       # n·c entries, not a sweep
    Q = _masked_orthonormal_basis(C)
    (norms,) = Kop.sweep([ProjResidualColNormPlan(Q)],
                         block_size=block_size, mesh=mesh)
    return norms


def uniform_adaptive2_indices(K, key: jax.Array, c: int, block_size=None,
                              mesh=None) -> jnp.ndarray:
    """Return c column indices via uniform + two adaptive rounds."""
    Kop = as_operator(K)
    n = Kop.n
    c0 = c - 2 * (c // 3)
    c1 = c // 3
    k0, k1, k2 = jax.random.split(key, 3)

    idx = jax.random.choice(k0, n, shape=(c0,), replace=False)
    for kk, extra in ((k1, c1), (k2, c1)):
        if extra == 0:
            continue
        norms = _residual_column_norms(Kop, idx, block_size=block_size,
                                       mesh=mesh)
        p = norms / jnp.maximum(jnp.sum(norms), 1e-30)
        new = jax.random.choice(kk, n, shape=(extra,), replace=True, p=p)
        idx = jnp.concatenate([idx, new])
    return idx
