"""Instrumented operator wrappers — the paper's Table-3 "#Entries" meter.

``CountingOperator`` wraps any ``SPSDOperator`` and records how many kernel
entries each pipeline actually *evaluates*, which is the quantity the
paper's efficiency claims are about.  Counters are plain Python ints bumped
at call/trace time (every public entry point in this repo invokes the
operator protocol from Python, so one ``sweep`` call == one pass over the
panels regardless of how ``jax.lax.scan`` re-executes the traced body):

- ``sweeps``  : panel-engine passes (each evaluates every row panel once)
- ``panels``  : total row panels materialized across those sweeps
- ``entries`` : kernel entries evaluated (sweeps count nblocks·b·n incl.
                clamp padding; direct block/columns/diag calls count their
                exact extent).  The fused Pallas routes evaluate the same
                row extent — per shard, one rectangular slab of
                ``local_slab_rows`` rows instead of a panel scan — so the
                count model holds for them unchanged.
- ``fused_sweeps`` : the subset of ``sweeps`` the inner operator claimed
                with a fused Pallas launch (single-device multi-RHS or the
                per-shard slab route); ``last_route`` records the most
                recent routing decision verbatim (including any
                ``+bf16_f32acc`` precision suffix)
- ``bf16_sweeps`` : the subset of sweeps/cross launches evaluated under a
                non-f32 tile-precision policy; ``last_precision`` records
                the policy of the most recent launch and ``last_slab_mode``
                whether a sharded claim used the scalar-prefetch slab
                launch ('prefetch') or the gathered row copy ('gather')
- ``append_sweeps`` : thin rectangular maintenance launches
                (``append_cross``) from the incremental append-row path
                (``repro.serve.incremental``) — metered separately from
                query-side ``cross_sweeps`` so the serving invariant
                (cross launches == query buckets) and the maintenance
                invariant (ONE thin sweep per appended batch, O(b·c)
                entries) are independently assertable
- ``blocks`` / ``columns`` / ``diags`` / ``fulls`` : direct-access calls

Used by the parity/entry-count tests (fast_model + streaming error must stay
≤ 2 sweeps; the fused ``fast_model_with_error`` at exactly 1) and by
``benchmarks/bench_time.py --streaming`` to print measured entry counts
alongside wall time.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core import sweep as sweep_lib
from repro.core.kernelop import SPSDOperator


class CountingOperator(SPSDOperator):
    """Transparent counting proxy around an ``SPSDOperator``."""

    def __init__(self, inner: SPSDOperator):
        self.inner = inner
        self.reset()

    def reset(self):
        self.counts = {"sweeps": 0, "panels": 0, "entries": 0,
                       "fused_sweeps": 0, "cross_sweeps": 0,
                       "append_sweeps": 0, "bf16_sweeps": 0,
                       "blocks": 0, "columns": 0, "diags": 0, "fulls": 0}
        self.last_route = None
        self.last_precision = None
        self.last_slab_mode = None
        self._in_sweep = False

    @property
    def n(self) -> int:
        return self.inner.n

    def rebind(self, inner: SPSDOperator) -> "CountingOperator":
        """Swap the wrapped operator WITHOUT resetting the meters.

        The incremental-maintenance path grows an operator's corpus between
        rounds (appended rows); long-lived wrappers — a serving replica's
        counter, the budget-regression harness — rebind to the grown
        operator so cumulative counts stay comparable across the growth,
        while every per-call count (``_count_sweep`` panels/entries,
        ``cross``'s n_q·n) reads ``self.n`` at call time and therefore
        tracks the live corpus automatically."""
        self.inner = inner
        return self

    # -- direct access (counted exactly) ------------------------------------

    def block(self, row_idx, col_idx):
        if not self._in_sweep:
            self.counts["blocks"] += 1
            self.counts["entries"] += int(row_idx.shape[0]) * int(col_idx.shape[0])
        return self.inner.block(row_idx, col_idx)

    def columns(self, idx):
        self.counts["columns"] += 1
        self.counts["entries"] += self.n * int(idx.shape[0])
        return self.inner.columns(idx)

    def diag(self):
        self.counts["diags"] += 1
        self.counts["entries"] += self.n
        return self.inner.diag()

    def full(self):
        self.counts["fulls"] += 1
        self.counts["entries"] += self.n * self.n
        return self.inner.full()  # repro: allow-dense(counting passthrough — the meter itself)

    # -- streaming protocol (counted per pass) ------------------------------

    def _count_sweep(self, block_size, mesh=None):
        dp = sweep_lib.mesh_data_size(mesh)
        bs = sweep_lib.resolved_block_size(self.n, self.n, block_size, dp)
        nblocks = -(-self.n // bs)
        if dp > 1:
            nblocks += (-nblocks) % dp       # sentinel padding panels
        self.counts["sweeps"] += 1
        self.counts["panels"] += nblocks
        self.counts["entries"] += nblocks * bs * self.n

    def sweep(self, plans: Sequence, block_size: Optional[int] = None,
              mesh=None):
        self._count_sweep(block_size, mesh)
        self._in_sweep = True
        try:
            # delegate to the inner op so its fast paths (e.g. the fused
            # Pallas multi-RHS launch) stay engaged under instrumentation
            out = self.inner.sweep(plans, block_size=block_size, mesh=mesh)
        finally:
            self._in_sweep = False
        # attribute the route only on success, so a sweep that raised before
        # dispatching can never inherit the previous call's routing decision
        self._attribute(getattr(self.inner, "_last_sweep_route", "panel"))
        return out

    def _attribute(self, route: str):
        self.last_route = route
        self.last_precision = getattr(self.inner, "precision", "f32")
        self.last_slab_mode = getattr(self.inner, "_last_slab_mode", None)
        if route.startswith("pallas_fused"):
            self.counts["fused_sweeps"] += 1
        if self.last_precision != "f32":
            self.counts["bf16_sweeps"] += 1

    def cross(self, Xq, Vs):
        """Query-side rectangular launches (``repro.serve``): one
        ``cross_sweeps`` tick and exactly n_q · n evaluated entries per call
        — the serving acceptance tests assert one tick per query bucket."""
        self.counts["cross_sweeps"] += 1
        self.counts["entries"] += int(Xq.shape[0]) * self.n
        out = self.inner.cross(Xq, Vs)
        self._attribute(getattr(self.inner, "_last_sweep_route",
                                "dense_rows"))
        return out

    def append_cross(self, Xq, Vs):
        """The incremental append-row maintenance launch: same rectangular
        shape as ``cross`` but metered as ``append_sweeps`` (not
        ``cross_sweeps``), so the O(b·c) absorb claim — ONE thin sweep of
        exactly n_new · n entries per appended batch, zero full sweeps — is
        asserted independently of the query-side launch accounting."""
        self.counts["append_sweeps"] += 1
        self.counts["entries"] += int(Xq.shape[0]) * self.n
        inner_call = getattr(self.inner, "append_cross", self.inner.cross)
        out = inner_call(Xq, Vs)
        self._attribute(getattr(self.inner, "_last_sweep_route",
                                "dense_rows"))
        return out

    def map_row_panels(self, fn, block_size: Optional[int] = None):
        self._count_sweep(block_size)
        self._in_sweep = True
        try:
            return self.inner.map_row_panels(fn, block_size)
        finally:
            self._in_sweep = False
