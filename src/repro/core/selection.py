"""Streaming column/row selection policies (the SelectionPolicy registry).

Which columns enter C (and rows enter R, for CUR) dominates Nyström/prototype
accuracy (Gittens & Mahoney 2013; Wang & Zhang 2014), yet selection is only
*linear-time* if it costs no more kernel-entry passes than the sketch itself.
This module gives selection the same pluggable, sweep-metered treatment the
kernels got: a ``SelectionPolicy`` declares its per-round sweep budget up
front, performs every kernel access through the operator protocol (``columns``
gathers + panel-engine ``sweep``s — never ``full()``), and is registered by
name so ``fast_model`` / ``fast_model_batched`` / ``fast_cur`` pick any policy
up with a ``selection=`` string and zero call-site changes.

Built-in policies (budgets are *exact* — asserted by ``CountingOperator``
regression tests in ``tests/test_sweep.py``):

=================  ======  ===============  ========  =======================
policy             rounds  sweeps / round   gathers   selection rule
=================  ======  ===============  ========  =======================
uniform            1       0                0         uniform w/o replacement
leverage           1       0                1 pilot   p_i ∝ approx leverage of
                                                      a uniform n×p pilot
                                                      panel (blocked Gram)
uniform_adaptive2  2       1                2         round 0 uniform, then
                                                      p_j ∝ residual column
                                                      norms (one
                                                      ``ProjResidualColNorm``
                                                      sweep per round)
=================  ======  ===============  ========  =======================

Every policy samples **without replacement** and zeroes the probabilities of
already-selected indices between adaptive rounds, so the returned index set is
always duplicate-free (duplicated columns waste budget and make C rank
deficient — the PR-5 bugfix).  ``mask`` restricts selection to the valid rows
of a padded (ragged-batch) operator; all sampling and residual statistics are
masked consistently.

Registering a custom policy::

    from repro.core import selection

    @selection.register_policy("first_k")
    def first_k() -> selection.SelectionPolicy:
        class FirstK(selection.SelectionPolicy):
            name, rounds, sweeps_per_round, gathers = "first_k", 1, 0, 0
            def select(self, K, key, c, **kw):
                return jnp.arange(c)
        return FirstK()

    ap = spsd.fast_model(K, key, c=100, s=400, selection="first_k")
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sweep as sweep_lib
from repro.core.kernelop import as_operator
from repro.core.leverage import row_leverage_scores_gram


class SelectionPolicy:
    """Protocol: pick ``c`` column indices of a square SPSD operator.

    Subclasses declare their kernel-access budget as class/instance fields —
    ``rounds`` (selection rounds that touch the kernel), ``sweeps_per_round``
    (panel-engine passes each such round costs), and ``gathers`` (n×c-panel
    ``columns`` gathers beyond the C panel the caller extracts) — and MUST
    meet it exactly: the budget regression tests meter every policy with
    ``CountingOperator``.
    """

    name: str = "?"
    rounds: int = 1
    sweeps_per_round: int = 0
    gathers: int = 0

    def sweep_budget(self) -> int:
        """Total declared panel-engine sweeps for one ``select`` call."""
        return self.rounds * self.sweeps_per_round

    def select(self, K, key: jax.Array, c: int, *,
               block_size: Optional[int] = None, mesh=None,
               mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Return ``c`` distinct column indices of ``K`` (mask-aware)."""
        raise NotImplementedError

    def select_pair(self, K, key: jax.Array, c: int, r: int, *,
                    block_size: Optional[int] = None, mesh=None,
                    mask: Optional[jnp.ndarray] = None):
        """Two independent index sets from one call (CUR's C and R sides).

        The default is two ``select`` calls — 2× the declared budget.
        Policies whose scores serve both sides of a symmetric operator
        (leverage) override this to share the scoring pass.
        """
        kc, kr = jax.random.split(key)
        kw = dict(block_size=block_size, mesh=mesh, mask=mask)
        return self.select(K, kc, c, **kw), self.select(K, kr, r, **kw)


def _uniform_indices(key: jax.Array, n: int, count: int,
                     mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Uniform sampling without replacement, restricted to ``mask``'s valid
    rows when given (p_i = 1/n_valid) — the historical ``fast_model`` P
    sampler, kept bit-identical so default seeds are unchanged."""
    if mask is None:
        return jax.random.choice(key, n, shape=(count,), replace=False)
    return jax.random.choice(key, n, shape=(count,), replace=False,
                             p=mask / jnp.sum(mask))


def _weighted_indices_without_replacement(
        key: jax.Array, weights: jnp.ndarray, count: int,
        allowed: jnp.ndarray) -> jnp.ndarray:
    """Sample ``count`` distinct indices with p ∝ ``weights`` on ``allowed``.

    Disallowed indices get exactly zero probability.  A tiny relative floor is
    added on the allowed set so the support never collapses below ``count``
    nonzero entries (e.g. residual weights that are exactly zero once C spans
    the whole column space fall back to uniform-over-allowed).
    """
    allowed = allowed.astype(jnp.float32)
    w = jnp.maximum(weights.astype(jnp.float32), 0.0) * allowed
    floor = (1e-9 * jnp.max(w) + 1e-30) * allowed
    p = w + floor
    return jax.random.choice(key, w.shape[0], shape=(count,), replace=False,
                             p=p / jnp.sum(p))


@dataclasses.dataclass
class UniformPolicy(SelectionPolicy):
    """Uniform sampling without replacement — 0 sweeps, 0 gathers."""

    name: str = "uniform"
    rounds: int = 1
    sweeps_per_round: int = 0
    gathers: int = 0

    def select(self, K, key, c, *, block_size=None, mesh=None, mask=None):
        return _uniform_indices(key, as_operator(K).n, c, mask)


@dataclasses.dataclass
class LeveragePolicy(SelectionPolicy):
    """Approximate-leverage column sampling from a uniform pilot panel.

    A uniform pilot of ``p = min(n, max(2c, c + oversample))`` columns is
    gathered (ONE n×p ``columns`` call — the only kernel access), its row
    leverage scores are computed by the blocked Gram pass
    (``row_leverage_scores_gram``: O(b·p + p²) peak memory, never an n×p
    transposed copy or SVD workspace), and ``c`` columns are drawn without
    replacement with p_i ∝ those scores.  For an SPSD K the row and column
    leverage of the pilot panel agree, so the same policy serves CUR's row
    side.  Kernel sweep budget: 0 (the Gram/quad-form passes stream over the
    already-materialized pilot panel, not over K).
    """

    name: str = "leverage"
    rounds: int = 1
    sweeps_per_round: int = 0
    gathers: int = 1
    pilot: Optional[int] = None     # pilot panel width (default max(2c, c+8))
    oversample: int = 8

    def _pilot_scores(self, Kop, kp: jax.Array, c: int,
                      mask, block_size, mesh) -> jnp.ndarray:
        """Approximate leverage scores from one uniform n×p pilot gather."""
        n = Kop.n
        p = self.pilot if self.pilot is not None else max(2 * c,
                                                          c + self.oversample)
        p = min(n, int(p))
        if mask is not None:
            # A masked operator has only n_valid real columns; a pilot wider
            # than that would pull zero-probability padding columns into the
            # panel (jax.random.choice(replace=False) falls back to them
            # silently) and corrupt every valid row's leverage score.  Clamp
            # the width when the count is concrete; under a traced mask
            # (vmapped ragged batches) the width is static, so remap any
            # overflow pick onto a valid column instead (duplicated pilot
            # columns only double-count in the Gram — padding never enters).
            nv = jnp.sum(mask)
            if not isinstance(nv, jax.core.Tracer):
                p = min(p, int(nv))
            pilot_idx = _uniform_indices(kp, n, p, mask)
            repl = jax.random.choice(jax.random.fold_in(kp, 1), n,
                                     shape=(p,), replace=True,
                                     p=mask / nv)
            pilot_idx = jnp.where(jnp.take(mask, pilot_idx) > 0,
                                  pilot_idx, repl)
        else:
            pilot_idx = _uniform_indices(kp, n, p, None)
        Cp = Kop.columns(pilot_idx)
        if mask is not None:
            Cp = Cp * mask[:, None]
        return row_leverage_scores_gram(Cp, block_size=block_size, mesh=mesh)

    @staticmethod
    def _allowed(n: int, mask) -> jnp.ndarray:
        return jnp.ones((n,), jnp.float32) if mask is None \
            else mask.astype(jnp.float32)

    def select(self, K, key, c, *, block_size=None, mesh=None, mask=None):
        Kop = as_operator(K)
        kp, ks = jax.random.split(key)
        lev = self._pilot_scores(Kop, kp, c, mask, block_size, mesh)
        return _weighted_indices_without_replacement(
            ks, lev, c, self._allowed(Kop.n, mask))

    def select_pair(self, K, key, c, r, *, block_size=None, mesh=None,
                    mask=None):
        """Both CUR sides from ONE pilot: for an SPSD operator the pilot
        panel's row and column leverage agree, so scoring twice would only
        duplicate the n×p gather and its Gram pass."""
        Kop = as_operator(K)
        kp, kc, kr = jax.random.split(key, 3)
        lev = self._pilot_scores(Kop, kp, max(c, r), mask, block_size, mesh)
        allowed = self._allowed(Kop.n, mask)
        return (_weighted_indices_without_replacement(kc, lev, c, allowed),
                _weighted_indices_without_replacement(kr, lev, r, allowed))


def _masked_orthonormal_basis(C: jnp.ndarray) -> jnp.ndarray:
    """Left singular vectors of C with zero-σ columns zeroed out, so Q Qᵀ is
    the orthogonal projector onto range(C) even when C is rank-deficient."""
    C32 = C.astype(jnp.float32)
    u, s, _ = jnp.linalg.svd(C32, full_matrices=False)
    cutoff = max(C.shape) * jnp.finfo(jnp.float32).eps * jnp.max(s)
    return u * (s > cutoff).astype(jnp.float32)[None, :]


def residual_column_norms(Kop, idx: jnp.ndarray,
                          block_size: Optional[int] = None, mesh=None,
                          mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """||(I − C C†) K||² column norms in ONE panel sweep (adaptive rounds).

    ``mask`` row-masks both the C panel and the sweep statistics, so padded
    operators never leak padding rows into the norms.
    """
    C = Kop.columns(idx)                       # n·c entries, not a sweep
    if mask is not None:
        C = C * mask[:, None]
    Q = _masked_orthonormal_basis(C)
    (norms,) = Kop.sweep([sweep_lib.ProjResidualColNormPlan(Q, mask)],
                         block_size=block_size, mesh=mesh)
    return norms


@dataclasses.dataclass
class UniformAdaptive2Policy(SelectionPolicy):
    """uniform + adaptive² (Wang, Luo, Zhang 2016): round 0 uniform, then
    ``adaptive_rounds`` rounds with p_j ∝ squared residual column norms
    ``||k_:j − C C† k_:j||²`` of the running sketch — ONE panel sweep per
    adaptive round via the projection identity
    ``||(I − QQᵀ) K e_j||² = ||K e_j||² − ||Qᵀ K e_j||²``.

    Already-selected indices get their probabilities zeroed before each draw
    and rounds sample WITHOUT replacement: the pre-PR-5 ``replace=True`` draw
    could hand the same dominant residual column to every slot of a round
    (duplicated columns in C — wasted budget, rank-deficient C).
    """

    name: str = "uniform_adaptive2"
    sweeps_per_round: int = 1
    adaptive_rounds: int = 2

    @property
    def rounds(self) -> int:            # sweep-costing rounds == adaptive ones
        return self.adaptive_rounds

    @property
    def gathers(self) -> int:           # one C gather per adaptive round
        return self.adaptive_rounds

    def select(self, K, key, c, *, block_size=None, mesh=None, mask=None):
        Kop = as_operator(K)
        extra = c // (self.adaptive_rounds + 1)
        if extra == 0:
            # Silently degrading to pure uniform would break the declared
            # sweep_budget() contract every metered caller relies on.
            raise ValueError(
                f"uniform_adaptive2 needs c ≥ {self.adaptive_rounds + 1} so "
                f"each adaptive round draws at least one column (got c={c}); "
                f"use selection='uniform' for smaller sketches")
        c0 = c - self.adaptive_rounds * extra
        keys = jax.random.split(key, self.adaptive_rounds + 1)
        idx = _uniform_indices(keys[0], Kop.n, c0, mask)
        for kk in keys[1:]:
            norms = residual_column_norms(Kop, idx, block_size=block_size,
                                          mesh=mesh, mask=mask)
            # Size every per-round mask to the row count THIS round's sweep
            # actually saw, not an n captured at entry: an incrementally
            # maintained operator can grow between rounds (appended rows —
            # repro.serve.incremental), and a stale n both hides the new
            # rows from the adaptive draw and diverges from the norms'
            # shape.  (``mask`` callers pad to a fixed n, so mask length
            # always matches.)
            n = int(norms.shape[0])
            valid = jnp.ones((n,), jnp.float32) if mask is None \
                else mask.astype(jnp.float32)
            selected = jnp.zeros((n,), jnp.float32).at[idx].set(1.0)
            new = _weighted_indices_without_replacement(
                kk, norms, extra, valid * (1.0 - selected))
            idx = jnp.concatenate([idx, new])
        return idx


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_POLICIES: Dict[str, Callable[..., SelectionPolicy]] = {}


def register_policy(name: str):
    """Decorator: register a ``SelectionPolicy`` factory under ``name``."""
    def deco(factory: Callable[..., SelectionPolicy]):
        _POLICIES[name] = factory
        return factory
    return deco


def get_policy(policy, **params) -> SelectionPolicy:
    """Resolve a policy name (or pass a ``SelectionPolicy`` through)."""
    if isinstance(policy, SelectionPolicy):
        return policy
    if policy not in _POLICIES:
        raise ValueError(f"unknown selection policy {policy!r}; registered: "
                         f"{registered_policies()}")
    return _POLICIES[policy](**params)


def registered_policies() -> Tuple[str, ...]:
    """Registered policy names, sorted (the test/benchmark sweep order)."""
    return tuple(sorted(_POLICIES))


register_policy("uniform")(UniformPolicy)
register_policy("leverage")(LeveragePolicy)
register_policy("uniform_adaptive2")(UniformAdaptive2Policy)
