"""CUR matrix decomposition (paper §5).

Given A (m×n), C = c columns, R = r rows:

- optimal:    U* = C† A R†                              (Eq. 8)  O(mn·min(c,r))
- drineas08:  U  = (P_R^T A P_C)†                        (Fig. 2c baseline)
- fast:       Ũ  = (S_C^T C)† (S_C^T A S_R) (R S_R)†     (Eq. 9)  O(cr/ε · min(m,n) · min(c,r))

plus the adaptive-sampling column/row selection used by Theorem 8.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import selection as selection_lib
from repro.core import sketch as sk
from repro.core import sweep as sweep_lib
from repro.core.kernelop import SPSDOperator, as_operator
from repro.core.leverage import (column_leverage_scores_gram, pinv,
                                 row_leverage_scores, row_leverage_scores_gram)


def _shape_of(A) -> tuple:
    """(m, n) of a dense matrix or an implicit (square) ``SPSDOperator``."""
    if isinstance(A, SPSDOperator):
        return A.n, A.n
    return A.shape


def _rows_of(A, idx: jnp.ndarray) -> jnp.ndarray:
    """A[idx, :] without densifying an implicit operator."""
    if isinstance(A, SPSDOperator):
        return A.block(jnp.asarray(idx), jnp.arange(A.n))
    return jnp.take(A, idx, axis=0)


def _cols_of(A, idx: jnp.ndarray) -> jnp.ndarray:
    """A[:, idx] without densifying an implicit operator."""
    if isinstance(A, SPSDOperator):
        return A.columns(jnp.asarray(idx))
    return jnp.take(A, idx, axis=1)


def _block_of(A, ridx: jnp.ndarray, cidx: jnp.ndarray) -> jnp.ndarray:
    """A[ridx][:, cidx] — an (|ridx| × |cidx|) block."""
    if isinstance(A, SPSDOperator):
        return A.block(jnp.asarray(ridx), jnp.asarray(cidx))
    return jnp.take(jnp.take(A, ridx, axis=0), cidx, axis=1)


class CURApprox(NamedTuple):
    C: jnp.ndarray                 # (m, c)
    U: jnp.ndarray                 # (c, r)
    R: jnp.ndarray                 # (r, n)
    col_indices: Optional[jnp.ndarray] = None
    row_indices: Optional[jnp.ndarray] = None

    def dense(self) -> jnp.ndarray:
        return self.C @ self.U @ self.R


def select_cur_sketches(A, key: jax.Array, c: int, r: int,
                        selection="uniform", block_size: int = 1024,
                        mesh=None):
    """Sample the columns/rows forming C and R (the paper's §5.3 setup).

    ``A`` may be dense or an implicit ``SPSDOperator`` (kernel CUR): only the
    selected n×c / r×n panels are ever materialized.  ``selection`` names a
    registered ``SelectionPolicy`` (``repro.core.selection``); non-uniform
    policies need a square (SPSD) ``A`` — for an implicit operator the
    leverage/adaptive statistics stream through the operator protocol
    (blocked-Gram pilot leverage, ``ProjResidualColNorm`` sweeps), so C/R
    selection never materializes an O(n·r) intermediate beyond the C and R
    panels themselves.
    """
    kc, kr = jax.random.split(key)
    m, n = _shape_of(A)
    pol = selection_lib.get_policy(selection)
    if pol.name == "uniform":
        cidx = jax.random.choice(kc, n, shape=(c,), replace=False)
        ridx = jax.random.choice(kr, m, shape=(r,), replace=False)
    else:
        if m != n:
            raise ValueError(
                f"selection policy {pol.name!r} scores columns of a square "
                f"SPSD A; got shape {(m, n)} — use selection='uniform' for "
                f"rectangular matrices")
        # one call for both sides: policies with shareable scores (leverage
        # on a symmetric operator) pay for their pilot/scoring pass once
        cidx, ridx = pol.select_pair(as_operator(A), kc, c, r,
                                     block_size=block_size, mesh=mesh)
    return _cols_of(A, cidx), _rows_of(A, ridx), cidx, ridx


def optimal_U(A: jnp.ndarray, C: jnp.ndarray, R: jnp.ndarray) -> jnp.ndarray:
    return pinv(C) @ A.astype(jnp.float32) @ pinv(R)


def drineas08_U(A: jnp.ndarray, cidx: jnp.ndarray, ridx: jnp.ndarray) -> jnp.ndarray:
    """U = (P_R^T A P_C)†  — the poor-quality baseline of Fig. 2(c)."""
    W = jnp.take(jnp.take(A, ridx, axis=0), cidx, axis=1)   # (r, c)
    return pinv(W)                                           # (c, r)


def fast_U_cur(ScC: jnp.ndarray, ScASr: jnp.ndarray, RSr: jnp.ndarray) -> jnp.ndarray:
    """Ũ = (S_C^T C)† (S_C^T A S_R) (R S_R)†  (Eq. 9)."""
    return pinv(ScC) @ ScASr.astype(jnp.float32) @ pinv(RSr)


def blocked_right_sketch(A, S, block_size: int = 1024,
                         mesh=None) -> jnp.ndarray:
    """A S (m × s) streamed over row panels of A via the sweep engine.

    The dense route ``S.left(A.T).T`` stages an n×m transposed copy (and, for
    SRHT, a zero-padded one on top); sweeping row panels keeps the peak
    footprint at O(b·n + m·s) — the CUR analogue of the SPSD panel protocol —
    and a non-trivial ``mesh`` shards the panels across devices.

    An implicit ``SPSDOperator`` A routes through its own ``sweep``, so a
    Pallas-backed kernel claims matmul-shaped sketches with the fused
    (per-shard, rectangular-slab) launch.  For dense A under a non-trivial
    mesh, each shard claims its contiguous row slab through the engine's
    ``slab_fn`` hook — one ``S.right`` application per device instead of a
    panel scan — whenever the per-device slab stays inside the panel element
    budget (so the streaming memory story is preserved).
    """
    if isinstance(A, SPSDOperator):
        return sk.right_streaming(S, A, block_size=block_size, mesh=mesh)
    if isinstance(S, sk.GaussianSketch):
        return S.right(A)       # one GEMM; blocking would redraw S per block
    m, n = A.shape
    plan = sweep_lib.SketchRightPlan(S, S.s)
    dp = sweep_lib.mesh_data_size(mesh)
    slab_fn = None
    if dp > 1 and sweep_lib.local_slab_rows(m, n, block_size, dp) * n \
            <= sweep_lib.PANEL_ELEMENT_BUDGET:
        def slab_fn(row_idx, valid):
            slab = jnp.take(A, row_idx, axis=0)
            return (plan.update(plan.init(m, n), slab, row_idx, valid),)
    (AS,) = sweep_lib.sweep_panels(
        lambda idx: jnp.take(A, idx, axis=0), m, n, [plan],
        block_size=block_size, mesh=mesh, slab_fn=slab_fn)
    return AS


def fast_cur(
    A,
    key: jax.Array,
    c: int,
    r: int,
    sc: int,
    sr: int,
    sketch_kind: str = "leverage",
    enforce_subset: bool = True,
    scale: bool = False,
    streaming: bool = False,
    block_size: int = 1024,
    mesh=None,
    selection="uniform",
) -> CURApprox:
    """End-to-end fast CUR: select C/R, then the sketched Ũ (Thm 9 setup).

    ``selection`` picks WHICH columns/rows form C and R through the
    ``SelectionPolicy`` registry (uniform / leverage / uniform_adaptive2 /
    custom); for an implicit operator every policy statistic streams —
    leverage via the blocked-Gram pilot pass, adaptive residual norms via
    ``ProjResidualColNormPlan`` sweeps — adding exactly the policy's declared
    sweeps and nothing else to the PR 2/3 pass budget.
    Column-selection sketches observe only an (sc × sr) block of A plus C and R.
    Leverage sampling uses row scores of C (for S_C) and of R^T (for S_R).
    With ``streaming=True`` everything routes through the sweep engine:
    S_C^T A S_R via ``blocked_right_sketch`` (no transposed full-size
    temporaries), and the R-side leverage scores via the blocked Gram R Rᵀ
    pass (``column_leverage_scores_gram``) instead of densifying the n×r
    transpose — the path that survives n ≫ 10⁵.  ``mesh`` shards the sweeps
    (selection included).

    ``A`` may also be an implicit ``SPSDOperator`` (kernel CUR): every access
    goes through the operator protocol — C/R/blocks are gathered panels, and
    projection sketches stream through ``A.sweep``, where a Pallas-backed
    ``RBFKernel`` claims them with the fused (sharded) multi-RHS launch.
    Operators always take the streaming route; A is never densified.
    """
    is_op = isinstance(A, SPSDOperator)
    streaming = streaming or is_op
    m, n = _shape_of(A)
    kcr, kc, kr = jax.random.split(key, 3)
    C, R, cidx, ridx = select_cur_sketches(A, kcr, c, r, selection=selection,
                                           block_size=block_size, mesh=mesh)

    if sketch_kind in ("uniform", "leverage"):
        if sketch_kind == "leverage":
            if streaming:
                lev_c = row_leverage_scores_gram(C, block_size, mesh=mesh)
                lev_r = column_leverage_scores_gram(R, block_size, mesh=mesh)
            else:
                lev_c = row_leverage_scores(C)
                lev_r = row_leverage_scores(R.T)
            Sc = sk.leverage_column_sketch(kc, lev_c, sc, scale=scale)
            Sr = sk.leverage_column_sketch(kr, lev_r, sr, scale=scale)
        else:
            Sc = sk.uniform_column_sketch(kc, m, sc, scale=scale)
            Sr = sk.uniform_column_sketch(kr, n, sr, scale=scale)
        if enforce_subset:
            # §4.5 applied to CUR: rows selected by R ⊂ S_C, cols selected by C ⊂ S_R
            Sc = sk.subset_union_sketch(Sc, ridx, m)
            Sr = sk.subset_union_sketch(Sr, cidx, n)
        ScC = Sc.left(C)
        RSr = Sr.left(R.T).T
        blk = _block_of(A, Sc.indices, Sr.indices)
        ScASr = blk * (Sc.scales[:, None] * Sr.scales[None, :])
    else:
        Sc = sk.make_sketch(sketch_kind, kc, m, sc)
        Sr = sk.make_sketch(sketch_kind, kr, n, sr)
        ScC = Sc.left(C)
        RSr = Sr.left(R.T).T
        if streaming:
            ScASr = Sc.left(blocked_right_sketch(A, Sr, block_size, mesh=mesh))
        else:
            ScASr = Sc.left(Sr.left(A.T).T)

    U = fast_U_cur(ScC, ScASr, RSr)
    return CURApprox(C=C, U=U, R=R, col_indices=cidx, row_indices=ridx)


def optimal_cur(A: jnp.ndarray, key: jax.Array, c: int, r: int) -> CURApprox:
    C, R, cidx, ridx = select_cur_sketches(A, key, c, r)
    return CURApprox(C=C, U=optimal_U(A, C, R), R=R,
                     col_indices=cidx, row_indices=ridx)


# ---------------------------------------------------------------------------
# Adaptive row selection (Wang & Zhang 2013; used by Theorem 8)
# ---------------------------------------------------------------------------

def adaptive_row_indices(A: jnp.ndarray, base: jnp.ndarray, key: jax.Array,
                         extra: int) -> jnp.ndarray:
    """Sample ``extra`` rows ∝ squared residual norms against rows in ``base``."""
    R1 = jnp.take(A, base, axis=0)
    resid = A.astype(jnp.float32) - (A.astype(jnp.float32) @ pinv(R1)) @ R1.astype(jnp.float32)
    norms = jnp.sum(resid * resid, axis=1)
    p = norms / jnp.maximum(jnp.sum(norms), 1e-30)
    idx = jax.random.choice(key, A.shape[0], shape=(extra,), replace=True, p=p)
    return jnp.concatenate([base, idx])


def relative_error(A: jnp.ndarray, approx: CURApprox) -> jnp.ndarray:
    A32 = A.astype(jnp.float32)
    Rm = A32 - approx.dense().astype(jnp.float32)  # repro: allow-dense(CUR error oracle — A is already dense)
    return jnp.sum(Rm * Rm) / jnp.sum(A32 * A32)
