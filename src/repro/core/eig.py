"""Downstream solvers on C U C^T (paper Appendix A).

These are what make the fast model useful: with (C, U) at hand the k-eigendecomposition
costs O(nc²) and the regularized solve O(nc²) (O(c³+nc) given the SVD of C).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp



class EigResult(NamedTuple):
    eigenvalues: jnp.ndarray    # (k,) descending
    eigenvectors: jnp.ndarray   # (n, k) orthonormal


def approx_eigh(C: jnp.ndarray, U: jnp.ndarray, k: int) -> EigResult:
    """Lemma 10: eigendecomposition of C U C^T in O(nc²).

    C = U_C Σ_C V_C^T;  Z = (Σ_C V_C^T) U (Σ_C V_C^T)^T = V_Z Λ V_Z^T;
    then C U C^T = (U_C V_Z) Λ (U_C V_Z)^T.
    """
    C32 = C.astype(jnp.float32)
    Uc, sc, Vct = jnp.linalg.svd(C32, full_matrices=False)
    M = (sc[:, None] * Vct) @ U.astype(jnp.float32) @ (sc[:, None] * Vct).T
    M = 0.5 * (M + M.T)
    lam, Vz = jnp.linalg.eigh(M)                     # ascending
    lam = lam[::-1]
    Vz = Vz[:, ::-1]
    vecs = Uc @ Vz
    return EigResult(eigenvalues=lam[:k], eigenvectors=vecs[:, :k])


def woodbury_solve(C: jnp.ndarray, U: jnp.ndarray, alpha: float,
                   y: jnp.ndarray) -> jnp.ndarray:
    """Lemma 11: solve (C U C^T + αIₙ) w = y in O(nc²).

    (CUC^T + αI)⁻¹ = α⁻¹ I − α⁻¹ C (α U⁻¹ + C^T C)⁻¹ C^T   (α>0, U SPSD).

    Implemented in the inverse-free form α U (α I + C^T C U)⁻¹ so singular U is
    fine (matches the Moore–Penrose limit used in the paper's experiments).

    Assumptions, validated up front:

    - ``alpha`` must be a strictly positive finite ridge: the identity
      divides by α, so α = 0 (or NaN/inf) produces NaN rows silently — an
      unregularized solve on a rank-deficient C U Cᵀ has no unique solution;
      use a pseudo-inverse route instead.
    - ``U`` must be SPSD (the fast/Nyström U matrices are, up to round-off):
      for indefinite U the inner α I + CᵀC U can be singular and the
      Woodbury identity itself no longer holds.

    A traced ``alpha`` (jit/vmap/grad over the ridge) cannot be validated at
    trace time and is passed through unchecked — the caller owns α > 0 there.
    """
    if not isinstance(alpha, jax.core.Tracer):
        a = float(alpha)
        if not (a > 0.0) or a == float("inf"):
            raise ValueError(
                f"woodbury_solve: alpha must be a finite positive ridge, "
                f"got {a!r}; the Woodbury identity divides by alpha and "
                f"would silently return NaN")
    C32 = C.astype(jnp.float32)
    U32 = U.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    CtC = C32.T @ C32
    c = C32.shape[1]
    # M = (α U^{-1} + C^T C)^{-1} = U (α I + C^T C U)^{-1}
    inner = alpha * jnp.eye(c, dtype=jnp.float32) + CtC @ U32
    M = U32 @ jnp.linalg.solve(inner, jnp.eye(c, dtype=jnp.float32))
    Cty = C32.T @ y32
    return (y32 - C32 @ (M @ Cty)) / alpha


def kpca_features(C: jnp.ndarray, U: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, EigResult]:
    """§6.3 KPCA: train features = Λ^{1/2} V^T  columns (returned as (n, k))."""
    eig = approx_eigh(C, U, k)
    lam = jnp.maximum(eig.eigenvalues, 0.0)
    feats = eig.eigenvectors * jnp.sqrt(lam)[None, :]
    return feats, eig


def kpca_transform(eig: EigResult, k_x: jnp.ndarray) -> jnp.ndarray:
    """Test features Λ^{-1/2} V^T k(x) for kernel column(s) k_x (n, b)."""
    lam = jnp.maximum(eig.eigenvalues, 1e-12)
    return (eig.eigenvectors.T @ k_x) / jnp.sqrt(lam)[:, None]


def misalignment(U_true: jnp.ndarray, V_approx: jnp.ndarray) -> jnp.ndarray:
    """Eq. 10: (1/k)||U_k − Ṽ Ṽ^T U_k||_F² ∈ [0, 1]."""
    k = U_true.shape[1]
    proj = V_approx @ (V_approx.T @ U_true)
    d = U_true - proj
    return jnp.sum(d * d) / k


def streaming_subspace_eigh(K, k: int, key=None, oversample: int = 8,
                            power_iters: int = 6, block_size=None,
                            mesh=None) -> EigResult:
    """Top-k eigenpairs of an SPSD *operator* by randomized subspace
    iteration (Halko–Martinsson–Tropp) — the exact-eigvec reference of the
    workload benches.

    Every application of K streams through ``matmat`` panel sweeps; the
    n×n kernel is never materialized.  ``power_iters+2`` sweeps total
    (probe, re-orthogonalized power steps, Rayleigh–Ritz), each a full
    multi-RHS pass over the operator.  Complements
    ``spsd.streaming_topk_eigvals`` (values only) with the eigenvector
    variant kernel-PCA misalignment needs.
    """
    from repro.core import spsd as spsd_lib
    from repro.core.kernelop import as_operator
    Kop = as_operator(K)
    if key is None:
        key = spsd_lib.default_probe_key()
    q = min(Kop.n, k + oversample)
    Y = Kop.matmat(jax.random.normal(key, (Kop.n, q), jnp.float32),
                   block_size=block_size, mesh=mesh)
    for _ in range(power_iters):
        Qb, _ = jnp.linalg.qr(Y)
        Y = Kop.matmat(Qb, block_size=block_size, mesh=mesh)
    Qb, _ = jnp.linalg.qr(Y)
    B = Qb.T @ Kop.matmat(Qb, block_size=block_size, mesh=mesh)
    B = 0.5 * (B + B.T)
    lam, W = jnp.linalg.eigh(B)                      # ascending
    lam = lam[::-1]
    W = W[:, ::-1]
    return EigResult(eigenvalues=lam[:k], eigenvectors=(Qb @ W)[:, :k])


def spectral_embedding(C: jnp.ndarray, U: jnp.ndarray, k: int,
                       eps: float = 1e-9,
                       degrees: jnp.ndarray | None = None) -> jnp.ndarray:
    """§6.4: normalized-Laplacian top-k eigenvectors from CUC^T ≈ K.

    d = CUC^T 1;  L = I − D^{-1/2} CUC^T D^{-1/2}; bottom-k of L = top-k of
    (D^{-1/2}C) U (D^{-1/2}C)^T — computed via Lemma 10. Rows are normalized.

    ``degrees`` substitutes *exact* degree sums d = K1 for the model-implied
    ones (one streamed ``matmat`` panel sweep on the kernel operator) — the
    degree-normalized route the spectral workload bench uses, so the
    normalization does not inherit the approximation's error.
    """
    ones = jnp.ones((C.shape[0], 1), C.dtype)
    d = ((C @ (U @ (C.T @ ones)))[:, 0] if degrees is None
         else degrees.astype(C.dtype))
    dinv = 1.0 / jnp.sqrt(jnp.maximum(d, eps))
    Cn = C * dinv[:, None]
    eig = approx_eigh(Cn, U, k)
    V = eig.eigenvectors
    norms = jnp.linalg.norm(V, axis=1, keepdims=True)
    return V / jnp.maximum(norms, eps)
