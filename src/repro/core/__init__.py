"""The paper's primary contribution: fast SPSD approximation + fast CUR.

Public API re-exports.
"""
from repro.core.kernelop import (DenseSPSD, LinearKernel, PairwiseKernel,
                                 RBFKernel, SPSDOperator, as_operator)
from repro.kernels.pairwise.specs import (KernelSpec, get_spec,
                                          register_kernel, registered_kernels)
from repro.core.sweep import (ColumnGatherPlan, DiagPlan, FrobeniusPlan,
                              GramPlan, MatmulPlan, ProjResidualColNormPlan,
                              ResidualFroPlan, RowQuadFormPlan,
                              SketchRightPlan, mesh_data_size, sweep_operator,
                              sweep_panels)
from repro.core.instrument import CountingOperator
from repro.core.selection import (LeveragePolicy, SelectionPolicy,
                                  UniformAdaptive2Policy, UniformPolicy,
                                  get_policy, register_policy,
                                  registered_policies, residual_column_norms)
# per-spec streaming calibration lives in repro.kernels.pairwise.calibrate
# (NOT re-exported here: benchmarks/common.py has an unrelated eta-targeted
# calibrate_sigma and the two must never be import-confused)
from repro.core.leverage import (column_leverage_scores,
                                 column_leverage_scores_gram,
                                 orthonormal_basis, pinv, row_coherence,
                                 row_leverage_scores, row_leverage_scores_gram)
from repro.core.sketch import (SKETCH_KINDS, ColumnSketch, CountSketch,
                               GaussianSketch, MaskedSketch, SRHTSketch,
                               count_sketch, fwht, leverage_column_sketch,
                               make_sketch, plan_for_sketch, right_streaming,
                               srht_sketch, subset_union_sketch, sym_streaming,
                               uniform_column_sketch)
from repro.core.spsd import (SPSDApprox, bucket_by_size, error_vs_best_rank_k,
                             fast_U, fast_model, fast_model_batched,
                             fast_model_from_C, fast_model_ragged,
                             fast_model_with_error, nystrom_U, nystrom_model,
                             prototype_U, prototype_model, relative_error,
                             sample_C, streaming_topk_eigvals)
from repro.core.cur import (CURApprox, adaptive_row_indices,
                            blocked_right_sketch, drineas08_U, fast_U_cur,
                            fast_cur, optimal_U, optimal_cur)
from repro.core.eig import (EigResult, approx_eigh, kpca_features,
                            kpca_transform, misalignment, spectral_embedding,
                            woodbury_solve)
from repro.core.adaptive import uniform_adaptive2_indices
from repro.core.sketched_attention import (LandmarkState, build_landmark_state,
                                           landmark_decode, select_landmarks,
                                           signed_den_floor,
                                           sketched_attention)

__all__ = [k for k in dir() if not k.startswith("_")]
