"""Composable LM substrate: layers, attention, MoE, recurrence, full models."""
from repro.models.model import build_model, Model  # noqa: F401
