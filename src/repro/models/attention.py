"""Attention mixers: GQA / MQA / sliding-window / MLA / cross / landmark-decode.

Layouts: activations are (B, S, d_model); heads live in (B, S, H, D) einsums so
the 'heads' axis is shardable over the mesh 'model' axis.  ``attn_impl``
selects the XLA einsum path (default; what the dry-run lowers) or the Pallas
flash kernel (TPU target, validated in interpret mode).

Decode caches (one per layer; stacked over scanned layers):

- full / global : {"k": (B, Smax, KV, D), "v": ...}           (pos passed in)
- local         : ring buffer {"k": (B, W, KV, D), "v": ...}
- MLA           : {"ckv": (B, Smax, R), "krope": (B, Smax, Dr)} — the latent
                  cache *is* a learned sketch of the KV Gram (DESIGN.md §5)
- landmark      : the paper's fast-model factors per head:
                  {"k_land": (B, KV, c, D), "uv": (B, KV, c, Dv),
                   "u1": (B, KV, c), "offset": (B, KV)}
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.sketched_attention import signed_den_floor
from repro.distributed import sharding as shd
from repro.models import layers as L


def _sp_active(cfg: ModelConfig, S: int) -> bool:
    """Sequence-parallel attention: only when heads don't divide the TP axis
    (otherwise head sharding is strictly better) and positions do."""
    if not cfg.seq_parallel_attn or S <= 1:
        return False
    tp = shd.ambient_axis_size("model")
    return tp > 1 and cfg.n_heads % tp != 0 and S % tp == 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig,
                   cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    if cfg.use_mla and not cross:
        p = {
            "wq_a": L.dense_init(ks[0], (d, cfg.q_lora_rank), cfg.pdtype),
            "q_norm": L.init_rmsnorm(cfg.q_lora_rank, cfg.pdtype),
            "wq_b": L.dense_init(
                ks[1], (cfg.q_lora_rank, h, cfg.qk_nope_dim + cfg.qk_rope_dim),
                cfg.pdtype),
            "wkv_a": L.dense_init(
                ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), cfg.pdtype),
            "kv_norm": L.init_rmsnorm(cfg.kv_lora_rank, cfg.pdtype),
            "wkv_b": L.dense_init(
                ks[3], (cfg.kv_lora_rank, h, cfg.qk_nope_dim + cfg.v_head_dim),
                cfg.pdtype),
            "wo": L.dense_init(ks[4], (h, cfg.v_head_dim, d), cfg.pdtype),
        }
        return p
    p = {
        "wq": L.dense_init(ks[0], (d, h, hd), cfg.pdtype),
        "wk": L.dense_init(ks[1], (d, kv, hd), cfg.pdtype),
        "wv": L.dense_init(ks[2], (d, kv, hd), cfg.pdtype),
        "wo": L.dense_init(ks[3], (h, hd, d), cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(hd, cfg.pdtype)
        p["k_norm"] = L.init_rmsnorm(hd, cfg.pdtype)
    return p


# ---------------------------------------------------------------------------
# qkv projections
# ---------------------------------------------------------------------------

def _qkv(params: dict, cfg: ModelConfig, x: jnp.ndarray,
         positions: jnp.ndarray, theta: float):
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = L.apply_rope(q.swapaxes(1, 2), positions, theta).swapaxes(1, 2)
    k = L.apply_rope(k.swapaxes(1, 2), positions, theta).swapaxes(1, 2)
    return q, k, v


NEG = -1e30
# dense path only when the full (Sq, Sk) score panel is small; otherwise a
# q-block scan (XLA-flash) keeps the transient at (B, H, bq, Sk)
CHUNK_Q = 1024
DENSE_LIMIT = 2048 * 2048


def _blk_attend(qb: jnp.ndarray, kb: jnp.ndarray, vb: jnp.ndarray,
                row_ids: jnp.ndarray, col_ids: jnp.ndarray, *,
                scale: float, causal: bool, window: Optional[int],
                kv_valid: Optional[jnp.ndarray]) -> jnp.ndarray:
    """One score panel. qb (B,bq,H,D), kb/vb (B,L,H,D); ids are absolute
    token positions (masks are *computed*, never materialized globally)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", qb.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
    m = jnp.ones((row_ids.shape[0], col_ids.shape[0]), bool)
    if causal:
        m &= col_ids[None, :] <= row_ids[:, None]
    if window is not None:
        m &= (row_ids[:, None] - col_ids[None, :]) < window
    logits = jnp.where(m[None, None], logits, NEG)
    if kv_valid is not None:                      # (B or 1, L) key validity
        logits = jnp.where(kv_valid[:, None, None, :], logits, NEG)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vb.astype(jnp.float32))


def _gqa_decode_read(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     cfg: ModelConfig,
                     kv_valid: Optional[jnp.ndarray]) -> jnp.ndarray:
    """q (B,1,H,D), k/v (B,Sk,KV,Dv) -> (B,1,H,Dv) without repeating KV."""
    B, _, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (D ** 0.5)
    if kv_valid is not None:
        logits = jnp.where(kv_valid[:, None, None, :], logits, NEG)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(B, 1, H, v.shape[-1]).astype(cfg.cdtype)


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, cfg: ModelConfig,
          *, causal: bool = True, window: Optional[int] = None,
          offs: Optional[int] = None,
          kv_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D) -> (B,Sq,H,Dv).

    GQA broadcasts KV to H (shards cleanly: 'heads'->model).  offs aligns
    queries to keys (decode: Sk - Sq).  Masks are computed per block from
    position iotas; ``kv_valid`` is an optional (B|1, Sk) key-validity row
    (decode cache bounds / ring buffers).
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if offs is None:
        offs = Sk - Sq
    G = H // KV
    if Sq == 1 and G > 1:
        # grouped decode read: the KV cache is read ONCE per step instead of
        # materializing a G-times repeated copy (§Perf-C iteration 3 — the
        # decode memory term is dominated by exactly this read)
        return _gqa_decode_read(q, k, v, cfg, kv_valid=kv_valid)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    scale = 1.0 / (D ** 0.5)
    rows = jnp.arange(Sq) + offs
    cols = jnp.arange(Sk)
    sp = _sp_active(cfg, Sq)
    sp_spec = P(None, "model", None, None)
    CHUNK_Q = cfg.chunk_q

    if Sq * Sk <= DENSE_LIMIT or Sq % CHUNK_Q != 0:
        if sp:
            q = shd.constrain(q, sp_spec)
        out = _blk_attend(q, k, v, rows, cols, scale=scale, causal=causal,
                          window=window, kv_valid=kv_valid)
        if sp:
            out = shd.constrain(out, sp_spec)
        return out.astype(cfg.cdtype)

    nb = Sq // CHUNK_Q
    qb = q.reshape(B, nb, CHUNK_Q, H, q.shape[-1]).swapaxes(0, 1)
    rb = rows.reshape(nb, CHUNK_Q)
    sp_blk = _sp_active(cfg, CHUNK_Q)

    if window is not None and Sk > 2 * (window + CHUNK_Q):
        # banded local attention: slice only the keys the window can reach
        L = window + CHUNK_Q
        L = -(-L // 128) * 128

        def body(_, xs):
            qi, ri = xs
            if sp_blk:
                qi = shd.constrain(qi, sp_spec)
            start = jnp.clip(ri[0] - window + 1, 0, Sk - L)
            kb = jax.lax.dynamic_slice_in_dim(k, start, L, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, L, axis=1)
            ci = start + cols[:L]
            kvv = None if kv_valid is None else \
                jax.lax.dynamic_slice_in_dim(kv_valid, start, L, axis=1)
            o = _blk_attend(qi, kb, vb, ri, ci, scale=scale, causal=causal,
                            window=window, kv_valid=kvv)
            if sp_blk:
                o = shd.constrain(o, sp_spec)
            return None, o
    else:
        def body(_, xs):
            qi, ri = xs
            if sp_blk:
                qi = shd.constrain(qi, sp_spec)
            o = _blk_attend(qi, k, v, ri, cols, scale=scale, causal=causal,
                            window=window, kv_valid=kv_valid)
            if sp_blk:
                o = shd.constrain(o, sp_spec)
            return None, o

    if cfg.unroll_scans and nb <= 64:
        blocks = [body(None, (qb[i], rb[i]))[1] for i in range(nb)]
        ob = jnp.stack(blocks)
    else:
        _, ob = jax.lax.scan(body, None, (qb, rb))
    out = ob.swapaxes(0, 1).reshape(B, Sq, H, v.shape[-1])
    return out.astype(cfg.cdtype)


# ---------------------------------------------------------------------------
# full-sequence self-attention (train / prefill)
# ---------------------------------------------------------------------------

def attention_full(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                   positions: jnp.ndarray, kind: str = "attn") -> jnp.ndarray:
    if cfg.use_mla:
        return _mla_full(params, cfg, x, positions)
    sp = _sp_active(cfg, x.shape[1])
    if sp:
        # heads-misfit: shard query positions over 'model' so the q/k/v/o
        # projections and the score panels are TP-parallel in the sequence
        x = shd.constrain(x, P(None, "model", None))
    theta = cfg.rope_theta_local if kind == "local" else cfg.rope_theta
    q, k, v = _qkv(params, cfg, x, positions, theta)
    if sp:
        q = shd.constrain(q, P(None, "model", None, None))
        # keys/values: ONE explicit all-gather per layer (batch stays on the
        # DP axes, 'model' replicated) — without this GSPMD re-gathers the
        # seq-sharded K/V inside every q-block of the scan (iteration B1
        # measured 5.1 TB of all-gather; B2 makes the gather per-layer)
        dp = tuple(a for a in ("pod", "data")
                   if shd.ambient_axis_size(a) > 1)
        kv_spec = P(dp if dp else None, None, None, None)
        k = shd.constrain(k, kv_spec)
        v = shd.constrain(v, kv_spec)
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        window = cfg.window if kind == "local" else None
        out = fa_ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=window)
        out = out.transpose(0, 2, 1, 3)
    else:
        window = cfg.window if kind == "local" else None
        out = _sdpa(q, k, v, cfg, causal=True, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cfg.cdtype))
    if sp:
        y = shd.constrain(y, P(None, "model", None))
    return y


def _mla_full(params: dict, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray) -> jnp.ndarray:
    dt = cfg.cdtype
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    ql = L.rmsnorm(params["q_norm"], x @ params["wq_a"].astype(dt),
                   cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, params["wq_b"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope.swapaxes(1, 2), positions,
                          cfg.rope_theta).swapaxes(1, 2)

    kv_a = x @ params["wkv_a"].astype(dt)                    # (B,S,R+dr)
    ckv = L.rmsnorm(params["kv_norm"], kv_a[..., :cfg.kv_lora_rank],
                    cfg.norm_eps)
    k_rope = L.apply_rope(kv_a[..., None, cfg.kv_lora_rank:].swapaxes(1, 2),
                          positions, cfg.rope_theta).swapaxes(1, 2)  # (B,S,1,dr)
    kvb = jnp.einsum("bsr,rhk->bshk", ckv, params["wkv_b"].astype(dt))
    k_nope, v = kvb[..., :dn], kvb[..., dn:]

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, H, dr))], axis=-1)
    out = _sdpa(qf, kf, v, cfg, causal=True)                 # (B,S,H,dv)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                    enc_k: jnp.ndarray, enc_v: jnp.ndarray) -> jnp.ndarray:
    """enc_k/enc_v: (B, S_enc, KV, D) precomputed from encoder output."""
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
    out = _sdpa(q, enc_k, enc_v, cfg, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def encoder_kv(params: dict, cfg: ModelConfig, enc_out: jnp.ndarray):
    dt = cfg.cdtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    if cfg.qk_norm:
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> dict:
    """Zero-initialized cache struct for one layer (shapes only matter
    for the dry-run; serve.py fills them via prefill)."""
    dt = cfg.cdtype
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.use_mla and kind in ("attn", "global"):
        return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
                "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt)}
    if kind == "local" and cfg.window is not None:
        w = min(cfg.window, max_len)
        return {"k": jnp.zeros((batch, w, kv, hd), dt),
                "v": jnp.zeros((batch, w, kv, hd), dt)}
    if kind == "global" and cfg.use_landmark_decode:
        c = cfg.landmark_c
        return {"k_land": jnp.zeros((batch, kv, c, hd), dt),
                "uv": jnp.zeros((batch, kv, c, hd), dt),
                "u1": jnp.zeros((batch, kv, c), jnp.float32),
                "offset": jnp.zeros((batch, kv), jnp.float32)}
    return {"k": jnp.zeros((batch, max_len, kv, hd), dt),
            "v": jnp.zeros((batch, max_len, kv, hd), dt)}


def cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> dict:
    """ShapeDtypeStruct version of init_cache (dry-run, no allocation)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.eval_shape(lambda: init_cache(cfg, kind, batch, max_len)))


# ---------------------------------------------------------------------------
# decode steps
# ---------------------------------------------------------------------------

def attention_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                     cache: dict, pos: jnp.ndarray,
                     kind: str = "attn") -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d). pos: scalar current position. Returns (y, new_cache)."""
    if cfg.use_mla and kind in ("attn", "global"):
        return _mla_decode(params, cfg, x, cache, pos)
    if kind == "global" and cfg.use_landmark_decode and "k_land" in cache:
        return _landmark_decode(params, cfg, x, cache, pos), cache

    theta = cfg.rope_theta_local if kind == "local" else cfg.rope_theta
    positions = pos[None]
    q, k_new, v_new = _qkv(params, cfg, x, positions, theta)

    if kind == "local" and cfg.window is not None:
        W = cache["k"].shape[1]
        slot = pos % W
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
        j = jnp.arange(W)
        slot_pos = pos - ((pos - j) % W)
        valid = ((slot_pos >= 0) & (slot_pos <= pos))[None]  # (1, W)
        out = _sdpa(q, k_cache, v_cache, cfg, causal=False, kv_valid=valid)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
        S = k_cache.shape[1]
        valid = (jnp.arange(S) <= pos)[None]                 # (1, S)
        out = _sdpa(q, k_cache, v_cache, cfg, causal=False, kv_valid=valid)
        new_cache = {"k": k_cache, "v": v_cache}

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cfg.cdtype))
    return y, new_cache


def _mla_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                cache: dict, pos: jnp.ndarray) -> Tuple[jnp.ndarray, dict]:
    """Absorbed MLA decode: attend in the latent (kv_lora) space.

    The latent cache ckv is exactly a *learned* c-dimensional sketch of the
    K/V Gram — the architectural cousin of the paper's C = KP (DESIGN.md §5).
    """
    dt = cfg.cdtype
    dn, dr, dv, R = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)
    positions = pos[None]

    ql = L.rmsnorm(params["q_norm"], x @ params["wq_a"].astype(dt),
                   cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, params["wq_b"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope.swapaxes(1, 2), positions,
                          cfg.rope_theta).swapaxes(1, 2)

    kv_a = x @ params["wkv_a"].astype(dt)
    ckv_new = L.rmsnorm(params["kv_norm"], kv_a[..., :R], cfg.norm_eps)
    krope_new = L.apply_rope(kv_a[..., None, R:].swapaxes(1, 2), positions,
                             cfg.rope_theta).swapaxes(1, 2)[:, :, 0]  # (B,1,dr)

    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], krope_new.astype(cache["krope"].dtype), pos, axis=1)

    wkv_b = params["wkv_b"].astype(dt)                       # (R, H, dn+dv)
    w_k, w_v = wkv_b[..., :dn], wkv_b[..., dn:]
    if cfg.mla_absorb:
        # q W_k^T: (B,1,H,dn) x (R,H,dn) -> (B,1,H,R); attend against ckv.
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, w_k)
        s_lat = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                           ckv.astype(jnp.float32))
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                            krope.astype(jnp.float32))
        logits = (s_lat + s_rope) / ((dn + dr) ** 0.5)
        S = ckv.shape[1]
        mask = (jnp.arange(S) <= pos)[None, None, None, :]
        logits = jnp.where(mask, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)                  # (B,H,1,S)
        o_lat = jnp.einsum("bhst,btr->bshr", w, ckv.astype(jnp.float32))
        out = jnp.einsum("bshr,rhk->bshk", o_lat, w_v.astype(jnp.float32))
    else:
        k_nope = jnp.einsum("btr,rhk->bthk", ckv, w_k)
        v = jnp.einsum("btr,rhk->bthk", ckv, w_v)
        kf = jnp.concatenate([k_nope, jnp.broadcast_to(
            krope[:, :, None], k_nope.shape[:3] + (dr,))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        S = ckv.shape[1]
        valid = (jnp.arange(S) <= pos)[None]
        out = _sdpa(qf, kf, v, cfg, causal=False, kv_valid=valid)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(dt),
                   params["wo"].astype(dt))
    return y, {"ckv": ckv, "krope": krope}


def _landmark_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                     cache: dict, pos: jnp.ndarray) -> jnp.ndarray:
    """One-token read against the paper's fast-model factors, O(c·d).

    The new token is *not* folded into the landmark state (the state is a
    context summary built at prefill; serve.py rebuilds it periodically —
    the 'streaming refresh' policy, DESIGN.md §4.1).
    """
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
    q = L.apply_rope(q.swapaxes(1, 2), pos[None], cfg.rope_theta)  # (B,H,1,D)
    q = q[:, :, 0]                                           # (B,H,D)
    KV = cfg.n_kv_heads
    G = cfg.n_heads // KV
    B, H, D = q.shape
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)

    kl = cache["k_land"].astype(jnp.float32)                 # (B,KV,c,D)
    logits = jnp.einsum("bkgd,bkcd->bkgc", qg, kl) / (D ** 0.5)
    cvec = jnp.exp(logits - cache["offset"][:, :, None, None])
    num = jnp.einsum("bkgc,bkcv->bkgv", cvec,
                     cache["uv"].astype(jnp.float32))
    den = jnp.einsum("bkgc,bkc->bkg", cvec, cache["u1"])
    out = num / signed_den_floor(den)[..., None]
    out = out.reshape(B, 1, H, out.shape[-1]).astype(dt)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def build_landmark_cache(params: dict, cfg: ModelConfig, k: jnp.ndarray,
                         v: jnp.ndarray, key: jax.Array) -> dict:
    """Prefill-side construction of the landmark cache from full K/V
    (B, S, KV, D): the paper's Algorithm 1 applied to the softmax Gram,
    batched over (B, KV)."""
    from repro.core.sketched_attention import build_landmark_state

    def one(kh, vh, kk):
        st = build_landmark_state(
            kh, vh, kk, c=cfg.landmark_c, theta=cfg.landmark_theta,
            selection=getattr(cfg, "landmark_selection", "strided"))
        return st.k_land, st.UV, st.U1, st.scale

    B, S, KV, D = k.shape
    keys = jax.random.split(key, B * KV).reshape(B, KV)
    kt = k.transpose(0, 2, 1, 3)                             # (B,KV,S,D)
    vt = v.transpose(0, 2, 1, 3)
    k_land, uv, u1, off = jax.vmap(jax.vmap(one))(kt, vt, keys)
    return {"k_land": k_land, "uv": uv, "u1": u1, "offset": off}
