"""Recurrent mixers: RG-LRU (recurrentgemma), mLSTM / sLSTM (xLSTM).

All three expose the same triple of entry points used by transformer.py:

- ``*_full(params, cfg, x)``              train/prefill over a full sequence
- ``*_decode(params, cfg, x, state)``     one token, carrying state
- ``init_*_state(cfg, batch)``            zero decode state

Sub-quadratic by construction:
- RG-LRU trains via ``jax.lax.associative_scan`` on the linear recurrence
  h_t = a_t h_{t-1} + b_t  (O(S log S) elementwise, no S^2 anywhere);
- mLSTM uses the stabilized *chunkwise* form — intra-chunk (L x L) masked
  matmuls + inter-chunk scanned matrix state (O(S·L) + O(S/L) state GEMMs);
- sLSTM is inherently sequential (scalar memory w/ recurrent gate mixing):
  one fused ``lax.scan`` over time.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


# ===========================================================================
# RG-LRU block (Griffin recurrent block: gate branch ⊙ (conv -> RG-LRU))
# ===========================================================================

_RGLRU_C = 8.0


def init_rglru(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    # Λ init so that a = exp(-c softplus Λ) spans ~(0.9, 0.999)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C))         # softplus^-1
    return {
        "w_gate": L.dense_init(ks[0], (d, w), cfg.pdtype),
        "w_x": L.dense_init(ks[1], (d, w), cfg.pdtype),
        "conv_k": L.dense_init(ks[2], (cfg.rglru_conv_width, w), cfg.pdtype,
                               scale=cfg.rglru_conv_width ** -0.5),
        "w_a": L.dense_init(ks[3], (w, w), cfg.pdtype),
        "w_i": L.dense_init(ks[4], (w, w), cfg.pdtype),
        "lam": lam.astype(jnp.float32),
        "w_out": L.dense_init(ks[6], (w, d), cfg.pdtype),
    }


def _causal_conv_full(x: jnp.ndarray, kern: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, S, w), kern: (K, w)."""
    K = kern.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(K):
        out = out + xp[:, j:j + x.shape[1], :] * kern[j][None, None, :]
    return out


def _rglru_gates(params: dict, cfg: ModelConfig, u: jnp.ndarray):
    """u: (..., w) post-conv input -> (log_a, b) of the recurrence."""
    dt = cfg.cdtype
    r = jax.nn.sigmoid(u @ params["w_a"].astype(dt)).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ params["w_i"].astype(dt)).astype(jnp.float32)
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (
        i * u.astype(jnp.float32))
    return log_a, b


def rglru_full(params: dict, cfg: ModelConfig,
               x: jnp.ndarray) -> jnp.ndarray:
    dt = cfg.cdtype
    gate = jax.nn.gelu(x @ params["w_gate"].astype(dt), approximate=True)
    u = x @ params["w_x"].astype(dt)
    u = _causal_conv_full(u, params["conv_k"].astype(dt))
    log_a, b = _rglru_gates(params, cfg, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    y = (h.astype(dt) * gate) @ params["w_out"].astype(dt)
    return y


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, w),
                              cfg.cdtype)}


def rglru_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                 state: dict) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d)."""
    dt = cfg.cdtype
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ params["w_gate"].astype(dt), approximate=True)
    u_new = xt @ params["w_x"].astype(dt)                    # (B, w)
    hist = jnp.concatenate([state["conv"], u_new[:, None]], axis=1)  # (B,K,w)
    kern = params["conv_k"].astype(dt)
    u = jnp.einsum("bkw,kw->bw", hist, kern)
    log_a, b = _rglru_gates(params, cfg, u)
    h = jnp.exp(log_a) * state["h"] + b
    y = (h.astype(dt) * gate) @ params["w_out"].astype(dt)
    return y[:, None], {"h": h, "conv": hist[:, 1:]}


# ===========================================================================
# mLSTM (matrix memory, chunkwise-stabilized)
# ===========================================================================

def init_mlstm(key: jax.Array, cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq": L.dense_init(ks[0], (d, h, hd), cfg.pdtype),
        "wk": L.dense_init(ks[1], (d, h, hd), cfg.pdtype),
        "wv": L.dense_init(ks[2], (d, h, hd), cfg.pdtype),
        "wi": L.dense_init(ks[3], (d, h), jnp.float32),
        "wf": L.dense_init(ks[4], (d, h), jnp.float32),
        "bf": jnp.full((h,), 3.0, jnp.float32),              # open forget gate
        "wog": L.dense_init(ks[5], (d, h, hd), cfg.pdtype),
        "wo": L.dense_init(ks[6], (h, hd, d), cfg.pdtype),
    }


def _mlstm_proj(params: dict, cfg: ModelConfig, x: jnp.ndarray):
    dt = cfg.cdtype
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt)) * (hd ** -0.5)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    li = (x.astype(jnp.float32) @ params["wi"])              # log input gate
    lf = jax.nn.log_sigmoid(x.astype(jnp.float32) @ params["wf"]
                            + params["bf"])                  # log forget
    og = jax.nn.sigmoid(
        jnp.einsum("bsd,dhk->bshk", x, params["wog"].astype(dt)))
    return q, k, v, li, lf, og


def mlstm_full(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    Lc = min(cfg.mlstm_chunk, S)
    assert S % Lc == 0, (S, Lc)
    nC = S // Lc
    q, k, v, li, lf, og = _mlstm_proj(params, cfg, x)

    def resh(t, extra):                                      # (B,S,...) chunks
        return t.reshape((B, nC, Lc) + extra).swapaxes(0, 1)

    qc = resh(q.astype(jnp.float32), (H, hd))
    kc = resh(k.astype(jnp.float32), (H, hd))
    vc = resh(v.astype(jnp.float32), (H, hd))
    lic = resh(li, (H,))
    lfc = resh(lf, (H,))

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)

    def chunk_step(carry, inp):
        C_hat, n_hat, m_prev = carry
        qch, kch, vch, lich, lfch = inp                      # (B,Lc,H,...)
        b = jnp.cumsum(lfch, axis=1)                         # (B,Lc,H)
        g = lich - b                                         # log source wts
        gmax = jax.lax.cummax(g, axis=1)
        m_i = b + jnp.maximum(m_prev[:, None], gmax)         # (B,Lc,H)
        inter = jnp.exp(b + m_prev[:, None] - m_i)           # (B,Lc,H)

        # intra: D_ij = exp(b_i + g_j - m_i) for j<=i
        Dij = jnp.exp(b[:, :, None] + g[:, None, :]
                      - m_i[:, :, None])                     # (B,Lc,Lc,H)
        tri = jnp.tril(jnp.ones((Lc, Lc), jnp.float32))
        Dij = Dij * tri[None, :, :, None]
        sij = jnp.einsum("blhk,bjhk->bljh", qch, kch) * Dij
        intra_num = jnp.einsum("bljh,bjhk->blhk", sij, vch)
        intra_den = jnp.sum(sij, axis=2)                     # (B,Lc,H)

        inter_num = jnp.einsum("blhk,bhkv->blhv", qch, C_hat) * inter[..., None]
        inter_den = jnp.einsum("blhk,bhk->blh", qch, n_hat) * inter

        num = intra_num + inter_num
        den = jnp.maximum(jnp.abs(intra_den + inter_den), jnp.exp(-m_i))
        h = num / den[..., None]                             # (B,Lc,H,hd)

        # state update to chunk end
        bL = b[:, -1]                                        # (B,H)
        m_new = m_i[:, -1]
        decay = jnp.exp(bL + m_prev - m_new)
        # exp(bL - b_j + li_j - m_new) = exp(bL + g_j - m_new)
        src = jnp.exp(bL[:, None] + g - m_new[:, None])      # (B,Lc,H)
        C_new = decay[:, :, None, None] * C_hat + jnp.einsum(
            "bjh,bjhk,bjhv->bhkv", src, kch, vch)
        n_new = decay[:, :, None] * n_hat + jnp.einsum(
            "bjh,bjhk->bhk", src, kch)
        return (C_new, n_new, m_new), h

    if cfg.unroll_scans and nC <= 128:
        carry, blocks = (C0, n0, m0), []
        for i in range(nC):
            carry, h = chunk_step(carry, (qc[i], kc[i], vc[i],
                                          lic[i], lfc[i]))
            blocks.append(h)
        hs = jnp.stack(blocks)
    else:
        (_, _, _), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                     (qc, kc, vc, lic, lfc))
    hs = hs.swapaxes(0, 1).reshape(B, S, H, hd)              # (B,S,H,hd)
    out = hs.astype(cfg.cdtype) * og
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cfg.cdtype))


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    H, hd = cfg.n_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def mlstm_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                 state: dict) -> Tuple[jnp.ndarray, dict]:
    q, k, v, li, lf, og = _mlstm_proj(params, cfg, x)        # S = 1
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    li, lf, og = li[:, 0], lf[:, 0], og[:, 0]
    m_new = jnp.maximum(lf + state["m"], li)
    decay = jnp.exp(lf + state["m"] - m_new)
    src = jnp.exp(li - m_new)
    C = decay[..., None, None] * state["C"] + src[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = decay[..., None] * state["n"] + src[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(cfg.cdtype) * og
    y = jnp.einsum("bhk,hkd->bd", h, params["wo"].astype(cfg.cdtype))
    return y[:, None], {"C": C, "n": n, "m": m_new}


# ===========================================================================
# sLSTM (scalar memory, exponential gating, recurrent mixing)
# ===========================================================================

def init_slstm(key: jax.Array, cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 9)
    p = {}
    for i, name in enumerate(("z", "i", "f", "o")):
        p[f"w{name}"] = L.dense_init(ks[i], (d, h, hd), cfg.pdtype)
        p[f"r{name}"] = L.dense_init(ks[4 + i], (h, hd, hd), cfg.pdtype,
                                     scale=hd ** -0.5)
    p["bf"] = jnp.full((h, hd), 3.0, jnp.float32)
    p["wo_proj"] = L.dense_init(ks[8], (h, hd, d), cfg.pdtype)
    return p


def _slstm_step(params: dict, cfg: ModelConfig, xt_proj: dict, state: dict):
    """One timestep. xt_proj: precomputed x projections (B,H,hd) per gate."""
    dt = jnp.float32
    h_prev = state["h"]

    def rec(name):
        return jnp.einsum("bhk,hkj->bhj", h_prev,
                          params[f"r{name}"].astype(dt))

    z = jnp.tanh(xt_proj["z"] + rec("z"))
    li = xt_proj["i"] + rec("i")                             # log input gate
    lf = jax.nn.log_sigmoid(xt_proj["f"] + rec("f")
                            + params["bf"][None])            # log forget
    o = jax.nn.sigmoid(xt_proj["o"] + rec("o"))
    m_new = jnp.maximum(lf + state["m"], li)
    i_s = jnp.exp(li - m_new)
    f_s = jnp.exp(lf + state["m"] - m_new)
    c = f_s * state["c"] + i_s * z
    n = jnp.maximum(f_s * state["n"] + i_s, 1e-6)
    h = o * (c / n)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_full(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    B, S, d = x.shape
    proj = {name: jnp.einsum("bsd,dhk->bshk", x.astype(jnp.float32),
                             params[f"w{name}"].astype(jnp.float32))
            for name in ("z", "i", "f", "o")}
    state = init_slstm_state(cfg, B)

    def step(st, xs):
        st2 = _slstm_step(params, cfg, xs, st)
        return st2, st2["h"]

    xs = {k: v.swapaxes(0, 1) for k, v in proj.items()}      # (S,B,H,hd)
    _, hs = jax.lax.scan(step, state, xs)
    hs = hs.swapaxes(0, 1)                                   # (B,S,H,hd)
    return jnp.einsum("bshk,hkd->bsd", hs.astype(cfg.cdtype),
                      params["wo_proj"].astype(cfg.cdtype))


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    H, hd = cfg.n_heads, cfg.head_dim
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": jnp.full_like(z, 1e-6), "h": z,
            "m": jnp.full_like(z, -1e30)}


def slstm_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                 state: dict) -> Tuple[jnp.ndarray, dict]:
    proj = {name: jnp.einsum("bd,dhk->bhk", x[:, 0].astype(jnp.float32),
                             params[f"w{name}"].astype(jnp.float32))
            for name in ("z", "i", "f", "o")}
    st2 = _slstm_step(params, cfg, proj, state)
    y = jnp.einsum("bhk,hkd->bd", st2["h"].astype(cfg.cdtype),
                   params["wo_proj"].astype(cfg.cdtype))
    return y[:, None], st2
