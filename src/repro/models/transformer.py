"""Block assembly: residual blocks, scanned stacks, decoder-only LM, enc-dec.

A model is ``prefix blocks`` (unscanned, e.g. deepseek's first-3 dense) +
``R`` scanned *superblocks* (one pass through cfg.layer_pattern) +
``remainder blocks`` (pattern prefix, e.g. recurrentgemma's trailing 2).

Every block kind exposes three modes:
  train   : (x) -> (x', aux)
  prefill : (x) -> (x', aux, cache_entry)   cache sized ``max_len``
  decode  : (x, cache_entry, pos) -> (x', cache_entry')
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R


ATTN_KINDS = ("attn", "local", "global")
REC_KINDS = ("mlstm", "slstm", "rglru")


def _is_moe_layer(cfg: ModelConfig, in_prefix: bool) -> bool:
    return cfg.n_experts > 0 and not in_prefix


def _has_mlp(cfg: ModelConfig, kind: str, moe: bool) -> bool:
    if kind in ("mlstm", "slstm"):
        return False                                         # xLSTM blocks
    return moe or cfg.d_ff > 0 or cfg.dense_d_ff > 0


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block(key: jax.Array, cfg: ModelConfig, kind: str,
               moe: bool, dense_ff: Optional[int] = None) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": L.init_rmsnorm(cfg.d_model, cfg.pdtype)}
    if kind in ATTN_KINDS:
        p["mixer"] = A.init_attention(k1, cfg)
    elif kind == "mlstm":
        p["mixer"] = R.init_mlstm(k1, cfg)
    elif kind == "slstm":
        p["mixer"] = R.init_slstm(k1, cfg)
    elif kind == "rglru":
        p["mixer"] = R.init_rglru(k1, cfg)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        p["post_norm1"] = L.init_rmsnorm(cfg.d_model, cfg.pdtype)
    if _has_mlp(cfg, kind, moe):
        p["norm2"] = L.init_rmsnorm(cfg.d_model, cfg.pdtype)
        if moe:
            p["moe"] = M.init_moe(k2, cfg)
        else:
            p["mlp"] = L.init_mlp(k3, cfg, d_ff=dense_ff)
        if cfg.post_norm:
            p["post_norm2"] = L.init_rmsnorm(cfg.d_model, cfg.pdtype)
    return p


def _mixer_full(params: dict, cfg: ModelConfig, kind: str, x: jnp.ndarray,
                positions: jnp.ndarray, causal: bool = True) -> jnp.ndarray:
    if kind in ATTN_KINDS:
        if not causal:
            return _encoder_attention(params, cfg, x, positions)
        return A.attention_full(params, cfg, x, positions, kind)
    if kind == "mlstm":
        return R.mlstm_full(params, cfg, x)
    if kind == "slstm":
        return R.slstm_full(params, cfg, x)
    return R.rglru_full(params, cfg, x)


def _encoder_attention(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                       positions: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional self-attention (whisper encoder)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd
    sp = A._sp_active(cfg, x.shape[1])
    if sp:
        x = shd.constrain(x, P(None, "model", None))
    q, k, v = A._qkv(params, cfg, x, positions, cfg.rope_theta)
    out = A._sdpa(q, k, v, cfg, causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cfg.cdtype))
    if sp:
        y = shd.constrain(y, P(None, "model", None))
    return y


def block_full(params: dict, cfg: ModelConfig, kind: str, moe: bool,
               x: jnp.ndarray, positions: jnp.ndarray,
               causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = _mixer_full(params["mixer"], cfg, kind,
                    L.rmsnorm(params["norm1"], x, cfg.norm_eps),
                    positions, causal)
    if cfg.post_norm:
        h = L.rmsnorm(params["post_norm1"], h, cfg.norm_eps)
    h = checkpoint_name(h, "mixer_out")
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in params or "moe" in params:
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if "moe" in params:
            h, aux = M.moe_ffn(params["moe"], cfg, h)
        else:
            h = L.mlp(params["mlp"], cfg, h)
        if cfg.post_norm:
            h = L.rmsnorm(params["post_norm2"], h, cfg.norm_eps)
        h = checkpoint_name(h, "mlp_out")
        x = x + h
    return x, aux


# --- prefill: block_full + cache construction ------------------------------

def block_prefill(params: dict, cfg: ModelConfig, kind: str, moe: bool,
                  x: jnp.ndarray, positions: jnp.ndarray, max_len: int,
                  key: jax.Array) -> Tuple[jnp.ndarray, dict]:
    """Returns (x', cache_entry). Shares compute structure with block_full."""
    B, S, _ = x.shape
    xin = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        cache = _attn_prefill_cache(params["mixer"], cfg, kind, xin,
                                    positions, max_len, key)
        h = A.attention_full(params["mixer"], cfg, xin, positions, kind)
    elif kind == "mlstm":
        h = R.mlstm_full(params["mixer"], cfg, xin)
        cache = _rec_prefill_state(params["mixer"], cfg, kind, xin)
    elif kind == "slstm":
        h = R.slstm_full(params["mixer"], cfg, xin)
        cache = _rec_prefill_state(params["mixer"], cfg, kind, xin)
    else:
        h = R.rglru_full(params["mixer"], cfg, xin)
        cache = _rec_prefill_state(params["mixer"], cfg, kind, xin)
    if cfg.post_norm:
        h = L.rmsnorm(params["post_norm1"], h, cfg.norm_eps)
    x = x + h
    if "mlp" in params or "moe" in params:
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if "moe" in params:
            h, _ = M.moe_ffn(params["moe"], cfg, h)
        else:
            h = L.mlp(params["mlp"], cfg, h)
        if cfg.post_norm:
            h = L.rmsnorm(params["post_norm2"], h, cfg.norm_eps)
        x = x + h
    return x, cache


def _attn_prefill_cache(mp: dict, cfg: ModelConfig, kind: str,
                        xin: jnp.ndarray, positions: jnp.ndarray,
                        max_len: int, key: jax.Array) -> dict:
    B, S, _ = xin.shape
    dt = cfg.cdtype
    if cfg.use_mla:
        kv_a = xin @ mp["wkv_a"].astype(dt)
        ckv = L.rmsnorm(mp["kv_norm"], kv_a[..., :cfg.kv_lora_rank],
                        cfg.norm_eps)
        krope = L.apply_rope(
            kv_a[..., None, cfg.kv_lora_rank:].swapaxes(1, 2), positions,
            cfg.rope_theta).swapaxes(1, 2)[:, :, 0]
        pad = max_len - S
        return {"ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                "krope": jnp.pad(krope, ((0, 0), (0, pad), (0, 0)))}
    theta = cfg.rope_theta_local if kind == "local" else cfg.rope_theta
    _, k, v = A._qkv(mp, cfg, xin, positions, theta)         # (B,S,KV,D)
    if kind == "global" and cfg.use_landmark_decode:
        return A.build_landmark_cache(mp, cfg, k, v, key)
    if kind == "local" and cfg.window is not None:
        W = min(cfg.window, max_len)
        j = jnp.arange(W)
        src = jnp.maximum(S - W, 0) + j                      # last W positions
        src = jnp.clip(src, 0, S - 1)
        slots = src % W
        kw = jnp.take(k, src, axis=1)
        vw = jnp.take(v, src, axis=1)
        kr = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(kw)
        vr = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(vw)
        return {"k": kr, "v": vr}
    pad = max_len - S
    return {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}


def _rec_prefill_state(mp: dict, cfg: ModelConfig, kind: str,
                       xin: jnp.ndarray) -> dict:
    """Recompute the final recurrent state by a decode-scan over the input.

    O(S) like the parallel pass; keeps the *_full implementations scan-free.
    """
    B = xin.shape[0]
    if kind == "mlstm":
        state = R.init_mlstm_state(cfg, B)
        step = functools.partial(R.mlstm_decode, mp, cfg)
    elif kind == "slstm":
        state = R.init_slstm_state(cfg, B)
        step = functools.partial(R.slstm_decode, mp, cfg)
    else:
        state = R.init_rglru_state(cfg, B)
        step = functools.partial(R.rglru_decode, mp, cfg)

    def body(st, xt):
        _, st2 = step(xt[:, None], st)
        return st2, None

    st, _ = jax.lax.scan(body, state, xin.swapaxes(0, 1))
    return st


# --- decode ----------------------------------------------------------------

def block_decode(params: dict, cfg: ModelConfig, kind: str, moe: bool,
                 x: jnp.ndarray, cache: dict,
                 pos: jnp.ndarray) -> Tuple[jnp.ndarray, dict]:
    xin = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        h, cache = A.attention_decode(params["mixer"], cfg, xin, cache,
                                      pos, kind)
    elif kind == "mlstm":
        h, cache = R.mlstm_decode(params["mixer"], cfg, xin, cache)
    elif kind == "slstm":
        h, cache = R.slstm_decode(params["mixer"], cfg, xin, cache)
    else:
        h, cache = R.rglru_decode(params["mixer"], cfg, xin, cache)
    if cfg.post_norm:
        h = L.rmsnorm(params["post_norm1"], h, cfg.norm_eps)
    x = x + h
    if "mlp" in params or "moe" in params:
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if "moe" in params:
            h, _ = M.moe_ffn(params["moe"], cfg, h)
        else:
            h = L.mlp(params["mlp"], cfg, h)
        if cfg.post_norm:
            h = L.rmsnorm(params["post_norm2"], h, cfg.norm_eps)
        x = x + h
    return x, cache


def block_cache_shape(cfg: ModelConfig, kind: str, batch: int,
                      max_len: int):
    """eval_shape-able zero cache for one block (decode dry-run)."""
    if kind in ATTN_KINDS:
        return A.init_cache(cfg, kind, batch, max_len)
    if kind == "mlstm":
        return R.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return R.init_slstm_state(cfg, batch)
    return R.init_rglru_state(cfg, batch)


# ---------------------------------------------------------------------------
# stacks (prefix + scanned superblocks + remainder)
# ---------------------------------------------------------------------------

def stack_layout(cfg: ModelConfig):
    """-> (prefix_kinds, pattern, n_repeats, remainder_kinds)."""
    pattern = tuple(cfg.layer_pattern)
    prefix = tuple(pattern[i % len(pattern)]
                   for i in range(cfg.first_k_dense))
    n_rest = cfg.n_layers - cfg.first_k_dense
    reps = n_rest // len(pattern)
    remainder = pattern[: n_rest % len(pattern)]
    return prefix, pattern, reps, remainder


def init_stack(key: jax.Array, cfg: ModelConfig) -> dict:
    prefix, pattern, reps, remainder = stack_layout(cfg)
    kp, ks, kr = jax.random.split(key, 3)
    params = {}
    params["prefix"] = tuple(
        init_block(jax.random.fold_in(kp, i), cfg, kind, moe=False,
                   dense_ff=cfg.dense_d_ff or None)
        for i, kind in enumerate(prefix))

    def init_super(k):
        kk = jax.random.split(k, len(pattern))
        return tuple(init_block(kk[i], cfg, kind,
                                moe=_is_moe_layer(cfg, False))
                     for i, kind in enumerate(pattern))

    if reps > 0:
        if cfg.scan_layers:
            keys = jax.random.split(ks, reps)
            params["scanned"] = jax.vmap(init_super)(keys)
        else:
            params["scanned"] = [init_super(jax.random.fold_in(ks, i))
                                 for i in range(reps)]
    else:
        params["scanned"] = ()
    params["remainder"] = tuple(
        init_block(jax.random.fold_in(kr, i), cfg, kind,
                   moe=_is_moe_layer(cfg, False))
        for i, kind in enumerate(remainder))
    return params


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if cfg.remat == "save_io":
        # collective-aware remat: save the post-all-reduce mixer/mlp outputs
        # so the backward recompute does not re-run the forward TP
        # all-reduces (6/layer -> 4/layer AR volume) at the cost of two
        # bf16 (B_micro, S, d) residuals per layer
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "mixer_out", "mlp_out"))
    return jax.checkpoint(fn)


def stack_full(params: dict, cfg: ModelConfig, x: jnp.ndarray,
               positions: jnp.ndarray, causal: bool = True):
    """Train-mode stack. Returns (x, aux_sum)."""
    prefix, pattern, reps, remainder = stack_layout(cfg)
    aux = jnp.zeros((), jnp.float32)
    for p, kind in zip(params["prefix"], prefix):
        x, a = block_full(p, cfg, kind, False, x, positions, causal)
        aux = aux + a

    def super_body(carry, sb_params):
        h, ax = carry
        for i, kind in enumerate(pattern):
            h, a = block_full(sb_params[i], cfg, kind,
                              _is_moe_layer(cfg, False), h, positions, causal)
            ax = ax + a
        return (h, ax), None

    if reps > 0:
        body = _remat(cfg, super_body)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["scanned"])
        else:
            # unrolled path: same remat policy so the dry-run's layer-count
            # extrapolation (dryrun.py) measures the true per-layer cost
            for sb in params["scanned"]:
                (x, aux), _ = body((x, aux), sb)

    for p, kind in zip(params["remainder"], remainder):
        x, a = block_full(p, cfg, kind, _is_moe_layer(cfg, False), x,
                          positions, causal)
        aux = aux + a
    return x, aux


def stack_prefill(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray, max_len: int, key: jax.Array):
    prefix, pattern, reps, remainder = stack_layout(cfg)
    caches = {"prefix": [], "scanned": None, "remainder": []}
    for i, (p, kind) in enumerate(zip(params["prefix"], prefix)):
        x, c = block_prefill(p, cfg, kind, False, x, positions, max_len,
                             jax.random.fold_in(key, 1000 + i))
        caches["prefix"].append(c)

    def super_body(h, xs):
        sb_params, kd = xs
        kk = jax.random.wrap_key_data(kd)
        cs = []
        for i, kind in enumerate(pattern):
            h, c = block_prefill(sb_params[i], cfg, kind,
                                 _is_moe_layer(cfg, False), h, positions,
                                 max_len, jax.random.fold_in(kk, i))
            cs.append(c)
        return h, tuple(cs)

    if reps > 0:
        keys = jax.random.key_data(jax.random.split(key, reps))
        if cfg.scan_layers:
            x, sc = jax.lax.scan(super_body, x, (params["scanned"], keys))
        else:
            sc_list = []
            for i, sb in enumerate(params["scanned"]):
                x, c = super_body(x, (sb, keys[i]))
                sc_list.append(c)
            sc = jax.tree.map(lambda *xs: jnp.stack(xs), *sc_list)
        caches["scanned"] = sc

    for i, (p, kind) in enumerate(zip(params["remainder"], remainder)):
        x, c = block_prefill(p, cfg, kind, _is_moe_layer(cfg, False), x,
                             positions, max_len,
                             jax.random.fold_in(key, 2000 + i))
        caches["remainder"].append(c)
    caches["prefix"] = tuple(caches["prefix"])
    caches["remainder"] = tuple(caches["remainder"])
    return x, caches


def stack_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                 caches: dict, pos: jnp.ndarray):
    prefix, pattern, reps, remainder = stack_layout(cfg)
    new_prefix = []
    for p, kind, c in zip(params["prefix"], prefix, caches["prefix"]):
        x, c2 = block_decode(p, cfg, kind, False, x, c, pos)
        new_prefix.append(c2)

    def super_body(h, xs):
        sb_params, sb_cache = xs
        cs = []
        for i, kind in enumerate(pattern):
            h, c2 = block_decode(sb_params[i], cfg, kind,
                                 _is_moe_layer(cfg, False), h, sb_cache[i],
                                 pos)
            cs.append(c2)
        return h, tuple(cs)

    new_scanned = caches.get("scanned")
    if reps > 0:
        if cfg.scan_layers:
            x, new_scanned = jax.lax.scan(
                super_body, x, (params["scanned"], caches["scanned"]))
        else:
            outs = []
            for i, sb in enumerate(params["scanned"]):
                sb_cache = jax.tree.map(lambda t: t[i], caches["scanned"])
                x, c2 = super_body(x, (sb, sb_cache))
                outs.append(c2)
            new_scanned = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    new_rem = []
    for p, kind, c in zip(params["remainder"], remainder,
                          caches["remainder"]):
        x, c2 = block_decode(p, cfg, kind, _is_moe_layer(cfg, False), x, c,
                             pos)
        new_rem.append(c2)
    return x, {"prefix": tuple(new_prefix), "scanned": new_scanned,
               "remainder": tuple(new_rem)}


def stack_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    prefix, pattern, reps, remainder = stack_layout(cfg)
    cache = {
        "prefix": tuple(block_cache_shape(cfg, kind, batch, max_len)
                        for kind in prefix),
        "remainder": tuple(block_cache_shape(cfg, kind, batch, max_len)
                           for kind in remainder),
        "scanned": None,
    }
    if reps > 0:
        one = tuple(block_cache_shape(cfg, kind, batch, max_len)
                    for kind in pattern)
        cache["scanned"] = jax.tree.map(
            lambda t: jnp.zeros((reps,) + t.shape, t.dtype), one)
    return cache
