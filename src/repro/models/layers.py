"""Shared layers: norms, embeddings, RoPE, MLP variants.

Pure-function style: ``init_*`` returns a params pytree; ``apply`` functions
take (params, x).  Sharding is attached *by name* via the rules in
``repro.distributed.sharding`` — parameter path names here are load-bearing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}          # gemma-style (1+scale)


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embed(key: jax.Array, cfg: ModelConfig) -> dict:
    # tied embeddings double as the unembed: std d^-1/2 keeps init-time
    # logits O(1) (scale_embed restores O(1) input activations).
    emb_std = cfg.d_model ** -0.5 if cfg.tie_embeddings else 1.0
    p = {"embedding": dense_init(key, (cfg.vocab_size, cfg.d_model),
                                 cfg.pdtype, scale=emb_std)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(jax.random.fold_in(key, 1),
                                  (cfg.d_model, cfg.vocab_size), cfg.pdtype)
    return p


def embed(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(params["embedding"].astype(cfg.cdtype), tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    return x


def unembed(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embedding"].astype(cfg.cdtype).T
    else:
        w = params["unembed"].astype(cfg.cdtype)
    logits = x @ w
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, D) with positions (S,) or (..., S)."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                       # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    ff = cfg.d_ff if d_ff is None else d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {"wi_gate": dense_init(k1, (d, ff), cfg.pdtype),
                "wi_up": dense_init(k2, (d, ff), cfg.pdtype),
                "wo": dense_init(k3, (ff, d), cfg.pdtype)}
    return {"wi_up": dense_init(k2, (d, ff), cfg.pdtype),
            "wo": dense_init(k3, (ff, d), cfg.pdtype)}


def mlp(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    dt = cfg.cdtype
    up = x @ params["wi_up"].astype(dt)
    if cfg.mlp_variant == "swiglu":
        gate = jax.nn.silu(x @ params["wi_gate"].astype(dt))
        h = gate * up
    elif cfg.mlp_variant == "geglu":
        gate = jax.nn.gelu(x @ params["wi_gate"].astype(dt), approximate=True)
        h = gate * up
    elif cfg.mlp_variant == "relu2":
        h = jnp.square(jax.nn.relu(up))
    elif cfg.mlp_variant == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(cfg.mlp_variant)
    return h @ params["wo"].astype(dt)
