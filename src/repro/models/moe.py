"""Mixture-of-Experts FFN (deepseek-v3 / qwen2-moe families).

Dispatch is the static-shape sort-based gather path (TPU-native; no dense
(T, E, C) one-hot):

  1. route: top-k softmax probs per token
  2. sort the T*k assignments by expert id (stable argsort)
  3. capacity-bound each expert to C = cf * T * k / E slots; overflow drops
  4. gather tokens into an (E, C, d) buffer — under pjit this is the
     data->expert all-to-all — run all experts as one batched GEMM,
     scatter-add back with the routing weights.

Shared experts (deepseek's 1, qwen's 4) are a plain dense MLP of width
n_shared * moe_d_ff added unconditionally.

``shard_map`` variant (moe_impl='shard_map'): the same algorithm with the
expert GEMMs under an explicit mesh-axis shard_map so the all-to-all is
scheduled manually — used by the §Perf hillclimb.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, E), jnp.float32),
        "wi_gate": L.dense_init(ks[1], (E, d, ff), cfg.pdtype),
        "wi_up": L.dense_init(ks[2], (E, d, ff), cfg.pdtype),
        "wo": L.dense_init(ks[3], (E, ff, d), cfg.pdtype),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * ff
        p["shared"] = L.init_mlp(ks[4], cfg, d_ff=sff)
    return p


def _route(params: dict, cfg: ModelConfig, xf: jnp.ndarray):
    """xf: (T, d) -> topk weights (T, k), indices (T, k), aux loss scalar."""
    logits = xf.astype(jnp.float32) @ params["router"]       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e (frac_tokens_e * mean_prob_e)
    E = cfg.n_experts
    hard = jnp.zeros((xf.shape[0], E), jnp.float32)
    hard = hard.at[jnp.arange(xf.shape[0])[:, None], idx].add(1.0)
    frac = jnp.mean(hard, axis=0) / cfg.moe_top_k
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_prob)
    return w, idx, aux


def _dispatch_compute(params: dict, cfg: ModelConfig, xf: jnp.ndarray,
                      w: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Sort-based capacity dispatch. xf: (T, d) -> (T, d)."""
    T, d = xf.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    Tk = T * k
    C = max(1, int(cfg.capacity_factor * Tk / E))
    C = -(-C // 8) * 8                                       # pad to 8

    eids = idx.reshape(-1)                                   # (Tk,)
    tok = jnp.arange(Tk, dtype=jnp.int32) // k
    wts = w.reshape(-1)

    order = jnp.argsort(eids, stable=True)
    se = eids[order]
    st = tok[order]
    sw = wts[order]
    first = jnp.searchsorted(se, se, side="left")
    pos_in_e = jnp.arange(Tk, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)         # drop row at end

    buf = jnp.zeros((E * C + 1, d), cfg.cdtype)
    buf = buf.at[slot].set(jnp.take(xf, st, axis=0))
    eb = buf[: E * C].reshape(E, C, d)                       # (E, C, d)

    dt = cfg.cdtype
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb,
                                  params["wi_gate"].astype(dt)))
    up = jnp.einsum("ecd,edf->ecf", eb, params["wi_up"].astype(dt))
    ob = jnp.einsum("ecf,efd->ecd", gate * up, params["wo"].astype(dt))
    ob_flat = jnp.concatenate(
        [ob.reshape(E * C, d), jnp.zeros((1, d), dt)], axis=0)

    vals = jnp.take(ob_flat, slot, axis=0) * (
        sw * keep.astype(jnp.float32))[:, None].astype(dt)
    out = jnp.zeros((T, d), dt).at[st].add(vals)
    return out


def moe_ffn(params: dict, cfg: ModelConfig,
            x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    if cfg.moe_impl == "shard_map" and _ep_axes_available(cfg):
        out, aux = _moe_shard_map(params, cfg, xf)
    else:
        w, idx, aux = _route(params, cfg, xf)
        out = _dispatch_compute(params, cfg, xf, w, idx)
    if cfg.n_shared_experts:
        out = out + L.mlp(params["shared"], cfg, xf)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (explicit all_to_all over ('data','model'))
# ---------------------------------------------------------------------------
# GSPMD cannot partition the data-dependent scatter of the gather path: it
# falls back to replicating the (Tk, d) token buffer on every chip, which the
# dry-run measures as hundreds of seconds of all-gather per step on
# deepseek-v3.  The production fix is the explicit EP protocol:
#
#   1. tokens are split across the whole ('data','model') group (each chip
#      routes a disjoint slice),
#   2. each chip sorts its assignments by destination expert and lays them
#      out as (n_ep, E_loc*C, d),
#   3. one all_to_all delivers every chip its own experts' tokens,
#   4. local expert GEMMs, reverse all_to_all, unsort, weighted combine,
#   5. one psum over 'model' restores the (replicated-over-TP) activations.
#
# Expert weights are sharded E -> ('data','model') (one expert per chip on
# the 256-chip pod for deepseek's 256 experts): no ZeRO all-gather is needed
# for expert banks at all.

def _ep_axes(cfg):
    return ("data", "model")


def _ep_axes_available(cfg) -> bool:
    try:
        from repro.distributed.sharding import ambient_axis_size
        n = 1
        for a in _ep_axes(cfg):
            n *= ambient_axis_size(a)
        return n > 1 and cfg.n_experts % n == 0
    except Exception:                                         # noqa: BLE001
        return False


def _moe_shard_map(params: dict, cfg: ModelConfig, xf: jnp.ndarray):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from jax._src import mesh as _mesh_lib

    mesh = _mesh_lib.thread_resources.env.physical_mesh
    axes = _ep_axes(cfg)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_ep = 1
    for a in axes:
        n_ep *= dict(mesh.shape).get(a, 1)

    tok_spec = P(dp if dp else None, None)     # (T, d): batch rows over DP

    def body(xf_l, router, wig, wiu, wo):
        # xf_l: this dp-slice's tokens, replicated over 'model'.
        # Each 'model' rank takes a disjoint token slice -> EP over n_ep.
        tp = dict(mesh.shape).get("model", 1)
        T_rep, d = xf_l.shape
        T_loc = T_rep // tp
        rank = jax.lax.axis_index("model")
        xs = jax.lax.dynamic_slice_in_dim(xf_l, rank * T_loc, T_loc, axis=0)

        E, k = cfg.n_experts, cfg.moe_top_k
        E_loc = E // n_ep
        logits = xs.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        frac = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32),
                        axis=(0, 1))          # already averaged over k slots
        aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
        aux = jax.lax.pmean(aux, axes)

        Tk = T_loc * k
        C = max(8, -(-int(cfg.capacity_factor * Tk / E) // 8) * 8)
        eids = idx.reshape(-1)
        tok = jnp.arange(Tk, dtype=jnp.int32) // k
        wts = w.reshape(-1)
        order = jnp.argsort(eids, stable=True)
        se, st, sw = eids[order], tok[order], wts[order]
        first = jnp.searchsorted(se, se, side="left")
        pos = jnp.arange(Tk, dtype=jnp.int32) - first.astype(jnp.int32)
        keep = pos < C
        slot = jnp.where(keep, se * C + pos, E * C)

        dt = cfg.cdtype
        sbuf = jnp.zeros((E * C + 1, d), dt).at[slot].set(
            jnp.take(xs, st, axis=0).astype(dt))
        sbuf = sbuf[: E * C].reshape(n_ep, E_loc * C, d)
        rbuf = jax.lax.all_to_all(sbuf, axes, split_axis=0, concat_axis=0,
                                  tiled=False)
        rb = rbuf.reshape(n_ep, E_loc, C, d).transpose(1, 0, 2, 3) \
                 .reshape(E_loc, n_ep * C, d)

        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", rb, wig.astype(dt)))
        up = jnp.einsum("ecd,edf->ecf", rb, wiu.astype(dt))
        ob = jnp.einsum("ecf,efd->ecd", gate * up, wo.astype(dt))

        ob = ob.reshape(E_loc, n_ep, C, d).transpose(1, 0, 2, 3) \
               .reshape(n_ep, E_loc * C, d)
        obuf = jax.lax.all_to_all(ob, axes, split_axis=0, concat_axis=0,
                                  tiled=False)
        flat = jnp.concatenate([obuf.reshape(E * C, d),
                                jnp.zeros((1, d), dt)], axis=0)
        vals = jnp.take(flat, slot, axis=0) * (
            sw * keep.astype(jnp.float32))[:, None].astype(dt)
        out_l = jnp.zeros((T_loc, d), dt).at[st].add(vals)

        # reassemble the 'model'-replicated activation: disjoint slices sum
        out = jnp.zeros((T_rep, d), dt)
        out = jax.lax.dynamic_update_slice_in_dim(out, out_l, rank * T_loc,
                                                  axis=0)
        out = jax.lax.psum(out, "model")
        return out, aux

    ep_spec = P(axes, None, None)              # (E, d, ff): E over EP group
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(None, None), ep_spec, ep_spec, ep_spec),
        out_specs=(tok_spec, P()),
        check_rep=False)
    return fn(xf, params["router"], params["wi_gate"], params["wi_up"],
              params["wo"])
