"""Top-level models: decoder-only LM, early-fusion VLM, whisper enc-dec.

``build_model(cfg)`` -> ``Model`` with five pure entry points the launcher
jits/pjits:

  init(key)                               -> params
  loss(params, batch)                     -> (scalar, metrics)     train_step
  forward(params, batch)                  -> (logits, aux)
  prefill(params, batch, key, max_len)    -> (last_logits, cache)  serve
  decode_step(params, cache, tokens, pos) -> (logits, cache)       serve_step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import transformer as T

MOE_AUX_WEIGHT = 0.01
MTP_WEIGHT = 0.3


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    cache_shape: Callable        # (batch, max_len) -> zero cache pytree


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over all positions; f32 logsumexp; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# decoder-only LM (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------

def _init_lm(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, ks, kf, km = jax.random.split(key, 4)
    params = {
        "embed": L.init_embed(ke, cfg),
        "stack": T.init_stack(ks, cfg),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
    }
    if cfg.mtp:
        km1, km2 = jax.random.split(km)
        params["mtp"] = {
            "proj": L.dense_init(km1, (2 * cfg.d_model, cfg.d_model),
                                 cfg.pdtype),
            "norm_h": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
            "norm_e": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
            "block": T.init_block(km2, cfg, "attn", moe=False,
                                  dense_ff=cfg.dense_d_ff or None),
            "final_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
        }
    return params


def _embed_inputs(params: dict, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    x = L.embed(params["embed"], cfg, batch["tokens"])
    if "patches" in batch:                                   # early fusion
        n_patch = batch["patches"].shape[1]
        x = jnp.concatenate(
            [batch["patches"].astype(cfg.cdtype), x[:, n_patch:]], axis=1)
    return x


def _lm_hidden(params: dict, cfg: ModelConfig, batch: dict):
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(x.shape[1])
    x, aux = T.stack_full(params["stack"], cfg, x, positions)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def _lm_forward(params: dict, cfg: ModelConfig, batch: dict):
    h, aux = _lm_hidden(params, cfg, batch)
    return L.unembed(params["embed"], cfg, h), aux


def _mtp_loss(params: dict, cfg: ModelConfig, batch: dict,
              h: jnp.ndarray) -> jnp.ndarray:
    """deepseek MTP: predict t+2 from [norm(h_t); norm(emb(token_{t+1}))]."""
    mp = params["mtp"]
    tok_next = jnp.roll(batch["tokens"], -1, axis=1)
    e = L.embed(params["embed"], cfg, tok_next)
    z = jnp.concatenate([L.rmsnorm(mp["norm_h"], h, cfg.norm_eps),
                         L.rmsnorm(mp["norm_e"], e, cfg.norm_eps)], axis=-1)
    z = z @ mp["proj"].astype(cfg.cdtype)
    positions = jnp.arange(z.shape[1])
    z, _ = T.block_full(mp["block"], cfg, "attn", False, z, positions)
    z = L.rmsnorm(mp["final_norm"], z, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, z)
    labels2 = jnp.roll(batch["labels"], -1, axis=1)
    labels2 = labels2.at[:, -2:].set(-1)                     # no target
    return softmax_xent(logits, labels2)


def _lm_loss(params: dict, cfg: ModelConfig, batch: dict):
    h, aux = _lm_hidden(params, cfg, batch)
    logits = L.unembed(params["embed"], cfg, h)
    ce = softmax_xent(logits, batch["labels"])
    total = ce + MOE_AUX_WEIGHT * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp:
        mtp = _mtp_loss(params, cfg, batch, h)
        total = total + MTP_WEIGHT * mtp
        metrics["mtp"] = mtp
    metrics["loss"] = total
    return total, metrics


def _lm_prefill(params: dict, cfg: ModelConfig, batch: dict, key: jax.Array,
                max_len: int):
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(x.shape[1])
    x, caches = T.stack_prefill(params["stack"], cfg, x, positions, max_len,
                                key)
    h_last = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, h_last)
    return logits[:, 0], caches


def _lm_decode(params: dict, cfg: ModelConfig, cache: dict,
               tokens: jnp.ndarray, pos: jnp.ndarray):
    x = L.embed(params["embed"], cfg, tokens)                # (B, 1, d)
    x, cache = T.stack_decode(params["stack"], cfg, x, cache, pos)
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, h)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# whisper-style encoder-decoder
# ---------------------------------------------------------------------------

def _sinusoid(S: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, n_layers=cfg.n_enc_layers,
                               layer_pattern=("attn",), first_k_dense=0)


def _dec_cfg(cfg: ModelConfig) -> ModelConfig:
    # decoder params are always *stored* stacked (vmap init); execution
    # scans when cfg.scan_layers else unrolls over slices (dry-run A/B)
    return dataclasses.replace(cfg, n_layers=cfg.n_dec_layers,
                               layer_pattern=("attn",), first_k_dense=0,
                               scan_layers=True)


def _slice_i(tree, i):
    return jax.tree.map(lambda t: t[i], tree)


def _init_encdec(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kd, kx, kt, kp = jax.random.split(key, 5)
    dec_reps = cfg.n_dec_layers
    xattn = jax.vmap(
        lambda k: {"xattn": A.init_attention(k, cfg, cross=True),
                   "xnorm": L.init_rmsnorm(cfg.d_model, cfg.pdtype)}
    )(jax.random.split(kx, dec_reps))
    return {
        "frontend_proj": L.dense_init(kp, (cfg.frontend_dim, cfg.d_model),
                                      cfg.pdtype),
        "encoder": T.init_stack(ke, _enc_cfg(cfg)),
        "enc_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
        "decoder": T.init_stack(kd, _dec_cfg(cfg)),
        "xattn": xattn,
        "embed": L.init_embed(kt, cfg),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
    }


def _encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray):
    x = frames.astype(cfg.cdtype) @ params["frontend_proj"].astype(cfg.cdtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(cfg.cdtype)[None]
    positions = jnp.arange(x.shape[1])
    x, _ = T.stack_full(params["encoder"], _enc_cfg(cfg), x, positions,
                        causal=False)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_stack(params: dict, cfg: ModelConfig, x: jnp.ndarray,
               enc_out: jnp.ndarray, positions: jnp.ndarray):
    """Decoder: (self-attn block + cross-attn) pairs, scanned or unrolled."""
    dcfg = _dec_cfg(cfg)

    def body(h, xs):
        sb, xp = xs
        h, _ = T.block_full(sb[0], dcfg, "attn", False, h, positions)
        xnorm = L.rmsnorm(xp["xnorm"], h, cfg.norm_eps)
        ek, ev = A.encoder_kv(xp["xattn"], cfg, enc_out)
        h = h + A.cross_attention(xp["xattn"], cfg, xnorm, ek, ev)
        return h, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, (params["decoder"]["scanned"],
                                      params["xattn"]))
    else:
        for i in range(cfg.n_dec_layers):
            x, _ = body(x, (_slice_i(params["decoder"]["scanned"], i),
                            _slice_i(params["xattn"], i)))
    return x


def _encdec_loss(params: dict, cfg: ModelConfig, batch: dict):
    enc_out = _encode(params, cfg, batch["frames"])
    x = L.embed(params["embed"], cfg, batch["tokens"])
    positions = jnp.arange(x.shape[1])
    x = _dec_stack(params, cfg, x, enc_out, positions)
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, h)
    ce = softmax_xent(logits, batch["labels"])
    return ce, {"ce": ce, "loss": ce}


def _encdec_forward(params: dict, cfg: ModelConfig, batch: dict):
    enc_out = _encode(params, cfg, batch["frames"])
    x = L.embed(params["embed"], cfg, batch["tokens"])
    positions = jnp.arange(x.shape[1])
    x = _dec_stack(params, cfg, x, enc_out, positions)
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], cfg, h), jnp.zeros((), jnp.float32)


def _encdec_prefill(params: dict, cfg: ModelConfig, batch: dict,
                    key: jax.Array, max_len: int):
    """Encode frames; prime the decoder self-attn cache with the BOS token;
    precompute per-layer cross-attention KV."""
    enc_out = _encode(params, cfg, batch["frames"])
    dcfg = _dec_cfg(cfg)
    x = L.embed(params["embed"], cfg, batch["tokens"])       # (B, 1, d)
    positions = jnp.arange(x.shape[1])

    def body(h, xs):
        sb, xp, kd = xs
        kk = jax.random.wrap_key_data(kd)
        h, c = T.block_prefill(sb[0], dcfg, "attn", False, h, positions,
                               max_len, kk)
        xnorm = L.rmsnorm(xp["xnorm"], h, cfg.norm_eps)
        ek, ev = A.encoder_kv(xp["xattn"], cfg, enc_out)
        h = h + A.cross_attention(xp["xattn"], cfg, xnorm, ek, ev)
        return h, (c, (ek, ev))

    keys = jax.random.key_data(jax.random.split(key, cfg.n_dec_layers))
    if cfg.scan_layers:
        x, (self_c, enc_kv) = jax.lax.scan(
            body, x, (params["decoder"]["scanned"], params["xattn"], keys))
    else:
        outs = []
        for i in range(cfg.n_dec_layers):
            x, o = body(x, (_slice_i(params["decoder"]["scanned"], i),
                            _slice_i(params["xattn"], i), keys[i]))
            outs.append(o)
        self_c, enc_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    h = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, h)
    return logits[:, 0], {"self": self_c, "enc_kv": enc_kv}


def _encdec_decode(params: dict, cfg: ModelConfig, cache: dict,
                   tokens: jnp.ndarray, pos: jnp.ndarray):
    dcfg = _dec_cfg(cfg)
    x = L.embed(params["embed"], cfg, tokens)

    def body(h, xs):
        sb, xp, c, ekv = xs
        h, c2 = T.block_decode(sb[0], dcfg, "attn", False, h, c, pos)
        xnorm = L.rmsnorm(xp["xnorm"], h, cfg.norm_eps)
        h = h + A.cross_attention(xp["xattn"], cfg, xnorm, ekv[0], ekv[1])
        return h, c2

    if cfg.scan_layers:
        x, self_c = jax.lax.scan(
            body, x, (params["decoder"]["scanned"], params["xattn"],
                      cache["self"], cache["enc_kv"]))
    else:
        outs = []
        for i in range(cfg.n_dec_layers):
            x, c2 = body(x, (_slice_i(params["decoder"]["scanned"], i),
                             _slice_i(params["xattn"], i),
                             _slice_i(cache["self"], i),
                             _slice_i(cache["enc_kv"], i)))
            outs.append(c2)
        self_c = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, h)
    return logits[:, 0], {"self": self_c, "enc_kv": cache["enc_kv"]}


def _encdec_cache(cfg: ModelConfig, batch: int, max_len: int,
                  enc_len: int = 1500) -> dict:
    dcfg = _dec_cfg(cfg)
    one = T.block_cache_shape(dcfg, "attn", batch, max_len)
    reps = cfg.n_dec_layers
    self_c = jax.tree.map(lambda t: jnp.zeros((reps,) + t.shape, t.dtype),
                          one)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    ekv = (jnp.zeros((reps, batch, enc_len, kv, hd), cfg.cdtype),
           jnp.zeros((reps, batch, enc_len, kv, hd), cfg.cdtype))
    return {"self": self_c, "enc_kv": ekv}


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

def _bind(fn, cfg):
    """Bind ``cfg`` into the second positional slot of ``fn(first, cfg, *rest)``."""
    @functools.wraps(fn)
    def wrapped(first, *rest):
        return fn(first, cfg, *rest)
    return wrapped


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encdec:
        return Model(
            cfg=cfg,
            init=functools.partial(_init_encdec, cfg=cfg),
            forward=_bind(_encdec_forward, cfg),
            loss=_bind(_encdec_loss, cfg),
            prefill=_bind(_encdec_prefill, cfg),
            decode_step=_bind(_encdec_decode, cfg),
            cache_shape=functools.partial(_encdec_cache, cfg),
        )
    return Model(
        cfg=cfg,
        init=functools.partial(_init_lm, cfg=cfg),
        forward=_bind(_lm_forward, cfg),
        loss=_bind(_lm_loss, cfg),
        prefill=_bind(_lm_prefill, cfg),
        decode_step=_bind(_lm_decode, cfg),
        cache_shape=functools.partial(T.stack_cache, cfg),
    )
