"""Per-``KernelSpec`` streaming bandwidth calibration (median-heuristic family).

The RBF "median heuristic" — set σ from the median pairwise distance — has a
kernel-agnostic core: every registered spec's entries are an elementwise
function of ONE pairwise statistic (``sqdist`` / ``dot`` / ``l1dist``), so a
quantile of that statistic fixes the spec's scale parameter such that typical
entries land in the kernel's responsive range.  PR 4 left ``calibrate_sigma``
RBF-only and dense; here it generalizes to every spec and streams:

1. the statistic is exposed as an operator (``PairwiseKernel.stat_operator``:
   the spec's stat with an identity entry function), so
2. an n × m panel of statistic values against ``m`` uniform anchor points is
   ONE ``columns`` gather — exactly n·m statistic evaluations (a direct
   block for pairwise kernels; generic operators stream it through the
   panel engine's selected-column gather), and
3. a registered per-spec *calibration rule* maps the quantile of those values
   to the spec's parameters (σ for rbf, γ for laplacian/polynomial, ℓ for
   matern32; linear has none).

Custom kernels register a rule next to their spec::

    from repro.kernels.pairwise import calibrate, specs

    @calibrate.register_calibration("cauchy")
    def _cal_cauchy(stat_q, base_spec):
        return specs.get_spec("cauchy", gamma=1.0 / max(stat_q, 1e-12))

    spec = calibrate.calibrate_sigma(X, spec="cauchy")
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.pairwise import specs as _specs
from repro.kernels.pairwise.specs import KernelSpec

_EPS = 1e-12


def anchor_indices(key: jax.Array, n: int, anchors: int) -> jnp.ndarray:
    """Uniform without-replacement anchor columns for the statistic panel."""
    return jax.random.choice(key, n, shape=(min(anchors, n),), replace=False)


def stat_quantile(stat_op, q: float = 0.5, anchors: int = 128,
                  key: Optional[jax.Array] = None,
                  anchor_idx: Optional[jnp.ndarray] = None,
                  transform: Optional[Callable] = None) -> jnp.ndarray:
    """q-quantile of a statistic operator's entries against anchor columns.

    ``stat_op`` is any ``SPSDOperator`` whose entries are the raw pairwise
    statistic (``PairwiseKernel.stat_operator()``); the n × m anchor panel is
    ONE ``columns`` gather — exactly n·m statistic evaluations, the same
    budget class as the C panel, budget-asserted by the calibration tests.
    (``PairwiseKernel`` answers it as a direct block from the data; generic
    operators stream it through the panel engine's selected-column gather —
    never a full-operator sweep, which would evaluate all n² entries.)  The
    quantile is exact over those n·m pairs; ``transform`` (e.g. ``jnp.abs``
    for the signed dot statistic) is applied first.  Pass ``anchor_idx`` to
    pin the anchor set (parity tests); otherwise it is drawn from ``key``.
    """
    if anchor_idx is None:
        key = jax.random.PRNGKey(0) if key is None else key
        anchor_idx = anchor_indices(key, stat_op.n, anchors)
    S = stat_op.columns(jnp.asarray(anchor_idx))
    if transform is not None:
        S = transform(S)
    return jnp.quantile(S.astype(jnp.float32), q)


@dataclasses.dataclass(frozen=True)
class CalibrationRule:
    """How a spec family turns a statistic quantile into parameters.

    ``needs_stat=False`` marks parameterless families (linear): the
    statistic sweep is skipped entirely and ``apply`` receives 0.0.
    """

    apply: Callable[[float, KernelSpec], KernelSpec]
    transform: Optional[Callable] = None     # pre-quantile (e.g. abs for dot)
    needs_stat: bool = True


_RULES: Dict[str, CalibrationRule] = {}


def register_calibration(name: str, transform: Optional[Callable] = None,
                         needs_stat: bool = True):
    """Decorator: register ``fn(stat_q, base_spec) -> KernelSpec`` for the
    spec family ``name`` (``transform`` preprocesses statistic values before
    the quantile — e.g. ``jnp.abs`` for signed dot products; pass
    ``needs_stat=False`` for parameterless families to skip the sweep)."""
    def deco(fn: Callable[[float, KernelSpec], KernelSpec]):
        _RULES[name] = CalibrationRule(apply=fn, transform=transform,
                                       needs_stat=needs_stat)
        return fn
    return deco


def registered_calibrations() -> Tuple[str, ...]:
    return tuple(sorted(_RULES))


def calibrate_sigma(X: jnp.ndarray, spec="rbf", *, q: float = 0.5,
                    anchors: int = 128, key: Optional[jax.Array] = None,
                    anchor_idx: Optional[jnp.ndarray] = None,
                    use_pallas: bool = False, stat_op=None) -> KernelSpec:
    """Calibrated ``KernelSpec`` for ``spec`` from one streaming gather pass.

    ``spec`` is a registered name or a ``KernelSpec`` (whose non-scale
    parameters — e.g. polynomial degree/coef0 — are preserved).  The spec's
    pairwise statistic is quantiled against ``anchors`` uniform anchor points
    in ONE n×m gather (see ``stat_quantile`` — n·m statistic evaluations,
    never a full sweep) and mapped to parameters by the family's registered
    calibration rule.  ``stat_op`` overrides the statistic operator
    (instrumented wrappers in tests).  Generalizes the RBF-only dense
    calibration of PR 4 to every registered spec.
    """
    base = _specs.get_spec(spec) if isinstance(spec, str) else spec
    if base.name not in _RULES:
        raise ValueError(
            f"no calibration rule for kernel {base.name!r} (registered: "
            f"{registered_calibrations()}); add one with "
            f"@register_calibration({base.name!r})")
    rule = _RULES[base.name]
    if not rule.needs_stat:            # parameterless family: no sweep at all
        return rule.apply(0.0, base)
    if stat_op is None:
        from repro.core.kernelop import PairwiseKernel
        stat_op = PairwiseKernel(jnp.asarray(X), _specs.stat_only(base),
                                 use_pallas)
    qv = stat_quantile(stat_op, q=q, anchors=anchors, key=key,
                       anchor_idx=anchor_idx, transform=rule.transform)
    return rule.apply(float(qv), base)


# ---------------------------------------------------------------------------
# built-in rules: typical statistic -> O(1) argument of the entry function
# ---------------------------------------------------------------------------

@register_calibration("rbf")
def _cal_rbf(stat_q: float, base: KernelSpec) -> KernelSpec:
    """Median heuristic: σ² = q(‖x−y‖²)/2, so the typical entry is e^{-1}."""
    return _specs.get_spec("rbf", sigma=(max(stat_q, _EPS) / 2.0) ** 0.5)


@register_calibration("laplacian")
def _cal_laplacian(stat_q: float, base: KernelSpec) -> KernelSpec:
    """γ = 1/q(‖x−y‖₁): the typical L1 distance maps to entry e^{-1}."""
    return _specs.get_spec("laplacian", gamma=1.0 / max(stat_q, _EPS))


@register_calibration("matern32")
def _cal_matern32(stat_q: float, base: KernelSpec) -> KernelSpec:
    """ℓ = typical distance √q(‖x−y‖²): entry (1+√3)e^{-√3} at that range."""
    return _specs.get_spec("matern32",
                           length_scale=max(stat_q, _EPS) ** 0.5)


@register_calibration("polynomial", transform=jnp.abs)
def _cal_polynomial(stat_q: float, base: KernelSpec) -> KernelSpec:
    """γ = 1/q(|xᵀy|) keeps γ·xᵀy O(1), so (γ xᵀy + c)ᵖ neither explodes nor
    collapses to cᵖ; degree and coef0 carry over from the base spec."""
    return _specs.get_spec("polynomial", degree=base.param("degree"),
                           gamma=1.0 / max(stat_q, _EPS),
                           coef0=base.param("coef0"))


@register_calibration("linear", needs_stat=False)
def _cal_linear(stat_q: float, base: KernelSpec) -> KernelSpec:
    """K = X Xᵀ has no scale parameter — calibration is the identity (and
    the statistic sweep is skipped: 0 passes)."""
    return base
