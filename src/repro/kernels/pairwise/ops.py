"""Public jit'd wrappers for the pairwise kernel sweep template.

Handles arbitrary (non-tile-aligned) shapes by zero-padding the point sets and
slicing the output; padding rows produce garbage kernel values that are sliced
away (block path) or contracted against zero-padded V rows (matmat path),
never read.

Backend selection (interpret mode on CPU containers, compiled on real TPU) is
resolved at *call* time, not import time: each public wrapper reads
``jax.default_backend()`` when invoked — unless the caller passes an explicit
``interpret=`` — and threads the choice into the jit cache as a static
argument, so flipping the backend after import can never run a stale
interpret decision.  The ``spec`` is likewise a static argument: registry
factories cache their ``KernelSpec`` objects, so each (kernel, params) pair
costs one compilation, not one per call.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.pairwise import kernel as _k
from repro.kernels.pairwise import specs as _specs
from repro.kernels.pairwise.specs import KernelSpec


def _interpret_mode() -> bool:
    """CPU containers interpret the TPU kernel; real TPU compiles it.

    A function (not a module constant) on purpose: the backend may be chosen
    after this module is imported, so the decision must be re-read per call.
    """
    return jax.default_backend() != "tpu"


def _pad_rows(X: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = X.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return X
    return jnp.pad(X, ((0, pad), (0, 0)))


def _pad_cols(V: jnp.ndarray, mult: int) -> jnp.ndarray:
    m = V.shape[1]
    pad = (-m) % mult
    if pad == 0:
        return V
    return jnp.pad(V, ((0, 0), (0, pad)))


@partial(jax.jit, static_argnames=("spec", "use_pallas", "interpret"))
def _kernel_block_jit(Xr: jnp.ndarray, Xc: jnp.ndarray, edges,
                      spec: KernelSpec, use_pallas: bool,
                      interpret: bool) -> jnp.ndarray:
    if not use_pallas:
        return _specs.apply(spec, Xr, Xc, edges)
    nr, nc = Xr.shape[0], Xc.shape[0]
    Xrp = _pad_rows(Xr, _k.BLOCK_R)
    Xcp = _pad_rows(Xc, _k.BLOCK_C)
    out = _k.pairwise_block_padded(spec, Xrp, Xcp, interpret=interpret,
                                   edges=edges)
    return out[:nr, :nc]


def kernel_block(spec: KernelSpec, Xr: jnp.ndarray, Xc: jnp.ndarray,
                 use_pallas: bool = True, interpret: bool | None = None,
                 edges: jnp.ndarray | None = None) -> jnp.ndarray:
    """K-block entry_fn(stat(x_r, x_c)) of shape (len(Xr), len(Xc)).

    ``edges`` (a sign-split segment table, see
    ``repro.kernels.pairwise.signsplit``) opts l1dist statistics into the
    MXU route; ``None`` — and every non-l1dist stat — keeps the reference
    path.  ``None`` vs array is a pytree-structure change, so each choice
    costs one jit entry per spec, as before.
    """
    if interpret is None:
        interpret = _interpret_mode()
    return _kernel_block_jit(Xr, Xc, edges, spec, use_pallas, interpret)


@partial(jax.jit, static_argnames=("spec", "use_pallas", "interpret"))
def _kernel_matmat_multi_rows_jit(Xr: jnp.ndarray, Xc: jnp.ndarray, Vs,
                                  edges, spec: KernelSpec, use_pallas: bool,
                                  interpret: bool):
    Vs = tuple(Vs)
    if not use_pallas:
        K = _specs.apply(spec, Xr, Xc, edges)
        dt = spec.tile_dtype()
        return tuple(
            jax.lax.dot_general(K.astype(dt), V.astype(dt),
                                dimension_numbers=(((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
            for V in Vs)
    nr = Xr.shape[0]
    ms = [V.shape[1] for V in Vs]
    Xrp = _pad_rows(Xr, _k.BLOCK_R)
    Xcp = _pad_rows(Xc, _k.BLOCK_C)
    Vps = tuple(_pad_cols(_pad_rows(V, _k.BLOCK_C), 128) for V in Vs)
    outs = _k.pairwise_matmat_multi_padded(spec, Xrp, Xcp, Vps,
                                           interpret=interpret, edges=edges)
    return tuple(out[:nr, :m] for out, m in zip(outs, ms))


def kernel_matmat_multi_rows(spec: KernelSpec, Xr: jnp.ndarray,
                             Xc: jnp.ndarray, Vs, use_pallas: bool = True,
                             interpret: bool | None = None,
                             edges: jnp.ndarray | None = None):
    """[K(Xr, Xc) @ V for V in Vs] — the rectangular row-slab fusion.

    The gather-based fast path of the sweep engine: the caller materializes
    its row slab ``Xr = X[r0:r1]`` and passes the full column points ``Xc``,
    so only that slab's (128 × 128) kernel tiles are ever computed — once,
    in VMEM — and contracted against every right-hand side.  Prefer
    ``kernel_matmat_multi_slab`` when the slab is a contiguous range of
    ``Xc`` — it addresses the slab in-launch instead of copying it.
    """
    if interpret is None:
        interpret = _interpret_mode()
    return _kernel_matmat_multi_rows_jit(Xr, Xc, tuple(Vs), edges, spec,
                                         use_pallas, interpret)


@partial(jax.jit,
         static_argnames=("spec", "slab_len", "use_pallas", "interpret"))
def _kernel_matmat_multi_slab_jit(X: jnp.ndarray, start_row, Vs, edges,
                                  spec: KernelSpec, slab_len: int,
                                  use_pallas: bool, interpret: bool):
    Vs = tuple(Vs)
    n = X.shape[0]
    start = jnp.asarray(start_row, jnp.int32)
    if not use_pallas:
        # dense fallback mirrors the clip-gather semantics: rows past n read
        # the last row and are discarded by the caller's validity mask
        row_idx = jnp.clip(start + jnp.arange(slab_len), 0, n - 1)
        Xr = jnp.take(X, row_idx, axis=0)
        K = _specs.apply(spec, Xr, X, edges)
        dt = spec.tile_dtype()
        return tuple(
            jax.lax.dot_general(K.astype(dt), V.astype(dt),
                                dimension_numbers=(((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
            for V in Vs)
    ms = [V.shape[1] for V in Vs]
    Xp = _pad_rows(X, _k.BLOCK_R)
    Vps = tuple(_pad_cols(_pad_rows(V, _k.BLOCK_C), 128) for V in Vs)
    # align the dynamic start down to a 128-row block boundary; the launch
    # covers [off·128, off·128 + nblocks·128) and the requested slab is cut
    # out afterwards (within ∈ [0, 128), so one extra block always suffices)
    off = start // _k.BLOCK_R
    within = start - off * _k.BLOCK_R
    nblocks = (slab_len + 2 * _k.BLOCK_R - 1) // _k.BLOCK_R
    outs = _k.pairwise_matmat_multi_slab(spec, Xp, off, nblocks, Vps,
                                         interpret=interpret, edges=edges)
    return tuple(
        jax.lax.dynamic_slice_in_dim(out, within, slab_len, axis=0)[:, :m]
        for out, m in zip(outs, ms))


def kernel_matmat_multi_slab(spec: KernelSpec, X: jnp.ndarray, start_row,
                             slab_len: int, Vs, use_pallas: bool = True,
                             interpret: bool | None = None,
                             edges: jnp.ndarray | None = None):
    """[K(X[start:start+slab_len], X) @ V for V in Vs] without gathering.

    The scalar-prefetch slab launch: ``start_row`` may be a TRACED scalar —
    it rides a ``PrefetchScalarGridSpec`` into the row-tile index map, so
    one compiled launch serves every slab position of a shard_map sweep and
    no device ever materializes a row-slice copy of ``X``.  Rows at indices
    ≥ n (a tail slab) are duplicates of the last row/block; callers mask
    them (the sweep engine's validity mask already does).
    """
    if interpret is None:
        interpret = _interpret_mode()
    return _kernel_matmat_multi_slab_jit(X, start_row, tuple(Vs), edges,
                                         spec, int(slab_len), use_pallas,
                                         interpret)


def kernel_matmat_multi(spec: KernelSpec, X: jnp.ndarray, Vs,
                        use_pallas: bool = True,
                        interpret: bool | None = None,
                        edges: jnp.ndarray | None = None):
    """[K(X, X) @ V for V in Vs] with each kernel tile computed ONCE.

    The sweep-engine fast path: all right-hand sides (projection sketches,
    Hutchinson probes, one-hot column gathers for C = K P) are contracted
    against the same VMEM-resident kernel tile in a single Pallas launch.
    The square special case of ``kernel_matmat_multi_rows``.
    """
    return kernel_matmat_multi_rows(spec, X, X, Vs, use_pallas=use_pallas,
                                    interpret=interpret, edges=edges)


def kernel_matmat(spec: KernelSpec, X: jnp.ndarray, V: jnp.ndarray,
                  use_pallas: bool = True,
                  interpret: bool | None = None,
                  edges: jnp.ndarray | None = None) -> jnp.ndarray:
    """K(X, X) @ V fused: kernel tiles never leave VMEM (streaming matmat)."""
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    (out,) = kernel_matmat_multi(spec, X, (V2,), use_pallas=use_pallas,
                                 interpret=interpret, edges=edges)
    return out[:, 0] if squeeze else out


@partial(jax.jit, static_argnames=("spec", "interpret"))
def _sketched_gram_jit(Xs: jnp.ndarray, scales, edges, spec: KernelSpec,
                       interpret):
    blk = _kernel_block_jit(Xs, Xs, edges, spec, True, interpret)
    if scales is not None:
        blk = blk * (scales[:, None] * scales[None, :])
    return blk


def sketched_gram(spec: KernelSpec, Xs: jnp.ndarray,
                  scales: jnp.ndarray | None = None,
                  interpret: bool | None = None,
                  edges: jnp.ndarray | None = None) -> jnp.ndarray:
    """S^T K S for a column sketch S given the selected points Xs = X[idx].

    ``edges`` (optional): a sign-split segment table covering ``Xs`` routes
    an l1dist statistic through the MXU form (selected points are a subset
    of the operator's data, so the operator's own table stays exact)."""
    if interpret is None:
        interpret = _interpret_mode()
    return _sketched_gram_jit(Xs, scales, edges, spec, interpret)
