"""Public jit'd wrappers for the pairwise kernel sweep template.

Handles arbitrary (non-tile-aligned) shapes by zero-padding the point sets and
slicing the output; padding rows produce garbage kernel values that are sliced
away (block path) or contracted against zero-padded V rows (matmat path),
never read.

Backend selection (interpret mode on CPU containers, compiled on real TPU) is
resolved at *call* time, not import time: each public wrapper reads
``jax.default_backend()`` when invoked — unless the caller passes an explicit
``interpret=`` — and threads the choice into the jit cache as a static
argument, so flipping the backend after import can never run a stale
interpret decision.  The ``spec`` is likewise a static argument: registry
factories cache their ``KernelSpec`` objects, so each (kernel, params) pair
costs one compilation, not one per call.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.pairwise import kernel as _k
from repro.kernels.pairwise import specs as _specs
from repro.kernels.pairwise.specs import KernelSpec


def _interpret_mode() -> bool:
    """CPU containers interpret the TPU kernel; real TPU compiles it.

    A function (not a module constant) on purpose: the backend may be chosen
    after this module is imported, so the decision must be re-read per call.
    """
    return jax.default_backend() != "tpu"


def _pad_rows(X: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = X.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return X
    return jnp.pad(X, ((0, pad), (0, 0)))


def _pad_cols(V: jnp.ndarray, mult: int) -> jnp.ndarray:
    m = V.shape[1]
    pad = (-m) % mult
    if pad == 0:
        return V
    return jnp.pad(V, ((0, 0), (0, pad)))


@partial(jax.jit, static_argnames=("spec", "use_pallas", "interpret"))
def _kernel_block_jit(Xr: jnp.ndarray, Xc: jnp.ndarray, spec: KernelSpec,
                      use_pallas: bool, interpret: bool) -> jnp.ndarray:
    if not use_pallas:
        return _specs.apply(spec, Xr, Xc)
    nr, nc = Xr.shape[0], Xc.shape[0]
    Xrp = _pad_rows(Xr, _k.BLOCK_R)
    Xcp = _pad_rows(Xc, _k.BLOCK_C)
    out = _k.pairwise_block_padded(spec, Xrp, Xcp, interpret=interpret)
    return out[:nr, :nc]


def kernel_block(spec: KernelSpec, Xr: jnp.ndarray, Xc: jnp.ndarray,
                 use_pallas: bool = True,
                 interpret: bool | None = None) -> jnp.ndarray:
    """K-block entry_fn(stat(x_r, x_c)) of shape (len(Xr), len(Xc))."""
    if interpret is None:
        interpret = _interpret_mode()
    return _kernel_block_jit(Xr, Xc, spec, use_pallas, interpret)


@partial(jax.jit, static_argnames=("spec", "use_pallas", "interpret"))
def _kernel_matmat_multi_rows_jit(Xr: jnp.ndarray, Xc: jnp.ndarray, Vs,
                                  spec: KernelSpec, use_pallas: bool,
                                  interpret: bool):
    Vs = tuple(Vs)
    if not use_pallas:
        K = _specs.apply(spec, Xr, Xc)
        return tuple(K @ V.astype(jnp.float32) for V in Vs)
    nr = Xr.shape[0]
    ms = [V.shape[1] for V in Vs]
    Xrp = _pad_rows(Xr, _k.BLOCK_R)
    Xcp = _pad_rows(Xc, _k.BLOCK_C)
    Vps = tuple(_pad_cols(_pad_rows(V, _k.BLOCK_C), 128) for V in Vs)
    outs = _k.pairwise_matmat_multi_padded(spec, Xrp, Xcp, Vps,
                                           interpret=interpret)
    return tuple(out[:nr, :m] for out, m in zip(outs, ms))


def kernel_matmat_multi_rows(spec: KernelSpec, Xr: jnp.ndarray,
                             Xc: jnp.ndarray, Vs, use_pallas: bool = True,
                             interpret: bool | None = None):
    """[K(Xr, Xc) @ V for V in Vs] — the rectangular row-slab fusion.

    The shard_map fast path of the sweep engine: each device gathers its
    contiguous local row slab ``Xr = X[r0:r1]`` and passes the full column
    points ``Xc``, so only that slab's (128 × 128) kernel tiles are ever
    computed — once, in VMEM — and contracted against every right-hand side.
    """
    if interpret is None:
        interpret = _interpret_mode()
    return _kernel_matmat_multi_rows_jit(Xr, Xc, tuple(Vs), spec, use_pallas,
                                         interpret)


def kernel_matmat_multi(spec: KernelSpec, X: jnp.ndarray, Vs,
                        use_pallas: bool = True,
                        interpret: bool | None = None):
    """[K(X, X) @ V for V in Vs] with each kernel tile computed ONCE.

    The sweep-engine fast path: all right-hand sides (projection sketches,
    Hutchinson probes, one-hot column gathers for C = K P) are contracted
    against the same VMEM-resident kernel tile in a single Pallas launch.
    The square special case of ``kernel_matmat_multi_rows``.
    """
    return kernel_matmat_multi_rows(spec, X, X, Vs, use_pallas=use_pallas,
                                    interpret=interpret)


def kernel_matmat(spec: KernelSpec, X: jnp.ndarray, V: jnp.ndarray,
                  use_pallas: bool = True,
                  interpret: bool | None = None) -> jnp.ndarray:
    """K(X, X) @ V fused: kernel tiles never leave VMEM (streaming matmat)."""
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    (out,) = kernel_matmat_multi(spec, X, (V2,), use_pallas=use_pallas,
                                 interpret=interpret)
    return out[:, 0] if squeeze else out


@partial(jax.jit, static_argnames=("spec", "interpret"))
def _sketched_gram_jit(Xs: jnp.ndarray, spec: KernelSpec, scales, interpret):
    blk = _kernel_block_jit(Xs, Xs, spec, True, interpret)
    if scales is not None:
        blk = blk * (scales[:, None] * scales[None, :])
    return blk


def sketched_gram(spec: KernelSpec, Xs: jnp.ndarray,
                  scales: jnp.ndarray | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """S^T K S for a column sketch S given the selected points Xs = X[idx]."""
    if interpret is None:
        interpret = _interpret_mode()
    return _sketched_gram_jit(Xs, spec, scales, interpret)
