from repro.kernels.pairwise import kernel, ops, ref, specs  # noqa: F401
from repro.kernels.pairwise.specs import (KernelSpec, get_spec,  # noqa: F401
                                          register_kernel,
                                          registered_kernels, stat_only)
from repro.kernels.pairwise import calibrate  # noqa: F401
from repro.kernels.pairwise.calibrate import (calibrate_sigma,  # noqa: F401
                                              register_calibration,
                                              stat_quantile)
