"""KernelSpec: the pluggable kernel-operator registry.

The paper's O(n) cost analysis (Table 3 "#Entries") is kernel-agnostic — it
only needs SPSD kernel entries computed on the fly from the data points.  A
``KernelSpec`` captures exactly what varies between kernels so that ONE tiled
Pallas sweep template (``repro.kernels.pairwise.kernel``) serves all of them:

- ``stat``: which pairwise statistic a (BLOCK_R, BLOCK_C) tile computes from
  the point tiles — ``'sqdist'`` (‖x−y‖₂², MXU cross product + VPU combine),
  ``'dot'`` (xᵀy, pure MXU), or ``'l1dist'`` (‖x−y‖₁: the MXU sign-split
  route of ``repro.kernels.pairwise.signsplit`` when the operator has a
  segment plan for its data, else a VPU accumulation over the feature axis —
  the retained reference route).
- ``entry_fn``: a *pure elementwise* statistic → kernel-entry function (runs
  on the VPU inside the kernel, and verbatim in the dense fallback).
- ``precision``: the mixed-precision tile policy — ``'f32'`` (default) or
  ``'bf16_f32acc'`` (operand tiles quantized to bf16, every contraction and
  elementwise combine accumulated in f32 via ``preferred_element_type``).
  The policy is a spec FIELD so it rides the existing static-argument
  plumbing (jit keys, serve artifacts, registry factories) for free; derive
  variants with ``spec.with_precision("bf16_f32acc")``.

Everything else — tiling, padding, the multi-right-hand-side fusion, the
shard_map row-slab claim, diag shortcuts — is shared machinery.

Registering a custom kernel
---------------------------

Factories are registered by name and return (cached) ``KernelSpec`` objects,
so jit caches key on one spec instance per parameter set::

    from repro.kernels.pairwise import specs

    @specs.register_kernel("cauchy")
    def cauchy(gamma: float = 1.0) -> specs.KernelSpec:
        gamma = float(gamma)
        return specs.KernelSpec(
            name="cauchy",
            stat="sqdist",                            # reuse the MXU distance
            entry_fn=lambda sq: 1.0 / (1.0 + gamma * sq),
            params=(("gamma", gamma),))

    spec = specs.get_spec("cauchy", gamma=0.5)

    from repro.core import PairwiseKernel
    K = PairwiseKernel(X, spec, use_pallas=True)      # full fused-sweep path

That is the whole integration: the operator layer, the sweep-engine routing
(``pallas_fused`` / ``pallas_fused_sharded`` / ``panel``), CUR, eig, and the
benchmarks all pick the new kernel up through the registry with zero
per-call-site changes.  ``entry_fn`` must be elementwise and produce an SPSD
kernel for the intended statistic — the registry does not check positivity.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.pairwise import signsplit

#: statistics the sweep template knows how to compute from point tiles
STAT_KINDS = ("sqdist", "dot", "l1dist")

#: tile-evaluation precision policies (operand dtype × accumulator dtype)
PRECISIONS = ("f32", "bf16_f32acc")


def tile_dtype(precision: str):
    """Operand dtype of a precision policy (accumulators are always f32)."""
    if precision == "bf16_f32acc":
        return jnp.bfloat16  # repro: allow-dtype(the precision policy's own definition site)
    if precision == "f32":
        return jnp.float32
    raise ValueError(f"unknown precision {precision!r}; one of {PRECISIONS}")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One SPSD kernel family for the shared pairwise sweep template.

    ``entry_fn`` maps the pairwise statistic elementwise to kernel entries
    (f32 in, f32 out) and must be jax-traceable; it runs unchanged inside the
    Pallas kernel body and in the dense fallback.  ``params`` is a hashable
    ``((name, value), ...)`` tuple recorded for repr/factory caching — specs
    are compared and hashed by field identity, so always build them through
    the registered (cached) factories.
    """

    name: str
    stat: str
    entry_fn: Callable[[jnp.ndarray], jnp.ndarray]
    params: Tuple[Tuple[str, float], ...] = ()
    precision: str = "f32"

    def __post_init__(self):
        if self.stat not in STAT_KINDS:
            raise ValueError(
                f"KernelSpec {self.name!r}: unknown stat {self.stat!r}; "
                f"one of {STAT_KINDS}")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"KernelSpec {self.name!r}: unknown precision "
                f"{self.precision!r}; one of {PRECISIONS}")

    def param(self, name: str):
        return dict(self.params)[name]

    def with_precision(self, precision: str) -> "KernelSpec":
        """This spec under another tile-precision policy (cached — one
        object per (spec, precision), preserving the one-jit-entry-per-
        parameter-set invariant the factories establish)."""
        return _with_precision(self, precision)

    def tile_dtype(self):
        """Operand dtype the tile/dense paths quantize point blocks to."""
        return tile_dtype(self.precision)

    def __repr__(self):  # stable, param-revealing (lambdas repr poorly)
        ps = ", ".join(f"{k}={v}" for k, v in self.params)
        prec = "" if self.precision == "f32" else f", {self.precision}"
        return f"KernelSpec({self.name}({ps}), stat={self.stat}{prec})"


#: (spec, precision) -> variant.  A manual cache (not lru_cache) so the
#: round-trip can be seeded: X.with_precision(p).with_precision(q) must land
#: on the SAME object as X.with_precision(q) — including q == X.precision,
#: where it must be X itself — or the jit caches fork per route.
_PRECISION_VARIANTS: dict = {}


def _with_precision(spec: KernelSpec, precision: str) -> KernelSpec:
    if precision == spec.precision:
        return spec
    key = (spec, precision)
    hit = _PRECISION_VARIANTS.get(key)
    if hit is None:
        hit = dataclasses.replace(spec, precision=precision)
        _PRECISION_VARIANTS[key] = hit
        _PRECISION_VARIANTS[(hit, spec.precision)] = spec
    return hit


# ---------------------------------------------------------------------------
# dense statistic + entry evaluation (the non-Pallas route / diag shortcut)
# ---------------------------------------------------------------------------

_DOT_DN = (((1,), (1,)), ((), ()))


def dot_f32acc(Xr: jnp.ndarray, Xc: jnp.ndarray) -> jnp.ndarray:
    """Xr @ Xc.T with an f32 accumulator regardless of operand dtype — the
    one contraction primitive every tile/dense statistic routes through, so
    the bf16_f32acc policy means the same thing everywhere (bf16 operands on
    the MXU, ``preferred_element_type=f32`` partial sums)."""
    return jax.lax.dot_general(Xr, Xc, dimension_numbers=_DOT_DN,
                               preferred_element_type=jnp.float32)


def _sqdist(Xr: jnp.ndarray, Xc: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared distances, MXU-friendly: |x|² + |y|² − 2 x·y.

    Operands may be bf16 (the precision policy's quantization); the norms
    and the combine run in f32 on the quantized values so dense and tile
    routes stay bit-comparable per policy.
    """
    Xr32 = Xr.astype(jnp.float32)
    Xc32 = Xc.astype(jnp.float32)
    xx = jnp.sum(Xr32 * Xr32, axis=1)
    yy = jnp.sum(Xc32 * Xc32, axis=1)
    cross = dot_f32acc(Xr, Xc)
    return jnp.maximum(xx[:, None] + yy[None, :] - 2.0 * cross, 0.0)


def _l1dist(Xr: jnp.ndarray, Xc: jnp.ndarray) -> jnp.ndarray:
    """Pairwise L1 distances accumulated one feature at a time — the VPU
    reference route.

    The MXU default for fused launches is the sign-split decomposition
    (``signsplit.l1dist``), which needs a data-derived segment plan; this
    loop is the plan-free fallback (continuous/high-cardinality features,
    traced inputs) and the parity oracle the MXU route is asserted against.
    Looping the feature axis keeps the live set at one (nr, nc) f32
    accumulator regardless of d (the broadcast form is d× that).
    """
    Xr = Xr.astype(jnp.float32)
    Xc = Xc.astype(jnp.float32)
    nr, nc = Xr.shape[0], Xc.shape[0]

    def body(k, acc):
        xr = jax.lax.dynamic_slice_in_dim(Xr, k, 1, axis=1)     # (nr, 1)
        xc = jax.lax.dynamic_slice_in_dim(Xc, k, 1, axis=1)     # (nc, 1)
        return acc + jnp.abs(xr - xc.T)

    return jax.lax.fori_loop(0, Xr.shape[1], body,
                             jnp.zeros((nr, nc), jnp.float32))


def stat_block(stat: str, Xr: jnp.ndarray, Xc: jnp.ndarray,
               precision: str = "f32",
               edges: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """The (|Xr| × |Xc|) pairwise statistic (f32 out).

    ``precision`` quantizes the point operands (``tile_dtype``) while every
    accumulator stays f32.  ``edges`` — a sign-split segment table — selects
    the MXU route for ``l1dist``; without it the VPU reference loop runs.
    Other statistics ignore ``edges``.
    """
    dt = tile_dtype(precision)
    Xr = Xr.astype(dt)
    Xc = Xc.astype(dt)
    if stat == "dot":
        return dot_f32acc(Xr, Xc)
    if stat == "sqdist":
        return _sqdist(Xr, Xc)
    if stat == "l1dist":
        if edges is not None:
            return signsplit.l1dist(Xr, Xc, edges, dt)
        return _l1dist(Xr, Xc)
    raise ValueError(f"unknown stat {stat!r}")


def apply(spec: KernelSpec, Xr: jnp.ndarray, Xc: jnp.ndarray,
          edges: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """K[ri, cj] = entry_fn(stat(x_ri, x_cj)) — the dense evaluation every
    non-Pallas route (panel scans, ``full()``) runs.  Precision follows the
    spec; ``edges`` opts l1dist statistics into the MXU sign-split form."""
    return spec.entry_fn(
        stat_block(spec.stat, Xr, Xc, spec.precision, edges))


def diag(spec: KernelSpec, X: jnp.ndarray) -> jnp.ndarray:
    """diag(K) in O(n·d) without touching any off-diagonal entry.

    Distance statistics vanish on the diagonal (stat ≡ 0 → a constant
    entry, e.g. 1.0 for rbf/laplacian/matern); the dot statistic reduces to
    the row norms ‖x_i‖² (computed on precision-quantized values so the
    diagonal matches what a fused sweep would produce under the policy).
    """
    X32 = X.astype(spec.tile_dtype()).astype(jnp.float32)
    if spec.stat == "dot":
        t = jnp.sum(X32 * X32, axis=1)
    else:
        t = jnp.zeros((X.shape[0],), jnp.float32)
    return spec.entry_fn(t)


@functools.lru_cache(maxsize=None)
def _stat_only(stat: str) -> KernelSpec:
    return KernelSpec(f"stat[{stat}]", stat, lambda t: t)


def stat_only(spec) -> KernelSpec:
    """Identity-entry spec over ``spec``'s pairwise statistic.

    The resulting kernel's entries ARE the raw statistic (‖x−y‖², xᵀy, or
    ‖x−y‖₁), so the whole operator/sweep machinery — including the fused
    Pallas template — can stream statistic panels; per-spec bandwidth
    calibration (``repro.kernels.pairwise.calibrate``) quantiles them in one
    sweep.  ``spec`` may be a ``KernelSpec`` or a bare stat name.  Cached, so
    each statistic costs one jit entry.
    """
    return _stat_only(spec if isinstance(spec, str) else spec.stat)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., KernelSpec]] = {}


def register_kernel(name: str):
    """Decorator: register a ``KernelSpec`` factory under ``name``."""
    def deco(factory: Callable[..., KernelSpec]):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_spec(name: str, **params) -> KernelSpec:
    """Build the named spec (default parameters unless overridden)."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; registered: "
                         f"{registered_kernels()}")
    return _REGISTRY[name](**params)


def registered_kernels() -> Tuple[str, ...]:
    """Registered kernel names, sorted (the benchmark/test sweep order)."""
    return tuple(sorted(_REGISTRY))


# Parameterizations that keep entries O(1) on standardized/unit-scale data —
# the single source the registry-sweeping benchmarks and parity tests share
# (polynomial is normalized by 1/d, the sklearn convention).  Kernels not
# listed (user-registered specs) fall back to their factory defaults, so a
# custom registration never breaks the registry sweeps.
_SUGGESTED_PARAMS = {
    "rbf": lambda d: dict(sigma=1.5),
    "laplacian": lambda d: dict(gamma=0.3),
    "matern32": lambda d: dict(length_scale=1.5),
    "polynomial": lambda d: dict(degree=3, gamma=1.0 / d, coef0=1.0),
    "linear": lambda d: {},
}


def suggested_params(name: str, d: int = 8) -> dict:
    """Benchmark/test parameters for ``name`` given feature dim ``d``
    (``{}`` — factory defaults — for kernels without an entry)."""
    fn = _SUGGESTED_PARAMS.get(name)
    return fn(d) if fn is not None else {}


def suggested_spec(name: str, d: int = 8) -> KernelSpec:
    """``get_spec`` with the suggested benchmark/test parameters."""
    return get_spec(name, **suggested_params(name, d))


# ---------------------------------------------------------------------------
# built-in specs (cached: one spec object — hence one jit cache entry — per
# parameter set)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _rbf(sigma: float) -> KernelSpec:
    gamma = 1.0 / (2.0 * sigma ** 2)
    return KernelSpec("rbf", "sqdist",
                      lambda sq: jnp.exp(-gamma * sq),
                      params=(("sigma", sigma),))


@register_kernel("rbf")
def rbf(sigma: float = 1.0) -> KernelSpec:
    """K_ij = exp(−‖x_i − x_j‖² / (2σ²))."""
    return _rbf(float(sigma))


@functools.lru_cache(maxsize=None)
def _laplacian(gamma: float) -> KernelSpec:
    return KernelSpec("laplacian", "l1dist",
                      lambda t: jnp.exp(-gamma * t),
                      params=(("gamma", gamma),))


@register_kernel("laplacian")
def laplacian(gamma: float = 1.0) -> KernelSpec:
    """K_ij = exp(−γ ‖x_i − x_j‖₁) (the exponential/L1 kernel of the
    Gittens–Mahoney Nyström evaluation suite)."""
    return _laplacian(float(gamma))


@functools.lru_cache(maxsize=None)
def _matern32(length_scale: float) -> KernelSpec:
    a = 3.0 ** 0.5 / length_scale

    def entry(sq):
        r = jnp.sqrt(jnp.maximum(sq, 0.0))
        return (1.0 + a * r) * jnp.exp(-a * r)

    return KernelSpec("matern32", "sqdist", entry,
                      params=(("length_scale", length_scale),))


@register_kernel("matern32")
def matern32(length_scale: float = 1.0) -> KernelSpec:
    """Matérn-3/2: K_ij = (1 + √3 r/ℓ) exp(−√3 r/ℓ), r = ‖x_i − x_j‖₂."""
    return _matern32(float(length_scale))


@functools.lru_cache(maxsize=None)
def _polynomial(degree: int, gamma: Optional[float],
                coef0: float) -> KernelSpec:
    def entry(t):
        g = gamma if gamma is not None else 1.0
        return (g * t + coef0) ** degree

    return KernelSpec("polynomial", "dot", entry,
                      params=(("degree", degree), ("gamma", gamma),
                              ("coef0", coef0)))


@register_kernel("polynomial")
def polynomial(degree: int = 3, gamma: Optional[float] = None,
               coef0: float = 1.0) -> KernelSpec:
    """K_ij = (γ xᵢᵀxⱼ + c)ᵖ — SPSD for integer p ≥ 1, γ > 0, c ≥ 0.

    ``gamma=None`` means 1.0 (pass e.g. ``1/d`` to keep entries O(1) on
    standardized data, the sklearn convention).
    """
    return _polynomial(int(degree), None if gamma is None else float(gamma),
                       float(coef0))


@functools.lru_cache(maxsize=None)
def _linear() -> KernelSpec:
    return KernelSpec("linear", "dot", lambda t: t)


@register_kernel("linear")
def linear() -> KernelSpec:
    """K = X Xᵀ — the identity entry function over the dot statistic."""
    return _linear()
