"""One tiled Pallas sweep template for every pairwise kernel (TPU-native).

Generalization of the fused RBF kernels (paper Fig. 1 memory trick): the
(BLOCK_R, BLOCK_C) kernel tile is produced from the point tiles — the pairwise
*statistic* on the MXU/VPU, then the spec's pure elementwise ``entry_fn`` on
the VPU — and is consumed while still in VMEM, so no kernel entry is ever
staged in HBM:

- ``pairwise_block_padded``        one K block (the S^T K S / C panel path),
- ``pairwise_matmat_multi_padded`` [K(Xr, Xc) @ V for V in Vs] with each
  kernel tile computed ONCE and contracted against every right-hand side —
  the single-sweep panel engine at the kernel-tile level,
- ``pairwise_matmat_multi_slab``   the shard_map per-device fast path: the
  row slab is addressed INSIDE the launch via a scalar-prefetch row-offset
  index map (``PrefetchScalarGridSpec``), so each device's grid walks its
  contiguous block range of the shared padded X instead of contracting a
  gathered copy.

Statistics (``KernelSpec.stat``):

- ``'dot'``     xᵀy — one MXU contraction.
- ``'sqdist'``  ‖x−y‖₂² — MXU cross term + VPU norms/combine.
- ``'l1dist'``  ‖x−y‖₁ — with a sign-split segment table (``edges``) two MXU
  contractions over per-point segment embeddings built in VMEM
  (``repro.kernels.pairwise.signsplit``); without one, the reference VPU
  ``fori_loop`` over the feature axis (live set independent of d).

Precision (``KernelSpec.precision``): point tiles and the kernel tile are
quantized to ``spec.tile_dtype()`` (bf16 under ``bf16_f32acc``); every MXU
contraction accumulates f32 via ``preferred_element_type``; ``entry_fn``
always sees an f32 statistic.  The dense fallback (``specs.stat_block``)
applies the identical policy, so routes stay comparable per mode.

Output tiles are (128, 128) MXU/lane aligned; HBM traffic stays
O((nr + nc)·d + Σ nc·m_i + Σ nr·m_i) — the Table-3 "#Entries" story for the
whole kernel family, not just RBF.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pairwise.specs import KernelSpec, stat_block

BLOCK_R = 128
BLOCK_C = 128


def _entry_tile(xr_ref, xc_ref, spec: KernelSpec,
                e_ref=None) -> jnp.ndarray:
    """One (BLOCK_R, BLOCK_C) f32 tile of kernel entries from two VMEM point
    tiles.  The statistic math is shared verbatim with the dense fallback
    (``specs.stat_block``: MXU contractions for dot/sqdist and the
    sign-split l1 route, the d-independent VPU ``fori_loop`` otherwise), so
    the Pallas and panel routes can never diverge.  Point tiles are
    quantized to the spec's precision policy; the statistic and ``entry_fn``
    run in f32."""
    dt = spec.tile_dtype()
    xr = xr_ref[...].astype(dt)
    xc = xc_ref[...].astype(dt)
    edges = e_ref[...] if e_ref is not None else None
    return spec.entry_fn(
        stat_block(spec.stat, xr, xc, spec.precision, edges))


def _contract_tile(k_tile, v_ref, spec: KernelSpec) -> jnp.ndarray:
    """K-tile × V-tile under the precision policy: operands quantized to the
    tile dtype, f32 partial sums on the MXU."""
    dt = spec.tile_dtype()
    return jax.lax.dot_general(
        k_tile.astype(dt), v_ref[...].astype(dt),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _pairwise_block_kernel(xr_ref, xc_ref, *refs, spec: KernelSpec,
                           has_edges: bool):
    """One (BLOCK_R, BLOCK_C) output tile of kernel entries.

    xr_ref: (BLOCK_R, d) VMEM tile of row points
    xc_ref: (BLOCK_C, d) VMEM tile of column points
    refs:   optional (d, B−1) sign-split edge table, then the
            (BLOCK_R, BLOCK_C) VMEM output tile
    """
    e_ref = refs[0] if has_edges else None
    o_ref = refs[-1]
    o_ref[...] = _entry_tile(xr_ref, xc_ref, spec, e_ref)


def _pairwise_matmat_multi_kernel(xr_ref, xc_ref, *refs, spec: KernelSpec,
                                  nv: int, has_edges: bool):
    """Multi-right-hand-side fusion: one K tile, ``nv`` contractions.

    The (BLOCK_R, BLOCK_C) kernel tile is produced once and immediately
    contracted against every (BLOCK_C, m_i) right-hand tile while still in
    VMEM.  ``refs`` is an optional edge-table ref, then ``nv`` V refs, then
    ``nv`` output accumulator refs; the column-tile grid axis j walks the
    contraction.
    """
    e_ref = refs[0] if has_edges else None
    refs = refs[1:] if has_edges else refs
    v_refs, o_refs = refs[:nv], refs[nv:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        for o_ref in o_refs:
            o_ref[...] = jnp.zeros_like(o_ref)

    k_tile = _entry_tile(xr_ref, xc_ref, spec, e_ref)
    for v_ref, o_ref in zip(v_refs, o_refs):
        o_ref[...] += _contract_tile(k_tile, v_ref, spec)


def _edge_in_spec(edges, extra_grid_args: int = 0):
    """BlockSpec broadcasting the whole (d, B−1) edge table to every tile."""
    if extra_grid_args:
        return pl.BlockSpec(edges.shape, lambda i, j, *_: (0, 0))
    return pl.BlockSpec(edges.shape, lambda i, j: (0, 0))


def pairwise_matmat_multi_padded(spec: KernelSpec, Xr: jnp.ndarray,
                                 Xc: jnp.ndarray, Vs,
                                 interpret: bool = False, edges=None):
    """[K(Xr, Xc) @ V for V in Vs] over padded inputs, one kernel launch.

    ``Xr`` and ``Xc`` may differ: the grid is rectangular
    (nr/BLOCK_R × nc/BLOCK_C), which is how a row *slab* of the kernel is
    evaluated against the full point set — each grid row computes only its
    slab's kernel tiles in VMEM and contracts them against every right-hand
    side exactly once.  Padded column points produce garbage kernel entries
    that meet zero-padded V rows, so their contribution vanishes for every
    ``entry_fn``.  ``edges`` (optional) selects the sign-split MXU route for
    l1dist specs.
    """
    nr, d = Xr.shape
    nc = Xc.shape[0]
    assert nr % BLOCK_R == 0 and nc % BLOCK_C == 0, (nr, nc)
    for V in Vs:
        assert V.shape[0] == nc and V.shape[1] % 128 == 0, V.shape
    grid = (nr // BLOCK_R, nc // BLOCK_C)
    has_edges = edges is not None
    in_specs = [
        pl.BlockSpec((BLOCK_R, d), lambda i, j: (i, 0)),
        pl.BlockSpec((BLOCK_C, d), lambda i, j: (j, 0)),
    ]
    operands = [Xr, Xc]
    if has_edges:
        in_specs.append(_edge_in_spec(edges))
        operands.append(edges)
    in_specs += [
        pl.BlockSpec((BLOCK_C, V.shape[1]), lambda i, j: (j, 0))
        for V in Vs
    ]
    return pl.pallas_call(
        functools.partial(_pairwise_matmat_multi_kernel, spec=spec,
                          nv=len(Vs), has_edges=has_edges),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((BLOCK_R, V.shape[1]), lambda i, j: (i, 0))
            for V in Vs
        ],
        out_shape=[jax.ShapeDtypeStruct((nr, V.shape[1]), jnp.float32)
                   for V in Vs],
        interpret=interpret,
    )(*operands, *Vs)


def _pairwise_matmat_slab_kernel(off_ref, xr_ref, xc_ref, *refs,
                                 spec: KernelSpec, nv: int, has_edges: bool):
    """Slab-launch body: identical math to the multi kernel; ``off_ref`` (the
    prefetched row-block offset) is consumed by the index maps, not here."""
    del off_ref
    _pairwise_matmat_multi_kernel(xr_ref, xc_ref, *refs, spec=spec, nv=nv,
                                  has_edges=has_edges)


def pairwise_matmat_multi_slab(spec: KernelSpec, X: jnp.ndarray,
                               off_blocks: jnp.ndarray, nblocks_r: int, Vs,
                               interpret: bool = False, edges=None):
    """[K(X[slab], X) @ V for V in Vs] with the slab addressed in-launch.

    The scalar-prefetch replacement for gather-then-launch: ``off_blocks``
    (a traced (1,) int32 — the slab's first 128-row block of the shared
    padded ``X``) rides ``PrefetchScalarGridSpec``, and the row point tile's
    index map adds it to the grid row index.  Each device of a shard_map
    sweep therefore walks its contiguous block range of the SAME operand
    ``X`` — no per-device row-slice copy of the point set is materialized,
    and one compiled launch serves every slab position.  Row-block indices
    are clamped to the last block so a tail slab reads (and the caller
    discards) duplicate rows instead of reading out of bounds.
    """
    n, d = X.shape
    assert n % BLOCK_R == 0, n
    max_block = n // BLOCK_R - 1
    nr = nblocks_r * BLOCK_R
    for V in Vs:
        assert V.shape[0] == n and V.shape[1] % 128 == 0, V.shape

    def row_map(i, j, off_ref):
        return (jnp.minimum(off_ref[0] + i, max_block), 0)

    in_specs = [
        pl.BlockSpec((BLOCK_R, d), row_map),
        pl.BlockSpec((BLOCK_C, d), lambda i, j, off_ref: (j, 0)),
    ]
    operands = [X, X]
    has_edges = edges is not None
    if has_edges:
        in_specs.append(_edge_in_spec(edges, extra_grid_args=1))
        operands.append(edges)
    in_specs += [
        pl.BlockSpec((BLOCK_C, V.shape[1]), lambda i, j, off_ref: (j, 0))
        for V in Vs
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks_r, n // BLOCK_C),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((BLOCK_R, V.shape[1]), lambda i, j, off_ref: (i, 0))
            for V in Vs
        ],
    )
    return pl.pallas_call(
        functools.partial(_pairwise_matmat_slab_kernel, spec=spec,
                          nv=len(Vs), has_edges=has_edges),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((nr, V.shape[1]), jnp.float32)
                   for V in Vs],
        interpret=interpret,
    )(jnp.asarray(off_blocks, jnp.int32).reshape((1,)), *operands, *Vs)


def pairwise_block_padded(spec: KernelSpec, Xr: jnp.ndarray, Xc: jnp.ndarray,
                          interpret: bool = False,
                          edges=None) -> jnp.ndarray:
    """Pallas call over padded inputs; shapes must be multiples of the tiles."""
    nr, d = Xr.shape
    nc = Xc.shape[0]
    assert nr % BLOCK_R == 0 and nc % BLOCK_C == 0, (nr, nc)
    grid = (nr // BLOCK_R, nc // BLOCK_C)
    has_edges = edges is not None
    in_specs = [
        pl.BlockSpec((BLOCK_R, d), lambda i, j: (i, 0)),
        pl.BlockSpec((BLOCK_C, d), lambda i, j: (j, 0)),
    ]
    operands = [Xr, Xc]
    if has_edges:
        in_specs.append(_edge_in_spec(edges))
        operands.append(edges)
    return pl.pallas_call(
        functools.partial(_pairwise_block_kernel, spec=spec,
                          has_edges=has_edges),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr, nc), jnp.float32),
        interpret=interpret,
    )(*operands)
