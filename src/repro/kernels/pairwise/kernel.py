"""One tiled Pallas sweep template for every pairwise kernel (TPU-native).

Generalization of the fused RBF kernels (paper Fig. 1 memory trick): the
(BLOCK_R, BLOCK_C) kernel tile is produced from the point tiles — the pairwise
*statistic* on the MXU/VPU, then the spec's pure elementwise ``entry_fn`` on
the VPU — and is consumed while still in VMEM, so no kernel entry is ever
staged in HBM:

- ``pairwise_block_padded``        one K block (the S^T K S / C panel path),
- ``pairwise_matmat_multi_padded`` [K(Xr, Xc) @ V for V in Vs] with each
  kernel tile computed ONCE and contracted against every right-hand side —
  the single-sweep panel engine at the kernel-tile level, and (with Xr a
  contiguous row slab of Xc) the shard_map per-device fast path.

Statistics (``KernelSpec.stat``):

- ``'dot'``     xᵀy — one MXU contraction.
- ``'sqdist'``  ‖x−y‖₂² — MXU cross term + VPU norms/combine.
- ``'l1dist'``  ‖x−y‖₁ — no MXU form; a VPU ``fori_loop`` over the feature
  axis accumulates |x_k − y_k| into the (BLOCK_R, BLOCK_C) tile, keeping the
  VMEM working set independent of d (the broadcast form would stage a
  (BLOCK_R, BLOCK_C, d) temporary).

Output tiles are (128, 128) MXU/lane aligned; HBM traffic stays
O((nr + nc)·d + Σ nc·m_i + Σ nr·m_i) — the Table-3 "#Entries" story for the
whole kernel family, not just RBF.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise.specs import KernelSpec, stat_block

BLOCK_R = 128
BLOCK_C = 128


def _entry_tile(xr_ref, xc_ref, spec: KernelSpec) -> jnp.ndarray:
    """One (BLOCK_R, BLOCK_C) tile of kernel entries from two VMEM point
    tiles.  The statistic math is shared verbatim with the dense fallback
    (``specs.stat_block``: MXU cross products for dot/sqdist, the
    d-independent VPU ``fori_loop`` accumulator for l1dist), so the Pallas
    and panel routes can never diverge."""
    xr = xr_ref[...].astype(jnp.float32)
    xc = xc_ref[...].astype(jnp.float32)
    return spec.entry_fn(stat_block(spec.stat, xr, xc))


def _pairwise_block_kernel(xr_ref, xc_ref, o_ref, *, spec: KernelSpec):
    """One (BLOCK_R, BLOCK_C) output tile of kernel entries.

    xr_ref: (BLOCK_R, d) VMEM tile of row points
    xc_ref: (BLOCK_C, d) VMEM tile of column points
    o_ref:  (BLOCK_R, BLOCK_C) VMEM output tile
    """
    o_ref[...] = _entry_tile(xr_ref, xc_ref, spec)


def _pairwise_matmat_multi_kernel(xr_ref, xc_ref, *refs, spec: KernelSpec,
                                  nv: int):
    """Multi-right-hand-side fusion: one K tile, ``nv`` contractions.

    The (BLOCK_R, BLOCK_C) kernel tile is produced once and immediately
    contracted against every (BLOCK_C, m_i) right-hand tile while still in
    VMEM.  ``refs`` is ``nv`` V refs followed by ``nv`` output accumulator
    refs; the column-tile grid axis j walks the contraction.
    """
    v_refs, o_refs = refs[:nv], refs[nv:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        for o_ref in o_refs:
            o_ref[...] = jnp.zeros_like(o_ref)

    k_tile = _entry_tile(xr_ref, xc_ref, spec)
    for v_ref, o_ref in zip(v_refs, o_refs):
        o_ref[...] += jax.lax.dot_general(
            k_tile, v_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def pairwise_matmat_multi_padded(spec: KernelSpec, Xr: jnp.ndarray,
                                 Xc: jnp.ndarray, Vs,
                                 interpret: bool = False):
    """[K(Xr, Xc) @ V for V in Vs] over padded inputs, one kernel launch.

    ``Xr`` and ``Xc`` may differ: the grid is rectangular
    (nr/BLOCK_R × nc/BLOCK_C), which is how the shard_map sweep fast path
    launches one row *slab* per device — ``Xr`` is the device's contiguous
    row range of the point set, ``Xc`` the full set, so each device computes
    only its slab's kernel tiles in VMEM and contracts them against every
    right-hand side exactly once.  Padded column points produce garbage
    kernel entries that meet zero-padded V rows, so their contribution
    vanishes for every ``entry_fn``.
    """
    nr, d = Xr.shape
    nc = Xc.shape[0]
    assert nr % BLOCK_R == 0 and nc % BLOCK_C == 0, (nr, nc)
    for V in Vs:
        assert V.shape[0] == nc and V.shape[1] % 128 == 0, V.shape
    grid = (nr // BLOCK_R, nc // BLOCK_C)
    return pl.pallas_call(
        functools.partial(_pairwise_matmat_multi_kernel, spec=spec,
                          nv=len(Vs)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_C, d), lambda i, j: (j, 0)),
        ] + [
            pl.BlockSpec((BLOCK_C, V.shape[1]), lambda i, j: (j, 0))
            for V in Vs
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_R, V.shape[1]), lambda i, j: (i, 0))
            for V in Vs
        ],
        out_shape=[jax.ShapeDtypeStruct((nr, V.shape[1]), jnp.float32)
                   for V in Vs],
        interpret=interpret,
    )(Xr, Xc, *Vs)


def pairwise_block_padded(spec: KernelSpec, Xr: jnp.ndarray, Xc: jnp.ndarray,
                          interpret: bool = False) -> jnp.ndarray:
    """Pallas call over padded inputs; shapes must be multiples of the tiles."""
    nr, d = Xr.shape
    nc = Xc.shape[0]
    assert nr % BLOCK_R == 0 and nc % BLOCK_C == 0, (nr, nc)
    grid = (nr // BLOCK_R, nc // BLOCK_C)
    return pl.pallas_call(
        functools.partial(_pairwise_block_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_C, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr, nc), jnp.float32),
        interpret=interpret,
    )(Xr, Xc)
