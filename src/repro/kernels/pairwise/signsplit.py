"""MXU sign-split decomposition of the pairwise L1 statistic.

The laplacian kernel's ``l1dist`` statistic has no inner-product form, so the
tile path historically paid a d-iteration VPU ``fori_loop`` per (128 × 128)
kernel tile while every other registered statistic rode the MXU.  This module
gives ‖x−y‖₁ a matmul form via *sign-split segments*: partition each
feature's value range into segments (buckets) s with edges e₀ < e₁ < …; when
x_k and y_k fall in different segments the sign of (x_k − y_k) is determined
by the segment ORDER, so the signed contribution factorizes into products of
one-point functions — per-segment rank-d contractions the MXU can batch.

Derivation (per scalar u, v with segment indices i(u), i(v)):

    |u − v| = 1[i(u) > i(v)]·(u − v) + 1[i(v) > i(u)]·(v − u)
              + 1[i(u) = i(v)]·|u − v|

    1[i(u) > i(v)]·u = Σ_s (u·δ_s(u))·L_s(v)       δ_s(u) = 1[i(u) = s]
    1[i(u) > i(v)]·v = Σ_s δ_s(u)·(v·L_s(v))       L_s(v) = 1[i(v) < s]

so with per-point embeddings over (feature × segment) slots

    α(u) = ⊕_s ( u·δ_s(u), −δ_s(u) )               (d·2B dims)
    β(v) = ⊕_s ( L_s(v),  v·L_s(v) )               (d·2B dims)

the cross-segment part of the distance is two MXU contractions:

    ‖x − y‖₁ = α(x)·β(y) + β(x)·α(y)   +   Σ_k 1[same segment]·|x_k − y_k|

The trailing same-segment residual vanishes — making the identity EXACT —
whenever every segment contains at most ONE distinct data value per feature.
``build_plan`` therefore derives the edges from the operator's own data
(midpoints between consecutive distinct values) and only returns a plan when
every feature's cardinality fits the segment budget; otherwise the caller
keeps the VPU reference loop.  Low-cardinality features are the common case
for the paper's laplacian workloads (the Gittens–Mahoney evaluation datasets
— letters, pendigits, mushrooms — are all small-integer or categorical), and
quantized/standardized pipelines hit it by construction.

Cost model per (R × C) tile: 2 contractions of inner dimension 2·d·B on the
MXU plus O((R + C)·d·B) VPU embedding work, versus the reference route's
d-step VPU loop over (R × C) tiles.  HBM traffic is unchanged — embeddings
are built in VMEM from the raw (tile × d) point tiles and the shared (d, B−1)
edge table; nothing of size n·d·B ever exists.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: default per-feature segment budget: embeddings are 2·d·B wide, so 32
#: keeps the MXU contraction's inner dimension modest (512 at d=8) while
#: covering the small-integer / categorical cardinalities the laplacian
#: evaluation datasets actually have.
MAX_SEGMENTS = 32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SignSplitPlan:
    """Per-feature segment edges for the MXU l1dist route.

    ``edges`` is (d, B−1) f32, ascending per row, padded with +inf (padded
    segments are empty).  Exactness contract: every realized value of feature
    k — on BOTH sides of the pairwise block — lies in a segment of its own,
    which ``build_plan`` guarantees by placing edges at midpoints between
    consecutive distinct data values.
    """

    edges: jnp.ndarray

    def tree_flatten(self):
        return (self.edges,), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def segments(self) -> int:
        return int(self.edges.shape[1]) + 1


def build_plan(X, max_segments: int = MAX_SEGMENTS) -> Optional[SignSplitPlan]:
    """Derive sign-split edges from the data, or None when inapplicable.

    Host-side (numpy) one-time O(n·d log n) pass: per feature, the sorted
    distinct values; edges at consecutive midpoints.  Returns None — caller
    keeps the VPU reference route — when any feature has more than
    ``max_segments`` distinct values (continuous data), or when ``X`` is a
    tracer (plans cannot be built under jit/vmap; the VPU route is always
    safe there).
    """
    if isinstance(X, jax.core.Tracer):
        return None
    Xh = np.asarray(X, np.float32)
    if Xh.ndim != 2 or not np.all(np.isfinite(Xh)):
        return None
    d = Xh.shape[1]
    per_feature = []
    for k in range(d):
        u = np.unique(Xh[:, k])
        if u.shape[0] > max_segments:
            return None
        per_feature.append((u[:-1] + u[1:]) / 2.0)
    width = max(max(len(m) for m in per_feature), 1)
    edges = np.full((d, width), np.inf, np.float32)
    for k, m in enumerate(per_feature):
        edges[k, :len(m)] = m
    return SignSplitPlan(edges=jnp.asarray(edges))


def query_in_plan(X, Xq) -> bool:
    """True iff every query value lies ON the plan data's lattice.

    The sign-split identity drops the same-segment residual, and
    ``build_plan`` places exactly one distinct data value of ``X`` in each
    segment — so the MXU form is exact for a query point iff each of its
    feature values EQUALS some realized value of that feature in ``X``
    (then a same-segment pairing implies equal values, residual 0).  This
    host-side membership check is what lets serving route ``cross`` through
    the MXU for on-lattice queries — e.g. appended rows drawn from the same
    categorical/quantized pipeline as the training data — while off-lattice
    queries keep the always-exact VPU loop.  Tracers (jit-abstract queries)
    and non-finite values are conservatively off-plan.
    """
    if isinstance(X, jax.core.Tracer) or isinstance(Xq, jax.core.Tracer):
        return False
    Xh = np.asarray(X, np.float32)
    Qh = np.asarray(Xq, np.float32)
    if Qh.ndim == 1:
        Qh = Qh[None, :]
    if Xh.ndim != 2 or Qh.ndim != 2 or Qh.shape[1] != Xh.shape[1]:
        return False
    if not np.all(np.isfinite(Qh)):
        return False
    return all(bool(np.isin(Qh[:, k], np.unique(Xh[:, k])).all())
               for k in range(Xh.shape[1]))


def embed(X: jnp.ndarray, edges: jnp.ndarray,
          compute_dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(α, β) sign-split embeddings, each (m, d·2B), from points (m, d).

    Pure jnp and shape-static, so it runs identically inside the Pallas tile
    body (point tiles in VMEM, edge table broadcast to every tile) and in the
    dense parity oracle.  Segment indicators are computed in f32 regardless
    of ``compute_dtype`` (they are exact 0/1 decisions); the value-carrying
    slots are cast to ``compute_dtype`` so the bf16 tile policy quantizes
    exactly the same numbers the reference route quantizes.
    """
    m, d = X.shape
    nseg = edges.shape[1] + 1
    X32 = X.astype(jnp.float32)
    ge = (X32[:, :, None] >= edges[None, :, :]).astype(jnp.float32)
    ones = jnp.ones((m, d, 1), jnp.float32)
    zeros = jnp.zeros((m, d, 1), jnp.float32)
    # delta_s = 1[x >= e_{s-1}]·1[x < e_s] with e_{-1} = −inf, e_{B-1} = +inf;
    # L_s = 1[segment(x) < s] = 1[x < e_{s-1}]
    delta = jnp.concatenate([ones, ge], axis=2) * \
        jnp.concatenate([1.0 - ge, ones], axis=2)
    L = jnp.concatenate([zeros, 1.0 - ge], axis=2)
    xv = X32[:, :, None]
    alpha = jnp.concatenate([xv * delta, -delta], axis=2)
    beta = jnp.concatenate([L, xv * L], axis=2)
    alpha = alpha.reshape(m, d * 2 * nseg).astype(compute_dtype)
    beta = beta.reshape(m, d * 2 * nseg).astype(compute_dtype)
    return alpha, beta


def l1dist(Xr: jnp.ndarray, Xc: jnp.ndarray, edges: jnp.ndarray,
           compute_dtype=jnp.float32) -> jnp.ndarray:
    """Pairwise ‖x−y‖₁ via the sign-split MXU form (two contractions).

    The SHARED implementation of the MXU route: ``kernel._entry_tile`` calls
    this on VMEM point tiles and the dense/oracle paths call it on whole
    blocks, so the Pallas and non-Pallas sign-split routes can never diverge.
    Accumulation is always f32 (``preferred_element_type``); only the
    operand tiles follow ``compute_dtype``.
    """
    ar, br = embed(Xr, edges, compute_dtype)
    ac, bc = embed(Xc, edges, compute_dtype)
    dn = (((1,), (1,)), ((), ()))
    out = jax.lax.dot_general(ar, bc, dimension_numbers=dn,
                              preferred_element_type=jnp.float32)
    out = out + jax.lax.dot_general(br, ac, dimension_numbers=dn,
                                    preferred_element_type=jnp.float32)
    return jnp.maximum(out, 0.0)
