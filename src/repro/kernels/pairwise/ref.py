"""Pure-jnp oracles for the pairwise kernel sweep template.

One *independent* dense implementation per registered kernel — written from
the textbook formulas, NOT from ``KernelSpec.entry_fn`` — so the parity tests
check the spec definitions themselves, not just the Pallas plumbing around
them.  Small shapes only: every oracle materializes the full block.
"""
from __future__ import annotations
# repro: allow-file(RPR003: dense f32 oracles — operands are cast to f32 before every contraction)

import jax.numpy as jnp

from repro.kernels.pairwise.specs import KernelSpec


def _sq(Xr: jnp.ndarray, Xc: jnp.ndarray) -> jnp.ndarray:
    Xr = Xr.astype(jnp.float32)
    Xc = Xc.astype(jnp.float32)
    rr = jnp.sum(Xr * Xr, axis=1)
    cc = jnp.sum(Xc * Xc, axis=1)
    return jnp.maximum(rr[:, None] + cc[None, :] - 2.0 * (Xr @ Xc.T), 0.0)


def rbf_block(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """K[ri, cj] = exp(-|x_ri - x_cj|^2 / (2 sigma^2)), f32 accumulation."""
    return jnp.exp(-_sq(Xr, Xc) / (2.0 * sigma ** 2))


def laplacian_block(Xr: jnp.ndarray, Xc: jnp.ndarray,
                    gamma: float) -> jnp.ndarray:
    """K[ri, cj] = exp(-gamma * ||x_ri - x_cj||_1) via the full broadcast."""
    Xr = Xr.astype(jnp.float32)
    Xc = Xc.astype(jnp.float32)
    l1 = jnp.sum(jnp.abs(Xr[:, None, :] - Xc[None, :, :]), axis=-1)
    return jnp.exp(-gamma * l1)


def matern32_block(Xr: jnp.ndarray, Xc: jnp.ndarray,
                   length_scale: float) -> jnp.ndarray:
    """K[ri, cj] = (1 + sqrt(3) r / l) exp(-sqrt(3) r / l), r = ||.||_2."""
    r = jnp.sqrt(_sq(Xr, Xc))
    z = (3.0 ** 0.5) * r / length_scale
    return (1.0 + z) * jnp.exp(-z)


def polynomial_block(Xr: jnp.ndarray, Xc: jnp.ndarray, degree: int = 3,
                     gamma: float | None = None,
                     coef0: float = 1.0) -> jnp.ndarray:
    """K[ri, cj] = (gamma x_ri . x_cj + coef0)^degree."""
    g = 1.0 if gamma is None else gamma
    dot = Xr.astype(jnp.float32) @ Xc.astype(jnp.float32).T
    return (g * dot + coef0) ** degree


def linear_block(Xr: jnp.ndarray, Xc: jnp.ndarray) -> jnp.ndarray:
    """K[ri, cj] = x_ri . x_cj."""
    return Xr.astype(jnp.float32) @ Xc.astype(jnp.float32).T


_ORACLES = {
    "rbf": rbf_block,
    "laplacian": laplacian_block,
    "matern32": matern32_block,
    "polynomial": polynomial_block,
    "linear": linear_block,
}


def kernel_block(spec: KernelSpec, Xr: jnp.ndarray,
                 Xc: jnp.ndarray) -> jnp.ndarray:
    """Dispatch to the named oracle with the spec's parameters."""
    if spec.name not in _ORACLES:
        raise KeyError(f"no ref oracle for kernel {spec.name!r}; known: "
                       f"{tuple(sorted(_ORACLES))}")
    return _ORACLES[spec.name](Xr, Xc, **dict(spec.params))


def kernel_matmat_multi_rows(spec: KernelSpec, Xr: jnp.ndarray,
                             Xc: jnp.ndarray, Vs):
    """Rectangular row-slab oracle: [K(Xr, Xc) @ V for V in Vs]."""
    K = kernel_block(spec, Xr, Xc)
    return tuple(K @ V.astype(jnp.float32) for V in Vs)


def kernel_matmat(spec: KernelSpec, X: jnp.ndarray,
                  V: jnp.ndarray) -> jnp.ndarray:
    """K(X, X) @ V oracle (materializes K — small shapes only)."""
    return kernel_block(spec, X, X) @ V.astype(jnp.float32)
