"""Back-compat RBF wrappers over the generalized pairwise kernel template.

The fused kernels were generalized into ``repro.kernels.pairwise`` (one tiled
Pallas sweep template parameterized by a ``KernelSpec``); these wrappers keep
the original RBF-specific signatures alive by binding the registry's ``rbf``
spec.  Backend selection (interpret on CPU, compiled on TPU) stays resolved
at *call* time via this module's ``_interpret_mode`` so tests and
multi-backend processes can patch/flip it per call.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pairwise import ops as _pw
from repro.kernels.pairwise.specs import rbf as _rbf_spec


def _interpret_mode() -> bool:
    """CPU containers interpret the TPU kernel; real TPU compiles it."""
    return _pw._interpret_mode()


def rbf_block(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float,
              use_pallas: bool = True) -> jnp.ndarray:
    """K-block exp(-|x_r - x_c|^2 / 2 sigma^2) of shape (len(Xr), len(Xc))."""
    return _pw.kernel_block(_rbf_spec(sigma), Xr, Xc, use_pallas=use_pallas,
                            interpret=_interpret_mode())


def rbf_matmat(X: jnp.ndarray, V: jnp.ndarray, sigma: float,
               use_pallas: bool = True) -> jnp.ndarray:
    """K(X, X) @ V fused: kernel tiles never leave VMEM (streaming matmat)."""
    return _pw.kernel_matmat(_rbf_spec(sigma), X, V, use_pallas=use_pallas,
                             interpret=_interpret_mode())


def rbf_matmat_multi_rows(Xr: jnp.ndarray, Xc: jnp.ndarray, Vs, sigma: float,
                          use_pallas: bool = True):
    """[K(Xr, Xc) @ V for V in Vs] — the rectangular row-slab fusion."""
    return _pw.kernel_matmat_multi_rows(_rbf_spec(sigma), Xr, Xc, Vs,
                                        use_pallas=use_pallas,
                                        interpret=_interpret_mode())


def rbf_matmat_multi(X: jnp.ndarray, Vs, sigma: float,
                     use_pallas: bool = True):
    """[K(X, X) @ V for V in Vs] with each kernel tile computed ONCE."""
    return _pw.kernel_matmat_multi(_rbf_spec(sigma), X, Vs,
                                   use_pallas=use_pallas,
                                   interpret=_interpret_mode())


def sketched_gram(Xs: jnp.ndarray, sigma: float,
                  scales: jnp.ndarray | None = None) -> jnp.ndarray:
    """S^T K S for a column sketch S given the selected points Xs = X[idx]."""
    return _pw.sketched_gram(_rbf_spec(sigma), Xs, scales=scales,
                             interpret=_interpret_mode())
