"""Public jit'd wrappers for the fused RBF block kernels.

Handles arbitrary (non-tile-aligned) shapes by zero-padding the point sets and
slicing the output; padding rows produce garbage kernel values that are sliced
away, never read.

Backend selection (interpret mode on CPU containers, compiled on real TPU) is
resolved at *call* time, not import time: each public wrapper reads
``jax.default_backend()`` when invoked and threads the choice into the jit
cache as a static argument, so flipping the backend after import (tests,
multi-backend processes) can never run a stale interpret decision.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rbf_sketch import kernel as _k
from repro.kernels.rbf_sketch import ref as _ref


def _interpret_mode() -> bool:
    """CPU containers interpret the TPU kernel; real TPU compiles it.

    A function (not a module constant) on purpose: the backend may be chosen
    after this module is imported, so the decision must be re-read per call.
    """
    return jax.default_backend() != "tpu"


def _pad_rows(X: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = X.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return X
    return jnp.pad(X, ((0, pad), (0, 0)))


def _pad_cols(V: jnp.ndarray, mult: int) -> jnp.ndarray:
    m = V.shape[1]
    pad = (-m) % mult
    if pad == 0:
        return V
    return jnp.pad(V, ((0, 0), (0, pad)))


@partial(jax.jit, static_argnames=("sigma", "use_pallas", "interpret"))
def _rbf_block_jit(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float,
                   use_pallas: bool, interpret: bool) -> jnp.ndarray:
    if not use_pallas:
        return _ref.rbf_block(Xr, Xc, sigma)
    nr, nc = Xr.shape[0], Xc.shape[0]
    Xrp = _pad_rows(Xr, _k.BLOCK_R)
    Xcp = _pad_rows(Xc, _k.BLOCK_C)
    out = _k.rbf_block_padded(Xrp, Xcp, sigma, interpret=interpret)
    return out[:nr, :nc]


def rbf_block(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float,
              use_pallas: bool = True) -> jnp.ndarray:
    """K-block exp(-|x_r - x_c|^2 / 2 sigma^2) of shape (len(Xr), len(Xc))."""
    return _rbf_block_jit(Xr, Xc, sigma, use_pallas, _interpret_mode())


@partial(jax.jit, static_argnames=("sigma", "use_pallas", "interpret"))
def _rbf_matmat_jit(X: jnp.ndarray, V: jnp.ndarray, sigma: float,
                    use_pallas: bool, interpret: bool) -> jnp.ndarray:
    if not use_pallas:
        return _ref.rbf_matmat(X, V, sigma)
    n = X.shape[0]
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    m = V2.shape[1]
    mult = max(_k.BLOCK_R, _k.BLOCK_C)
    Xp = _pad_rows(X, mult)
    Vp = _pad_cols(_pad_rows(V2, mult), 128)
    out = _k.rbf_matmat_padded(Xp, Xp, Vp, sigma, interpret=interpret)
    out = out[:n, :m]
    return out[:, 0] if squeeze else out


def rbf_matmat(X: jnp.ndarray, V: jnp.ndarray, sigma: float,
               use_pallas: bool = True) -> jnp.ndarray:
    """K(X, X) @ V fused: kernel tiles never leave VMEM (streaming matmat).

    Row/column point counts are zero-padded to tile multiples; padded columns
    of K meet zero-padded rows of V, so their contribution vanishes, and
    padded output rows are sliced away.
    """
    return _rbf_matmat_jit(X, V, sigma, use_pallas, _interpret_mode())


@partial(jax.jit, static_argnames=("sigma", "use_pallas", "interpret"))
def _rbf_matmat_multi_rows_jit(Xr: jnp.ndarray, Xc: jnp.ndarray, Vs,
                               sigma: float, use_pallas: bool,
                               interpret: bool):
    Vs = tuple(Vs)
    if not use_pallas:
        K = _ref.rbf_block(Xr, Xc, sigma)
        return tuple(K @ V.astype(jnp.float32) for V in Vs)
    nr = Xr.shape[0]
    ms = [V.shape[1] for V in Vs]
    Xrp = _pad_rows(Xr, _k.BLOCK_R)
    Xcp = _pad_rows(Xc, _k.BLOCK_C)
    Vps = tuple(_pad_cols(_pad_rows(V, _k.BLOCK_C), 128) for V in Vs)
    outs = _k.rbf_matmat_multi_padded(Xrp, Xcp, Vps, sigma,
                                      interpret=interpret)
    return tuple(out[:nr, :m] for out, m in zip(outs, ms))


def rbf_matmat_multi_rows(Xr: jnp.ndarray, Xc: jnp.ndarray, Vs, sigma: float,
                          use_pallas: bool = True):
    """[K(Xr, Xc) @ V for V in Vs] — the rectangular row-slab fusion.

    The shard_map fast path of the sweep engine: each device gathers its
    contiguous local row slab ``Xr = X[r0:r1]`` (a row-offset slice of the
    full point set) and passes the full column points ``Xc``, so only that
    slab's (128 × 128) kernel tiles are ever computed — once, in VMEM — and
    contracted against every right-hand side.  Rows of ``Xr`` are padded to
    BLOCK_R, rows of ``Xc`` (and of each V, in lockstep) to BLOCK_C; padded
    K columns meet zero-padded V rows, so their contribution vanishes.
    """
    return _rbf_matmat_multi_rows_jit(Xr, Xc, tuple(Vs), sigma, use_pallas,
                                      _interpret_mode())


def rbf_matmat_multi(X: jnp.ndarray, Vs, sigma: float,
                     use_pallas: bool = True):
    """[K(X, X) @ V for V in Vs] with each kernel tile computed ONCE.

    The sweep-engine fast path: all right-hand sides (projection sketches,
    Hutchinson probes, one-hot column gathers for C = K P) are contracted
    against the same VMEM-resident kernel tile in a single Pallas launch, so
    the n×n entry evaluation is paid once for the whole product bundle.
    The square special case of ``rbf_matmat_multi_rows``.
    """
    return rbf_matmat_multi_rows(X, X, Vs, sigma, use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("sigma", "interpret"))
def _sketched_gram_jit(Xs: jnp.ndarray, sigma: float, scales, interpret):
    blk = _rbf_block_jit(Xs, Xs, sigma, True, interpret)
    if scales is not None:
        blk = blk * (scales[:, None] * scales[None, :])
    return blk


def sketched_gram(Xs: jnp.ndarray, sigma: float,
                  scales: jnp.ndarray | None = None) -> jnp.ndarray:
    """S^T K S for a column sketch S given the selected points Xs = X[idx]."""
    return _sketched_gram_jit(Xs, sigma, scales, _interpret_mode())
