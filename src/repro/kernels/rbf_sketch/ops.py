"""Public jit'd wrapper for the fused RBF block kernel.

Handles arbitrary (non-tile-aligned) shapes by zero-padding the point sets and
slicing the output; padding rows produce garbage kernel values that are sliced
away, never read.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rbf_sketch import kernel as _k
from repro.kernels.rbf_sketch import ref as _ref

# CPU containers interpret the TPU kernel; on real TPU set interpret=False.
_INTERPRET = jax.default_backend() != "tpu"


def _pad_rows(X: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = X.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return X
    return jnp.pad(X, ((0, pad), (0, 0)))


@partial(jax.jit, static_argnames=("sigma", "use_pallas"))
def rbf_block(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float,
              use_pallas: bool = True) -> jnp.ndarray:
    """K-block exp(-|x_r - x_c|^2 / 2 sigma^2) of shape (len(Xr), len(Xc))."""
    if not use_pallas:
        return _ref.rbf_block(Xr, Xc, sigma)
    nr, nc = Xr.shape[0], Xc.shape[0]
    Xrp = _pad_rows(Xr, _k.BLOCK_R)
    Xcp = _pad_rows(Xc, _k.BLOCK_C)
    out = _k.rbf_block_padded(Xrp, Xcp, sigma, interpret=_INTERPRET)
    return out[:nr, :nc]


def _pad_cols(V: jnp.ndarray, mult: int) -> jnp.ndarray:
    m = V.shape[1]
    pad = (-m) % mult
    if pad == 0:
        return V
    return jnp.pad(V, ((0, 0), (0, pad)))


@partial(jax.jit, static_argnames=("sigma", "use_pallas"))
def rbf_matmat(X: jnp.ndarray, V: jnp.ndarray, sigma: float,
               use_pallas: bool = True) -> jnp.ndarray:
    """K(X, X) @ V fused: kernel tiles never leave VMEM (streaming matmat).

    Row/column point counts are zero-padded to tile multiples; padded columns
    of K meet zero-padded rows of V, so their contribution vanishes, and
    padded output rows are sliced away.
    """
    if not use_pallas:
        return _ref.rbf_matmat(X, V, sigma)
    n = X.shape[0]
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    m = V2.shape[1]
    mult = max(_k.BLOCK_R, _k.BLOCK_C)
    Xp = _pad_rows(X, mult)
    Vp = _pad_cols(_pad_rows(V2, mult), 128)
    out = _k.rbf_matmat_padded(Xp, Xp, Vp, sigma, interpret=_INTERPRET)
    out = out[:n, :m]
    return out[:, 0] if squeeze else out


@partial(jax.jit, static_argnames=("sigma", "use_pallas"))
def rbf_matmat_multi(X: jnp.ndarray, Vs, sigma: float,
                     use_pallas: bool = True):
    """[K(X, X) @ V for V in Vs] with each kernel tile computed ONCE.

    The sweep-engine fast path: all right-hand sides (projection sketches,
    Hutchinson probes, one-hot column gathers for C = K P) are contracted
    against the same VMEM-resident kernel tile in a single Pallas launch, so
    the n×n entry evaluation is paid once for the whole product bundle.
    """
    Vs = tuple(Vs)
    if not use_pallas:
        return tuple(_ref.rbf_matmat(X, V, sigma) for V in Vs)
    n = X.shape[0]
    ms = [V.shape[1] for V in Vs]
    mult = max(_k.BLOCK_R, _k.BLOCK_C)
    Xp = _pad_rows(X, mult)
    Vps = tuple(_pad_cols(_pad_rows(V, mult), 128) for V in Vs)
    outs = _k.rbf_matmat_multi_padded(Xp, Xp, Vps, sigma,
                                      interpret=_INTERPRET)
    return tuple(out[:n, :m] for out, m in zip(outs, ms))


@partial(jax.jit, static_argnames=("sigma",))
def sketched_gram(Xs: jnp.ndarray, sigma: float,
                  scales: jnp.ndarray | None = None) -> jnp.ndarray:
    """S^T K S for a column sketch S given the selected points Xs = X[idx]."""
    blk = rbf_block(Xs, Xs, sigma)
    if scales is not None:
        blk = blk * (scales[:, None] * scales[None, :])
    return blk
