"""Public jit'd wrapper for the fused RBF block kernel.

Handles arbitrary (non-tile-aligned) shapes by zero-padding the point sets and
slicing the output; padding rows produce garbage kernel values that are sliced
away, never read.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rbf_sketch import kernel as _k
from repro.kernels.rbf_sketch import ref as _ref

# CPU containers interpret the TPU kernel; on real TPU set interpret=False.
_INTERPRET = jax.default_backend() != "tpu"


def _pad_rows(X: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = X.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return X
    return jnp.pad(X, ((0, pad), (0, 0)))


@partial(jax.jit, static_argnames=("sigma", "use_pallas"))
def rbf_block(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float,
              use_pallas: bool = True) -> jnp.ndarray:
    """K-block exp(-|x_r - x_c|^2 / 2 sigma^2) of shape (len(Xr), len(Xc))."""
    if not use_pallas:
        return _ref.rbf_block(Xr, Xc, sigma)
    nr, nc = Xr.shape[0], Xc.shape[0]
    Xrp = _pad_rows(Xr, _k.BLOCK_R)
    Xcp = _pad_rows(Xc, _k.BLOCK_C)
    out = _k.rbf_block_padded(Xrp, Xcp, sigma, interpret=_INTERPRET)
    return out[:nr, :nc]


@partial(jax.jit, static_argnames=("sigma",))
def sketched_gram(Xs: jnp.ndarray, sigma: float,
                  scales: jnp.ndarray | None = None) -> jnp.ndarray:
    """S^T K S for a column sketch S given the selected points Xs = X[idx]."""
    blk = rbf_block(Xs, Xs, sigma)
    if scales is not None:
        blk = blk * (scales[:, None] * scales[None, :])
    return blk
