from repro.kernels.rbf_sketch import kernel, ops, ref  # noqa: F401
