"""Pure-jnp oracle for the fused RBF block kernel."""
from __future__ import annotations
# repro: allow-file(RPR003: dense f32 oracle — operands are cast to f32 before every contraction)

import jax.numpy as jnp


def rbf_block(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """K[ri, cj] = exp(-|x_ri - x_cj|^2 / (2 sigma^2)), f32 accumulation."""
    Xr = Xr.astype(jnp.float32)
    Xc = Xc.astype(jnp.float32)
    rr = jnp.sum(Xr * Xr, axis=1)
    cc = jnp.sum(Xc * Xc, axis=1)
    sq = rr[:, None] + cc[None, :] - 2.0 * (Xr @ Xc.T)
    sq = jnp.maximum(sq, 0.0)
    gamma = 1.0 / (2.0 * sigma ** 2)
    return jnp.exp(-gamma * sq)


def rbf_matmat(X: jnp.ndarray, V: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """K(X, X) @ V oracle (materializes K — small shapes only)."""
    return rbf_block(X, X, sigma) @ V.astype(jnp.float32)


def rbf_matmat_multi(X: jnp.ndarray, Vs, sigma: float):
    """[K(X, X) @ V for V in Vs] oracle (materializes K — small shapes only)."""
    K = rbf_block(X, X, sigma)
    return tuple(K @ V.astype(jnp.float32) for V in Vs)


def rbf_matmat_multi_rows(Xr: jnp.ndarray, Xc: jnp.ndarray, Vs, sigma: float):
    """Rectangular row-slab oracle: [K(Xr, Xc) @ V for V in Vs]."""
    K = rbf_block(Xr, Xc, sigma)
    return tuple(K @ V.astype(jnp.float32) for V in Vs)


def sketched_gram(Xs: jnp.ndarray, sigma: float,
                  scales: jnp.ndarray | None = None) -> jnp.ndarray:
    """S^T K S for a column-selection sketch: rows Xs = X[S.indices]."""
    blk = rbf_block(Xs, Xs, sigma)
    if scales is not None:
        blk = blk * (scales[:, None] * scales[None, :])
    return blk
