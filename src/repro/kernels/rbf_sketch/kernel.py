"""Back-compat shim: the fused RBF Pallas kernels are now the ``rbf`` spec of
the generalized pairwise sweep template (``repro.kernels.pairwise.kernel``).

Kept so existing imports of the padded entry points and tile constants keep
working; new code should target the pairwise template directly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pairwise import kernel as _pk
from repro.kernels.pairwise.kernel import BLOCK_C, BLOCK_R  # noqa: F401
from repro.kernels.pairwise.specs import rbf as _rbf_spec


def rbf_block_padded(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float,
                     interpret: bool = False) -> jnp.ndarray:
    """Pallas call over padded inputs; shapes must be multiples of the tiles."""
    return _pk.pairwise_block_padded(_rbf_spec(sigma), Xr, Xc,
                                     interpret=interpret)


def rbf_matmat_multi_padded(Xr: jnp.ndarray, Xc: jnp.ndarray, Vs,
                            sigma: float, interpret: bool = False):
    """[K(Xr, Xc) @ V for V in Vs] over padded inputs, one kernel launch."""
    return _pk.pairwise_matmat_multi_padded(_rbf_spec(sigma), Xr, Xc, Vs,
                                            interpret=interpret)


def rbf_matmat_padded(Xr: jnp.ndarray, Xc: jnp.ndarray, V: jnp.ndarray,
                      sigma: float, interpret: bool = False) -> jnp.ndarray:
    """K(Xr, Xc) @ V over padded inputs; all dims must be tile multiples."""
    (out,) = _pk.pairwise_matmat_multi_padded(_rbf_spec(sigma), Xr, Xc, (V,),
                                              interpret=interpret)
    return out
