"""Fused RBF kernel-block Pallas kernel (paper Fig. 1 memory trick, TPU-native).

The paper's fast model only ever touches an ``n x c`` panel and an ``s x s``
block of the kernel matrix.  On TPU we compute those blocks straight from the
data ``X`` without staging the pairwise-distance matrix in HBM:

  - the cross term ``Xr @ Xc^T`` runs on the MXU (f32 accumulation),
  - ``exp(-gamma * max(|x_i|^2 + |x_j|^2 - 2 x_i.x_j, 0))`` runs on the VPU,
  - output tiles are (block_r, block_c) = (128, 128) — MXU/lane aligned,
  - the feature dimension d stays resident in VMEM per tile (d <= a few
    thousand for the paper's datasets; the tile working set is
    2*128*d + 128*128 floats, well under the ~16 MB v5e VMEM budget).

HBM traffic is O((nr + nc) * d + nr * nc) instead of O(n^2 * d) for a full
materialization — exactly the Table-3 "#Entries" story.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 128
BLOCK_C = 128


def _rbf_block_kernel(xr_ref, xc_ref, o_ref, *, gamma: float):
    """One (BLOCK_R, BLOCK_C) output tile.

    xr_ref: (BLOCK_R, d) VMEM tile of row points
    xc_ref: (BLOCK_C, d) VMEM tile of column points
    o_ref:  (BLOCK_R, BLOCK_C) VMEM output tile
    """
    xr = xr_ref[...].astype(jnp.float32)
    xc = xc_ref[...].astype(jnp.float32)
    # MXU: cross inner products with f32 accumulation.
    cross = jax.lax.dot_general(
        xr, xc,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # VPU: norms, combine, exp.
    rr = jnp.sum(xr * xr, axis=1, keepdims=True)          # (BLOCK_R, 1)
    cc = jnp.sum(xc * xc, axis=1, keepdims=True)          # (BLOCK_C, 1)
    sq = jnp.maximum(rr + cc.T - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-gamma * sq)


def _rbf_matmat_kernel(xr_ref, xc_ref, v_ref, o_ref, *, gamma: float):
    """One (BLOCK_R, m) output tile of K(Xr, Xc) @ V, accumulated over the
    column-tile grid axis.

    The (BLOCK_R, BLOCK_C) kernel tile lives only in VMEM/registers: it is
    produced on the MXU/VPU and immediately contracted against the matching
    (BLOCK_C, m) tile of V, so HBM traffic is O((nr + nc)·d + nc·m + nr·m)
    instead of O(nr·nc) for staging K.

    xr_ref: (BLOCK_R, d) row points        — revisited across j
    xc_ref: (BLOCK_C, d) column points     — walks the contraction axis j
    v_ref:  (BLOCK_C, m) right-hand tile   — walks j in lockstep with xc
    o_ref:  (BLOCK_R, m) accumulator tile
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    xr = xr_ref[...].astype(jnp.float32)
    xc = xc_ref[...].astype(jnp.float32)
    cross = jax.lax.dot_general(
        xr, xc,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    rr = jnp.sum(xr * xr, axis=1, keepdims=True)
    cc = jnp.sum(xc * xc, axis=1, keepdims=True)
    k_tile = jnp.exp(-gamma * jnp.maximum(rr + cc.T - 2.0 * cross, 0.0))
    o_ref[...] += jax.lax.dot_general(
        k_tile, v_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _rbf_matmat_multi_kernel(xr_ref, xc_ref, *refs, gamma: float, nv: int):
    """Multi-right-hand-side fusion: one K tile, ``nv`` contractions.

    The (BLOCK_R, BLOCK_C) kernel tile is produced once on the MXU/VPU and
    immediately contracted against every (BLOCK_C, m_i) right-hand tile while
    still in VMEM — the single-sweep panel engine at the kernel-tile level.
    ``refs`` is ``nv`` V refs followed by ``nv`` output accumulator refs.
    """
    v_refs, o_refs = refs[:nv], refs[nv:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        for o_ref in o_refs:
            o_ref[...] = jnp.zeros_like(o_ref)

    xr = xr_ref[...].astype(jnp.float32)
    xc = xc_ref[...].astype(jnp.float32)
    cross = jax.lax.dot_general(
        xr, xc,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    rr = jnp.sum(xr * xr, axis=1, keepdims=True)
    cc = jnp.sum(xc * xc, axis=1, keepdims=True)
    k_tile = jnp.exp(-gamma * jnp.maximum(rr + cc.T - 2.0 * cross, 0.0))
    for v_ref, o_ref in zip(v_refs, o_refs):
        o_ref[...] += jax.lax.dot_general(
            k_tile, v_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def rbf_matmat_multi_padded(Xr: jnp.ndarray, Xc: jnp.ndarray, Vs,
                            sigma: float, interpret: bool = False):
    """[K(Xr, Xc) @ V for V in Vs] over padded inputs, one kernel launch.

    ``Xr`` and ``Xc`` may differ: the grid is rectangular
    (nr/BLOCK_R × nc/BLOCK_C), which is how the shard_map sweep fast path
    launches one row *slab* per device — ``Xr`` is the device's contiguous
    row range of the point set (a row-offset slice), ``Xc`` the full set, so
    each device computes only its slab's kernel tiles in VMEM and contracts
    them against every right-hand side exactly once.
    """
    nr, d = Xr.shape
    nc = Xc.shape[0]
    assert nr % BLOCK_R == 0 and nc % BLOCK_C == 0, (nr, nc)
    for V in Vs:
        assert V.shape[0] == nc and V.shape[1] % 128 == 0, V.shape
    gamma = 1.0 / (2.0 * float(sigma) ** 2)
    grid = (nr // BLOCK_R, nc // BLOCK_C)
    return pl.pallas_call(
        functools.partial(_rbf_matmat_multi_kernel, gamma=gamma, nv=len(Vs)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_C, d), lambda i, j: (j, 0)),
        ] + [
            pl.BlockSpec((BLOCK_C, V.shape[1]), lambda i, j: (j, 0))
            for V in Vs
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_R, V.shape[1]), lambda i, j: (i, 0))
            for V in Vs
        ],
        out_shape=[jax.ShapeDtypeStruct((nr, V.shape[1]), jnp.float32)
                   for V in Vs],
        interpret=interpret,
    )(Xr, Xc, *Vs)


def rbf_matmat_padded(Xr: jnp.ndarray, Xc: jnp.ndarray, V: jnp.ndarray,
                      sigma: float, interpret: bool = False) -> jnp.ndarray:
    """K(Xr, Xc) @ V over padded inputs; all dims must be tile multiples."""
    nr, d = Xr.shape
    nc, m = V.shape
    assert Xc.shape[0] == nc and nr % BLOCK_R == 0 and nc % BLOCK_C == 0, \
        (Xr.shape, Xc.shape, V.shape)
    assert m % 128 == 0, m
    gamma = 1.0 / (2.0 * float(sigma) ** 2)
    grid = (nr // BLOCK_R, nc // BLOCK_C)
    return pl.pallas_call(
        functools.partial(_rbf_matmat_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_C, d), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_C, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, m), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, m), jnp.float32),
        interpret=interpret,
    )(Xr, Xc, V)


def rbf_block_padded(Xr: jnp.ndarray, Xc: jnp.ndarray, sigma: float,
                     interpret: bool = False) -> jnp.ndarray:
    """Pallas call over padded inputs; shapes must be multiples of the tiles."""
    nr, d = Xr.shape
    nc = Xc.shape[0]
    assert nr % BLOCK_R == 0 and nc % BLOCK_C == 0, (nr, nc)
    gamma = 1.0 / (2.0 * float(sigma) ** 2)
    grid = (nr // BLOCK_R, nc // BLOCK_C)
    return pl.pallas_call(
        functools.partial(_rbf_block_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_C, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr, nc), jnp.float32),
        interpret=interpret,
    )(Xr, Xc)
