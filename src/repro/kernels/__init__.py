"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel is a package with three modules:

- ``kernel.py`` — the ``pl.pallas_call`` body with explicit BlockSpec VMEM tiling
  (TPU is the target; ``interpret=True`` validates on CPU);
- ``ops.py``    — the jit'd public wrapper (padding, dtype policy, vmap);
- ``ref.py``    — the pure-jnp oracle every test asserts against.

Kernels:

- ``pairwise``            ONE tiled sweep template for every SPSD kernel
                          family (paper Fig. 1 / footnote-2 memory trick: K
                          never hits HBM), parameterized by a ``KernelSpec``
                          (elementwise distance→entry fn) registry: rbf,
                          laplacian, matern32, polynomial, linear, …
- ``rbf_sketch``          back-compat RBF bindings of the pairwise template
- ``flash_attention``     tiled online-softmax attention (causal / GQA / sliding
                          window) for the LM substrate
- ``landmark_attention``  the paper's fast-SPSD U applied to the attention Gram:
                          fused exp-logits x (U @ R̂V) read — O(c·d) per query
"""
from repro.kernels.pairwise import ops as pairwise_ops           # noqa: F401
from repro.kernels.rbf_sketch import ops as rbf_ops              # noqa: F401
from repro.kernels.flash_attention import ops as attention_ops   # noqa: F401
from repro.kernels.landmark_attention import ops as landmark_ops  # noqa: F401
