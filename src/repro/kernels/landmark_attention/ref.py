"""Pure-jnp oracle for the fused landmark-attention read.

Given the context-side factors of the paper's fast model
(k_land (c,d), UV = U(R̂V) (c,dv), U1 = U(R̂1) (c,)), the per-query read is

    cvec = exp(q @ k_land^T / sqrt(d) - offset)        (m, c)
    out  = (cvec @ UV) / sgnfloor(cvec @ U1, eps)      (m, dv)

where ``sgnfloor`` floors |den| at eps with the sign preserved (an
indefinite fast-U can push the normalizer negative; clamping to +eps would
flip the output sign).
"""
from __future__ import annotations
# repro: allow-file(RPR003: dense f32 oracle — operands are cast to f32 before every contraction)

import jax.numpy as jnp


def landmark_read(Q: jnp.ndarray, k_land: jnp.ndarray, UV: jnp.ndarray,
                  U1: jnp.ndarray, offset: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    d = Q.shape[-1]
    inv_sqrt_d = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = (Q.astype(jnp.float32) @ k_land.astype(jnp.float32).T
              ) * inv_sqrt_d - offset
    cvec = jnp.exp(logits)
    num = cvec @ UV.astype(jnp.float32)
    den = cvec @ U1.astype(jnp.float32)
    den = jnp.where(den < 0.0, -1.0, 1.0) * jnp.maximum(jnp.abs(den), eps)
    return (num / den[:, None]).astype(Q.dtype)
