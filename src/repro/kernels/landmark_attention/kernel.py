"""Fused landmark-attention read (the paper's fast model on the softmax Gram).

After ``build_landmark_state`` has produced the context-side factors
(k_land, UV = U^fast (R̂ V), U1 = U^fast (R̂ 1)), attending m queries to an
n-token context costs O(m * c * d) — *independent of n*.  This kernel fuses

    exp(Q K_land^T / sqrt(d) - offset)  ->  (. @ UV) / (. @ U1)

so the (m, c) score panel never leaves VMEM:

- Q is tiled (BQ, d); k_land (c, d), UV (c, dv), U1 (c, 1) are VMEM-resident
  per tile (c <= a few hundred landmarks, ~KBs);
- both GEMMs hit the MXU; exp and the divide run on the VPU;
- HBM traffic per tile: BQ*d in, BQ*dv out — the roofline-optimal minimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128


def _landmark_kernel(q_ref, kl_ref, uv_ref, u1_ref, off_ref, o_ref, *,
                     eps: float):
    q = q_ref[...].astype(jnp.float32)                      # (bq, d)
    kl = kl_ref[...].astype(jnp.float32)                    # (c, d)
    uv = uv_ref[...].astype(jnp.float32)                    # (c, dv)
    u1 = u1_ref[...].astype(jnp.float32)                    # (c, 1)
    off = off_ref[0, 0]

    d = q.shape[1]
    inv_sqrt_d = 1.0 / (d ** 0.5)
    logits = jax.lax.dot_general(
        q, kl, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * inv_sqrt_d - off
    cvec = jnp.exp(logits)                                  # (bq, c)
    num = jax.lax.dot(cvec, uv, preferred_element_type=jnp.float32)
    den = jax.lax.dot(cvec, u1, preferred_element_type=jnp.float32)
    # sign-preserving floor: an indefinite fast-U can push den negative, and
    # a plain maximum(den, eps) would flip the sign of the whole output row
    den = jnp.where(den < 0.0, -1.0, 1.0) * jnp.maximum(jnp.abs(den), eps)
    o_ref[...] = (num / den).astype(o_ref.dtype)


def landmark_read_padded(Q: jnp.ndarray, k_land: jnp.ndarray,
                         UV: jnp.ndarray, U1: jnp.ndarray,
                         offset: jnp.ndarray, eps: float = 1e-6,
                         interpret: bool = False) -> jnp.ndarray:
    m, d = Q.shape
    c, dv = UV.shape
    assert m % BLOCK_Q == 0, m
    grid = (m // BLOCK_Q,)
    off2 = jnp.asarray(offset, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_landmark_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_Q, d), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((c, dv), lambda i: (0, 0)),
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, dv), Q.dtype),
        interpret=interpret,
    )(Q, k_land, UV, U1.reshape(c, 1), off2)
