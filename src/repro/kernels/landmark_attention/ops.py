"""Public jit'd wrapper for the fused landmark read.

Interpret-vs-compile is resolved per call in the un-jitted wrapper (never at
import) and rides the jit cache as a static argument — the
``pairwise.ops._interpret_mode`` idiom.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.landmark_attention import kernel as _k
from repro.kernels.landmark_attention import ref as _ref


def _interpret_mode() -> bool:
    """CPU containers interpret the TPU kernel; real TPU compiles it.

    A function (not a module constant) on purpose: the backend may be chosen
    after this module is imported, so the decision must be re-read per call.
    """
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _landmark_read_jit(Q: jnp.ndarray, k_land: jnp.ndarray, UV: jnp.ndarray,
                       U1: jnp.ndarray, offset: jnp.ndarray,
                       use_pallas: bool, interpret: bool) -> jnp.ndarray:
    if not use_pallas:
        return _ref.landmark_read(Q, k_land, UV, U1, offset)
    m = Q.shape[0]
    pad = (-m) % _k.BLOCK_Q
    Qp = jnp.pad(Q, ((0, pad), (0, 0))) if pad else Q
    out = _k.landmark_read_padded(Qp, k_land, UV, U1, offset,
                                  interpret=interpret)
    return out[:m]


def landmark_read(Q: jnp.ndarray, k_land: jnp.ndarray, UV: jnp.ndarray,
                  U1: jnp.ndarray, offset: jnp.ndarray,
                  use_pallas: bool = True,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Attend Q (m, d) to a prebuilt LandmarkState -> (m, dv)."""
    if interpret is None:
        interpret = _interpret_mode()
    return _landmark_read_jit(Q, k_land, UV, U1, offset, use_pallas,
                              interpret)
