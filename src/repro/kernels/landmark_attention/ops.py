"""Public jit'd wrapper for the fused landmark read."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.landmark_attention import kernel as _k
from repro.kernels.landmark_attention import ref as _ref

_INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("use_pallas",))
def landmark_read(Q: jnp.ndarray, k_land: jnp.ndarray, UV: jnp.ndarray,
                  U1: jnp.ndarray, offset: jnp.ndarray,
                  use_pallas: bool = True) -> jnp.ndarray:
    """Attend Q (m, d) to a prebuilt LandmarkState -> (m, dv)."""
    if not use_pallas:
        return _ref.landmark_read(Q, k_land, UV, U1, offset)
    m = Q.shape[0]
    pad = (-m) % _k.BLOCK_Q
    Qp = jnp.pad(Q, ((0, pad), (0, 0))) if pad else Q
    out = _k.landmark_read_padded(Qp, k_land, UV, U1, offset,
                                  interpret=_INTERPRET)
    return out[:m]
