from repro.kernels.landmark_attention import kernel, ops, ref  # noqa: F401
