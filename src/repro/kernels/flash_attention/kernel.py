"""Tiled online-softmax (flash) attention Pallas kernel for TPU.

Design points (TPU-adapted, not a CUDA port):

- grid = (B, Hq, nq, nk); the trailing ``nk`` axis is sequential on TPU, so the
  per-(B, H, q-tile) running state (m, l, acc) lives in VMEM scratch and is
  carried across the k-tiles — no atomics, no shared-memory reduction tree.
- GQA is an *index-map* trick: the K/V BlockSpecs map q-head h to kv-head
  ``h // group`` so grouped heads reread the same KV tile from HBM (which the
  compiler keeps in VMEM across adjacent grid steps) instead of materializing
  ``jnp.repeat``'d KV.
- blocks are (BQ, D) x (BK, D) with BQ = BK = 128: the s-tile (128 x 128) and
  p @ v both hit the MXU with f32 accumulation; masks are VPU iota compares.
- causal + sliding-window masking is positional, supporting the decode case
  (Sq < Sk) by right-aligning queries to keys.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, sq: int, sk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                     # (bk, dv)

    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale         # (bq, bk)

    row = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    col = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    offs = sk - sq                                          # decode alignment
    mask = col < sk                                         # K padding
    if causal:
        mask &= col <= (row + offs)
    if window is not None:
        mask &= ((row + offs) - col) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                     # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)     # all-masked tiles
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)

    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_padded(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           sq: int, sk: int, causal: bool,
                           window: Optional[int],
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jnp.ndarray:
    """Padded call: q (B,Hq,SQp,D), k/v (B,Hkv,SKp,D); SQp/SKp tile multiples.

    ``sq``/``sk`` are the unpadded logical lengths used for masking.
    """
    B, Hq, SQp, D = q.shape
    Hkv, SKp = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    assert SQp % bq == 0 and SKp % bk == 0, (SQp, SKp, bq, bk)
    group = Hq // Hkv
    grid = (B, Hq, SQp // bq, SKp // bk)

    scale = 1.0 / (float(D) ** 0.5)
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, sq=sq, sk=sk)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, SQp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
