"""Pure-jnp oracle for tiled attention (causal / GQA / sliding window)."""
from __future__ import annotations
# repro: allow-file(RPR003: dense f32 oracle — operands are cast to f32 before every contraction)

from typing import Optional

import jax.numpy as jnp


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True,
              window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.

    window = w keeps keys with 0 <= row - col < w (plus causality).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)

    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    row = jnp.arange(Sq)[:, None]
    col = jnp.arange(Sk)[None, :]
    # decode-style alignment: query i attends to keys [0, Sk - Sq + i]
    offs = Sk - Sq
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= col <= (row + offs)
    if window is not None:
        mask &= ((row + offs) - col) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)
