"""Public jit'd wrapper: padding + block-size policy for flash attention.

Backend selection (interpret mode on CPU containers, compiled on real TPU)
is resolved at *call* time in the un-jitted wrapper and threaded into the
jit cache as a static argument — the same idiom as
``repro.kernels.pairwise.ops`` — so flipping the backend after import can
never run a stale interpret decision.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


def _interpret_mode() -> bool:
    """CPU containers interpret the TPU kernel; real TPU compiles it.

    A function (not a module constant) on purpose: the backend may be chosen
    after this module is imported, so the decision must be re-read per call.
    """
    return jax.default_backend() != "tpu"


def _pad_seq(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    s = x.shape[2]
    pad = (-s) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "block_q", "block_k", "interpret"))
def _flash_attention_jit(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         causal: bool, window: Optional[int],
                         use_pallas: bool, block_q: int, block_k: int,
                         interpret: bool) -> jnp.ndarray:
    if not use_pallas:
        return _ref.attention(q, k, v, causal=causal, window=window)
    sq, sk = q.shape[2], k.shape[2]
    bq = min(block_q, max(16, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(16, 1 << (sk - 1).bit_length()))
    qp = _pad_seq(q, bq)
    kp = _pad_seq(k, bk)
    vp = _pad_seq(v, bk)
    out = _k.flash_attention_padded(
        qp, kp, vp, sq=sq, sk=sk, causal=causal, window=window,
        bq=bq, bk=bk, interpret=interpret)
    return out[:, :, :sq, :]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    use_pallas: bool = True,
                    block_q: int = _k.DEFAULT_BQ,
                    block_k: int = _k.DEFAULT_BK,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Attention over q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D) with GQA broadcast.

    Decode (Sq < Sk) right-aligns queries to keys; ``window`` is a sliding
    window measured in key positions behind the query.
    """
    if interpret is None:
        interpret = _interpret_mode()
    return _flash_attention_jit(q, k, v, causal, window, use_pallas,
                                block_q, block_k, interpret)
