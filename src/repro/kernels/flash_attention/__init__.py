from repro.kernels.flash_attention import kernel, ops, ref  # noqa: F401
