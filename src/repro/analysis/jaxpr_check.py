"""Jaxpr abstract-interpretation checks: the dynamic contracts, statically.

``jax.make_jaxpr`` runs every public entry point *abstractly* — no kernel
entry is ever evaluated — while a :class:`CountingOperator` wrapped around
the smoke operator bumps its Python-side meters at trace time.  That one
trace yields three static verdicts:

RPRJ01 *densify detector* — walk the closed jaxpr (recursing into pjit /
    scan / cond / pallas_call sub-jaxprs) and fail if any intermediate
    value is Θ(n²) for the operator's n.  The streaming claim of
    arXiv:1503.08395 holds iff no trace ever materializes the kernel.
RPRJ02 *sweep-budget verifier* — the trace-time counters must equal each
    ``SelectionPolicy.sweep_budget()`` declaration and the documented
    pipeline contracts (``fast_model`` = 1 + budget, ``fast_cur`` =
    1 + 2·budget, ``serve_kernel_model`` = one cross launch per bucket).
    Registered policies are discovered from the registry, so a new policy
    is checked the moment it registers.
RPRJ03 *accumulation-precision scan* — under the ``bf16_f32acc`` policy
    every ``dot_general`` with a low-precision operand must emit an f32
    result (i.e. carry ``preferred_element_type=f32``); scanned for every
    registered kernel spec.

Entry points traced: ``fast_model`` (every registered policy),
``fast_model_with_error``, ``fast_cur`` (every registered policy), each
policy's ``select`` (plus a GROWING-operator variant for every policy with
a nonzero sweep budget — the incremental-append invariant), and
``serve_kernel_model`` over a small built artifact.  The incremental
``append_rows`` absorb is checked concretely (its refresh algebra is
host-side f64 numpy by design): one ``append_sweeps`` tick, exactly b·c
entries, zero panel/full/cross launches.  Smoke shapes are tiny — tracing
costs seconds, not sweeps.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.core import cur as cur_lib
from repro.core import selection as selection_lib
from repro.core import spsd
from repro.core.instrument import CountingOperator
from repro.core.kernelop import PairwiseKernel
from repro.kernels.pairwise import specs as pw_specs

# smoke shape: big enough that Θ(n²) separates from Θ(n·c), Θ(128·n)
# padded-sketch slabs, and the launch template's constant (128 × 128) VMEM
# tiles — n²/2 must exceed all three — yet small enough that tracing is
# instant.  n=512 puts the threshold at 131072 elements vs 65536 for the
# largest legitimate slab (a right-hand side padded to 128 lanes).
SMOKE_N = 512
SMOKE_D = 4
SMOKE_C = 12
SMOKE_S = 24
SMOKE_BLOCK = 64          # keeps legitimate row panels (64 × n) thin
DENSIFY_FRACTION = 0.5    # an aval ≥ n²/2 elements counts as densified

_LOW_PRECISION = (jnp.bfloat16, jnp.float16)


def _smoke_points(n: int = SMOKE_N, d: int = SMOKE_D, seed: int = 0,
                  lattice: bool = False) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    if lattice:  # small per-feature cardinality -> sign-split MXU route
        X = rng.integers(0, 5, size=(n, d)).astype(np.float32)
    else:
        X = rng.standard_normal((n, d)).astype(np.float32)
    return jnp.asarray(X)


def smoke_operator(spec_name: str = "rbf", precision: str = "f32",
                   n: int = SMOKE_N, d: int = SMOKE_D,
                   use_pallas: bool = True) -> CountingOperator:
    """A counting-wrapped PairwiseKernel at the smoke shape."""
    lattice = spec_name == "laplacian"
    X = _smoke_points(n=n, d=d, lattice=lattice)
    params = pw_specs.suggested_params(spec_name, d)
    spec = pw_specs.get_spec(spec_name, **params).with_precision(precision)
    return CountingOperator(PairwiseKernel(X, spec, use_pallas))


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _subjaxprs(params: dict):
    """Yield every Jaxpr hiding in an eqn's params (pjit/scan/cond/pallas)."""
    def visit(val):
        if hasattr(val, "jaxpr") and hasattr(val.jaxpr, "eqns"):
            yield val.jaxpr             # ClosedJaxpr
        elif hasattr(val, "eqns"):
            yield val                   # bare Jaxpr
        elif isinstance(val, (tuple, list)):
            for item in val:
                yield from visit(item)
        elif isinstance(val, dict):
            for item in val.values():
                yield from visit(item)
    for val in params.values():
        yield from visit(val)


def iter_eqns(closed):
    """Every eqn in a (closed) jaxpr, recursing into sub-jaxprs."""
    jaxpr = getattr(closed, "jaxpr", closed)
    stack = [jaxpr]
    seen = set()
    while stack:
        jx = stack.pop()
        if id(jx) in seen:
            continue
        seen.add(id(jx))
        for eqn in jx.eqns:
            yield eqn
            stack.extend(_subjaxprs(eqn.params))


def _aval_of(var):
    return getattr(var, "aval", None)


def scan_densify(closed, n: int, entry: str) -> List[Finding]:
    """RPRJ01: any intermediate with ≥ DENSIFY_FRACTION·n² elements."""
    threshold = max(1, int(n * n * DENSIFY_FRACTION))
    findings: List[Finding] = []
    reported = set()
    for eqn in iter_eqns(closed):
        for var in list(eqn.outvars) + list(eqn.invars):
            aval = _aval_of(var)
            shape = getattr(aval, "shape", None)
            if not shape:
                continue
            size = int(np.prod([int(s) for s in shape]))
            if size < threshold:
                continue
            sig = (eqn.primitive.name, tuple(int(s) for s in shape))
            if sig in reported:
                continue
            reported.add(sig)
            findings.append(Finding(
                path=f"jaxpr:{entry}", line=0, rule="RPRJ01",
                message=(f"Θ(n²) intermediate {tuple(shape)} "
                         f"({size} elems ≥ {threshold}) at primitive "
                         f"'{eqn.primitive.name}' — a streaming entry point "
                         f"materialized the operator (n={n})"),
                snippet=f"{eqn.primitive.name}{tuple(shape)}"))
    return findings


def scan_contractions(closed, entry: str) -> List[Finding]:
    """RPRJ03: dot_general with a low-precision operand must emit f32."""
    findings: List[Finding] = []
    reported = set()
    for eqn in iter_eqns(closed):
        if eqn.primitive.name != "dot_general":
            continue
        in_dts = [getattr(_aval_of(v), "dtype", None) for v in eqn.invars]
        out_dts = [getattr(_aval_of(v), "dtype", None) for v in eqn.outvars]
        if not any(dt in _LOW_PRECISION for dt in in_dts):
            continue
        if all(dt == jnp.float32 for dt in out_dts if dt is not None):
            continue
        sig = (tuple(str(d) for d in in_dts), tuple(str(d) for d in out_dts))
        if sig in reported:
            continue
        reported.add(sig)
        findings.append(Finding(
            path=f"jaxpr:{entry}", line=0, rule="RPRJ03",
            message=(f"dot_general accumulates {in_dts} -> {out_dts} under "
                     "a low-precision tile policy — pass "
                     "preferred_element_type=jnp.float32 (specs.dot_f32acc)"),
            snippet=f"dot_general{sig}"))
    return findings


def _check_counts(entry: str, counts: Dict[str, int],
                  expected: Dict[str, int]) -> List[Finding]:
    """RPRJ02: trace-time meters vs declared budgets."""
    findings = []
    for key, want in expected.items():
        got = counts.get(key, 0)
        if got != want:
            findings.append(Finding(
                path=f"jaxpr:{entry}", line=0, rule="RPRJ02",
                message=(f"declared budget says {key}={want} but the "
                         f"abstract trace metered {key}={got} — the "
                         "declaration and the implementation disagree"),
                snippet=f"{entry}:{key}={got}!={want}"))
    return findings


def _trace(entry: str, fn: Callable, *args) -> Tuple[Optional[object],
                                                     List[Finding]]:
    """make_jaxpr(fn)(*args); a raised exception is itself a finding."""
    try:
        return jax.make_jaxpr(fn)(*args), []
    except Exception as exc:  # noqa: BLE001 — any trace failure is a gate failure
        return None, [Finding(
            path=f"jaxpr:{entry}", line=0, rule="RPRJ02",
            message=f"entry point failed to trace abstractly: {exc!r}",
            snippet=f"{entry}:trace-error")]


def _entry_report(entry: str, counts: Dict[str, int],
                  expected: Dict[str, int],
                  findings: Sequence[Finding]) -> dict:
    return {"entry": entry, "counts": dict(counts),
            "expected": dict(expected),
            "ok": not findings}


# ---------------------------------------------------------------------------
# entry-point checks (each returns (findings, report))
# ---------------------------------------------------------------------------

def check_policy_select(policy_name: str,
                        op: Optional[CountingOperator] = None,
                        ) -> Tuple[List[Finding], dict]:
    """policy.select == sweep_budget() sweeps, gathers as declared, 0 fulls."""
    pol = selection_lib.get_policy(policy_name)
    opc = op if op is not None else smoke_operator()
    opc.reset()
    entry = f"select[{policy_name}]"
    closed, findings = _trace(
        entry,
        lambda key: pol.select(opc, key, SMOKE_C, block_size=SMOKE_BLOCK),
        jax.random.PRNGKey(0))
    expected = {"sweeps": pol.sweep_budget(), "columns": pol.gathers,
                "fulls": 0}
    if closed is not None:
        findings += _check_counts(entry, opc.counts, expected)
        findings += scan_densify(closed, opc.n, entry)
        findings += scan_contractions(closed, entry)
    return findings, _entry_report(entry, opc.counts, expected, findings)


class _GrowingOperator(CountingOperator):
    """A CountingOperator whose corpus GROWS after every panel sweep — the
    trace-time model of the incremental maintainer rebinding the live
    operator between adaptive selection rounds (appended rows arriving
    while ``select`` runs).  The meters are cumulative across the growth
    (``rebind`` keeps them), so budget declarations stay assertable."""

    def __init__(self, X_full: jnp.ndarray, spec, n0: int, grow: int,
                 use_pallas: bool = True):
        self._X_full = X_full
        self._spec = spec
        self._grow = grow
        self._use_pallas = use_pallas
        self._live_n = n0
        super().__init__(PairwiseKernel(X_full[:n0], spec, use_pallas))

    def sweep(self, plans, block_size=None, mesh=None):
        out = super().sweep(plans, block_size=block_size, mesh=mesh)
        nxt = min(self._live_n + self._grow, int(self._X_full.shape[0]))
        if nxt != self._live_n:
            self._live_n = nxt
            self.rebind(PairwiseKernel(self._X_full[:nxt], self._spec,
                                       self._use_pallas))
        return out


def check_policy_select_grown(policy_name: str,
                              grow: int = SMOKE_BLOCK,
                              ) -> Tuple[List[Finding], dict]:
    """Adaptive selection over a GROWING operator: budgets still exact.

    The incremental append-row path can grow an operator's n between a
    policy's adaptive rounds; a policy that sizes per-round masks from an
    n captured at entry either hides the appended rows from the draw or
    fails to broadcast against the grown round's statistics (the latter
    surfaces here as a trace failure → RPRJ02).  The declared sweep/gather
    budget must hold unchanged — growth adds rows, never kernel passes.
    """
    pol = selection_lib.get_policy(policy_name)
    params = pw_specs.suggested_params("rbf", SMOKE_D)
    spec = pw_specs.get_spec("rbf", **params)
    X_full = _smoke_points(n=SMOKE_N + pol.sweep_budget() * grow, d=SMOKE_D)
    opc = _GrowingOperator(X_full, spec, n0=SMOKE_N, grow=grow)
    entry = f"select_grown[{policy_name}]"
    closed, findings = _trace(
        entry,
        lambda key: pol.select(opc, key, SMOKE_C, block_size=SMOKE_BLOCK),
        jax.random.PRNGKey(0))
    expected = {"sweeps": pol.sweep_budget(), "columns": pol.gathers,
                "fulls": 0}
    if closed is not None:
        findings += _check_counts(entry, opc.counts, expected)
        if pol.sweep_budget() > 0 and opc._live_n <= SMOKE_N:
            findings.append(Finding(
                path=f"jaxpr:{entry}", line=0, rule="RPRJ02",
                message=("growth harness did not grow the operator — the "
                         "grown-selection invariant was checked vacuously"),
                snippet=f"{entry}:no-growth"))
        findings += scan_densify(closed, opc._live_n, entry)
        findings += scan_contractions(closed, entry)
    return findings, _entry_report(entry, opc.counts, expected, findings)


def check_append(batch_rows: int = 16) -> Tuple[List[Finding], dict]:
    """Incremental absorb: ONE thin metered launch of exactly b·c entries.

    Runs CONCRETELY, not under ``make_jaxpr`` — the refresh algebra is
    host-side f64 numpy by design (it mirrors ``build_artifact``'s
    accuracy contract), so the abstract tracer would reject it.  The
    RPRJ02 budget verdict is the same: the ``CountingOperator`` meters are
    bumped identically either way, and O(b·c) is asserted via the exact
    ``entries`` count (zero panel sweeps, zero fulls, zero query crosses).
    """
    from repro.serve.artifact import build_artifact
    from repro.serve.incremental import append_rows, init_state

    n, d, c, s = SMOKE_N, 6, 12, 24
    X = _smoke_points(n=n, d=d, seed=7)
    y = jnp.asarray(np.random.default_rng(8).standard_normal(n), jnp.float32)
    spec = pw_specs.get_spec("rbf", sigma=1.5)
    entry = "append_rows"
    expected = {"append_sweeps": 1, "sweeps": 0, "fulls": 0,
                "cross_sweeps": 0, "columns": 0,
                "entries": batch_rows * c}
    opc = None
    try:
        artifact = build_artifact(X, y, spec, c, s,
                                  key=jax.random.PRNGKey(0),
                                  use_pallas=False)
        state = init_state(artifact, y)
        opc = CountingOperator(artifact.landmark_operator())
        rng = np.random.default_rng(9)
        X_new = jnp.asarray(rng.standard_normal((batch_rows, d)), jnp.float32)
        y_new = jnp.asarray(rng.standard_normal(batch_rows), jnp.float32)
        append_rows(artifact, state, X_new, y_new, op=opc)
        findings = _check_counts(entry, opc.counts, expected)
    except Exception as exc:  # noqa: BLE001 — any failure is a gate failure
        findings = [Finding(
            path=f"jaxpr:{entry}", line=0, rule="RPRJ02",
            message=f"append path failed to run: {exc!r}",
            snippet=f"{entry}:run-error")]
    counts = opc.counts if opc is not None else {}
    return findings, _entry_report(entry, counts, expected, findings)


def check_fast_model(policy_name: str = "uniform",
                     precision: str = "f32") -> Tuple[List[Finding], dict]:
    """fast_model(gaussian, streaming) == 1 sweep + the policy's budget."""
    pol = selection_lib.get_policy(policy_name)
    opc = smoke_operator(precision=precision)
    entry = f"fast_model[{policy_name}"
    entry += f",{precision}]" if precision != "f32" else "]"
    closed, findings = _trace(
        entry,
        lambda key: spsd.fast_model(
            opc, key, c=SMOKE_C, s=SMOKE_S, s_sketch="gaussian",
            streaming=True, block_size=SMOKE_BLOCK, selection=policy_name),
        jax.random.PRNGKey(0))
    expected = {"sweeps": 1 + pol.sweep_budget(), "fulls": 0}
    if closed is not None:
        findings += _check_counts(entry, opc.counts, expected)
        findings += scan_densify(closed, opc.n, entry)
        findings += scan_contractions(closed, entry)
    return findings, _entry_report(entry, opc.counts, expected, findings)


def check_fast_model_with_error(policy_name: str = "uniform",
                                ) -> Tuple[List[Finding], dict]:
    """Model + Hutchinson error fused: STILL 1 sweep + the policy budget."""
    pol = selection_lib.get_policy(policy_name)
    opc = smoke_operator()
    entry = f"fast_model_with_error[{policy_name}]"
    closed, findings = _trace(
        entry,
        lambda key: spsd.fast_model_with_error(
            opc, key, c=SMOKE_C, s=SMOKE_S, s_sketch="gaussian", probes=8,
            block_size=SMOKE_BLOCK, selection=policy_name),
        jax.random.PRNGKey(0))
    expected = {"sweeps": 1 + pol.sweep_budget(), "fulls": 0}
    if closed is not None:
        findings += _check_counts(entry, opc.counts, expected)
        findings += scan_densify(closed, opc.n, entry)
        findings += scan_contractions(closed, entry)
    return findings, _entry_report(entry, opc.counts, expected, findings)


def check_fast_cur(policy_name: str = "uniform",
                   ) -> Tuple[List[Finding], dict]:
    """Streaming kernel-CUR: 1 sweep + 2× the policy budget (C and R)."""
    pol = selection_lib.get_policy(policy_name)
    opc = smoke_operator()
    entry = f"fast_cur[{policy_name}]"
    closed, findings = _trace(
        entry,
        lambda key: cur_lib.fast_cur(
            opc, key, c=SMOKE_C, r=SMOKE_C, sc=SMOKE_S, sr=SMOKE_S,
            sketch_kind="gaussian", block_size=SMOKE_BLOCK,
            selection=policy_name),
        jax.random.PRNGKey(3))
    expected = {"sweeps": 1 + 2 * pol.sweep_budget(), "fulls": 0}
    if closed is not None:
        findings += _check_counts(entry, opc.counts, expected)
        findings += scan_densify(closed, opc.n, entry)
        findings += scan_contractions(closed, entry)
    return findings, _entry_report(entry, opc.counts, expected, findings)


def check_serve(precision: str = "f32") -> Tuple[List[Finding], dict]:
    """serve_kernel_model: one fused cross launch per query bucket, 0 sweeps.

    Builds a tiny real artifact (concrete, off-trace), then traces the
    serving path over abstract query batches whose sizes force two buckets.
    """
    from repro.serve.artifact import build_artifact
    from repro.serve.engine import QueryRequest, plan_buckets, \
        serve_kernel_model

    n, d, c, s = SMOKE_N, 6, 12, 24
    X = _smoke_points(n=n, d=d, seed=7)
    y = jnp.asarray(np.random.default_rng(8).standard_normal(n),
                    jnp.float32)
    spec = pw_specs.get_spec("rbf", sigma=1.5)
    artifact = build_artifact(X, y, spec, c, s, key=jax.random.PRNGKey(0),
                              use_pallas=False)
    opc = CountingOperator(
        artifact.landmark_operator(use_pallas=True, precision=precision))
    sizes = (40, 5, 4)   # bucket_by_size -> [[40], [5, 4]]: two launches
    reqs = [QueryRequest(X=jnp.zeros((m, d))) for m in sizes]
    nbuckets = len(plan_buckets(reqs))
    entry = "serve_kernel_model"
    entry += f"[{precision}]" if precision != "f32" else ""

    def run(*qs):
        res = serve_kernel_model(
            artifact, [QueryRequest(X=q) for q in qs], op=opc)
        return tuple(r.out for r in res)

    closed, findings = _trace(
        entry, run, *[jnp.zeros((m, d), jnp.float32) for m in sizes])
    expected = {"cross_sweeps": nbuckets, "sweeps": 0, "fulls": 0}
    if closed is not None:
        findings += _check_counts(entry, opc.counts, expected)
        findings += scan_densify(closed, n, entry)
        findings += scan_contractions(closed, entry)
    return findings, _entry_report(entry, opc.counts, expected, findings)


def check_kernel_precision(spec_name: str) -> Tuple[List[Finding], dict]:
    """One bf16_f32acc sweep per registered kernel: every dot accumulates f32."""
    opc = smoke_operator(spec_name=spec_name, precision="bf16_f32acc")
    entry = f"sweep[{spec_name},bf16_f32acc]"
    from repro.core import sweep as sweep_lib
    closed, findings = _trace(
        entry,
        lambda V: opc.sweep([sweep_lib.MatmulPlan(V)],
                            block_size=SMOKE_BLOCK),
        jnp.zeros((opc.n, 8), jnp.float32))
    expected = {"sweeps": 1, "fulls": 0}
    if closed is not None:
        findings += _check_counts(entry, opc.counts, expected)
        findings += scan_densify(closed, opc.n, entry)
        findings += scan_contractions(closed, entry)
    return findings, _entry_report(entry, opc.counts, expected, findings)


def run_jaxpr_checks(log: Optional[Callable[[str], None]] = None,
                     ) -> Tuple[List[Finding], List[dict]]:
    """Every entry-point check over the live registries."""
    def note(msg):
        if log:
            log(msg)

    findings: List[Finding] = []
    reports: List[dict] = []

    policies = selection_lib.registered_policies()
    for name in policies:
        for check in (check_policy_select, check_fast_model, check_fast_cur):
            note(f"trace {check.__name__}[{name}]")
            fs, rep = check(name)
            findings += fs
            reports.append(rep)
    for name in policies:
        if selection_lib.get_policy(name).sweep_budget() > 0:
            note(f"trace select_grown[{name}]")
            fs, rep = check_policy_select_grown(name)
            findings += fs
            reports.append(rep)

    note("run append_rows (concrete)")
    fs, rep = check_append()
    findings += fs
    reports.append(rep)

    note("trace fast_model_with_error[uniform]")
    fs, rep = check_fast_model_with_error("uniform")
    findings += fs
    reports.append(rep)

    note("trace fast_model[uniform,bf16_f32acc]")
    fs, rep = check_fast_model("uniform", precision="bf16_f32acc")
    findings += fs
    reports.append(rep)

    for prec in ("f32", "bf16_f32acc"):
        note(f"trace serve_kernel_model[{prec}]")
        fs, rep = check_serve(precision=prec)
        findings += fs
        reports.append(rep)

    for spec_name in pw_specs.registered_kernels():
        note(f"trace sweep[{spec_name},bf16_f32acc]")
        fs, rep = check_kernel_precision(spec_name)
        findings += fs
        reports.append(rep)

    return findings, reports
