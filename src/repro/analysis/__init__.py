"""Static invariant checker: AST lint rules + jaxpr abstract interpretation.

Two passes over the repo's load-bearing guarantees (run both with
``python -m repro.analysis``):

- :mod:`repro.analysis.lint` — AST rules RPR001–RPR005 (no-densify,
  import-time backend capture, unaccumulated contractions, hard-coded
  dtypes, module-state randomness) with in-source
  ``# repro: allow-*(<reason>)`` waivers.
- :mod:`repro.analysis.jaxpr_check` — abstract traces of every public
  entry point: the Θ(n²) densify detector (RPRJ01), sweep-budget
  verification against each registered ``SelectionPolicy`` (RPRJ02), and
  the bf16_f32acc accumulation scan (RPRJ03).
"""
from repro.analysis.findings import Finding, compare_to_baseline, \
    load_baseline, write_baseline
from repro.analysis.lint import LintRule, get_rule, lint_file, lint_paths, \
    lint_source, register_rule, registered_rules
from repro.analysis.jaxpr_check import run_jaxpr_checks

__all__ = [
    "Finding", "compare_to_baseline", "load_baseline", "write_baseline",
    "LintRule", "get_rule", "lint_file", "lint_paths", "lint_source",
    "register_rule", "registered_rules", "run_jaxpr_checks",
]
