"""CLI for the static analysis gate.

    python -m repro.analysis                       # lint + jaxpr, exit 1 on findings
    python -m repro.analysis --baseline analysis_baseline.json
    python -m repro.analysis --json results/ANALYSIS_report.json
    python -m repro.analysis --no-jaxpr            # AST pass only (fast)
    python -m repro.analysis --write-baseline      # grandfather current findings

Exit codes: 0 clean (or fully baselined), 1 new findings, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import findings as findings_lib
from repro.analysis import lint as lint_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--paths", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON of grandfathered findings")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the full findings report to this path")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the jaxpr abstract-interpretation pass")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline file from current findings")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    def note(msg):
        if not args.quiet:
            print(f"[analysis] {msg}", file=sys.stderr)

    note(f"lint pass over {args.paths}")
    all_findings = lint_lib.lint_paths(args.paths)

    entry_reports = []
    if not args.no_jaxpr:
        # import deferred: the lint pass must work even where jax tracing
        # is unavailable/slow
        from repro.analysis.jaxpr_check import run_jaxpr_checks
        jf, entry_reports = run_jaxpr_checks(log=note)
        all_findings += jf

    if args.write_baseline:
        path = args.baseline or findings_lib.BASELINE_DEFAULT
        findings_lib.write_baseline(path, all_findings)
        note(f"wrote {len(all_findings)} finding(s) to {path}")
        return 0

    baseline = {}
    if args.baseline:
        try:
            baseline = findings_lib.load_baseline(args.baseline)
        except FileNotFoundError:
            print(f"error: baseline {args.baseline!r} not found",
                  file=sys.stderr)
            return 2
    new, stale = findings_lib.compare_to_baseline(all_findings, baseline)

    if args.json_out:
        report = findings_lib.report_dict(all_findings, new, stale,
                                          entry_reports)
        os.makedirs(os.path.dirname(args.json_out) or ".", exist_ok=True)
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        note(f"report written to {args.json_out}")

    baselined = len(all_findings) - len(new)
    for f in sorted(new):
        print(f.format())
    if stale:
        note(f"{len(stale)} baseline entr(ies) no longer fire — shrink the "
             "baseline with --write-baseline")
    note(f"{len(all_findings)} finding(s): {len(new)} new, "
         f"{baselined} baselined; {len(entry_reports)} entry traces")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
