"""AST lint engine: pluggable rules + ``# repro: allow-*`` annotations.

Rules register through :func:`register_rule`, the same decorator-registry
shape as ``KernelSpec`` / ``SelectionPolicy`` — adding a rule module under
``repro.analysis.rules`` and decorating a class is the whole integration.

Intentional violations are waived in-source, never in config, so the reason
lives next to the code it excuses:

    StKS = S.sym(Kop.full())  # repro: allow-dense(dense oracle, small c)

An annotation covers its own line, the line directly above the flagged
statement, or any line the flagged expression spans.  File-level waivers —
for modules whose whole point is a dense oracle — name the rule:

    # repro: allow-file(RPR003: f64 reference oracles, MXU policy n/a)

Empty reasons are themselves findings (RPR000): a waiver with no rationale
is debt, not documentation.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow-([a-z0-9-]+)\s*\((.*)\)\s*$")
_FILE_ALLOW_RE = re.compile(r"^\s*(RPR[A-Z0-9]+)\s*:\s*(.*)$")


class Annotations:
    """Parsed ``# repro: allow-*`` waivers for one source file."""

    def __init__(self, line_kinds: Dict[int, Set[str]],
                 file_rules: Set[str], empty: List[int]):
        self.line_kinds = line_kinds    # line -> {"dense", "dtype", ...}
        self.file_rules = file_rules    # {"RPR003", ...}
        self.empty_reason_lines = empty

    def allows(self, kind: str, start: int, end: Optional[int]) -> bool:
        lines = range(start - 1, (end or start) + 1)
        return any(kind in self.line_kinds.get(ln, ()) for ln in lines)


def parse_annotations(source: str) -> Annotations:
    line_kinds: Dict[int, Set[str]] = {}
    file_rules: Set[str] = set()
    empty: List[int] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        tokens = []
    comments = [(t.start[0], t.string) for t in tokens
                if t.type == tokenize.COMMENT]
    if not tokens:  # fall back to a line scan if tokenization failed
        comments = [(i + 1, line[line.index("#"):])
                    for i, line in enumerate(source.splitlines())
                    if "#" in line]
    for lineno, text in comments:
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        kind, reason = m.group(1), m.group(2).strip()
        if kind == "file":
            fm = _FILE_ALLOW_RE.match(reason)
            if fm and fm.group(2).strip():
                file_rules.add(fm.group(1))
            else:
                empty.append(lineno)
        elif not reason:
            empty.append(lineno)
        else:
            line_kinds.setdefault(lineno, set()).add(kind)
    return Annotations(line_kinds, file_rules, empty)


class LintContext:
    """Everything a rule needs to inspect one file."""

    def __init__(self, path: str, source: str, tree: ast.AST,
                 annotations: Annotations):
        self.path = path  # repo-relative posix path
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.annotations = annotations
        # names bound by `import jax` / `from jax import devices` etc. —
        # several rules resolve call targets through these
        self.import_aliases = _collect_imports(tree)

    def snippet(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: "LintRule", node: ast.AST, message: str,
                ) -> Optional[Finding]:
        """Build a Finding unless an allow-annotation waives it."""
        if rule.rule_id in self.annotations.file_rules:
            return None
        lineno = getattr(node, "lineno", 0)
        end = getattr(node, "end_lineno", None)
        if rule.allow_kind and self.annotations.allows(
                rule.allow_kind, lineno, end):
            return None
        return Finding(path=self.path, line=lineno, rule=rule.rule_id,
                       message=message, snippet=self.snippet(lineno))


def _collect_imports(tree: ast.AST) -> Dict[str, str]:
    """Local name -> dotted origin ('jnp' -> 'jax.numpy', 'devices' ->
    'jax.devices') for every import in the module."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.random.PRNGKey' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolved_name(ctx: LintContext, node: ast.AST) -> Optional[str]:
    """Like :func:`dotted_name` but with the module's import aliases applied
    to the root ('jr.PRNGKey' -> 'jax.random.PRNGKey')."""
    name = dotted_name(node)
    if name is None:
        return None
    root, _, rest = name.partition(".")
    origin = ctx.import_aliases.get(root)
    if origin is None:
        return name
    return f"{origin}.{rest}" if rest else origin


def module_scope_nodes(tree: ast.AST) -> Iterable[ast.AST]:
    """Yield nodes whose code runs at import time.

    Descends through class bodies and conditionals but not into function /
    lambda bodies — those are deferred.  Decorators and argument defaults DO
    run at import, so they are yielded.
    """
    def walk(node: ast.AST):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for part in (child.decorator_list, child.args.defaults,
                             child.args.kw_defaults):
                    for sub in part:
                        if sub is not None:
                            yield sub
                            yield from walk(sub)
                continue
            if isinstance(child, ast.Lambda):
                continue
            yield child
            yield from walk(child)
    yield from walk(tree)


# ---------------------------------------------------------------------------
# rule registry (register_rule decorator, mirroring register_kernel/policy)
# ---------------------------------------------------------------------------

class LintRule:
    """Base class: subclass, set the class attrs, implement ``check``."""

    rule_id: str = ""
    title: str = ""
    allow_kind: str = ""             # annotation kind that waives this rule
    scope: Tuple[str, ...] = ("src/repro/",)  # path prefixes this rule scans

    def applies_to(self, path: str) -> bool:
        return any(path.startswith(prefix) for prefix in self.scope)

    def check(self, ctx: LintContext) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


_RULES: Dict[str, LintRule] = {}


def register_rule(cls):
    """Class decorator: instantiate and register a LintRule by its id."""
    inst = cls()
    if not inst.rule_id:
        raise ValueError(f"{cls.__name__} must set rule_id")
    _RULES[inst.rule_id] = inst
    return cls


def registered_rules() -> List[LintRule]:
    _ensure_builtin_rules()
    return [_RULES[k] for k in sorted(_RULES)]


def get_rule(rule_id: str) -> LintRule:
    _ensure_builtin_rules()
    return _RULES[rule_id]


def _ensure_builtin_rules() -> None:
    # import for the registration side effect; cheap and idempotent
    from repro.analysis import rules  # noqa: F401


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def lint_source(source: str, path: str,
                rules: Optional[Sequence[LintRule]] = None,
                ignore_scope: bool = False) -> List[Finding]:
    """Lint one file's text under its repo-relative ``path``."""
    active = list(rules) if rules is not None else registered_rules()
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(path=path, line=exc.lineno or 0, rule="RPR000",
                        message=f"syntax error: {exc.msg}")]
    ann = parse_annotations(source)
    ctx = LintContext(path, source, tree, ann)
    findings: List[Finding] = [
        Finding(path=path, line=ln, rule="RPR000",
                message="allow-annotation without a reason — waivers must "
                        "say why", snippet=ctx.snippet(ln))
        for ln in ann.empty_reason_lines]
    for rule in active:
        if ignore_scope or rule.applies_to(path):
            findings.extend(rule.check(ctx))
    return findings


def lint_file(file_path: str, repo_root: Optional[str] = None,
              rules: Optional[Sequence[LintRule]] = None,
              ignore_scope: bool = False) -> List[Finding]:
    p = Path(file_path)
    rel = p.resolve()
    root = Path(repo_root).resolve() if repo_root else Path.cwd()
    try:
        rel_path = rel.relative_to(root).as_posix()
    except ValueError:
        rel_path = p.as_posix()
    source = p.read_text(encoding="utf-8")
    return lint_source(source, rel_path, rules=rules,
                       ignore_scope=ignore_scope)


def lint_paths(paths: Sequence[str], repo_root: Optional[str] = None,
               rules: Optional[Sequence[LintRule]] = None) -> List[Finding]:
    """Lint every ``.py`` file under ``paths`` (files or directories)."""
    findings: List[Finding] = []
    for entry in paths:
        p = Path(entry)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(str(f), repo_root=repo_root,
                                      rules=rules))
    return findings
