"""Finding records, baselines, and reports for the static analysis gate.

A :class:`Finding` is one violation of a repo invariant, located at a
``path:line`` (AST lint) or at a traced entry point (jaxpr checks, which have
no single source line — they use a ``jaxpr:<entry>`` pseudo-path and line 0).

Baselines grandfather known findings so the gate can land before the tree is
perfectly clean: a baseline maps finding *fingerprints* to occurrence counts,
and the gate fails only on findings beyond those counts.  Fingerprints hash
the offending source text rather than the line number, so unrelated edits
that shift lines don't churn the baseline — but the baselined debt can only
shrink, never grow.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

BASELINE_DEFAULT = "analysis_baseline.json"


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str          # repo-relative posix path, or "jaxpr:<entry>" pseudo-path
    line: int          # 1-based source line; 0 for jaxpr findings
    rule: str          # "RPR001".."RPR005" (lint) / "RPRJ01".."RPRJ03" (jaxpr)
    message: str
    snippet: str = ""  # stripped offending source line (fingerprint component)

    def fingerprint(self) -> str:
        """Stable identity for baselining: rule + file + offending text.

        The line number is deliberately excluded so edits elsewhere in the
        file don't invalidate the baseline; the snippet hash keeps two
        distinct violations in one file distinct.
        """
        text = self.snippet or self.message
        digest = hashlib.sha256(text.encode("utf-8")).hexdigest()[:16]
        return f"{self.rule}|{self.path}|{digest}"

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d


def _count_fingerprints(findings: Iterable[Finding]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for f in findings:
        fp = f.fingerprint()
        counts[fp] = counts.get(fp, 0) + 1
    return counts


def load_baseline(path: str) -> Dict[str, int]:
    """Read a baseline file -> {fingerprint: allowed count}.

    Accepts either the full report-style schema ({"findings": {...}}) or a
    bare mapping; missing file is an error (pass no --baseline instead).
    """
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    table = data.get("findings", data) if isinstance(data, dict) else {}
    out: Dict[str, int] = {}
    for key, val in table.items():
        if isinstance(key, str) and key.startswith("_"):
            continue  # "_comment" style keys
        out[key] = int(val)
    return out


def write_baseline(path: str, findings: Sequence[Finding],
                   comment: Optional[str] = None) -> None:
    payload = {
        "_comment": comment or (
            "Grandfathered static-analysis findings; this debt may only "
            "shrink. Regenerate with python -m repro.analysis "
            "--write-baseline after fixing (never to admit new findings)."),
        "findings": dict(sorted(_count_fingerprints(findings).items())),
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, sort_keys=False)
        fh.write("\n")


def compare_to_baseline(
        findings: Sequence[Finding],
        baseline: Dict[str, int]) -> Tuple[List[Finding], List[str]]:
    """-> (new findings beyond the baselined counts, stale baseline entries).

    New findings gate (exit 1); stale entries are advisory — the baseline
    can be regenerated smaller.
    """
    remaining = dict(baseline)
    new: List[Finding] = []
    for f in sorted(findings):
        fp = f.fingerprint()
        if remaining.get(fp, 0) > 0:
            remaining[fp] -= 1
        else:
            new.append(f)
    stale = sorted(fp for fp, cnt in remaining.items() if cnt > 0)
    return new, stale


def report_dict(findings: Sequence[Finding], new: Sequence[Finding],
                stale: Sequence[str], entry_reports: Sequence[dict] = (),
                ) -> dict:
    by_rule: Dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "version": 1,
        "total": len(findings),
        "new": len(new),
        "by_rule": dict(sorted(by_rule.items())),
        "findings": [f.to_dict() for f in sorted(findings)],
        "new_findings": [f.to_dict() for f in sorted(new)],
        "stale_baseline": list(stale),
        "jaxpr_entries": list(entry_reports),
    }
