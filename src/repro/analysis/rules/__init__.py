"""Built-in lint rules. Importing this package registers every rule."""
from repro.analysis.rules import backend, densify, precision, randomness  # noqa: F401
