"""RPR001 — no-densify: ``.full()`` / ``.dense()`` materializations.

The paper's entire claim (arXiv:1503.08395) is linear-time SPSD
approximation; a single unguarded ``op.full()`` turns a streaming path into
an Θ(n²) one.  The operators keep these methods as *oracles* — small-shape
references and booby-trapped escapes — so each call site must say why it is
allowed to densify:

    Kd = Kop.full().astype(jnp.float32)  # repro: allow-dense(f64 oracle, n<=2k)
"""
from __future__ import annotations

import ast

from repro.analysis.lint import (LintContext, LintRule, register_rule,
                                 resolved_name)

# zero-arg attribute calls with these names densify an operator; jnp.full /
# np.full take a shape argument and never match the zero-arg form
_DENSIFY_METHODS = ("full", "dense")


@register_rule
class NoDensifyRule(LintRule):
    rule_id = "RPR001"
    title = "no-densify"
    allow_kind = "dense"
    scope = ("src/repro/",)

    def check(self, ctx: LintContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if not isinstance(func, ast.Attribute):
                continue
            if func.attr not in _DENSIFY_METHODS:
                continue
            if node.args or node.keywords:
                continue  # jnp.full(shape, v) etc. — not an operator oracle
            target = resolved_name(ctx, func.value)
            # numpy/jax namespaces never expose zero-arg full/dense
            if target in ("numpy", "jax.numpy", "np", "jnp"):
                continue
            f = ctx.finding(
                self, node,
                f"'.{func.attr}()' materializes the full operator — "
                "stream via sweep()/block(), or annotate the oracle with "
                "'# repro: allow-dense(<reason>)'")
            if f:
                yield f
