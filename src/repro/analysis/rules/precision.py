"""RPR003 / RPR004 — precision-policy bypasses in the kernel layer.

RPR003 *unaccumulated contraction*: a ``dot_general`` / ``einsum`` / ``@``
under ``kernels/`` without ``preferred_element_type`` accumulates in the
operand dtype — under the ``bf16_f32acc`` policy that silently becomes bf16
accumulation, exactly the error the policy exists to forbid.  Use
``preferred_element_type=jnp.float32`` (or ``specs.dot_f32acc``).

RPR004 *hard-coded dtype literal*: kernel and serve modules must take tile
dtypes from ``spec.precision`` / ``spec.tile_dtype()``; a literal
``jnp.bfloat16`` forks the precision policy at one call site.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import (LintContext, LintRule, register_rule,
                                 resolved_name)

_CONTRACTION_TARGETS = (
    "jax.lax.dot_general",
    "jax.lax.dot",
    "jax.numpy.einsum",
    "jax.numpy.dot",
    "jax.numpy.matmul",
    "jax.numpy.tensordot",
    "jax.numpy.vdot",
    "jax.numpy.inner",
)

_LOW_PRECISION_DTYPES = ("bfloat16", "float16", "float8_e4m3fn",
                         "float8_e5m2", "half")
_DTYPE_NAMESPACES = ("jax.numpy", "numpy", "jax", "ml_dtypes")


@register_rule
class UnaccumulatedContractionRule(LintRule):
    rule_id = "RPR003"
    title = "unaccumulated contraction"
    allow_kind = "contraction"
    scope = ("src/repro/kernels/",)

    def check(self, ctx: LintContext):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and \
                    isinstance(node.op, ast.MatMult):
                f = ctx.finding(
                    self, node,
                    "'@' contraction accumulates in the operand dtype — use "
                    "dot_general(..., preferred_element_type=jnp.float32) "
                    "(specs.dot_f32acc) or annotate with "
                    "'# repro: allow-contraction(<reason>)'")
                if f:
                    yield f
                continue
            if not isinstance(node, ast.Call):
                continue
            target = resolved_name(ctx, node.func)
            if target not in _CONTRACTION_TARGETS:
                continue
            if any(kw.arg == "preferred_element_type"
                   for kw in node.keywords):
                continue
            f = ctx.finding(
                self, node,
                f"'{target}' without preferred_element_type accumulates in "
                "the operand dtype (bf16 under bf16_f32acc) — pass "
                "preferred_element_type=jnp.float32 or annotate with "
                "'# repro: allow-contraction(<reason>)'")
            if f:
                yield f


@register_rule
class HardCodedDtypeRule(LintRule):
    rule_id = "RPR004"
    title = "hard-coded low-precision dtype"
    allow_kind = "dtype"
    scope = ("src/repro/kernels/", "src/repro/serve/")

    def check(self, ctx: LintContext):
        for node in ast.walk(ctx.tree):
            name = None
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.ctx, ast.Load) and \
                    node.attr in _LOW_PRECISION_DTYPES:
                base = resolved_name(ctx, node.value)
                if base in _DTYPE_NAMESPACES:
                    name = f"{base}.{node.attr}"
            elif isinstance(node, ast.Constant) and \
                    isinstance(node.value, str) and \
                    node.value in _LOW_PRECISION_DTYPES:
                name = f"'{node.value}'"
            if name is None:
                continue
            f = ctx.finding(
                self, node,
                f"hard-coded {name} — tile dtypes must route through "
                "spec.precision / spec.tile_dtype() so the precision "
                "policy stays one switch; annotate the policy definition "
                "site with '# repro: allow-dtype(<reason>)'")
            if f:
                yield f
