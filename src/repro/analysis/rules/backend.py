"""RPR002 — import-time backend capture (the PR 3 bug class).

``_INTERPRET = jax.default_backend() != "tpu"`` at module scope freezes the
backend decision at import; flipping platforms afterwards (tests, multi-host
launches, ``jax.config`` updates) silently runs the stale choice.  The fixed
idiom resolves per call and threads the result as a static jit argument
(see ``repro.kernels.pairwise.ops._interpret_mode``).
"""
from __future__ import annotations

import ast

from repro.analysis.lint import (LintContext, LintRule, module_scope_nodes,
                                 register_rule, resolved_name)

# call targets (import-alias resolved) whose result depends on the active
# backend / device topology
_BACKEND_CALLS = (
    "jax.devices",
    "jax.local_devices",
    "jax.device_count",
    "jax.local_device_count",
    "jax.process_count",
    "jax.process_index",
    "jax.default_backend",
    "jax.lib.xla_bridge.get_backend",
    "jax.extend.backend.get_backend",
)


@register_rule
class ImportTimeBackendRule(LintRule):
    rule_id = "RPR002"
    title = "import-time backend capture"
    allow_kind = "backend"
    scope = ("src/repro/",)

    def check(self, ctx: LintContext):
        for node in module_scope_nodes(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = resolved_name(ctx, node.func)
            if target is None:
                continue
            if target in _BACKEND_CALLS or (
                    target.startswith("jax.") and
                    target.endswith((".devices", ".device_count",
                                     ".default_backend"))):
                f = ctx.finding(
                    self, node,
                    f"'{target}()' at module scope captures the backend at "
                    "import time — resolve per call (see "
                    "pairwise.ops._interpret_mode) or annotate with "
                    "'# repro: allow-backend(<reason>)'")
                if f:
                    yield f
