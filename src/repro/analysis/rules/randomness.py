"""RPR005 — module-state randomness.

Two shapes of hidden nondeterminism:

* ``np.random.<fn>`` global-state draws (``rand``, ``normal``, ``seed``…) —
  unreproducible across processes and import orders.  Seeded *generator
  constructors* (``default_rng``, ``Generator``, ``SeedSequence``,
  ``RandomState``) are the sanctioned replacement and are not flagged.
* PRNG keys minted at module scope (``jax.random.PRNGKey(...)`` as a module
  constant) — every caller silently shares one stream.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import (LintContext, LintRule, module_scope_nodes,
                                 register_rule, resolved_name)

_SEEDED_CONSTRUCTORS = ("default_rng", "Generator", "SeedSequence",
                        "RandomState", "Philox", "PCG64")
_KEY_CALLS = ("jax.random.PRNGKey", "jax.random.key")


def _is_global_numpy_random(target: str) -> bool:
    for root in ("numpy.random.", "np.random."):
        if target.startswith(root):
            return target[len(root):] not in _SEEDED_CONSTRUCTORS
    return False


@register_rule
class ModuleStateRandomnessRule(LintRule):
    rule_id = "RPR005"
    title = "module-state randomness"
    allow_kind = "randomness"
    scope = ("src/",)

    def check(self, ctx: LintContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = resolved_name(ctx, node.func)
            if target is None:
                continue
            if _is_global_numpy_random(target):
                f = ctx.finding(
                    self, node,
                    f"'{target}' draws from numpy's global RNG state — use "
                    "a seeded np.random.default_rng(...) generator, or "
                    "annotate with '# repro: allow-randomness(<reason>)'")
                if f:
                    yield f
        for node in module_scope_nodes(ctx.tree):
            if isinstance(node, ast.Call) and \
                    resolved_name(ctx, node.func) in _KEY_CALLS:
                f = ctx.finding(
                    self, node,
                    "PRNG key minted at module scope — every caller shares "
                    "one stream; take keys as arguments (or a documented "
                    "default constant) instead")
                if f:
                    yield f
