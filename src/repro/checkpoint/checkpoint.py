"""Sharded, atomically-committed checkpoints with retention + resharding.

Layout (one directory per step):

    <dir>/step_000000100.tmp/        # written first
        shard_00000_of_00001.npz     # this host's param/opt/data-state leaves
        manifest.json                # treedef paths, shapes, dtypes, host map
    <dir>/step_000000100/            # atomic rename after all shards land

Properties the runtime relies on:

- **Atomic commit**: the rename happens only after every shard + manifest is
  fsync'd, so a preemption mid-write never corrupts the latest checkpoint
  (the .tmp dir is ignored and garbage-collected on restart).
- **Per-host shards**: each host writes only the addressable shards of its
  jax.Arrays (multi-host) or everything (single-host). Restore reads every
  shard and reassembles by leaf path.
- **Resharding restore**: restore() returns host-local numpy trees; the
  launcher re-`device_put`s them under whatever mesh/sharding the *new*
  topology uses — checkpoints are therefore elastic across pod counts.
- **Retention**: keep the last ``keep`` checkpoints plus every multiple of
  ``keep_period`` (the long-horizon safety net).
- **Async commit**: save() can run the serialization on a background thread
  (``blocking=False``) so the train loop overlaps I/O with compute; join()
  waits (and is called before the next save or on preemption).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint directory exists but cannot be read back faithfully.

    Raised for file-level damage — missing/truncated ``manifest.json``,
    missing shard files, or an undecodable npz — as opposed to the
    ``KeyError`` / ``ValueError`` a *healthy* checkpoint raises when it does
    not match the requested ``like`` structure.  The serving path's
    recompute-on-corruption hook (``runtime.fault_tolerance.ArtifactRecovery``)
    catches exactly this type.
    """


def _flat_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((pstr, leaf))
    return out, treedef


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:09d}")


def save(directory: str, step: int, tree: Any,
         process_index: int = 0, process_count: int = 1) -> str:
    """Write one checkpoint synchronously; returns the committed path."""
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves, _ = _flat_with_paths(tree)
    arrays, manifest = {}, {}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i:05d}"
        arrays[key] = arr
        manifest[key] = {"path": path, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}

    shard = os.path.join(
        tmp, f"shard_{process_index:05d}_of_{process_count:05d}.npz")
    with open(shard, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    man = os.path.join(tmp, "manifest.json")
    with open(man, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    # single-host (and host 0 in multi-host after a barrier) commits
    if process_index == 0:
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    return final


def _committed_steps(directory: str):
    """Step numbers whose directories are validly COMMITTED, sorted ascending.

    Only entries that (a) parse as ``step_<int>``, (b) are not a ``.tmp``
    write in flight (or a stale one a crash left behind), (c) are actual
    directories, and (d) contain a ``manifest.json`` count.  (b)–(d) are the
    regression surface: a leftover tmp dir, a stray file named like a step,
    or a partially-deleted dir (a concurrent ``gc_tmp``/``_retain`` race)
    must never be reported as the latest checkpoint and then fail to
    restore.
    """
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        try:
            step = int(name.split("_")[1])
        except (IndexError, ValueError):
            continue
        path = os.path.join(directory, name)
        if not os.path.isdir(path):
            continue
        if not os.path.isfile(os.path.join(path, "manifest.json")):
            continue
        steps.append(step)
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def _read_step_arrays(directory: str, step: int):
    """{leaf path: array} for one committed step, with file-level damage
    (missing dir/manifest/shards, truncated json/npz) classified as
    ``CheckpointCorruptionError`` instead of leaking OSError/JSONDecodeError
    into the serving boot path."""
    path = _step_dir(directory, step)
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {}
        for name in sorted(os.listdir(path)):
            if not name.endswith(".npz"):
                continue
            with np.load(os.path.join(path, name)) as z:
                for key in z.files:
                    by_path[manifest[key]["path"]] = z[key]
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
        raise CheckpointCorruptionError(
            f"checkpoint step {step} at {path} is unreadable "
            f"({type(e).__name__}: {e})") from e
    if not by_path:
        raise CheckpointCorruptionError(
            f"checkpoint step {step} at {path} has no shard files")
    return by_path


def step_leaf_paths(directory: str, step: int) -> list:
    """Sorted leaf paths of a committed step, from the manifest ALONE.

    No array I/O: callers that only need to classify a step's KIND — a full
    artifact snapshot (carries ``meta_json``) vs an incremental delta
    (carries ``delta_json``, see ``repro.serve.incremental``) — peek here
    before deciding how to restore.  A missing/truncated manifest is
    classified as ``CheckpointCorruptionError`` like every other read."""
    path = _step_dir(directory, step)
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return sorted(str(m["path"]) for m in manifest.values())
    except (OSError, ValueError, KeyError, TypeError,
            json.JSONDecodeError) as e:
        raise CheckpointCorruptionError(
            f"checkpoint step {step} at {path} has no readable manifest "
            f"({type(e).__name__}: {e})") from e


def committed_steps(directory: str) -> list:
    """Public view of the junk-hardened committed-step listing (ascending).

    The incremental delta-chain loader and its GC walk this instead of
    re-implementing the tmp/stray-file/torn-dir filtering."""
    return _committed_steps(directory)


def remove_step(directory: str, step: int) -> None:
    """Delete one committed step directory (delta GC / compaction)."""
    shutil.rmtree(_step_dir(directory, step), ignore_errors=True)


def restore(directory: str, step: int, like: Any) -> Any:
    """Load a checkpoint into the structure of ``like`` (shapes must match
    leaf-for-leaf; shardings are applied by the caller — elastic restore)."""
    by_path = _read_step_arrays(directory, step)
    leaves, treedef = _flat_with_paths(like)
    out = []
    for pstr, leaf in leaves:
        if pstr not in by_path:
            raise KeyError(f"checkpoint step {step} at {directory} is "
                           f"missing leaf {pstr!r}")
        arr = by_path[pstr]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {pstr!r}: checkpoint shape {arr.shape} != {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_tree(directory: str, step: int) -> dict:
    """Load a checkpoint as a nested dict WITHOUT a ``like`` skeleton.

    The manifest already records every leaf's path/shape/dtype, so a fresh
    process that knows nothing about the stored shapes (a serving replica
    warm-booting a ``KernelModelArtifact`` whose c/d/head sizes were chosen
    at build time) can reconstruct the tree directly.  Leaf paths are split
    on ``/`` into nested string-keyed dicts — i.e. the tree must have been a
    JSON-style dict-of-dicts at save time (the artifact format is).
    """
    by_path = _read_step_arrays(directory, step)
    out: dict = {}
    for pstr, arr in by_path.items():
        node = out
        keys = pstr.split("/")
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = arr
    return out


def gc_tmp(directory: str) -> int:
    """Remove orphaned .tmp dirs (crash mid-write); returns count removed."""
    if not os.path.isdir(directory):
        return 0
    n = 0
    for name in os.listdir(directory):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
            n += 1
    return n


class CheckpointManager:
    """save/restore + retention + async commit."""

    def __init__(self, directory: str, keep: int = 3, keep_period: int = 0,
                 process_index: int = 0, process_count: int = 1):
        self.directory = directory
        self.keep = keep
        self.keep_period = keep_period
        self.process_index = process_index
        self.process_count = process_count
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        gc_tmp(directory)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = True):
        self.join()                                  # one in flight at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save(self.directory, step, host_tree,
                 self.process_index, self.process_count)
            self._retain()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore(self, step: int, like: Any) -> Any:
        return restore(self.directory, step, like)

    def restore_latest(self, like: Any):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like)

    # -- retention ------------------------------------------------------------

    def _retain(self):
        if self.process_index != 0:
            return
        # same validity filter as latest_step: junk entries (stray files,
        # stale tmp dirs, mid-gc partial dirs) neither crash the retention
        # thread on int() nor shift which real checkpoints are kept
        steps = _committed_steps(self.directory)
        doomed = steps[:-self.keep] if self.keep > 0 else []
        for s in doomed:
            if self.keep_period and s % self.keep_period == 0:
                continue
            shutil.rmtree(_step_dir(self.directory, s), ignore_errors=True)
