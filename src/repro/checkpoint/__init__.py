from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointCorruptionError,
    CheckpointManager,
    committed_steps,
    gc_tmp,
    latest_step,
    remove_step,
    restore,
    restore_tree,
    save,
    step_leaf_paths,
)
