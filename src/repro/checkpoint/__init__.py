from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointCorruptionError,
    CheckpointManager,
    gc_tmp,
    latest_step,
    restore,
    restore_tree,
    save,
)
