"""Production meshes.

Pure functions — importing this module never touches jax device state; the
mesh is built only when called (after the dry-run has set XLA_FLAGS).

Physical topology assumption (v5e): a pod is a 16x16 ICI torus (256 chips);
pods are joined over DCN.  Mesh-axis order is outermost-first =
slowest-interconnect-first, so GSPMD maps 'pod' collectives onto DCN and
keeps 'model' collectives on adjacent ICI links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def describe(mesh) -> str:
    return " x ".join(f"{k}={v}" for k, v in mesh.shape.items())
