"""Serving driver: batched prefill + decode with the paper's landmark
(fast-SPSD) attention available for long contexts.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
        --batch 4 --prompt-len 64 --gen 32 --landmark

The server keeps one decode cache per active batch; prefill builds it (for
landmark configs the prefill also builds the fast-model factors of every
global layer — Algorithm 1 applied to the softmax Gram, cost O(s^2 c) per
head). Greedy sampling; the loop is jit'd with donated cache.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.launch.train import parse_mesh
from repro.models.model import build_model


def generate(model, params, prompts: jnp.ndarray, gen: int, key,
             max_len: int | None = None):
    """prompts: (B, S) int32 -> (B, gen) greedy continuations."""
    B, S = prompts.shape
    max_len = max_len or (S + gen)
    logits, cache = model.prefill(params, {"tokens": prompts}, key, max_len)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    toks = [tok]
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tok[:, None],
                               jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(tok)
    return jnp.stack(toks, axis=1)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--mesh", default="1x1")
    p.add_argument("--landmark", action="store_true",
                   help="use fast-SPSD landmark decode on global layers")
    args = p.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.landmark:
        cfg = dataclasses.replace(cfg, use_landmark_decode=True)
    mesh = parse_mesh(args.mesh)
    model = build_model(cfg)

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        t0 = time.time()
        out = generate(model, params, prompts, args.gen,
                       jax.random.PRNGKey(2))
        out.block_until_ready()
        dt = time.time() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
        print("sample row:", np.asarray(out[0][:16]))
        assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))
        print("serve ok")


if __name__ == "__main__":
    main()
