import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory/cost/roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun

Each cell is lowered with explicit in/out shardings (ShapeDtypeStruct inputs
— nothing is allocated), compiled for the 16x16 single-pod mesh and/or the
2x16x16 multi-pod mesh, and the compiled artifact is mined for:

- memory_analysis()  -> bytes/chip (proves the cell fits 16 GB HBM)
- cost_analysis()    -> FLOPs + bytes accessed (roofline compute/memory terms)
- optimized HLO text -> per-collective byte volumes (roofline collective term)
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shapes_for
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as rl
from repro.launch.steps import build_cell


def scan_reps(cfg) -> int:
    if cfg.is_encdec:
        return cfg.n_enc_layers
    return (cfg.n_layers - cfg.first_k_dense) // len(cfg.layer_pattern)


def _reduced_cfg(cfg, extra_reps: int):
    """Unrolled config with ``extra_reps`` scanned superblocks (prefix and
    remainder kept) — used for the two-point layer-cost extrapolation,
    because XLA's cost_analysis counts a while-loop body exactly once.
    ``unroll_scans`` additionally unrolls the q-block attention and
    mlstm-chunk scans so they are fully counted too."""
    if cfg.is_encdec:
        return dataclasses.replace(
            cfg, n_layers=extra_reps, n_enc_layers=extra_reps,
            n_dec_layers=extra_reps, scan_layers=False, unroll_scans=True)
    plen = len(cfg.layer_pattern)
    rem = (cfg.n_layers - cfg.first_k_dense) % plen
    nl = cfg.first_k_dense + extra_reps * plen + rem
    return dataclasses.replace(cfg, n_layers=nl, scan_layers=False,
                               unroll_scans=True)


def _compile_cell(cfg, shape, mesh, accum=None):
    cell = build_cell(cfg, shape, mesh, accum=accum)
    donate = {"train": (0, 1), "prefill": (), "decode": (1,)}[cell.kind]
    jitted = jax.jit(cell.step_fn,
                     in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=donate)
    lowered = jitted.lower(*cell.abstract_args)
    compiled = lowered.compile()
    return cell, compiled


def _costs(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "hbm": float(rl.hbm_bytes(hlo)),
            "coll": coll,
            "n_coll": sum(hlo.count(c + "(") for c in rl._COLLECTIVES)}


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v in ("True", "False"):
        return k, v == "True"
    try:
        return k, int(v)
    except ValueError:
        pass
    try:
        return k, float(v)
    except ValueError:
        return k, v


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True,
             overrides: dict | None = None, tag: str = "",
             mesh_shape: str | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if mesh_shape:
        dims = tuple(int(x) for x in mesh_shape.split("x"))
        axes = {2: ("data", "model"),
                3: ("pod", "data", "model")}[len(dims)]
        mesh = mesh_lib.make_mesh(dims, axes)
        mesh_name = mesh_shape
        chips = 1
        for d in dims:
            chips *= d
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
        chips = 512 if multi_pod else 256
    t0 = time.time()

    # 1) full scanned compile: proves the cell lowers/shards + memory numbers
    with mesh:
        cell, compiled = _compile_cell(cfg, shape, mesh)
    t_full = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_size_in_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_size_in_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_size_in_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "alias_size_in_bytes": getattr(ma, "alias_size_in_bytes", 0),
            "generated_code_size_in_bytes": getattr(
                ma, "generated_code_size_in_bytes", 0),
        }
        # peak live bytes per chip ~ args + outputs + temps - donated aliases
        mem["bytes_per_chip"] = (mem["argument_size_in_bytes"]
                                 + mem["output_size_in_bytes"]
                                 + mem["temp_size_in_bytes"]
                                 - mem["alias_size_in_bytes"])
    except Exception as e:                                    # noqa: BLE001
        mem = {"error": str(e), "bytes_per_chip": 0}

    # 2) two-point unrolled extrapolation for per-chip cost terms
    #    (XLA counts a scan body once: corrected = A + (R-1) * (B - A)).
    #    The cost compiles unroll attention/mlstm chunk scans and force
    #    accum=1 so every FLOP of one optimizer step is visible.
    t1 = time.time()
    R = scan_reps(cfg)
    seq_linear = ("slstm" in cfg.layer_pattern and shape.kind != "decode"
                  and shape.seq_len > 2048)
    if seq_linear:
        # slstm's per-timestep lax.scan cannot be unrolled, and every other
        # cost in this (attention-free) arch is linear in S.  Probe at two
        # small sequence lengths S1, 2*S1 and decompose every quantity into
        #   A(S) = out_c + out_l*S + reps*(sup_l*S + body*S_steps)
        # where 'body' is each scan's counted-once residue (slstm: S steps).
        S1 = 1024
        sh1 = dataclasses.replace(shape, name=shape.name + "_s1",
                                  seq_len=S1)
        sh2 = dataclasses.replace(shape, name=shape.name + "_s2",
                                  seq_len=2 * S1)
        with mesh:
            _, cA1 = _compile_cell(_reduced_cfg(cell.cfg, 1), sh1, mesh,
                                   accum=1)
            _, cB1 = _compile_cell(_reduced_cfg(cell.cfg, 2), sh1, mesh,
                                   accum=1)
            _, cA2 = _compile_cell(_reduced_cfg(cell.cfg, 1), sh2, mesh,
                                   accum=1)
            _, cB2 = _compile_cell(_reduced_cfg(cell.cfg, 2), sh2, mesh,
                                   accum=1)
        A1, B1, A2, B2 = (_costs(c) for c in (cA1, cB1, cA2, cB2))
        A, B = A1, B1                       # for reporting n_coll etc.
        S = shape.seq_len

        def ex(key, kind=None):
            g = (lambda d: d[key]) if kind is None \
                else (lambda d: d[key][kind])
            sup1, sup2 = g(B1) - g(A1), g(B2) - g(A2)
            body = max(2 * sup1 - sup2, 0.0)       # slstm residue (1 count)
            sup_lin = (sup2 - sup1) / S1           # per-token superblock
            out1, out2 = g(A1) - sup1, g(A2) - sup2
            out_lin = (out2 - out1) / S1
            out_const = max(2 * out1 - out2, 0.0)
            return (out_const + out_lin * S
                    + R * (sup_lin * S + body * S))
    else:
        with mesh:
            _, cA = _compile_cell(_reduced_cfg(cell.cfg, 1), shape, mesh,
                                  accum=1)
            _, cB = _compile_cell(_reduced_cfg(cell.cfg, 2), shape, mesh,
                                  accum=1)
        A, B = _costs(cA), _costs(cB)

        def ex(key, kind=None):
            g = (lambda d: d[key]) if kind is None \
                else (lambda d: d[key][kind])
            return max(g(A) + (R - 1) * (g(B) - g(A)), 0.0)

    flops = ex("flops")
    byts = ex("bytes")
    hbm = ex("hbm")
    coll = {k: ex("coll", k) for k in A["coll"]}
    t_extra = time.time() - t1

    roof = rl.Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=byts / 1e9, hbm_gbytes=hbm / 1e9,
        coll_gbytes=sum(coll.values()) / 1e9,
        coll_by_kind={k: v / 1e9 for k, v in coll.items()},
        model_gflops=rl.model_flops(cell.cfg, shape) / 1e9,
        bytes_per_chip=float(mem.get("bytes_per_chip", 0.0)),
    ).finalize()
    rec = roof.to_json()
    rec["memory_analysis"] = mem
    rec["kind"] = cell.kind
    rec["compile_full_s"] = round(t_full, 2)
    rec["compile_extrap_s"] = round(t_extra, 2)
    rec["collective_count_per_superblock"] = A["n_coll"]
    rec["scan_reps"] = R

    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] kind={cell.kind} "
              f"compile={t_full:.1f}s extrap={t_extra:.1f}s reps={R}")
        print(f"  memory_analysis: "
              f"args={mem.get('argument_size_in_bytes', 0)/1e9:.3f} GB  "
              f"out={mem.get('output_size_in_bytes', 0)/1e9:.3f} GB  "
              f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.3f} GB  "
              f"-> {mem.get('bytes_per_chip', 0)/1e9:.3f} GB/chip")
        print(f"  cost_analysis: {roof.hlo_gflops:.1f} GFLOP  "
              f"{roof.hlo_gbytes:.1f} GB accessed (unfused) / "
              f"{roof.hbm_gbytes:.1f} GB (fusion-adj)  "
              f"collectives {roof.coll_gbytes:.3f} GB "
              f"{ {k: round(v, 3) for k, v in roof.coll_by_kind.items() if v} }")
        print(f"  roofline: compute={roof.compute_s*1e3:.2f} ms  "
              f"memory={roof.memory_s*1e3:.2f} ms  "
              f"collective={roof.collective_s*1e3:.2f} ms  "
              f"bound={roof.bottleneck}  useful={100*roof.useful_flops_frac:.1f}%")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json".replace(
            "/", "_")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=ARCHS)
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--mesh", choices=["single", "multi", "both"],
                   default="single")
    p.add_argument("--all", action="store_true",
                   help="run every (arch x shape) cell")
    p.add_argument("--out", default=None, help="directory for JSON results")
    p.add_argument("--set", nargs="*", default=[], dest="overrides",
                   help="config overrides, e.g. seq_parallel_attn=True")
    p.add_argument("--tag", default="", help="suffix for result filenames")
    p.add_argument("--mesh-shape", default=None,
                   help="override mesh, e.g. 32x8 (axes data,model)")
    args = p.parse_args(argv)
    overrides = dict(_parse_override(kv) for kv in args.overrides)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in shapes_for(a):
                cells.append((a, s.name))
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, out_dir=args.out,
                         overrides=overrides, tag=args.tag,
                         mesh_shape=args.mesh_shape)
            except Exception:                                 # noqa: BLE001
                failures.append((arch, shape, mp))
                traceback.print_exc()
    if failures:
        print(f"FAILED cells: {failures}", file=sys.stderr)
        sys.exit(1)
    print("dry-run complete: all cells compiled")


if __name__ == "__main__":
    main()
