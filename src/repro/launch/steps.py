"""Jittable step functions + shardings for one (arch, shape, mesh) cell.

``build_cell`` is the single entry point the dry-run, trainer, and server
share: given a ModelConfig, a ShapeConfig, and a mesh it returns the step
function, the abstract inputs (ShapeDtypeStructs — no allocation), and the
in/out shardings, ready for ``jax.jit(...).lower(...).compile()``.

Step kinds:

- train   : (params, opt_state, batch)            -> (params, opt_state, metrics)
- prefill : (params, batch)                       -> (last_logits, cache)
- decode  : (params, cache, tokens, pos)          -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig, config_for_shape, input_specs
from repro.distributed import sharding as shd
from repro.models.model import Model, build_model
from repro.optim import make_optimizer, warmup_cosine

WHISPER_DECODER_LEN = 448        # fixed decoder horizon (enc-dec decode cells)


class Cell(NamedTuple):
    cfg: ModelConfig
    shape: ShapeConfig
    model: Model
    step_fn: Callable
    abstract_args: tuple          # ShapeDtypeStructs, positional
    in_shardings: tuple
    out_shardings: Any
    kind: str                     # train | prefill | decode


def default_optimizer(cfg: ModelConfig):
    """adafactor for the >=100B configs (HBM budget), adamw otherwise."""
    if cfg.param_count() > 100e9:
        return make_optimizer("adafactor", momentum=False)
    return make_optimizer("adamw")


def make_train_step(model: Model, opt, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total: int = 10_000,
                    accum: int = 1):
    """One optimizer step; ``accum`` > 1 splits the global batch into
    sequential microbatches (activation memory / accum at ~zero comm cost:
    the gradient all-reduce still happens once, on the f32 accumulator)."""
    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def mb(g_acc, b):
                (_, met), g = grad_fn(params, b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return g_acc, met

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            g_sum, mets = jax.lax.scan(mb, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum, g_sum)
            metrics = jax.tree.map(lambda m: jnp.mean(m), mets)
        lr = warmup_cosine(opt_state.step, peak=peak_lr, warmup_steps=warmup,
                           total_steps=total)
        params, opt_state, om = opt.update(grads, opt_state, params, lr)
        return params, opt_state, {**metrics, **om, "lr": lr}
    return train_step


def default_accum(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Microbatch count so per-step activation temps fit ~8 GB/chip.

    Empirically (yi-6b dry-run memory_analysis sweep) the rematted live set
    is ~10x the naive bf16 block-input bound — f32 norm/softmax residuals at
    scan boundaries — so the budget uses that calibrated factor.  ``accum``
    is capped so each microbatch stays divisible by the DP axes (otherwise
    the reshape inside the scan would force a resharding collective).
    """
    if shape.kind != "train":
        return 1
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    local_b = max(shape.global_batch // dp, 1)
    layers = cfg.n_layers + (cfg.n_dec_layers if cfg.is_encdec else 0)
    act = layers * local_b * shape.seq_len * cfg.d_model * 2 * 10
    accum = 1
    while act / accum > 8e9 and accum < local_b:
        accum *= 2
    return accum


def _abstract_params(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def _abstract_opt_state(opt, abstract_params):
    return jax.eval_shape(lambda: opt.init(abstract_params))


def _specs(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               opt=None, accum: Optional[int] = None) -> Cell:
    """Assemble the jittable step + abstract args + shardings for a cell."""
    cfg = config_for_shape(cfg, shape)
    model = build_model(cfg)
    aparams = _abstract_params(model)
    psh = shd.param_shardings(aparams, mesh, fsdp=cfg.fsdp,
                              moe_ep2d=cfg.moe_impl == "shard_map")
    batch = input_specs(cfg, shape)
    repl = shd.replicated(mesh)

    if shape.kind == "train":
        opt = opt or default_optimizer(cfg)
        aopt = _abstract_opt_state(opt, aparams)
        # optimizer state inherits param shardings leaf-for-leaf by path+shape
        osh = _opt_shardings(aopt, aparams, psh, mesh)
        bsh = shd.batch_shardings(batch, mesh)
        step = make_train_step(
            model, opt,
            accum=accum if accum is not None
            else default_accum(cfg, shape, mesh))
        metrics_sh = repl
        return Cell(cfg, shape, model, step,
                    (aparams, aopt, batch),
                    (psh, osh, bsh),
                    (psh, osh, metrics_sh), "train")

    if shape.kind == "prefill":
        max_len = shape.seq_len
        if cfg.is_encdec:
            def prefill_step(params, batch):
                return model.prefill(params, batch, jax.random.PRNGKey(0),
                                     WHISPER_DECODER_LEN)
        else:
            def prefill_step(params, batch):
                return model.prefill(params, batch, jax.random.PRNGKey(0),
                                     max_len)
        bsh = shd.batch_shardings(batch, mesh)
        acache = jax.eval_shape(prefill_step, aparams, batch)[1]
        csh = shd.cache_shardings(acache, mesh)
        lsh = shd.NamedSharding(
            mesh, shd.batch_pspec((shape.global_batch, cfg.vocab_size), mesh))
        return Cell(cfg, shape, model, prefill_step,
                    (aparams, batch),
                    (psh, bsh),
                    (lsh, csh), "prefill")

    # decode
    B = shape.global_batch
    if cfg.is_encdec:
        acache = _specs(jax.eval_shape(
            lambda: model.cache_shape(B, WHISPER_DECODER_LEN,
                                      shape.seq_len)))
    else:
        acache = _specs(jax.eval_shape(
            lambda: model.cache_shape(B, shape.seq_len)))
    csh = shd.cache_shardings(acache, mesh)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tsh = shd.NamedSharding(mesh, shd.batch_pspec((B, 1), mesh))
    lsh = shd.NamedSharding(
        mesh, shd.batch_pspec((B, cfg.vocab_size), mesh))

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return Cell(cfg, shape, model, decode_step,
                (aparams, acache, tokens, pos),
                (psh, csh, tsh, shd.replicated(mesh)),
                (lsh, csh), "decode")


def _opt_shardings(aopt, aparams, psh, mesh):
    """Optimizer-state shardings: a state leaf whose path *suffix* matches a
    parameter path and whose shape matches that parameter inherits the
    parameter's sharding (so Adam's m/v are ZeRO-sharded exactly like the
    weights); factored/scalar stats are replicated (tiny)."""
    pinfo = {}
    psh_flat = jax.tree_util.tree_flatten_with_path(psh)[0]
    par_flat = jax.tree_util.tree_flatten_with_path(aparams)[0]
    for (ppath, sh), (_, leaf) in zip(psh_flat, par_flat):
        key = tuple(_key(k) for k in ppath)
        pinfo[key] = (leaf.shape, sh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(aopt)
    out = []
    for path, leaf in flat:
        keys = tuple(_key(k) for k in path)
        hit = None
        for i in range(len(keys)):
            cand = keys[i:]
            info = pinfo.get(cand)
            if info is not None and info[0] == leaf.shape:
                hit = info[1]
                break
        out.append(hit if hit is not None else shd.replicated(mesh))
    return jax.tree_util.tree_unflatten(treedef, out)


def _key(k):
    return str(getattr(k, "key", getattr(k, "idx", k)))
