"""Continuous-batching kernel-inference server over a KernelModelArtifact.

The production shape of ``repro.serve``: replicas precompute the factor
store once (``--build``), then any number of serving processes warm-boot
from the checkpoint (``--serve``) and answer KRR / KPCA / feature-map
queries with one rectangular fused cross-kernel launch per size bucket.

    # precompute + persist the artifact and a canned query trace
    PYTHONPATH=src python -m repro.launch.serve_kernel --build \
        --dir /tmp/serve_ckpt --n 240 --c 48 --s 96 --queries 12

    # fresh process: warm boot, replay the trace, assert parity + latency
    PYTHONPATH=src python -m repro.launch.serve_kernel --serve \
        --dir /tmp/serve_ckpt --require-warm --parity-tol 1e-5

``KernelServer`` runs the continuous-batching loop: callers ``submit``
requests from any thread; a background worker collects until ``max_batch``
requests are queued or the oldest has waited ``max_wait_s``, then flushes —
``plan_buckets`` groups the batch by query count (padding bounded by
``waste``) and each bucket is answered by ONE ``op.cross`` launch.  Every
request records its enqueue→complete latency; the CI serve-smoke job
asserts the replayed trace matches the dense oracles to ≤1e-5 and that
``cross_sweeps`` (via ``CountingOperator``) equals ``buckets_served``.

Corpus growth rides the same loop: ``submit_append`` enqueues a training
batch next to the queries; the worker absorbs it IN ARRIVAL ORDER through
an ``IncrementalMaintainer`` (one thin ``append_sweeps``-metered launch +
delta checkpoint per batch, see ``repro.serve.incremental``) and swaps the
refreshed artifact in for every later query — no rebuild, no restart.  The
``--append`` CLI leg replays that path and asserts the absorb was O(b·c):
exactly one append sweep per batch, zero panel/full sweeps, and ≤1e-5
parity against a dense f64 oracle on the GROWN corpus.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import threading
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core.instrument import CountingOperator
from repro.kernels.pairwise import specs as pw_specs
from repro.serve import (
    GenerationStats,
    IncrementalMaintainer,
    KernelModelArtifact,
    QueryRequest,
    StalenessPolicy,
    answer_batch,
    build_artifact,
    dense_krr_oracle,
    dense_oracle,
    is_delta_step,
    load_artifact,
    load_or_rebuild,
    parity_gap,
    plan_buckets,
    save_artifact,
)

TRACE_FILE = "trace.npz"
BUILD_FILE = "build.json"


# ---------------------------------------------------------------------------
# batching policy + server
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """When the collector flushes: at ``max_batch`` queued requests, or when
    the OLDEST queued request has waited ``max_wait_s`` (so a lone request's
    latency is bounded by max_wait_s + one launch, never unbounded).
    ``waste`` is the per-request padding bound ``plan_buckets`` enforces."""

    max_batch: int = 32
    max_wait_s: float = 0.01
    waste: float = 0.25


class _Pending:
    """Shared completion handle: ``wait()`` blocks until the batching loop
    fills ``result`` (or re-raises the flush error)."""

    __slots__ = ("t_enqueue", "result", "latency_s", "error", "_done")

    def __init__(self):
        self.t_enqueue = time.perf_counter()
        self.result = None
        self.latency_s: Optional[float] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    def wait(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError("request not answered within timeout")
        if self.error is not None:
            raise self.error
        return self.result


class PendingQuery(_Pending):
    """Handle returned by ``KernelServer.submit``; ``wait()`` returns the
    ``QueryResult``."""

    __slots__ = ("request",)

    def __init__(self, request: QueryRequest):
        super().__init__()
        self.request = request


class PendingAppend(_Pending):
    """Handle returned by ``KernelServer.submit_append``; ``wait()`` returns
    the ``GenerationStats`` of the absorbed batch.  Appends are absorbed in
    ARRIVAL ORDER relative to each other and to queries in the same flush,
    so a query submitted after an append is answered by the refreshed
    artifact."""

    __slots__ = ("X_new", "y_new")

    def __init__(self, X_new, y_new):
        super().__init__()
        self.X_new = np.asarray(X_new, np.float32)
        self.y_new = np.asarray(y_new, np.float32)


class KernelServer:
    """Threaded continuous-batching loop over ``answer_batch``.

    One background worker owns the launch path; ``submit`` is safe from any
    number of client threads.  Counters (``buckets_served``,
    ``requests_served``) and the per-request ``latencies_s`` log are the
    ground truth the bench and the serve-smoke assertions read.
    """

    def __init__(self, artifact: KernelModelArtifact,
                 policy: BatchPolicy = BatchPolicy(), op=None,
                 maintainer: Optional[IncrementalMaintainer] = None):
        self.artifact = artifact
        self.policy = policy
        self.op = artifact.landmark_operator() if op is None else op
        self.maintainer = maintainer
        self._cv = threading.Condition()
        self._queue: List[_Pending] = []
        self._stopping = False
        self.buckets_served = 0
        self.batches_served = 0
        self.requests_served = 0
        self.appends_served = 0
        self.latencies_s: List[float] = []
        self.append_latencies_s: List[float] = []
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    # -- client side --------------------------------------------------------

    def submit(self, X, task: str = "krr") -> PendingQuery:
        req = X if isinstance(X, QueryRequest) else QueryRequest(X, task)
        return self._enqueue(PendingQuery(req))

    def submit_append(self, X_new, y_new) -> PendingAppend:
        """Enqueue a training batch for incremental absorption (requires a
        ``maintainer``).  Absorbed in arrival order within the batching
        loop; ``wait()`` returns the batch's ``GenerationStats``."""
        if self.maintainer is None:
            raise RuntimeError(
                "KernelServer has no IncrementalMaintainer; construct with "
                "maintainer= to accept appends")
        return self._enqueue(PendingAppend(X_new, y_new))

    def _enqueue(self, pending):
        with self._cv:
            if self._stopping:
                raise RuntimeError("server is stopped")
            self._queue.append(pending)
            self._cv.notify_all()
        return pending

    def stop(self):
        """Drain the queue, then join the worker (idempotent)."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        self._worker.join()

    # -- worker side --------------------------------------------------------

    def _take_batch(self) -> List[PendingQuery]:
        """Block until a flush is due; return the batch (empty = shut down)."""
        with self._cv:
            while not self._queue and not self._stopping:
                self._cv.wait()
            if not self._queue:
                return []                                 # stopping + drained
            deadline = self._queue[0].t_enqueue + self.policy.max_wait_s
            while (len(self._queue) < self.policy.max_batch
                   and not self._stopping):
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                self._cv.wait(timeout=remaining)
            batch = self._queue[: self.policy.max_batch]
            del self._queue[: len(batch)]
            return batch

    def _loop(self):
        while True:
            batch = self._take_batch()
            if not batch:
                return
            try:
                self._flush(batch)
            except BaseException as e:                    # propagate to waiters
                for p in batch:
                    if not p._done.is_set():
                        p.error = e
                        p._done.set()

    def _flush(self, batch: List[_Pending]):
        """Process one collected batch IN ARRIVAL ORDER: maximal runs of
        queries are bucketed and launched together; each append between
        them is absorbed before the next run, so later queries see the
        refreshed artifact."""
        i = 0
        while i < len(batch):
            if isinstance(batch[i], PendingAppend):
                self._absorb(batch[i])
                i += 1
                continue
            j = i
            while j < len(batch) and not isinstance(batch[j], PendingAppend):
                j += 1
            self._answer(batch[i:j])
            i = j
        self.batches_served += 1

    def _answer(self, run: List[PendingQuery]):
        requests = [p.request for p in run]
        results = [None] * len(run)
        for bucket in plan_buckets(requests, waste=self.policy.waste):
            answers = answer_batch(
                self.artifact, [requests[i] for i in bucket], op=self.op,
                bucket=self.buckets_served)
            jax.block_until_ready([a.out for a in answers])
            self.buckets_served += 1
            for i, res in zip(bucket, answers):
                results[i] = res
        now = time.perf_counter()
        for p, res in zip(run, results):
            p.result = res
            p.latency_s = now - p.t_enqueue
            self.latencies_s.append(p.latency_s)
            self.requests_served += 1
            p._done.set()

    def _absorb(self, p: PendingAppend):
        old = self.artifact
        stats: GenerationStats = self.maintainer.append(p.X_new, p.y_new)
        art = self.maintainer.artifact
        if art is not old:
            # a re-sketch replaces the landmarks; the query op must follow
            # (rebind keeps the meters running across the swap)
            if art.X_landmarks is not old.X_landmarks and \
                    hasattr(self.op, "rebind"):
                self.op.rebind(art.landmark_operator())
            self.artifact = art
        p.result = stats
        p.latency_s = time.perf_counter() - p.t_enqueue
        self.append_latencies_s.append(p.latency_s)
        self.appends_served += 1
        p._done.set()


def percentile_ms(latencies_s: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(latencies_s, np.float64), q) * 1e3)


# ---------------------------------------------------------------------------
# canned trace: build-time oracle answers, replayed by fresh serving processes
# ---------------------------------------------------------------------------

def synth_problem(n: int, d: int, seed: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deterministic synthetic regression problem (shared by --build and the
    --serve rebuild hook, so a cold boot recreates the identical artifact)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    w = rng.standard_normal((d,)).astype(np.float32)
    y = np.tanh(X @ w) + 0.1 * rng.standard_normal(n).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(y, jnp.float32)


def synth_batches(params: dict, batches: int, rows: int
                  ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Append batches drawn from the SAME generative process as
    ``synth_problem`` (same seed stream prefix, so the grown corpus is the
    deterministic continuation of the base one)."""
    n, d, seed = params["n"], params["d"], params["seed"]
    rng = np.random.default_rng(seed)
    rng.standard_normal((n, d))                      # replay the base X draw
    w = rng.standard_normal((d,)).astype(np.float32)
    rng.standard_normal(n)                           # ... and the base noise
    out = []
    for _ in range(batches):
        Xb = rng.standard_normal((rows, d)).astype(np.float32)
        yb = np.tanh(Xb @ w) + 0.1 * rng.standard_normal(rows).astype(
            np.float32)
        out.append((Xb, yb))
    return out


def build_from_params(params: dict) -> KernelModelArtifact:
    X, y = synth_problem(params["n"], params["d"], params["seed"])
    spec = pw_specs.get_spec(params["kernel"], **params["spec_params"])
    return build_artifact(
        X, y, spec, c=params["c"], s=params["s"], alpha=params["alpha"],
        n_components=params["n_components"],
        key=jax.random.PRNGKey(params["seed"]),
        use_pallas=params["use_pallas"])


def write_trace(directory: str, artifact: KernelModelArtifact, params: dict,
                n_queries: int, seed: int) -> str:
    """Canned heterogeneous query trace + oracle-expected outputs.

    KRR expectations come from ``dense_krr_oracle`` (independent dense solve
    of the approximated kernel, f64); KPCA/feature expectations from the
    dense-route ``dense_oracle``.  A serving process that matches this file
    to ≤1e-5 has verified the Woodbury identity, the head algebra, the
    fused Pallas cross launch, and checkpoint persistence at once.
    """
    rng = np.random.default_rng(seed + 1)
    _, y = synth_problem(params["n"], params["d"], params["seed"])
    sizes = [int(rng.choice([5, 17, 33, 64])) for _ in range(n_queries)]
    tasks = [("krr", "kpca", "features")[i % 3] for i in range(n_queries)]
    payload = {"tasks": np.array(tasks), "sizes": np.array(sizes)}
    d = params["d"]
    for i, (nq, task) in enumerate(zip(sizes, tasks)):
        Xq = rng.standard_normal((nq, d)).astype(np.float32)
        if task == "krr":
            expected = dense_krr_oracle(artifact, Xq, y)
        else:
            expected = dense_oracle(artifact, Xq, task)
        payload[f"q{i}"] = Xq
        payload[f"e{i}"] = np.asarray(expected, np.float32)
    path = os.path.join(directory, TRACE_FILE)
    np.savez(path, **payload)
    return path


def load_trace(directory: str) -> List[Tuple[np.ndarray, str, np.ndarray]]:
    with np.load(os.path.join(directory, TRACE_FILE)) as z:
        tasks = [str(t) for t in z["tasks"]]
        return [(z[f"q{i}"], task, z[f"e{i}"])
                for i, task in enumerate(tasks)]


def replay_trace(server: KernelServer,
                 trace: Sequence[Tuple[np.ndarray, str, np.ndarray]]
                 ) -> Tuple[float, List[float]]:
    """Submit the whole trace (as concurrent clients would), wait for every
    answer, and return (worst parity gap vs expected, per-request latencies)."""
    pending = [server.submit(Xq, task) for Xq, task, _ in trace]
    gaps, lats = [], []
    for p, (_, _, expected) in zip(pending, trace):
        res = p.wait(timeout=60.0)
        gaps.append(parity_gap(res.out, expected))
        lats.append(p.latency_s)
    return max(gaps), lats


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _build(args) -> int:
    params = {
        "n": args.n, "d": args.d, "c": args.c, "s": args.s,
        "alpha": args.alpha, "n_components": args.n_components,
        "kernel": args.kernel, "spec_params": {"sigma": args.sigma},
        "seed": args.seed, "use_pallas": not args.no_pallas,
    }
    os.makedirs(args.dir, exist_ok=True)
    artifact = build_from_params(params)
    path = save_artifact(args.dir, artifact, step=0)
    with open(os.path.join(args.dir, BUILD_FILE), "w") as f:
        json.dump(params, f, indent=1)
    trace_path = write_trace(args.dir, artifact, params,
                             n_queries=args.queries, seed=args.seed)
    print(f"artifact (c={artifact.c}) committed at {path}")
    print(f"trace with {args.queries} queries at {trace_path}")
    return 0


def _serve(args) -> int:
    with open(os.path.join(args.dir, BUILD_FILE)) as f:
        params = json.load(f)

    artifact, recovery = load_or_rebuild(
        args.dir, lambda: build_from_params(params))
    boot = "warm" if recovery.warm else "cold"
    print(f"boot: {boot} "
          f"(events: {[e.kind for e in recovery.events]})")
    if args.require_warm and not recovery.warm:
        print("FAIL: --require-warm but boot was cold")
        return 1

    if args.append_batches > 0 and int(artifact.C.shape[0]) != params["n"]:
        # A previous append run left a delta chain on the store, so the
        # warm boot restored the grown chain tip — but the canned trace and
        # the synth base (X, y) describe the BASE corpus.  Restart the leg
        # from the latest FULL snapshot and drop the prior run's deltas:
        # the leg replays a deterministic append stream, so reruns are
        # idempotent instead of chaining deltas onto a stale tip.
        steps = ckpt.committed_steps(args.dir)
        fulls = [s for s in steps if not is_delta_step(args.dir, s)]
        if fulls:
            artifact = load_artifact(args.dir, step=max(fulls))
            for s in steps:
                if s > max(fulls):
                    ckpt.remove_step(args.dir, s)
            print(f"append leg: rebased on full step {max(fulls)} "
                  f"(dropped {len(steps) - len(fulls)} prior delta step(s))")

    op = CountingOperator(artifact.landmark_operator())
    policy = BatchPolicy(max_batch=args.max_batch,
                         max_wait_s=args.max_wait_ms / 1e3)
    maintainer = None
    if args.append_batches > 0:
        X_base, y_base = synth_problem(params["n"], params["d"],
                                       params["seed"])
        maintainer = IncrementalMaintainer(
            artifact, np.asarray(y_base), directory=args.dir,
            X=np.asarray(X_base),
            staleness=StalenessPolicy(
                drift_threshold=args.drift_threshold,
                error_budget=float("inf"), max_generations=0),
            op=op)
    server = KernelServer(artifact, policy, op=op, maintainer=maintainer)
    trace = load_trace(args.dir)
    try:
        gap_warmup, _ = replay_trace(server, trace)       # compile caches
        sweeps0, buckets0 = op.counts["cross_sweeps"], server.buckets_served
        gap, lats = replay_trace(server, trace)
        append_ok = True
        if args.append_batches > 0:
            append_ok = _append_leg(args, params, server, op)
    finally:
        server.stop()

    sweeps = op.counts["cross_sweeps"] - sweeps0
    buckets = server.buckets_served - buckets0
    p50, p99 = percentile_ms(lats, 50), percentile_ms(lats, 99)
    print(f"replayed {len(trace)} queries: parity {gap:.3e} "
          f"(warmup pass {gap_warmup:.3e})")
    print(f"launches: {sweeps} cross sweeps over {buckets} buckets "
          f"(route: {op.last_route})")
    print(f"latency: p50 {p50:.2f} ms  p99 {p99:.2f} ms")

    ok = append_ok
    if gap > args.parity_tol or gap_warmup > args.parity_tol:
        print(f"FAIL: parity {max(gap, gap_warmup):.3e} > "
              f"tol {args.parity_tol:.1e}")
        ok = False
    if sweeps != buckets:
        print(f"FAIL: {sweeps} cross sweeps != {buckets} buckets "
              f"(serving must launch exactly once per bucket)")
        ok = False
    if args.max_p50_ms is not None and p50 > args.max_p50_ms:
        print(f"FAIL: p50 {p50:.2f} ms > budget {args.max_p50_ms} ms")
        ok = False
    print("serve ok" if ok else "serve FAILED")
    return 0 if ok else 1


def _append_leg(args, params: dict, server: KernelServer,
                op: CountingOperator) -> bool:
    """The append-refresh replay: absorb batches through the live server,
    then hold the absorb to the O(b·c) meter contract and the grown-corpus
    parity contract."""
    batches = synth_batches(params, args.append_batches, args.append_rows)
    before = dict(op.counts)
    n_before = int(server.artifact.C.shape[0])

    pending = [server.submit_append(Xb, yb) for Xb, yb in batches]
    stats = [p.wait(timeout=60.0) for p in pending]
    gens = [s.generation for s in stats]
    app_p50 = percentile_ms([p.latency_s for p in pending], 50)
    print(f"append: absorbed {len(batches)} x {args.append_rows} rows "
          f"(n {n_before} -> {stats[-1].n_after}), p50 {app_p50:.2f} ms, "
          f"drift {stats[-1].drift:.3f}")

    ok = True
    # the O(b·c) contract: ONE thin metered launch per batch, nothing else
    deltas = {k: op.counts[k] - before.get(k, 0)
              for k in ("append_sweeps", "sweeps", "fulls", "cross_sweeps")}
    if deltas["append_sweeps"] != len(batches):
        print(f"FAIL: {deltas['append_sweeps']} append sweeps for "
              f"{len(batches)} batches (must be exactly one per batch)")
        ok = False
    if deltas["sweeps"] or deltas["fulls"] or deltas["cross_sweeps"]:
        print(f"FAIL: absorb touched the kernel beyond the thin launch "
              f"(sweeps={deltas['sweeps']} fulls={deltas['fulls']} "
              f"cross={deltas['cross_sweeps']})")
        ok = False
    if gens != list(range(gens[0], gens[0] + len(batches))):
        print(f"FAIL: generations {gens} not consecutive in arrival order")
        ok = False

    # grown-corpus parity: fresh queries vs a dense f64 oracle over the
    # artifact as it NOW stands (base + every appended row)
    rng = np.random.default_rng(params["seed"] + 2)
    _, y_base = synth_problem(params["n"], params["d"], params["seed"])
    y_full = np.concatenate([np.asarray(y_base)[:, None]]
                            + [yb[:, None] for _, yb in batches], axis=0)
    art = server.artifact
    gaps = []
    for nq in (5, 17, 33):
        Xq = rng.standard_normal((nq, params["d"])).astype(np.float32)
        expected = dense_krr_oracle(art, jnp.asarray(Xq),
                                    jnp.asarray(y_full, jnp.float32))
        res = server.submit(Xq, "krr").wait(timeout=60.0)
        gaps.append(float(parity_gap(res.out, expected)))
        for task in ("kpca", "features"):
            expected = dense_oracle(art, jnp.asarray(Xq), task)
            res = server.submit(Xq, task).wait(timeout=60.0)
            gaps.append(float(parity_gap(res.out, expected)))
    gap = max(gaps)
    print(f"append: grown-corpus parity {gap:.3e} over {len(gaps)} probes")
    if gap > args.parity_tol:
        print(f"FAIL: grown-corpus parity {gap:.3e} > "
              f"tol {args.parity_tol:.1e}")
        ok = False

    # persistence: every generation is a committed delta step, and a fresh
    # chain restore reproduces the LIVE artifact bitwise
    steps = ckpt.committed_steps(args.dir)
    if len(steps) < 1 + len(batches):
        print(f"FAIL: expected >= {1 + len(batches)} committed steps "
              f"(base + one delta per batch), found {steps}")
        ok = False
    restored = load_artifact(args.dir)
    if restored is None or \
            not np.array_equal(np.asarray(restored.C), np.asarray(art.C)) or \
            not np.array_equal(np.asarray(restored.heads["krr"]),
                               np.asarray(art.heads["krr"])):
        print("FAIL: delta-chain restore does not reproduce the live "
              "artifact bitwise")
        ok = False
    return ok


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="kernel-inference serving: precompute (--build) and "
                    "warm-boot replay (--serve)")
    p.add_argument("--build", action="store_true")
    p.add_argument("--serve", action="store_true")
    p.add_argument("--dir", required=True,
                   help="checkpoint directory (the factor store)")
    # build-side knobs (persisted to build.json for the rebuild hook)
    p.add_argument("--n", type=int, default=240)
    p.add_argument("--d", type=int, default=24)
    p.add_argument("--c", type=int, default=48)
    p.add_argument("--s", type=int, default=96)
    p.add_argument("--alpha", type=float, default=1.0)
    p.add_argument("--n-components", type=int, default=8)
    p.add_argument("--kernel", default="rbf")
    p.add_argument("--sigma", type=float, default=1.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--queries", type=int, default=12)
    p.add_argument("--no-pallas", action="store_true")
    # serve-side knobs
    p.add_argument("--require-warm", action="store_true",
                   help="fail unless the artifact restored from checkpoint")
    p.add_argument("--parity-tol", type=float, default=1e-5)
    p.add_argument("--max-p50-ms", type=float, default=None)
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--max-wait-ms", type=float, default=5.0)
    # incremental-append leg (serve side)
    p.add_argument("--append-batches", type=int, default=0,
                   help="absorb this many appended-row batches through the "
                        "live server and assert the O(b*c) meter + "
                        "grown-corpus parity contracts")
    p.add_argument("--append-rows", type=int, default=16,
                   help="rows per appended batch")
    p.add_argument("--drift-threshold", type=float, default=float("inf"),
                   help="staleness drift threshold for the append leg "
                        "(default: never re-sketch)")
    args = p.parse_args(argv)

    if args.build == args.serve:
        p.error("exactly one of --build / --serve is required")
    return _build(args) if args.build else _serve(args)


if __name__ == "__main__":
    raise SystemExit(main())
