"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

Wires every substrate layer together: config -> model -> data pipeline ->
pjit'd train step -> checkpoint manager (atomic, async, retained) ->
fault-tolerance hooks (preemption -> save-and-exit; restartable data state).
On this CPU container it is exercised with --smoke configs and a (1,1) or
(d,m) debug mesh; on real hardware the same file drives the production mesh
(--mesh 16x16).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import make_pipeline
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_train_step, default_optimizer
from repro.models.model import build_model
from repro.runtime import PreemptionHandler


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return mesh_lib.make_mesh(dims, axes)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq-len", type=int, default=256)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--mesh", default="1x1",
                   help="e.g. 1x1, 2x4, 16x16, 2x16x16")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--peak-lr", type=float, default=3e-4)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--compress-pod-grads", type=int, default=0,
                   help="CountSketch compression ratio for cross-pod "
                        "all-reduce (0 = off)")
    args = p.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = parse_mesh(args.mesh)
    model = build_model(cfg)
    opt = default_optimizer(cfg)
    step_fn = make_train_step(model, opt, peak_lr=args.peak_lr,
                              total=args.steps, warmup=max(args.steps // 10, 1),
                              accum=args.accum)

    pipe = make_pipeline("synthetic", vocab_size=cfg.vocab_size,
                         seq_len=args.seq_len, global_batch=args.global_batch)

    preempt = PreemptionHandler(install_signal=True)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        psh = shd.param_shardings(params, mesh, fsdp=cfg.fsdp)
        params = jax.device_put(params, psh)

        start = 0
        if mgr is not None:
            latest = mgr.latest_step()
            if latest is not None:
                state = mgr.restore(latest, {"params": params,
                                             "opt": opt_state})
                params = jax.device_put(state["params"], psh)
                opt_state = jax.tree.map(jnp.asarray, state["opt"],
                                         is_leaf=lambda x: hasattr(x, "shape"))
                opt_state = type(opt_state)(*opt_state) \
                    if not isinstance(opt_state, dict) else opt_state
                start = latest
                print(f"restored checkpoint @ step {latest}")

        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        t0 = time.time()
        losses = []
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, pipe.batch_at(step))
            params, opt_state, metrics = jstep(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                tput = (step - start + 1) * args.global_batch \
                    * args.seq_len / max(dt, 1e-9)
                print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"{tput:,.0f} tok/s")
            if mgr is not None and (
                    (step + 1) % args.ckpt_every == 0 or preempt.should_exit):
                mgr.save(step + 1, {"params": params, "opt": opt_state},
                         blocking=preempt.should_exit)
            if preempt.should_exit:
                print(f"preempted: checkpointed at step {step + 1}, exiting")
                break
        if mgr is not None:
            mgr.join()

    if len(losses) >= 20:
        first, last = np.mean(losses[:10]), np.mean(losses[-10:])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
