"""Roofline-term extraction from a compiled (dry-run) executable.

Three terms per (arch, shape, mesh), in seconds:

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

Sources:
- ``compiled.cost_analysis()`` -> 'flops' and 'bytes accessed'.  The compiled
  module is the per-device SPMD program, so these are PER-CHIP numbers
  (verified against hand-computed 6ND for yi-6b: hlo_flops*chips ~ 6ND+remat).
- collective bytes are NOT in cost_analysis: we walk the optimized HLO text
  and sum the *shape bytes* of every all-gather / all-reduce / reduce-scatter
  / all-to-all / collective-permute op.  Bytes are computed from the result
  shape (for all-gather: the gathered output; for reduce-scatter: the input =
  output * group); this is the volume that crosses links per chip up to the
  ring-algorithm factor 2(g-1)/g ~ 2 which we fold into EFFECTIVE_LINK_BW.

v5e hardware constants (per chip):
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Peak rates the roofline terms divide by — a PARAMETER, not a module
    global, so reports name the hardware they model instead of silently
    assuming v5e on whatever backend happens to be attached."""

    name: str
    peak_flops: float            # FLOP/s (dense matmul peak)
    hbm_bw: float                # bytes/s
    link_bw: float               # bytes/s per ICI link (ring effective)


#: v5e per-chip peaks (bf16 MXU) — the default target hardware
V5E = HardwareProfile("v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)

#: an honest CI profile: interpret-mode Pallas on a shared CPU runner.  The
#: numbers are order-of-magnitude host figures (a few AVX cores, DDR
#: bandwidth, loopback "links") — the point is that CPU reports say so,
#: rather than scoring a CPU wall-clock against a 197-TFLOP/s TPU.
CPU_INTERPRET = HardwareProfile("cpu-interpret", peak_flops=2e11,
                                hbm_bw=2e10, link_bw=1e10)


def default_profile() -> HardwareProfile:
    """V5E on a TPU backend, CPU_INTERPRET everywhere else."""
    try:
        import jax
        backend = jax.default_backend()
    except Exception:  # pragma: no cover - jax always present in this repo
        backend = "cpu"
    return V5E if backend == "tpu" else CPU_INTERPRET


# Back-compat module aliases (v5e values); new code should pass a
# ``HardwareProfile`` explicitly.
PEAK_FLOPS = V5E.peak_flops  # bf16 FLOP/s
HBM_BW = V5E.hbm_bw          # bytes/s
LINK_BW = V5E.link_bw        # bytes/s per ICI link (ring-collective effective)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%x = bf16[2,16,128]{...} all-gather(...)`; also tuple shapes
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\b("
    + "|".join(_COLLECTIVES) + r")\b")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


# ---------------------------------------------------------------------------
# fusion-adjusted HBM bytes
# ---------------------------------------------------------------------------
# XLA:CPU leaves elementwise chains as hundreds of tiny kLoop fusions, so
# cost_analysis()'s 'bytes accessed' wildly overcounts what a TPU (which
# fuses elementwise work into its dot/reduce kernels) moves through HBM.
# This walker models the *perfect-fusion* asymptote — the same idealization
# the roofline's compute term makes for the MXU: count operand+result bytes
# only for memory-real ops (matmuls, reductions, gathers/scatters, cache
# updates, sorts, collectives); every elementwise op is assumed fused into
# its consumer.  Activations still get counted exactly once: they are
# operands of the dots/reduces that consume them.

_MEM_OPS = (
    "dot(", "dot-general(", "convolution(", "reduce(", "reduce-window(",
    "scatter(", "gather(", "dynamic-slice(", "dynamic-update-slice(",
    "sort(", "copy(",
    "all-gather(", "all-reduce(", "reduce-scatter(", "all-to-all(",
    "collective-permute(",
)

# CPU wraps single non-elementwise ops in fusions named wrapped_<op>...;
# count those wrappers by instruction-name prefix.
_WRAPPED_COUNTED = ("wrapped_reduce", "wrapped_scatter", "wrapped_gather",
                    "wrapped_sort", "wrapped_dot", "wrapped_convolution",
                    "wrapped_dynamic", "wrapped_copy")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_LHS_SHAPES_RE = re.compile(r"([a-z]\d*[a-z]*\d*)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


_SKIP_COMPUTATIONS = ("%fused", "%wrapped", "%region")


def _computation_lines(hlo_text: str):
    """Yield (in_skipped_computation, line). Fusion bodies / reduce-apply
    regions are marked skipped: their interior ops live in VMEM on TPU."""
    skipped = False
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and ("(" in s and ")" in s):
            name = s.split()[0]
            skipped = any(name.startswith(p) for p in _SKIP_COMPUTATIONS)
        yield skipped, line
        if s == "}":
            skipped = False


def hbm_bytes(hlo_text: str) -> int:
    """Fusion-adjusted per-chip HBM traffic estimate from optimized HLO."""
    # pass 1: instruction name -> result bytes (module-wide)
    sizes: Dict[str, int] = {}
    for _, line in _computation_lines(hlo_text):
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        head = rhs.split("(", 1)[0]          # shapes before the opcode args
        total = 0
        for dt, dims in _LHS_SHAPES_RE.findall(head):
            if dt in _DTYPE_BYTES:
                total += _shape_bytes(dt, dims)
        if total:
            sizes[name] = total
    # pass 2: memory-real ops in non-fused computations: result + operands
    total = 0
    for skipped, line in _computation_lines(hlo_text):
        if skipped:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        opcode_part = rhs.split("(", 1)[0]
        counted = any(op[:-1] in opcode_part.split() for op in _MEM_OPS)
        if not counted and "fusion" in opcode_part.split():
            counted = any(name.startswith(p) for p in _WRAPPED_COUNTED)
        if not counted:
            continue
        total += sizes.get(name, 0)
        args = rhs.split("(", 1)[1] if "(" in rhs else ""
        args = args.split("),")[0]
        for op_name in _OPERAND_RE.findall(args):
            total += sizes.get(op_name, 0)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from optimized HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        # tuple results: sum every shape on the lhs before the op name
        lhs = line.split(kind)[0]
        total = 0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt in _DTYPE_BYTES:
                total += _shape_bytes(dt, dims)
        out[kind] += total
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float              # per-chip GFLOP (SPMD module)
    hlo_gbytes: float              # per-chip GB accessed (unfused bound)
    coll_gbytes: float             # per-chip collective GB (result shapes)
    coll_by_kind: Dict[str, float]
    model_gflops: float            # 6 * N_active * D (per step, all chips)
    bytes_per_chip: float          # from memory_analysis (peak, if available)
    hbm_gbytes: float = 0.0        # fusion-adjusted GB (memory-real ops)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flops_frac: float = 0.0
    profile_name: str = "v5e"

    def finalize(self, profile: Optional[HardwareProfile] = None):
        prof = V5E if profile is None else profile
        self.profile_name = prof.name
        self.compute_s = self.hlo_gflops * 1e9 / prof.peak_flops
        gb = self.hbm_gbytes if self.hbm_gbytes > 0 else self.hlo_gbytes
        self.memory_s = gb * 1e9 / prof.hbm_bw
        self.collective_s = self.coll_gbytes * 1e9 / prof.link_bw
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        if self.hlo_gflops > 0:
            self.useful_flops_frac = self.model_gflops / (
                self.hlo_gflops * self.chips)
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) per optimizer step; forward-only
    (2*N*D) for serving cells.  D = processed tokens for this cell."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_encdec:
            # each stream only crosses its half of the params:
            # 6*(N/2)*(enc tokens) + 6*(N/2)*(dec tokens)
            return 3.0 * n_active * shape.global_batch * (
                shape.seq_len + max(shape.seq_len // 8, 1))
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads the cache but 6ND
    # convention counts matmul params only
    return 2.0 * n_active * shape.global_batch


def analyze(compiled, hlo_text: str, *, arch: str, shape, cfg, mesh_name: str,
            chips: int, memory_stats: Optional[dict] = None,
            profile: Optional[HardwareProfile] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):                    # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    coll_total = sum(coll.values())
    mstats = memory_stats or {}
    r = Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=byts / 1e9,
        coll_gbytes=coll_total / 1e9,
        coll_by_kind={k: v / 1e9 for k, v in coll.items()},
        model_gflops=model_flops(cfg, shape) / 1e9,
        bytes_per_chip=float(mstats.get("bytes_per_chip", 0.0)),
    )
    return r.finalize(profile)


# ---------------------------------------------------------------------------
# kernel-layer scoring: the pairwise sweep template's per-launch roofline
# ---------------------------------------------------------------------------

def pairwise_launch_model(spec, nr: int, nc: int, d: int, m_total: int,
                          l1_route: Optional[str] = None,
                          segments: int = 0) -> Dict[str, float]:
    """Analytic FLOP/byte model of ONE fused pairwise launch, split by unit.

    ``nr × nc`` kernel entries from (nr, d) × (nc, d) points, contracted
    against right-hand sides totalling ``m_total`` columns.  The split
    matters because the point of the MXU-everywhere pipeline is moving work
    from the ``vpu_flops`` bucket to the ``mxu_flops`` bucket:

    - ``dot``      2d MXU FLOPs/entry.
    - ``sqdist``   2d MXU FLOPs/entry + O(1) VPU combine (+ row norms).
    - ``l1dist``   route-dependent — 'mxu_signsplit' pays two contractions
      of inner dimension 2·d·B (B = ``segments``): 8·d·B MXU FLOPs/entry
      plus O((nr+nc)·d·B) VPU embedding; 'vpu_loop' pays ~4d VPU
      FLOPs/entry (subtract, abs, accumulate, loop bookkeeping).

    The V contraction adds 2·m_total MXU FLOPs/entry; ``entry_fn`` is
    modeled at 8 VPU FLOPs/entry (transcendental-ish).  Bytes are the
    perfect-fusion HBM floor: points + right-hand sides in, outputs out —
    kernel tiles never touch HBM (that IS the fused template's claim).
    """
    entries = float(nr) * float(nc)
    stat = spec.stat
    if stat == "dot":
        mxu = 2.0 * d * entries
        vpu = 0.0
    elif stat == "sqdist":
        mxu = 2.0 * d * entries
        vpu = 4.0 * entries + 2.0 * (nr + nc) * d
    elif stat == "l1dist":
        if l1_route == "mxu_signsplit":
            inner = 2.0 * d * max(int(segments), 1)
            mxu = 2.0 * 2.0 * inner * entries          # two contractions
            vpu = 6.0 * (nr + nc) * inner              # VMEM embeddings
        else:
            mxu = 0.0
            vpu = 4.0 * d * entries                    # the reference loop
    else:  # pragma: no cover - specs validate stat
        raise ValueError(f"unknown stat {stat!r}")
    mxu += 2.0 * float(m_total) * entries              # K-tile @ V
    vpu += 8.0 * entries                               # entry_fn
    point_bytes = 2 if getattr(spec, "precision", "f32") != "f32" else 4
    gbytes = ((nr + nc) * d * point_bytes
              + (nc + nr) * m_total * 4.0) / 1e9
    return {"mxu_gflops": mxu / 1e9, "vpu_gflops": vpu / 1e9,
            "hbm_gbytes": gbytes}


def achieved_vs_roofline(spec, shape, mesh=None, *, measured_s: float,
                         m_total: int, l1_route: Optional[str] = None,
                         segments: int = 0,
                         profile: Optional[HardwareProfile] = None) -> dict:
    """Score one measured pairwise launch against its modeled roofline.

    ``shape`` is ``(nr, nc, d)`` for the launch; ``mesh`` (optional) divides
    the modeled work across its devices like the sharded sweep does.
    Returns a JSON-ready report: modeled compute/memory seconds under
    ``profile`` (``default_profile()`` when omitted — so CI's CPU-interpret
    numbers are scored against CPU peaks, not v5e's), the binding term, and
    ``achieved_frac`` = roofline_s / measured_s (1.0 means the launch runs
    at the modeled roof; interpret-mode values are tiny and that is the
    honest answer).
    """
    prof = default_profile() if profile is None else profile
    nr, nc, d = (int(x) for x in shape)
    chips = 1
    if mesh is not None and getattr(mesh, "devices", None) is not None:
        chips = max(1, int(mesh.devices.size))
    model = pairwise_launch_model(spec, nr, nc, d, m_total,
                                  l1_route=l1_route, segments=segments)
    compute_s = (model["mxu_gflops"] + model["vpu_gflops"]) * 1e9 / (
        chips * prof.peak_flops)
    memory_s = model["hbm_gbytes"] * 1e9 / (chips * prof.hbm_bw)
    roofline_s = max(compute_s, memory_s)
    return {
        "kernel": spec.name,
        "stat": spec.stat,
        "precision": getattr(spec, "precision", "f32"),
        "l1_route": l1_route,
        "shape": [nr, nc, d],
        "m_total": int(m_total),
        "chips": chips,
        "profile": prof.name,
        **{k: float(v) for k, v in model.items()},
        "compute_s": float(compute_s),
        "memory_s": float(memory_s),
        "bottleneck": "compute" if compute_s >= memory_s else "memory",
        "roofline_s": float(roofline_s),
        "measured_s": float(measured_s),
        "achieved_frac": float(roofline_s / measured_s)
        if measured_s > 0 else 0.0,
    }


# ---------------------------------------------------------------------------
# report aggregation
# ---------------------------------------------------------------------------

def format_table(rows: List[dict]) -> str:
    hdr = (f"{'arch':<18} {'shape':<12} {'mesh':<9} {'GB/chip':>8} "
           f"{'compute_s':>10} {'memory_s':>10} {'coll_s':>10} "
           f"{'bound':>7} {'useful%':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<9} "
            f"{r['bytes_per_chip']/1e9:>8.2f} "
            f"{r['compute_s']:>10.4f} {r['memory_s']:>10.4f} "
            f"{r['collective_s']:>10.4f} {r['bottleneck']:>7.7s} "
            f"{100*r['useful_flops_frac']:>7.1f}%")
    return "\n".join(lines)


def main(argv=None):
    import argparse
    import glob
    p = argparse.ArgumentParser()
    p.add_argument("--glob", default="results/dryrun/*.json")
    args = p.parse_args(argv)
    rows = []
    for f in sorted(glob.glob(args.glob)):
        with open(f) as fh:
            rows.append(json.load(fh))
    print(format_table(rows))


if __name__ == "__main__":
    main()
