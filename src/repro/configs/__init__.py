"""Architecture registry: the 10 assigned archs x 4 input shapes (40 cells).

``get_config(name)`` / ``get_smoke(name)`` return the exact published config
(or its reduced smoke twin).  ``config_for_shape`` applies per-cell variants
(e.g. gemma3 + long_500k enables the paper's landmark decode on the global
layers).  ``cells()`` enumerates every (arch, shape) dry-run cell, honouring
the long_500k skip rule for pure full-attention archs.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Iterator, List, Tuple

from repro.configs.base import (  # noqa: F401
    LONG_CONTEXT_OK,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    input_specs,
)

_MODULES = {
    "xlstm-125m": "repro.configs.xlstm_125m",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "minitron-4b": "repro.configs.minitron_4b",
    "yi-9b": "repro.configs.yi_9b",
    "yi-6b": "repro.configs.yi_6b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

ARCHS: List[str] = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCHS}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str) -> ModelConfig:
    return _module(name).FULL


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def config_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Per-cell config variants.

    - long_500k on gemma3: global layers decode through the paper's landmark
      (fast-SPSD) attention — the full KV cache for 500k tokens would be
      quadratic-time to attend and the landmark state is O(c) instead.
    - decode cells on MoE archs keep the gather dispatch (token batch of 1
      per step does not amortize an all_to_all).
    """
    if shape.name == "long_500k" and cfg.name.startswith("gemma3"):
        return dataclasses.replace(cfg, use_landmark_decode=True)
    return cfg


def shapes_for(name: str) -> List[ShapeConfig]:
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and name not in LONG_CONTEXT_OK:
            continue
        out.append(s)
    return out


def cells() -> Iterator[Tuple[str, ShapeConfig]]:
    for a in ARCHS:
        for s in shapes_for(a):
            yield a, s
