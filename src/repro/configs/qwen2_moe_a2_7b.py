"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
(hf:Qwen/Qwen1.5-MoE-A2.7B).

24L d_model=2048 16H (kv=16) moe_d_ff=1408 vocab=151936.  Every layer is
MoE; the 4 shared experts mirror the checkpoint's 5632-wide shared block as
4x1408.  ``long_500k`` skipped (full attention).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=151_936,
    n_experts=60, n_shared_experts=4, moe_top_k=4, moe_d_ff=1408,
    rope_theta=1_000_000.0,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab_size=512,
    n_experts=6, n_shared_experts=2, moe_top_k=2, moe_d_ff=48,
)
