"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.  Alternating mLSTM/sLSTM
blocks; the blocks carry their own up/down projections so there is no
separate MLP (d_ff=0).  long_500k runs natively: both mixers are recurrent
(O(1) state per token).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50304,
    layer_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
    mlstm_chunk=256,
)

SMOKE = ModelConfig(
    name="xlstm-125m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab_size=512,
    layer_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
    mlstm_chunk=32,
)
