"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 + MTP
(arXiv:2412.19437).

61L d_model=7168 128H d_ff(moe)=2048 vocab=129280; first 3 layers dense
(d_ff=18432); MLA q_lora=1536 kv_lora=512 nope=128 rope=64 v=128.

Memory adaptation for v5e-16GB (DESIGN.md §6): parameters live in bf16 and
training uses adafactor (factored stats) — full f32 AdamW state for 671B
params cannot fit 256x16GB; with EP(model) x ZeRO-3(data) sharding the bf16
weights are ~5.3 GB/chip on the single-pod mesh.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=0, vocab_size=129_280,
    n_experts=256, n_shared_experts=1, moe_top_k=8, moe_d_ff=2048,
    first_k_dense=3, dense_d_ff=18432,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    mtp=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab_size=512,
    n_experts=8, n_shared_experts=1, moe_top_k=2, moe_d_ff=48,
    first_k_dense=1, dense_d_ff=128,
    use_mla=True, q_lora_rank=24, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    mtp=True,
)
