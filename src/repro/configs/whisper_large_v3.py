"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed
(arXiv:2212.04356).

32L (x2: 32 enc + 32 dec) d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
The conv1d+GELU frontend is a STUB: ``input_specs()`` provides precomputed
128-mel frame embeddings (frontend_dim=128) projected into d_model.  The
decoder self-attends causally and cross-attends to the encoder output.
Decode shapes put ``seq_len`` in the *encoder* (cross-attention KV); the
decoder's own cache is the standard 448 positions.  ``long_500k`` skipped
(enc-dec).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51_866,
    is_encdec=True, n_enc_layers=32, n_dec_layers=32, frontend_dim=128,
    mlp_variant="gelu",
)

SMOKE = ModelConfig(
    name="whisper-large-v3-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    is_encdec=True, n_enc_layers=2, n_dec_layers=2, frontend_dim=16,
    mlp_variant="gelu",
)
