"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2
(arXiv:2402.19427, Griffin).

26L d_model=2560 10H (kv=1, MQA) d_ff=7680 vocab=256000.  Pattern
(rglru, rglru, local) x8 + (rglru, rglru) remainder = 26 layers; window
2048.  ``long_500k`` runs natively: RG-LRU state is O(1)/token and the
attention window is bounded.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048, lru_width=2560,
    rope_theta=10_000.0,
    tie_embeddings=True, scale_embed=True,
    mlp_variant="geglu",
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=192, vocab_size=512,
    layer_pattern=("rglru", "rglru", "local"),
    window=16, lru_width=64,
    tie_embeddings=True, scale_embed=True,
    mlp_variant="geglu",
)
