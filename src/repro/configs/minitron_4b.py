"""minitron-4b [dense] — width-pruned Nemotron-4 (arXiv:2407.14679).

32L d_model=3072 24H (kv=8) d_ff=9216 vocab=256000.  Plain GQA decoder with
squared-relu MLP (nemotron family).  Pure full attention: ``long_500k`` is
skipped (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256_000,
    mlp_variant="relu2",
    rope_theta=10_000.0,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="minitron-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512,
    mlp_variant="relu2",
)
