"""yi-9b [dense] — llama-arch GQA (arXiv:2403.04652).

48L d_model=4096 32H (kv=4) d_ff=11008 vocab=64000.  (Yi-9B is the
depth-upscaled Yi-6B: same width, 48 layers.)  Pure full attention:
``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64_000,
    rope_theta=10_000.0,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="yi-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=512,
)
