"""chameleon-34b [vlm] — early-fusion VQ image tokens (arXiv:2405.09818).

48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536.  The modality frontend
is a STUB per the assignment: ``input_specs()`` provides precomputed patch
embeddings that are fused into the leading token positions (early fusion).
QK-norm on (chameleon's divergence fix).  ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65_536,
    qk_norm=True,
    rope_theta=10_000.0,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="chameleon-34b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=512,
    qk_norm=True,
)
