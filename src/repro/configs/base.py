"""Config schema: architectures x input shapes (the 40 assigned cells).

``ModelConfig`` is the single source of truth a model is built from; every
assigned architecture is one instance in ``repro/configs/<id>.py``.  A
``ShapeConfig`` names one of the four assigned input shapes.  ``input_specs``
produces ShapeDtypeStruct stand-ins (no allocation) for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

MIXER_KINDS = ("attn", "local", "global", "mlstm", "slstm", "rglru", "xattn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    qk_norm: bool = False
    window: Optional[int] = None          # sliding-window size for 'local'
    layer_pattern: Tuple[str, ...] = ("attn",)
    attn_impl: str = "xla"                # xla | pallas
    # landmark (paper fast-SPSD) attention for long-context decode
    landmark_c: int = 256
    landmark_theta: int = 4
    use_landmark_decode: bool = False     # global layers use LandmarkState cache
    landmark_selection: str = "strided"   # or a SelectionPolicy registry name

    # --- mlp ---
    mlp_variant: str = "swiglu"           # swiglu | geglu | relu2

    # --- moe ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0
    dense_d_ff: int = 0
    moe_impl: str = "gather"              # gather | shard_map

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = True               # absorbed (latent-space) decode path

    # --- heads / embeddings ---
    tie_embeddings: bool = False
    scale_embed: bool = False
    norm_eps: float = 1e-6
    post_norm: bool = False               # gemma-style sandwich norm
    mtp: bool = False                     # deepseek multi-token prediction

    # --- encoder-decoder (whisper) ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    frontend_dim: int = 0                 # stubbed modality frontend width

    # --- recurrent ---
    rglru_conv_width: int = 4
    lru_width: int = 0
    mlstm_chunk: int = 256

    # --- numerics / compilation ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"                   # none | full | dots
    scan_layers: bool = True
    unroll_scans: bool = False            # dry-run cost compiles: unroll the
                                          # q-block / mlstm-chunk scans so
                                          # HLO cost analysis counts them
    seq_parallel_attn: bool = False       # sequence-parallel attention for
                                          # heads-misfit archs (H % TP != 0):
                                          # shards q-positions over 'model'
                                          # instead of replicating compute
    chunk_q: int = 1024                   # q-block size of the chunked
                                          # (XLA-flash) attention; smaller
                                          # blocks shrink the f32 score-panel
                                          # transient at slightly worse MXU
                                          # utilization
    fsdp: bool = False                    # also shard embed/ff dims over data
    logits_softcap: Optional[float] = None

    # ----- derived -----
    @property
    def pattern_repeats(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def pattern_remainder(self) -> Tuple[str, ...]:
        rem = self.n_layers % len(self.layer_pattern)
        return self.layer_pattern[:rem]

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                    # embed
        if not self.tie_embeddings:
            total += v * d                               # unembed
        for i, kind in enumerate(
                [self.layer_pattern[j % len(self.layer_pattern)]
                 for j in range(self.n_layers)]):
            total += self._mixer_params(kind) + self._mlp_params(i)
            total += 2 * d                               # two norms
        if self.is_encdec:
            # decoder self+cross blocks
            for _ in range(self.n_dec_layers):
                total += 2 * self._mixer_params("attn") + self._mlp_params(0)
                total += 3 * d
        return int(total)

    def _mixer_params(self, kind: str) -> int:
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        if kind in ("attn", "local", "global", "xattn"):
            if self.use_mla:
                qp = d * self.q_lora_rank + self.q_lora_rank * h * (
                    self.qk_nope_dim + self.qk_rope_dim)
                kvp = d * (self.kv_lora_rank + self.qk_rope_dim)
                kvp += self.kv_lora_rank * h * (self.qk_nope_dim + self.v_head_dim)
                op = h * self.v_head_dim * d
                return qp + kvp + op
            return d * h * hd + 2 * d * kv * hd + h * hd * d
        if kind == "mlstm":
            dq = h * hd
            return d * 2 * dq + 2 * dq * hd * 0 + 3 * d * dq + dq * d  # approx
        if kind == "slstm":
            return 4 * d * h * hd + 4 * h * hd * hd // max(h, 1)
        if kind == "rglru":
            w = self.lru_width or d
            return 2 * d * w + w * self.rglru_conv_width + 2 * w * w + w * d
        return 0

    def _mlp_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.n_experts and layer_idx >= self.first_k_dense:
            e = self.n_experts * 3 * d * self.moe_d_ff
            e += self.n_shared_experts * 3 * d * self.moe_d_ff
            e += d * self.n_experts                      # router
            return e
        ff = self.dense_d_ff if (self.n_experts and layer_idx < self.first_k_dense) \
            else self.d_ff
        if ff == 0:
            return 0
        mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        return mult * d * ff

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        inactive = (self.n_experts - self.moe_top_k) * 3 * d * self.moe_d_ff
        n_moe_layers = self.n_layers - self.first_k_dense
        return int(total - n_moe_layers * inactive)


# ---------------------------------------------------------------------------
# Input shapes (the four assigned cells per arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs that can run long_500k (sub-quadratic path exists)
LONG_CONTEXT_OK = {"xlstm-125m", "recurrentgemma-2b", "gemma3-12b"}


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  : {tokens (B, S) i32, labels (B, S) i32}  [+ frontend embeds]
    prefill: {tokens (B, S) i32}
    decode : {tokens (B, 1) i32, pos () i32} + cache specs (built separately)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.is_encdec:
        # stubbed conv frontend: precomputed frame embeddings, S frames,
        # decoder length S_dec = S // 8 (mechanical; documented in DESIGN.md)
        s_dec = max(S // 8, 1)
        if shape.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), cfg.cdtype),
                "tokens": jax.ShapeDtypeStruct((B, s_dec), i32),
                "labels": jax.ShapeDtypeStruct((B, s_dec), i32),
            }
        if shape.kind == "prefill":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), cfg.cdtype),
                "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.family == "vlm" and shape.kind == "train":
        # early fusion: a fixed budget of patch embeddings is prepended
        # (stub frontend); here they are part of the token stream already
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "patches": jax.ShapeDtypeStruct((B, 256, cfg.d_model), cfg.cdtype),
        }
    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}
