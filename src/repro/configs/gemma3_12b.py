"""gemma3-12b [dense] — 5:1 local:global interleave, 128k context
(hf:google/gemma-3-12b-pt family numbers as assigned).

48L d_model=3840 16H (kv=8) d_ff=15360 vocab=262144.  head_dim=256
(attention inner dim 4096 != d_model, as in the released checkpoints);
sliding window 1024 on local layers; global layers use rope_theta=1M vs
10k local; qk-norm on.  ``long_500k`` swaps the global layers' decode path
to the paper's landmark (fast-SPSD) attention — see configs/__init__.py
``config_for_shape``.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
    qk_norm=True, post_norm=True, tie_embeddings=True, scale_embed=True,
    landmark_c=512, landmark_theta=4,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=16,
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
    qk_norm=True, post_norm=True, tie_embeddings=True, scale_embed=True,
    landmark_c=8, landmark_theta=2,
)
