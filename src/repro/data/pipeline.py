"""Deterministic, restartable data pipeline.

Two sources behind one interface:

- ``SyntheticLM``  : a counter-based PRNG token stream (zipfian unigrams mixed
                     with a repeated-ngram process so the loss actually moves)
                     — fully deterministic in (seed, step), so a restore at
                     step k reproduces exactly the batches a non-failed run
                     would have seen (the fault-tolerance contract).
- ``BinCorpus``    : memmapped flat token file (one uint16/uint32 token per
                     entry), sliced into (B, S+1) windows by the same
                     counter-based indexing.

Sharding: each host materializes only its slice of the global batch
(``host_batch_slice``) and hands jax a global array via
``jax.make_array_from_process_local_data`` (multi-host) or the whole batch
(single-host / dry-run).  ``DataState`` is just the step counter — it is
stored inside the checkpoint, which is what makes the iterator restartable.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np


class DataState(NamedTuple):
    step: jnp.ndarray                 # () int32 — the only iterator state


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram_period: int = 16

    def batch_at(self, step: int) -> dict:
        """The full global batch for ``step`` (numpy, host-side)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(step)]))
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # zipfian unigrams (clipped into vocab)
        toks = rng.zipf(self.zipf_a, size=(B, S + 1)).astype(np.int64)
        toks = (toks - 1) % V
        # inject learnable structure: every row repeats its first ngram_period
        # tokens with period ngram_period over a random half of positions
        period = self.ngram_period
        idx = np.arange(S + 1) % period
        repeats = toks[:, :period][np.arange(B)[:, None], idx]
        gate = rng.random((B, S + 1)) < 0.5
        toks = np.where(gate, repeats, toks).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class BinCorpus:
    """Flat binary token file; one window per (step, row)."""
    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"

    def __post_init__(self):
        object.__setattr__(self, "_tokens",
                           np.memmap(self.path, dtype=self.dtype, mode="r"))

    @property
    def n_tokens(self) -> int:
        return int(self._tokens.shape[0])

    def batch_at(self, step: int) -> dict:
        B, S = self.global_batch, self.seq_len
        n_windows = max((self.n_tokens - 1) // S, 1)
        base = (step * B) % n_windows
        rows = []
        for b in range(B):
            w = (base + b) % n_windows
            seg = np.asarray(self._tokens[w * S: w * S + S + 1],
                             dtype=np.int64)
            if seg.shape[0] < S + 1:                     # wrap at EOF
                seg = np.concatenate(
                    [seg, np.asarray(self._tokens[: S + 1 - seg.shape[0]],
                                     dtype=np.int64)])
            rows.append(seg % self.vocab_size)
        toks = np.stack(rows).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def host_batch_slice(batch: dict, process_index: int, process_count: int
                     ) -> dict:
    """The rows of the global batch this host is responsible for."""
    def sl(x):
        B = x.shape[0]
        per = B // process_count
        return x[process_index * per:(process_index + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


def make_pipeline(kind: str, *, vocab_size: int, seq_len: int,
                  global_batch: int, seed: int = 0,
                  path: Optional[str] = None):
    if kind == "synthetic":
        return SyntheticLM(vocab_size=vocab_size, seq_len=seq_len,
                           global_batch=global_batch, seed=seed)
    if kind == "bin":
        assert path is not None
        return BinCorpus(path=path, vocab_size=vocab_size, seq_len=seq_len,
                         global_batch=global_batch)
    raise ValueError(f"unknown pipeline kind {kind!r}")
