from repro.data.pipeline import (  # noqa: F401
    DataState,
    SyntheticLM,
    host_batch_slice,
    make_pipeline,
)
