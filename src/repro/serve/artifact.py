"""KernelModelArtifact: the warm-boot factor store of the serving path.

After ``fast_model`` there is everything a replica needs to answer queries
*forever* without touching the n × n kernel again: the landmark points
X_S = X[P], the C basis K(X, X_S), the fast U, and small dense "heads" that
turn one rectangular cross-kernel launch G = K(X_query, X_S) into each
downstream answer:

- KRR prediction      f(x) = G  @ head_krr,   head = U Cᵀ w        (c × t)
- KPCA projection     z(x) = G  @ head_kpca,  head = U Cᵀ V Λ^-½   (c × k)
- Nyström features    φ(x) = G  @ head_feat,  head = E_r Λ_U,r^½   (c × r)

all derived from the Nyström out-of-sample extension of the fast model,
k̂(x, ·) = K(x, X_S) U Cᵀ (rows of C *are* K(x_i, X_S), so train points
round-trip exactly).  The KRR weights come from the cached
``woodbury_solve`` route, and the (c × c) Woodbury workspace
M = U (αI + CᵀC U)⁻¹ is kept on the artifact so re-fitting NEW targets on
the same kernel is two thin matmuls (``refit``), never another solve.

Persistence rides ``repro.checkpoint``: the artifact flattens to a
JSON-style dict tree (arrays + one ``meta_json`` string leaf for the
KernelSpec / selection metadata), committed atomically per step so replicas
boot warm from ``load_artifact`` — a fresh process needs no shape knowledge
(``checkpoint.restore_tree`` reconstructs from the manifest).  Damage is
detected as ``CheckpointCorruptionError`` and handled by
``load_or_rebuild`` through ``runtime.fault_tolerance.ArtifactRecovery``:
rebuild from source, persist, keep serving.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import eig as eig_lib
from repro.core import spsd
from repro.core.kernelop import PairwiseKernel
from repro.kernels.pairwise import specs as pw_specs
from repro.runtime.fault_tolerance import ArtifactRecovery

#: the query tasks the engine can answer; head matrices are keyed by these
TASKS = ("krr", "kpca", "features")


@dataclasses.dataclass
class KernelModelArtifact:
    """Everything ``serve_kernel_model`` needs, independent of train-set size
    at query time (heads are c × out; only ``C`` keeps an n-sized factor, for
    target re-fits and diagnostics)."""

    X_landmarks: jnp.ndarray            # (c, d) selected points X[P]
    C: jnp.ndarray                      # (n, c) basis K(X, X_S)
    U: jnp.ndarray                      # (c, c) fast-model U
    heads: Dict[str, jnp.ndarray]       # task -> (c, out_dim)
    woodbury_M: jnp.ndarray             # (c, c) cached U (αI + CᵀC U)⁻¹
    kpca_eigvals: jnp.ndarray           # (k,) spectrum of the KPCA head
    spec: pw_specs.KernelSpec           # calibrated kernel spec
    alpha: float                        # KRR ridge
    selection: str = "uniform"          # SelectionPolicy that chose P
    landmark_indices: Optional[jnp.ndarray] = None
    use_pallas: bool = True
    # sign-split plan for l1dist specs, built ONCE over the landmark points
    # at precompute time and persisted with the artifact: l1_route is
    # 'mxu_signsplit' (l1_edges holds the segment table), 'vpu_loop' (plan
    # infeasible — the VPU decision itself is replicated), or None
    # (non-l1dist spec, or a legacy checkpoint from before the field — the
    # operator falls back to its lazy per-instance build)
    l1_edges: Optional[jnp.ndarray] = None
    l1_route: Optional[str] = None

    @property
    def c(self) -> int:
        return int(self.X_landmarks.shape[0])

    def landmark_operator(self, use_pallas: Optional[bool] = None,
                          precision: Optional[str] = None) -> PairwiseKernel:
        """The data-backed operator query launches run through: a
        ``PairwiseKernel`` over the landmark points, so
        ``op.cross(X_query, heads)`` is K(X_query, X_S) @ head per head in
        one fused rectangular launch.  ``precision`` overrides the spec's
        tile policy for query-time launches (e.g. ``'bf16_f32acc'`` to serve
        an f32-built artifact with bf16 cross tiles)."""
        up = self.use_pallas if use_pallas is None else use_pallas
        spec = self.spec
        if precision is not None:
            spec = spec.with_precision(precision)
        op = PairwiseKernel(self.X_landmarks, spec, up)
        if self.l1_route is not None and spec.stat == "l1dist":
            # restore the precomputed sign-split plan instead of letting the
            # operator rebuild it host-side per instance (ROADMAP gap); a
            # persisted 'vpu_loop' decision seeds None so routing is
            # byte-identical to build time
            op._l1_edges_cache = \
                self.l1_edges if self.l1_route == "mxu_signsplit" else None
        return op

    def refit(self, y: jnp.ndarray) -> "KernelModelArtifact":
        """New KRR targets on the SAME kernel via the cached Woodbury
        workspace: w = (y − C M Cᵀ y)/α, head = U Cᵀ w — two thin matmuls,
        no solve.  Returns a copy with ``heads['krr']`` replaced."""
        y2 = (y[:, None] if y.ndim == 1 else y).astype(jnp.float32)
        C32 = self.C.astype(jnp.float32)
        w = (y2 - C32 @ (self.woodbury_M @ (C32.T @ y2))) / self.alpha
        heads = dict(self.heads)
        heads["krr"] = self.U.astype(jnp.float32) @ (C32.T @ w)
        return dataclasses.replace(self, heads=heads)


def _meta(artifact: KernelModelArtifact) -> str:
    return json.dumps({
        "spec_name": artifact.spec.name,
        "spec_params": list(artifact.spec.params),
        "spec_precision": artifact.spec.precision,
        "alpha": float(artifact.alpha),
        "selection": artifact.selection,
        "use_pallas": bool(artifact.use_pallas),
        "l1_route": artifact.l1_route,
        "format": 1,
    })


def artifact_to_tree(artifact: KernelModelArtifact) -> dict:
    """The JSON-style dict tree ``checkpoint.save`` persists (and
    ``checkpoint.restore_tree`` reconstructs shape-free)."""
    tree = {
        "X_landmarks": artifact.X_landmarks,
        "C": artifact.C,
        "U": artifact.U,
        "heads": dict(artifact.heads),
        "woodbury_M": artifact.woodbury_M,
        "kpca_eigvals": artifact.kpca_eigvals,
        "meta_json": _meta(artifact),
    }
    if artifact.landmark_indices is not None:
        tree["landmark_indices"] = artifact.landmark_indices
    if artifact.l1_edges is not None:
        tree["l1_edges"] = artifact.l1_edges
    return tree


def artifact_from_tree(tree: dict) -> KernelModelArtifact:
    meta = json.loads(str(np.asarray(tree["meta_json"]).item()))
    spec = pw_specs.get_spec(meta["spec_name"],
                             **{k: v for k, v in meta["spec_params"]})
    # precision is a spec field, not a factory param, so artifacts written
    # before the field existed restore as f32 (the old behavior)
    spec = spec.with_precision(meta.get("spec_precision", "f32"))
    idx = tree.get("landmark_indices")
    edges = tree.get("l1_edges")
    return KernelModelArtifact(
        X_landmarks=jnp.asarray(tree["X_landmarks"]),
        C=jnp.asarray(tree["C"]),
        U=jnp.asarray(tree["U"]),
        heads={k: jnp.asarray(v) for k, v in tree["heads"].items()},
        woodbury_M=jnp.asarray(tree["woodbury_M"]),
        kpca_eigvals=jnp.asarray(tree["kpca_eigvals"]),
        spec=spec,
        alpha=float(meta["alpha"]),
        selection=meta["selection"],
        landmark_indices=None if idx is None else jnp.asarray(idx),
        use_pallas=bool(meta["use_pallas"]),
        # legacy checkpoints carry no l1_route key -> None -> the operator's
        # lazy per-instance plan build (the pre-field behavior)
        l1_edges=None if edges is None else jnp.asarray(edges),
        l1_route=meta.get("l1_route"),
    )


# ---------------------------------------------------------------------------
# build (training side)
# ---------------------------------------------------------------------------

def build_artifact(
    X: jnp.ndarray,
    y: jnp.ndarray,
    spec: pw_specs.KernelSpec,
    c: int,
    s: int,
    *,
    alpha: float = 1.0,
    n_components: int = 8,
    n_features: Optional[int] = None,
    s_sketch: str = "gaussian",
    selection: str = "uniform",
    key: Optional[jax.Array] = None,
    use_pallas: bool = True,
    block_size: Optional[int] = None,
    mesh=None,
) -> KernelModelArtifact:
    """Algorithm 1 + every downstream head, once, at precompute time.

    Runs ``fast_model`` on the streaming substrate (``selection`` /
    ``mesh`` / ``block_size`` thread straight through), then derives the
    KRR weights via ``woodbury_solve``'s identity — keeping its (c × c)
    workspace for ``refit`` — the KPCA head from ``approx_eigh`` (Lemma 10),
    and the rank-``n_features`` Nyström feature head from the
    eigendecomposition of U.
    """
    key = jax.random.PRNGKey(0) if key is None else key
    X = jnp.asarray(X, jnp.float32)
    Kop = PairwiseKernel(X, spec, use_pallas)
    ap = spsd.fast_model(Kop, key, c=c, s=s, s_sketch=s_sketch,
                         selection=selection, block_size=block_size,
                         mesh=mesh)
    C32 = ap.C.astype(jnp.float32)
    U32 = 0.5 * (ap.U + ap.U.T).astype(jnp.float32)

    # KRR: w from the Woodbury identity, workspace cached for refits.  The
    # build-time algebra runs in f64 numpy (offline, host-side) so the f32
    # heads it emits are true-solution-accurate — the serving parity gate
    # (≤1e-5 vs the dense oracle) then measures only f32 rounding plus the
    # Pallas cross launch, not solver conditioning.
    a = float(alpha)
    if not (a > 0.0 and np.isfinite(a)):
        raise ValueError(f"alpha must be a finite positive ridge, got {a!r}")
    C64 = np.asarray(C32, np.float64)
    U64 = np.asarray(U32, np.float64)
    inner = a * np.eye(c) + (C64.T @ C64) @ U64
    M64 = U64 @ np.linalg.solve(inner, np.eye(c))
    y64 = np.asarray(y[:, None] if y.ndim == 1 else y, np.float64)
    w64 = (y64 - C64 @ (M64 @ (C64.T @ y64))) / a    # = woodbury_solve(C,U,a,y)
    head_krr = jnp.asarray(U64 @ (C64.T @ w64), jnp.float32)   # (c, t)
    M = jnp.asarray(M64, jnp.float32)

    # KPCA: z(x) = Λ^-½ Vᵀ k̂(x,·)ᵀ = K(x,X_S) · U Cᵀ V Λ^-½
    eres = eig_lib.approx_eigh(C32, U32, n_components)
    lam = jnp.maximum(eres.eigenvalues, 1e-12)
    head_kpca = U32 @ (C32.T @ eres.eigenvectors) / jnp.sqrt(lam)[None, :]

    # Nyström feature map: U = E Λ_U Eᵀ ⇒ φ(x) = Λ_U,r^½ E_rᵀ K(x,X_S)ᵀ
    r = c if n_features is None else min(int(n_features), c)
    lam_u, E = jnp.linalg.eigh(U32)                  # ascending
    lam_u = jnp.maximum(lam_u[::-1], 0.0)
    E = E[:, ::-1]
    head_feat = E[:, :r] * jnp.sqrt(lam_u[:r])[None, :]

    # Sign-split plan for the landmark operator, built once here (host-side
    # pass over the c landmark points) and persisted with the artifact so
    # warm-booted replicas and every landmark_operator() instance share it
    # instead of rebuilding per instance.
    X_land = jnp.take(X, ap.P_indices, axis=0)
    l1_edges, l1_route = None, None
    if spec.stat == "l1dist":
        from repro.kernels.pairwise import signsplit
        plan = signsplit.build_plan(X_land)
        l1_edges = None if plan is None else plan.edges
        l1_route = "vpu_loop" if plan is None else "mxu_signsplit"

    return KernelModelArtifact(
        X_landmarks=X_land,
        C=C32, U=U32,
        heads={"krr": head_krr, "kpca": head_kpca, "features": head_feat},
        woodbury_M=M, kpca_eigvals=eres.eigenvalues,
        spec=spec, alpha=a, selection=str(selection),
        landmark_indices=ap.P_indices, use_pallas=use_pallas,
        l1_edges=l1_edges, l1_route=l1_route)


# ---------------------------------------------------------------------------
# persistence (checkpoint/ + fault-tolerance recompute hook)
# ---------------------------------------------------------------------------

def save_artifact(directory: str, artifact: KernelModelArtifact,
                  step: int = 0) -> str:
    """Atomically commit the artifact as checkpoint ``step`` (refresh
    generations bump the step; replicas always boot the latest)."""
    return ckpt.save(directory, step, artifact_to_tree(artifact))


def load_artifact(directory: str,
                  step: Optional[int] = None) -> Optional[KernelModelArtifact]:
    """Latest (or pinned) committed artifact, or None when none exists.

    Delta-chain aware: when the target step is an incremental refresh
    generation (``delta_json`` leaf, see ``repro.serve.incremental``), the
    chain is replayed onto its base snapshot — a warm boot lands on the
    LIVE grown artifact, not the last full rebuild.  File-level damage and
    broken chains raise ``CheckpointCorruptionError`` — callers that must
    keep serving go through ``load_or_rebuild`` instead."""
    if step is None:
        step = ckpt.latest_step(directory)
        if step is None:
            return None
    # peek the step KIND from the manifest alone before choosing a decoder
    # (a delta tree has no meta_json leaf and would mis-classify as corrupt)
    if "delta_json" in ckpt.step_leaf_paths(directory, step):
        from repro.serve import incremental
        return incremental.load_artifact_chain(directory, step)
    tree = ckpt.restore_tree(directory, step)
    try:
        return artifact_from_tree(tree)
    except (KeyError, ValueError, TypeError, json.JSONDecodeError) as e:
        raise ckpt.CheckpointCorruptionError(
            f"artifact at {directory} step {step} does not decode "
            f"({type(e).__name__}: {e})") from e


def load_or_rebuild(
    directory: str,
    build_fn,
    recovery: Optional[ArtifactRecovery] = None,
    step: int = 0,
) -> Tuple[KernelModelArtifact, ArtifactRecovery]:
    """Warm boot with the recompute-on-corruption policy.

    ``build_fn()`` recreates the artifact from source data; it only runs
    when the store is missing or damaged, and its output is persisted so the
    next replica boots warm.  Returns ``(artifact, recovery)`` — inspect
    ``recovery.warm`` / ``recovery.events`` to distinguish warm from cold
    boots (the serve-smoke CI job requires warm).
    """
    if recovery is None:
        recovery = ArtifactRecovery(
            corruption_types=(ckpt.CheckpointCorruptionError,))
    out = recovery.run(
        load=lambda: load_artifact(directory),
        rebuild=build_fn,
        save=lambda a: save_artifact(directory, a, step=step))
    return out, recovery
