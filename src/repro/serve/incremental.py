"""Incremental artifact maintenance: absorb appended rows in O(b·c).

A served corpus grows; a full ``build_artifact`` recompute to absorb a
b-row batch is exactly the cost profile the fast model exists to avoid.
This module maintains a live ``KernelModelArtifact`` under appends with
ONE thin rectangular kernel launch per batch and small-matrix algebra
everywhere else:

- **Extend (C, SᵀKS)**: the new rows' only kernel contribution is
  G = K(X_new, X_S) — a (b × c) block answered by the existing
  ``PairwiseKernel.cross`` launch shape (``append_cross`` on a
  ``CountingOperator`` meters it as ``append_sweeps``, so the O(b·c)
  claim is asserted, never assumed).  C grows by vstack; the cached f64
  Gram statistics grow by rank-b updates: CᵀC += GᵀG, Cᵀy += Gᵀy_new.
- **Refresh fast U**: a damped landmark-residual update
  U' = U + η·sym(G⁺ (G − G U W) W⁺), η = b/(n+b), rank ≤ 2b — zero when
  the model already explains the new rows (G ≈ G U W on the landmark
  block), and a Nyström-consistent correction otherwise.  W = K(X_S,X_S)
  and W⁺ are computed ONCE at state init (landmarks never change).
- **Refresh the Woodbury workspace M = U(αI + CᵀC U)⁻¹ by low-rank
  update, never a from-scratch c×c re-solve**: the inner matrix moves by
  Δinner = CᵀC·ΔU + GᵀG·U', an exactly-factored rank ≤ 3b perturbation,
  so inner⁻¹ follows by the Woodbury identity with one (3b × 3b) solve.
  Because the factorization is exact, the refreshed M (and the KRR head
  derived from the cached Gram statistics) matches the dense f64 oracle
  on the GROWN corpus to rounding — the same ≤1e-5 parity contract
  ``build_artifact`` honors.
- **Refresh every head from c×c statistics** (no O(n·c) recompute): KRR
  from Cᵀw = (Cᵀy − CᵀC·M·Cᵀy)/α; KPCA via eigh(CᵀC) — the Lemma-10
  ``approx_eigh`` basis without touching the n-sized C; features from
  eigh(U').
- **Checkpoint refresh generations as DELTA steps**: each append commits
  a small delta (G, y_new, refreshed c×c state) layered on the base full
  snapshot in the same versioned store; ``load_chain`` replays the chain
  bitwise-stable, ``gc_superseded_deltas`` removes chains a newer full
  snapshot (``compact``) obsoleted, and damage anywhere in the chain is
  classified as ``CheckpointCorruptionError``.
- **Staleness policy**: the streaming error estimate (the build-time
  Hutchinson metric, extended per generation with the appended-row
  residual ‖G − G U W‖_F) and the per-batch drift are tracked per
  refresh generation; past a configurable threshold the maintainer
  triggers a full re-sketch through ``ArtifactRecovery`` (event kind
  'stale'), compacts the store, and keeps serving.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.kernels.pairwise import specs as pw_specs
from repro.runtime.fault_tolerance import (
    ArtifactRecovery,
    ArtifactStaleError,
)
from repro.serve.artifact import (
    KernelModelArtifact,
    artifact_from_tree,
    artifact_to_tree,
)

_TINY = 1e-30


# ---------------------------------------------------------------------------
# state + policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IncrementalState:
    """The f64 host-side workspace ``append_rows`` updates in O(b·c²).

    Everything needed to refresh the artifact without touching the n-sized
    C again: the Gram statistics (CᵀC, Cᵀy), the inverse of the Woodbury
    inner matrix (maintained by rank-b updates after the ONE solve at
    init), the landmark Gram W = K(X_S, X_S) and its pseudo-inverse
    (static — landmarks never change), and the running error accumulators
    behind the per-generation staleness signal.
    """

    CtC: np.ndarray                 # (c, c) f64  CᵀC of the LIVE corpus
    Cty: np.ndarray                 # (c, t) f64  Cᵀy
    inner_inv: np.ndarray           # (c, c) f64  (αI + CᵀC U)⁻¹
    U64: np.ndarray                 # (c, c) f64  live fast U
    W: np.ndarray                   # (c, c) f64  K(X_S, X_S)
    W_pinv: np.ndarray              # (c, c) f64  W⁺ (computed once)
    alpha: float
    n: int                          # live corpus size
    generation: int = 0             # refresh generation (0 = base build)
    res_sq: float = 0.0             # Σ‖G − G U W‖_F² over generations
    gram_sq: float = 0.0            # Σ‖G‖_F² over generations
    error_est: float = 0.0          # streaming relative-residual estimate

    @property
    def c(self) -> int:
        return int(self.CtC.shape[0])


@dataclasses.dataclass(frozen=True)
class StalenessPolicy:
    """When landmark reuse stops being safe (Gittens & Mahoney 2013: the
    leverage structure drifts; Wang 2014 bounds when reuse is fine).

    - ``drift_threshold``: per-batch relative residual
      ‖G − G U W‖_F / ‖G‖_F above this triggers a re-sketch — the
      appended rows are not explained by the frozen landmark basis.
    - ``error_budget``: the cumulative streaming error estimate (the
      per-generation-tracked Hutchinson-style metric) above this triggers
      a re-sketch even when each individual batch looked tame.
    - ``max_generations``: hard cap on delta-chain length (0 = unlimited)
      — bounds warm-boot replay cost regardless of drift.
    """

    drift_threshold: float = 0.5
    error_budget: float = 0.5
    max_generations: int = 0

    def should_resketch(self, stats: "GenerationStats") -> Optional[str]:
        """A human-readable reason to re-sketch, or None to keep going."""
        if stats.drift > self.drift_threshold:
            return (f"batch drift {stats.drift:.4f} > "
                    f"threshold {self.drift_threshold}")
        if stats.error_est > self.error_budget:
            return (f"streaming error estimate {stats.error_est:.4f} > "
                    f"budget {self.error_budget}")
        if 0 < self.max_generations <= stats.generation:
            return (f"generation {stats.generation} reached "
                    f"max_generations {self.max_generations}")
        return None


@dataclasses.dataclass(frozen=True)
class GenerationStats:
    """What one ``append_rows`` did — the staleness policy's input and the
    bench/CI assertion surface."""

    generation: int
    n_before: int
    batch_rows: int
    n_after: int
    drift: float                    # ‖G − G U W‖_F / ‖G‖_F of THIS batch
    error_est: float                # cumulative streaming estimate
    resketch: bool = False
    resketch_reason: str = ""


def landmark_gram(artifact: KernelModelArtifact) -> np.ndarray:
    """W = K(X_S, X_S) in f64 — c² entries, computed ONCE per state init
    through the reference spec apply (exact route)."""
    W = pw_specs.apply(artifact.spec, artifact.X_landmarks,
                       artifact.X_landmarks)
    return np.asarray(W, np.float64)


def init_state(artifact: KernelModelArtifact, y) -> IncrementalState:
    """Build the f64 workspace from a (freshly built or warm-booted)
    artifact and its training targets.  This is the ONE place a from-scratch
    c×c solve happens; every subsequent refresh is a rank-b update."""
    a = float(artifact.alpha)
    C64 = np.asarray(artifact.C, np.float64)
    U64 = np.asarray(artifact.U, np.float64)
    c = C64.shape[1]
    y64 = np.asarray(y, np.float64)
    if y64.ndim == 1:
        y64 = y64[:, None]
    if y64.shape[0] != C64.shape[0]:
        raise ValueError(f"y has {y64.shape[0]} rows for an n="
                         f"{C64.shape[0]} artifact")
    CtC = C64.T @ C64
    Cty = C64.T @ y64
    inner = a * np.eye(c) + CtC @ U64
    inner_inv = np.linalg.solve(inner, np.eye(c))
    W = landmark_gram(artifact)
    return IncrementalState(
        CtC=CtC, Cty=Cty, inner_inv=inner_inv, U64=U64,
        W=W, W_pinv=np.linalg.pinv(W), alpha=a, n=int(C64.shape[0]))


# ---------------------------------------------------------------------------
# the append-row refresh
# ---------------------------------------------------------------------------

def _sym(A: np.ndarray) -> np.ndarray:
    return 0.5 * (A + A.T)


def _refresh_heads(state: IncrementalState, artifact: KernelModelArtifact,
                   ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """Every head from c×c statistics (n never enters).

    KRR: Cᵀw = (Cᵀy − CᵀC·M·Cᵀy)/α (the cached-workspace identity
    ``refit`` uses, in f64), head = U·Cᵀw.
    KPCA: with CᵀC = V Σ² Vᵀ, Q = C V Σ⁻¹ is orthonormal and
    C U Cᵀ = Q (Σ Vᵀ U V Σ) Qᵀ — eigh of that c×c core Z is exactly the
    Lemma-10 ``approx_eigh`` spectrum, and the head
    U·CᵀVec/√Λ = U·(CᵀC·V Σ⁻¹·V_Z)/√Λ needs only CᵀC.
    Features: eigh(U) as at build time (already n-independent).
    """
    a = state.alpha
    U64 = state.U64
    M64 = U64 @ state.inner_inv
    Ctw = (state.Cty - state.CtC @ (M64 @ state.Cty)) / a
    head_krr = U64 @ Ctw

    k = int(artifact.heads["kpca"].shape[1])
    sig2, V = np.linalg.eigh(state.CtC)                      # ascending
    sig2 = np.maximum(sig2, 0.0)
    cutoff = max(1, state.n) * np.finfo(np.float64).eps * \
        float(np.max(sig2, initial=0.0))
    sig = np.sqrt(np.maximum(sig2, _TINY))
    live = (sig2 > cutoff).astype(np.float64)
    VS = V * (sig * live)[None, :]                           # V Σ (dead→0)
    VSinv = V * (live / sig)[None, :]                        # V Σ⁻¹ (dead→0)
    Z = VS.T @ U64 @ VS
    lam, VZ = np.linalg.eigh(_sym(Z))                        # ascending
    order = np.argsort(lam)[::-1][:k]
    lam_k = np.maximum(lam[order], 1e-12)
    Vec_basis = VSinv @ VZ[:, order]                         # Cᵀ·Q V_Z = CᵀC·this
    head_kpca = U64 @ (state.CtC @ Vec_basis) / np.sqrt(lam_k)[None, :]

    r = int(artifact.heads["features"].shape[1])
    lam_u, E = np.linalg.eigh(_sym(U64))                     # ascending
    lam_u = np.maximum(lam_u[::-1], 0.0)
    E = E[:, ::-1]
    head_feat = E[:, :r] * np.sqrt(lam_u[:r])[None, :]

    heads = {"krr": jnp.asarray(head_krr, jnp.float32),
             "kpca": jnp.asarray(head_kpca, jnp.float32),
             "features": jnp.asarray(head_feat, jnp.float32)}
    return heads, jnp.asarray(lam_k, jnp.float32)


def append_rows(
    artifact: KernelModelArtifact,
    state: IncrementalState,
    X_new,
    y_new,
    op=None,
    refresh_u: bool = True,
) -> Tuple[KernelModelArtifact, IncrementalState, GenerationStats,
           "DeltaRecord"]:
    """Absorb a b-row batch with ONE thin rectangular launch.

    ``op`` is the landmark operator the launch runs through (defaults to
    ``artifact.landmark_operator()``); a ``CountingOperator`` meters the
    launch as ``append_sweeps`` via its ``append_cross`` hook — exactly one
    tick, b·c entries, zero panel sweeps, zero fulls.  Everything after the
    launch is f64 host-side algebra on c×c/b×c matrices, mirroring
    ``build_artifact``'s accuracy contract: the refreshed KRR head matches
    the dense f64 oracle on the grown corpus to f32 rounding.

    Returns ``(artifact', state', stats, delta)`` — the delta is the
    checkpointable refresh-generation record (``save_delta``).
    """
    if op is None:
        op = artifact.landmark_operator()
    X_new = jnp.asarray(X_new, jnp.float32)
    if X_new.ndim == 1:
        X_new = X_new[None, :]
    b = int(X_new.shape[0])
    c = state.c
    a = state.alpha

    # THE kernel access: G = K(X_new, X_S), one (b × c) rectangular launch.
    launch = getattr(op, "append_cross", op.cross)
    (G,) = launch(X_new, (jnp.eye(c, dtype=jnp.float32),))
    G32 = jnp.asarray(G, jnp.float32)
    G64 = np.asarray(G32, np.float64)

    y64 = np.asarray(y_new, np.float64)
    if y64.ndim == 1:
        y64 = y64[:, None]
    if y64.shape[0] != b:
        raise ValueError(f"y_new has {y64.shape[0]} rows for a {b}-row batch")

    # drift: how badly the frozen landmark basis explains the new rows
    # (on the landmark block, the model predicts K(x_new, X_S) ≈ G U W).
    R = G64 - G64 @ state.U64 @ state.W
    g_sq = float(np.sum(G64 * G64))
    r_sq = float(np.sum(R * R))
    drift = float(np.sqrt(r_sq / max(g_sq, _TINY)))

    # Gram statistics: exact rank-b updates.
    CtC2 = state.CtC + G64.T @ G64
    Cty2 = state.Cty + G64.T @ y64

    # fast-U refresh: damped symmetric landmark-residual correction,
    # exactly factored as P_f @ Q_f with rank ≤ 2b (zero when R = 0).
    if refresh_u and b > 0:
        eta = b / max(state.n + b, 1)
        M1 = np.linalg.pinv(G64)                       # (c, b)
        M2 = R @ state.W_pinv                          # (b, c)
        P_f = np.concatenate([M1, M2.T], axis=1)       # (c, 2b)
        Q_f = 0.5 * eta * np.concatenate([M2, M1.T], axis=0)   # (2b, c)
        U2 = state.U64 + P_f @ Q_f
        U2 = _sym(U2)
    else:
        P_f = np.zeros((c, 0))
        Q_f = np.zeros((0, c))
        U2 = state.U64

    # Woodbury workspace refresh WITHOUT a from-scratch c×c solve:
    # inner' − inner = CᵀC·ΔU + (GᵀG)·U' = P @ Q with rank ≤ 3b, so
    # inner'⁻¹ = inner⁻¹ − inner⁻¹P (I + Q inner⁻¹ P)⁻¹ Q inner⁻¹
    # — one (≤3b × ≤3b) solve.  The factorization is EXACT, so the
    # refreshed workspace equals the dense recompute to f64 rounding.
    P = np.concatenate([state.CtC @ P_f, G64.T], axis=1)       # (c, ≤3b)
    Q = np.concatenate([Q_f, G64 @ U2], axis=0)                # (≤3b, c)
    IP = state.inner_inv @ P
    cap = np.eye(P.shape[1]) + Q @ IP
    inner_inv2 = state.inner_inv - IP @ np.linalg.solve(cap, Q @ state.inner_inv)

    res_sq = state.res_sq + r_sq
    gram_sq = state.gram_sq + g_sq
    error_est = float(np.sqrt(res_sq / max(gram_sq, _TINY)))
    state2 = IncrementalState(
        CtC=CtC2, Cty=Cty2, inner_inv=inner_inv2, U64=U2,
        W=state.W, W_pinv=state.W_pinv, alpha=a, n=state.n + b,
        generation=state.generation + 1,
        res_sq=res_sq, gram_sq=gram_sq, error_est=error_est)

    heads, kpca_eigvals = _refresh_heads(state2, artifact)
    M32 = jnp.asarray(U2 @ inner_inv2, jnp.float32)
    artifact2 = dataclasses.replace(
        artifact,
        C=jnp.concatenate([artifact.C, G32], axis=0),
        U=jnp.asarray(U2, jnp.float32),
        heads=heads, woodbury_M=M32, kpca_eigvals=kpca_eigvals)

    stats = GenerationStats(
        generation=state2.generation, n_before=state.n, batch_rows=b,
        n_after=state2.n, drift=drift, error_est=error_est)
    y32 = jnp.asarray(y64, jnp.float32)
    delta = DeltaRecord(
        generation=state2.generation, base_step=0, G=G32, y_new=y32,
        U=artifact2.U, heads=dict(heads), woodbury_M=M32,
        kpca_eigvals=kpca_eigvals, n_after=state2.n, drift=drift,
        error_est=error_est,
        state={"CtC": CtC2, "Cty": Cty2, "inner_inv": inner_inv2, "U64": U2})
    return artifact2, state2, stats, delta


# ---------------------------------------------------------------------------
# delta checkpoints: refresh generations layered on the versioned store
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeltaRecord:
    """One refresh generation, checkpointable: the appended block (G,
    y_new — O(b·c)), the refreshed small matrices (so chain replay is
    BITWISE the live artifact, no recomputation), and the f64 maintainer
    state (so a fresh process resumes appending without re-solving)."""

    generation: int
    base_step: int
    G: jnp.ndarray                        # (b, c) f32: the appended C rows
    y_new: jnp.ndarray                    # (b, t) f32
    U: jnp.ndarray
    heads: Dict[str, jnp.ndarray]
    woodbury_M: jnp.ndarray
    kpca_eigvals: jnp.ndarray
    n_after: int
    drift: float
    error_est: float
    state: Dict[str, np.ndarray]          # f64 CtC/Cty/inner_inv/U64


def _delta_meta(delta: DeltaRecord) -> str:
    return json.dumps({
        "generation": int(delta.generation),
        "base_step": int(delta.base_step),
        "n_after": int(delta.n_after),
        "drift": float(delta.drift),
        "error_est": float(delta.error_est),
        "format": 1,
    })


def delta_to_tree(delta: DeltaRecord) -> dict:
    return {
        "delta_json": _delta_meta(delta),
        "G": delta.G,
        "y_new": delta.y_new,
        "U": delta.U,
        "heads": dict(delta.heads),
        "woodbury_M": delta.woodbury_M,
        "kpca_eigvals": delta.kpca_eigvals,
        "state": {k: np.asarray(v, np.float64)
                  for k, v in delta.state.items()},
    }


def delta_from_tree(tree: dict) -> DeltaRecord:
    try:
        meta = json.loads(str(np.asarray(tree["delta_json"]).item()))
        return DeltaRecord(
            generation=int(meta["generation"]),
            base_step=int(meta["base_step"]),
            G=jnp.asarray(tree["G"]),
            y_new=jnp.asarray(tree["y_new"]),
            U=jnp.asarray(tree["U"]),
            heads={k: jnp.asarray(v) for k, v in tree["heads"].items()},
            woodbury_M=jnp.asarray(tree["woodbury_M"]),
            kpca_eigvals=jnp.asarray(tree["kpca_eigvals"]),
            n_after=int(meta["n_after"]),
            drift=float(meta["drift"]),
            error_est=float(meta["error_est"]),
            state={k: np.asarray(v, np.float64)
                   for k, v in tree["state"].items()})
    except (KeyError, ValueError, TypeError, json.JSONDecodeError) as e:
        raise ckpt.CheckpointCorruptionError(
            f"delta step does not decode ({type(e).__name__}: {e})") from e


def is_delta_step(directory: str, step: int) -> bool:
    """Manifest-peek kind check: delta steps carry a ``delta_json`` leaf,
    full artifact snapshots carry ``meta_json``."""
    return "delta_json" in ckpt.step_leaf_paths(directory, step)


def save_delta(directory: str, step: int, delta: DeltaRecord) -> str:
    """Commit one refresh generation as checkpoint ``step`` (atomic, same
    store/junk-hardening as full snapshots)."""
    return ckpt.save(directory, step, delta_to_tree(delta))


def _apply_chain(base: KernelModelArtifact,
                 deltas: List[DeltaRecord]) -> KernelModelArtifact:
    """Replay a delta chain onto its base — pure concatenation + field
    replacement of STORED arrays, so the result is bitwise the artifact
    that was live when the last delta committed."""
    if not deltas:
        return base
    C = jnp.concatenate([base.C] + [d.G for d in deltas], axis=0)
    last = deltas[-1]
    return dataclasses.replace(
        base, C=C, U=last.U, heads=dict(last.heads),
        woodbury_M=last.woodbury_M, kpca_eigvals=last.kpca_eigvals)


def load_chain(directory: str, step: Optional[int] = None,
               ) -> Tuple[Optional[KernelModelArtifact], List[DeltaRecord]]:
    """Restore the artifact at ``step`` (default: latest committed),
    replaying delta generations onto their base snapshot.

    Chain validation: every delta between the base and the target must be
    present, share the target's ``base_step``, and carry consecutive
    generations 1..k — anything else (a GC'd middle link, a delta whose
    base was compacted away, damage in any step) is
    ``CheckpointCorruptionError``, which ``load_or_rebuild`` turns into a
    rebuild-from-source.
    """
    steps = ckpt.committed_steps(directory)
    if step is None:
        if not steps:
            return None, []
        step = steps[-1]
    if not is_delta_step(directory, step):
        tree = ckpt.restore_tree(directory, step)
        return artifact_from_tree(tree), []

    target = delta_from_tree(ckpt.restore_tree(directory, step))
    base_step = target.base_step
    if base_step not in steps:
        raise ckpt.CheckpointCorruptionError(
            f"delta step {step} references base step {base_step}, which is "
            f"not committed in {directory}")
    if is_delta_step(directory, base_step):
        raise ckpt.CheckpointCorruptionError(
            f"delta step {step}'s base step {base_step} is itself a delta")
    base_tree = ckpt.restore_tree(directory, base_step)
    base = artifact_from_tree(base_tree)

    chain: List[DeltaRecord] = []
    for s in steps:
        if base_step < s <= step and is_delta_step(directory, s):
            d = delta_from_tree(ckpt.restore_tree(directory, s))
            if d.base_step == base_step:
                chain.append(d)
    chain.sort(key=lambda d: d.generation)
    gens = [d.generation for d in chain]
    if gens != list(range(1, len(chain) + 1)) or \
            (chain and chain[-1].generation != target.generation):
        raise ckpt.CheckpointCorruptionError(
            f"broken delta chain in {directory}: generations {gens} "
            f"(target generation {target.generation}, base {base_step})")
    artifact = _apply_chain(base, chain)
    if int(artifact.C.shape[0]) != target.n_after:
        raise ckpt.CheckpointCorruptionError(
            f"delta chain replay produced n={int(artifact.C.shape[0])} but "
            f"generation {target.generation} recorded n_after="
            f"{target.n_after}")
    return artifact, chain


def load_artifact_chain(directory: str, step: Optional[int] = None,
                        ) -> Optional[KernelModelArtifact]:
    """Chain-aware artifact restore (what ``serve.load_artifact`` delegates
    to when the latest committed step is a delta)."""
    artifact, _ = load_chain(directory, step)
    return artifact


def gc_superseded_deltas(directory: str) -> int:
    """Remove delta steps whose chain a newer FULL snapshot supersedes.

    A delta belongs to the chain of its ``base_step``; once a newer full
    snapshot (compaction or re-sketch) is committed, every delta based on
    an OLDER snapshot is unreachable by ``load_chain`` and is deleted.
    Junk entries (stray files, tmp dirs, torn manifests) are skipped, not
    crashed on — same hardening contract as ``latest_step``.
    """
    steps = ckpt.committed_steps(directory)
    kinds = {}
    for s in steps:
        try:
            kinds[s] = "delta" if is_delta_step(directory, s) else "full"
        except ckpt.CheckpointCorruptionError:
            continue                      # torn manifest: leave it alone
    fulls = [s for s, k in kinds.items() if k == "full"]
    if not fulls:
        return 0
    latest_full = max(fulls)
    removed = 0
    for s, kind in kinds.items():
        if kind != "delta":
            continue
        try:
            d = delta_from_tree(ckpt.restore_tree(directory, s))
            superseded = d.base_step < latest_full
        except ckpt.CheckpointCorruptionError:
            # an unreadable delta is dead weight either way once a full
            # snapshot exists after it; only GC it when it's older
            superseded = s < latest_full
        if superseded:
            ckpt.remove_step(directory, s)
            removed += 1
    return removed


def compact(directory: str, artifact: KernelModelArtifact,
            step: Optional[int] = None) -> int:
    """Commit a full snapshot of the LIVE artifact (default: one step past
    the latest committed) and GC the delta chain it supersedes.  Returns
    the new base step."""
    if step is None:
        steps = ckpt.committed_steps(directory)
        step = (steps[-1] + 1) if steps else 0
    ckpt.save(directory, step, artifact_to_tree(artifact))
    gc_superseded_deltas(directory)
    return step


# ---------------------------------------------------------------------------
# the maintainer: appends + delta checkpoints + staleness-triggered re-sketch
# ---------------------------------------------------------------------------

class IncrementalMaintainer:
    """Owns a live artifact under appends: one thin launch per batch, a
    delta checkpoint per refresh generation, and a staleness policy that
    escalates to a full re-sketch through ``ArtifactRecovery``.

    ``op`` (optional) is a long-lived operator wrapper for the thin
    launches — pass a ``CountingOperator`` to meter ``append_sweeps``; it
    is ``rebind``-ed to the fresh landmark operator after a re-sketch.
    ``rebuild_fn(X_full, y_full)`` recreates the artifact from the grown
    corpus; when provided, ``X`` (the base training points) must be too.
    """

    def __init__(self, artifact: KernelModelArtifact, y, *,
                 directory: Optional[str] = None,
                 X=None,
                 staleness: Optional[StalenessPolicy] = None,
                 rebuild_fn=None,
                 recovery: Optional[ArtifactRecovery] = None,
                 op=None,
                 base_step: Optional[int] = None):
        self.artifact = artifact
        self.directory = directory
        self.staleness = staleness or StalenessPolicy()
        self.rebuild_fn = rebuild_fn
        self.recovery = recovery
        self.op = op
        self.state = init_state(artifact, y)
        y2 = np.asarray(y, np.float32)
        self._y_parts: List[np.ndarray] = [
            y2 if y2.ndim == 2 else y2[:, None]]
        self._X_parts: List[np.ndarray] = \
            [] if X is None else [np.asarray(X, np.float32)]
        if base_step is not None:
            self.base_step = base_step
        elif directory is not None:
            self.base_step = ckpt.latest_step(directory) or 0
        else:
            self.base_step = 0

    # -- grown-corpus views -------------------------------------------------

    def y_full(self) -> np.ndarray:
        return np.concatenate(self._y_parts, axis=0)

    def X_full(self) -> np.ndarray:
        if not self._X_parts:
            raise ValueError(
                "IncrementalMaintainer needs the base X to rebuild from the "
                "grown corpus; pass X= at construction when rebuild_fn is "
                "set")
        return np.concatenate(self._X_parts, axis=0)

    # -- the append path ----------------------------------------------------

    def append(self, X_new, y_new) -> GenerationStats:
        """Absorb one batch: ONE thin launch, delta checkpoint, staleness
        check (which may replace the artifact via a full re-sketch)."""
        artifact2, state2, stats, delta = append_rows(
            self.artifact, self.state, X_new, y_new, op=self.op)
        self.artifact, self.state = artifact2, state2
        Xb = np.asarray(X_new, np.float32)
        yb = np.asarray(y_new, np.float32)
        if Xb.ndim == 1:
            Xb = Xb[None, :]
        self._X_parts.append(Xb) if self._X_parts else None
        self._y_parts.append(yb if yb.ndim == 2 else yb[:, None])
        if self.directory is not None:
            delta.base_step = self.base_step
            save_delta(self.directory, self.base_step + stats.generation,
                       delta)
        reason = self.staleness.should_resketch(stats)
        if reason is not None and self.rebuild_fn is not None:
            self._resketch(reason)
            stats = dataclasses.replace(stats, resketch=True,
                                        resketch_reason=reason)
        return stats

    def _resketch(self, reason: str):
        """Full rebuild on the grown corpus, routed through
        ``ArtifactRecovery`` so the decision is a recorded 'stale' event,
        then compact the store (new base snapshot, superseded deltas
        GC'd) and re-init the f64 workspace."""
        if self.recovery is None:
            self.recovery = ArtifactRecovery(
                corruption_types=(ckpt.CheckpointCorruptionError,),
                stale_types=(ArtifactStaleError,))

        gen = self.state.generation

        def load():
            raise ArtifactStaleError(
                f"refresh generation {gen}: {reason}")

        def save(art):
            if self.directory is not None:
                self.base_step = compact(self.directory, art)

        X_full, y_full = self.X_full(), self.y_full()
        artifact = self.recovery.run(
            load=load,
            rebuild=lambda: self.rebuild_fn(X_full, y_full),
            save=save)
        self.artifact = artifact
        self.state = init_state(artifact, y_full)
        if self.op is not None and hasattr(self.op, "rebind"):
            self.op.rebind(artifact.landmark_operator())
