"""Query-time inference over precomputed fast-SPSD factors.

``build_artifact`` (training side) -> ``save_artifact``/``load_or_rebuild``
(warm-boot factor store on ``repro.checkpoint``) -> ``serve_kernel_model``
(one rectangular fused cross-kernel launch per query bucket).  The
continuous-batching production loop lives in ``repro.launch.serve_kernel``;
appended-row maintenance (one thin launch per batch, delta checkpoints,
staleness-triggered re-sketch) lives in ``repro.serve.incremental``.
"""
from repro.serve.artifact import (  # noqa: F401
    TASKS,
    KernelModelArtifact,
    artifact_from_tree,
    artifact_to_tree,
    build_artifact,
    load_artifact,
    load_or_rebuild,
    save_artifact,
)
from repro.serve.engine import (  # noqa: F401
    QueryRequest,
    QueryResult,
    answer_batch,
    dense_krr_oracle,
    dense_oracle,
    parity_gap,
    plan_buckets,
    serve_kernel_model,
)
from repro.serve.incremental import (  # noqa: F401
    DeltaRecord,
    GenerationStats,
    IncrementalMaintainer,
    IncrementalState,
    StalenessPolicy,
    append_rows,
    compact,
    gc_superseded_deltas,
    init_state,
    is_delta_step,
    load_artifact_chain,
    load_chain,
    save_delta,
)
