"""serve_kernel_model: batched query answering over a KernelModelArtifact.

The whole query-time cost model is ONE rectangular cross-kernel launch per
bucket.  A bucket's requests — arbitrary mixes of KRR / KPCA / feature-map
tasks and query counts — are padded to the bucket height (``bucket_by_size``
bounds each request's padding at ``waste``), stacked into one flat
(rows × d) query block, and answered by a single
``op.cross(X_flat, heads)`` call: the fused row-slab Pallas template
computes each K(x_query, x_landmark) tile once in VMEM and contracts it
against every head the bucket needs.  Per-request outputs are slices of the
launch result; padding rows are computed-and-dropped (bounded by ``waste``),
never observed.

``op`` defaults to ``artifact.landmark_operator()`` and may be any wrapper
with the same ``cross`` contract — the smoke tests pass a
``CountingOperator`` and assert exactly one ``cross_sweeps`` tick per
bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp

from repro.core.spsd import bucket_by_size
from repro.serve.artifact import TASKS, KernelModelArtifact


@dataclasses.dataclass
class QueryRequest:
    """One inference request: ``task`` ∈ {'krr','kpca','features'} over query
    points ``X`` (n_q × d, same feature space as the training data)."""

    X: jnp.ndarray
    task: str = "krr"

    def __post_init__(self):
        if self.task not in TASKS:
            raise ValueError(f"unknown task {self.task!r}; one of {TASKS}")
        self.X = jnp.asarray(self.X, jnp.float32)
        if self.X.ndim == 1:
            self.X = self.X[None, :]

    @property
    def n_q(self) -> int:
        return int(self.X.shape[0])


@dataclasses.dataclass
class QueryResult:
    """``out`` is (n_q × t) predictions / (n_q × k) projections /
    (n_q × r) features depending on the request's task."""

    out: jnp.ndarray
    task: str
    bucket: int                       # which launch answered it (diagnostics)


def _as_request(q) -> QueryRequest:
    return q if isinstance(q, QueryRequest) else QueryRequest(X=q)


def answer_batch(artifact: KernelModelArtifact,
                 requests: Sequence[QueryRequest],
                 op=None, bucket: int = 0,
                 precision: Optional[str] = None) -> List[QueryResult]:
    """Answer one (already-bucketed) batch with ONE cross-kernel launch.

    Requests are padded to the batch's max height with zero points (their
    kernel rows are computed and discarded — the ``bucket_by_size`` waste
    bound), stacked, and every head any request needs rides the same launch
    as an extra right-hand side.  ``precision`` (when ``op`` is not given)
    overrides the artifact spec's tile policy for the cross launch.
    """
    requests = [_as_request(q) for q in requests]
    if not requests:
        return []
    if op is None:
        op = artifact.landmark_operator(precision=precision)
    tasks = tuple(t for t in TASKS
                  if any(r.task == t for r in requests))
    heads = tuple(artifact.heads[t].astype(jnp.float32) for t in tasks)

    h = max(r.n_q for r in requests)
    flat = jnp.concatenate(
        [jnp.pad(r.X, ((0, h - r.n_q), (0, 0))) for r in requests], axis=0)
    outs = op.cross(flat, heads)
    by_task: Dict[str, jnp.ndarray] = dict(zip(tasks, outs))

    results = []
    for i, r in enumerate(requests):
        block = by_task[r.task][i * h: i * h + r.n_q]
        results.append(QueryResult(out=block, task=r.task, bucket=bucket))
    return results


def plan_buckets(requests: Sequence[QueryRequest],
                 waste: float = 0.25) -> List[List[int]]:
    """Index groups per launch: ``bucket_by_size`` over the query counts, so
    each request pays at most a ``waste`` fraction of padding rows."""
    return bucket_by_size([r.n_q for r in requests], waste=waste)


def serve_kernel_model(
    artifact: KernelModelArtifact,
    queries,
    waste: float = 0.25,
    op=None,
    precision: Optional[str] = None,
) -> List[QueryResult]:
    """Answer a heterogeneous batch of queries: one rectangular fused launch
    per size bucket, results in input order.

    ``queries`` is a list of ``QueryRequest`` (or raw (n_q × d) arrays,
    treated as KRR requests).  ``precision`` (when ``op`` is not given)
    overrides the artifact spec's tile policy for every cross launch — the
    bf16_f32acc serving mode.  This is the one-shot entry point; the
    continuous-batching server (``repro.launch.serve_kernel``) calls
    ``plan_buckets`` + ``answer_batch`` itself so it can meter per-request
    latency.
    """
    requests = [_as_request(q) for q in queries]
    results: List[Optional[QueryResult]] = [None] * len(requests)
    if op is None:
        op = artifact.landmark_operator(precision=precision)
    for b, bucket in enumerate(plan_buckets(requests, waste)):
        answers = answer_batch(artifact, [requests[i] for i in bucket],
                               op=op, bucket=b)
        for i, res in zip(bucket, answers):
            results[i] = res
    return results


# ---------------------------------------------------------------------------
# dense oracles (parity targets for tests / the serve-smoke trace)
# ---------------------------------------------------------------------------

def dense_oracle(artifact: KernelModelArtifact, Xq: jnp.ndarray,
                 task: str = "krr") -> jnp.ndarray:
    """The non-Pallas reference: G = K(Xq, X_S) via the dense spec apply,
    head applied in plain jnp.  KRR additionally has the independent
    ``dense_krr_oracle`` below (no Woodbury, no artifact head)."""
    from repro.kernels.pairwise import specs as pw_specs
    G = pw_specs.apply(artifact.spec, jnp.asarray(Xq, jnp.float32),
                       artifact.X_landmarks)
    return G @ artifact.heads[task].astype(jnp.float32)


def dense_krr_oracle(artifact: KernelModelArtifact, Xq: jnp.ndarray,
                     y: jnp.ndarray) -> jnp.ndarray:
    """End-to-end dense KRR on the approximated kernel: solve
    (C U Cᵀ + αI) w = y with a direct dense solve (no Woodbury identity),
    then extend with k̂(x,·) = K(x,X_S) U Cᵀ.  The serving path must match
    this to ≤1e-5 — it exercises woodbury_solve's identity, the head
    algebra, the Pallas cross launch, and persistence in one number.  The
    solve runs in f64 numpy (like the build-time Woodbury workspace) so the
    parity gate measures the serving path, not solver conditioning."""
    import numpy as np

    from repro.kernels.pairwise import specs as pw_specs
    C = np.asarray(artifact.C, np.float64)
    U = np.asarray(artifact.U, np.float64)
    n = C.shape[0]
    Khat = C @ U @ C.T
    y2 = np.asarray(y[:, None] if y.ndim == 1 else y, np.float64)
    w = np.linalg.solve(Khat + artifact.alpha * np.eye(n), y2)
    G = np.asarray(
        pw_specs.apply(artifact.spec, jnp.asarray(Xq, jnp.float32),
                       artifact.X_landmarks), np.float64)
    return jnp.asarray(G @ (U @ (C.T @ w)), jnp.float32)


def parity_gap(a: jnp.ndarray, b: jnp.ndarray) -> float:
    """max |a − b| / max(1, max|b|): the scale-normalized parity metric every
    serving assertion uses (≤1e-5 in the smoke gates)."""
    import numpy as np
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b)) / max(1.0, float(np.max(np.abs(b)))))
