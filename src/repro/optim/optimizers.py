"""Optimizers as pure (init, update) pairs over pytrees.

- ``adamw``     : decoupled weight decay, f32 moments, global-norm clipping.
- ``adafactor`` : factored second moment + optional bf16 first moment — the
                  memory-frugal choice for the ≥100B configs (deepseek-v3),
                  where full Adam state (8 bytes/param) cannot fit v5e HBM.
- ``lion``      : sign-momentum; 4 bytes/param state.

States inherit the parameter PartitionSpecs leaf-for-leaf (ZeRO-style when
``fsdp`` shards params over 'data'), so ``distributed.param_shardings`` is
reused for the optimizer state as-is.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any                      # per-optimizer pytree (m, v, ...)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jnp.ndarray], tuple]
    # update(grads, state, params, lr) -> (new_params, new_state, metrics)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), gn


def _is_matrix(x) -> bool:
    return x.ndim >= 2 and min(x.shape[-2:]) >= 2


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: Optional[float] = 1.0
          ) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner={"m": jax.tree.map(zeros, params),
                               "v": jax.tree.map(zeros, params)})

    def update(grads, state, params, lr):
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        else:
            gn = global_norm(grads)
        t = state.step + 1
        tf = t.astype(jnp.float32)
        bc1 = 1.0 - b1 ** tf
        bc2 = 1.0 - b2 ** tf

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            p32 = p.astype(jnp.float32)
            p2 = p32 - lr * (step + weight_decay * p32)
            return p2.astype(p.dtype), m2, v2

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.inner["m"])
        flat_v = treedef.flatten_up_to(state.inner["v"])
        res = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        unf = treedef.unflatten
        return (unf([r[0] for r in res]),
                OptState(step=t, inner={"m": unf([r[1] for r in res]),
                                        "v": unf([r[2] for r in res])}),
                {"grad_norm": gn})

    return Optimizer("adamw", init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored v; optional bf16 momentum)
# ---------------------------------------------------------------------------

def adafactor(weight_decay: float = 0.0, eps: float = 1e-30,
              clip_norm: Optional[float] = 1.0, momentum: bool = False,
              decay: float = 0.8) -> Optimizer:
    """Factored second moment over the trailing two dims of each matrix.

    State per matrix param (..., r, c): row stats (..., r) + col stats
    (..., c) — ~0 bytes/param vs Adam's 8.
    """
    def init(params):
        def stats(p):
            if _is_matrix(p):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                       jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        inner = {"stats": jax.tree.map(stats, params,
                                       is_leaf=lambda x: hasattr(x, "shape"))}
        if momentum:
            inner["m"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
        return OptState(step=jnp.zeros((), jnp.int32), inner=inner)

    def update(grads, state, params, lr):
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        else:
            gn = global_norm(grads)
        t = state.step + 1
        beta2 = 1.0 - (t.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(p, g, st, m):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _is_matrix(p):
                r = beta2 * st["r"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                c = beta2 * st["c"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rmean = jnp.mean(r, axis=-1, keepdims=True)
                vhat = (r[..., :, None] * c[..., None, :]) \
                    / jnp.maximum(rmean[..., None], eps)
                new_st = {"r": r, "c": c}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                vhat = v
                new_st = {"v": v}
            u = g / jnp.sqrt(jnp.maximum(vhat, eps))
            # update clipping (Shazeer & Stern): RMS(u) <= 1
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms)
            if m is not None:
                m2 = 0.9 * m.astype(jnp.float32) + 0.1 * u
                u = m2
                m_out = m2.astype(jnp.bfloat16)
            else:
                m_out = None
            p32 = p.astype(jnp.float32)
            p2 = p32 - lr * (u + weight_decay * p32)
            return p2.astype(p.dtype), new_st, m_out

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_st = treedef.flatten_up_to(state.inner["stats"])
        flat_m = treedef.flatten_up_to(state.inner["m"]) if momentum \
            else [None] * len(flat_p)
        res = [upd(p, g, st, m)
               for p, g, st, m in zip(flat_p, flat_g, flat_st, flat_m)]
        unf = treedef.unflatten
        inner = {"stats": unf([r[1] for r in res])}
        if momentum:
            inner["m"] = unf([r[2] for r in res])
        return (unf([r[0] for r in res]),
                OptState(step=t, inner=inner), {"grad_norm": gn})

    return Optimizer("adafactor", init, update)


# ---------------------------------------------------------------------------
# Lion
# ---------------------------------------------------------------------------

def lion(b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1,
         clip_norm: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            inner={"m": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)})

    def update(grads, state, params, lr):
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        else:
            gn = global_norm(grads)

        def upd(p, g, m):
            g = g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            u = jnp.sign(b1 * m + (1 - b1) * g)
            p2 = p32 - lr * (u + weight_decay * p32)
            m2 = b2 * m + (1 - b2) * g
            return p2.astype(p.dtype), m2

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.inner["m"])
        res = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        unf = treedef.unflatten
        return (unf([r[0] for r in res]),
                OptState(step=state.step + 1,
                         inner={"m": unf([r[1] for r in res])}),
                {"grad_norm": gn})

    return Optimizer("lion", init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    if name == "lion":
        return lion(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
