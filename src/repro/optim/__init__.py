from repro.optim.optimizers import (  # noqa: F401
    OptState,
    Optimizer,
    adafactor,
    adamw,
    lion,
    make_optimizer,
)
from repro.optim.schedule import warmup_cosine, warmup_linear  # noqa: F401
from repro.optim.compress import (  # noqa: F401
    CompressorState,
    countsketch_compress,
    countsketch_decompress,
    make_gradient_compressor,
)
