"""Sketched gradient compression for the cross-pod all-reduce.

The paper's Lemma-2 toolbox (here: CountSketch, the O(nnz) family member) is
reused as a *distributed-optimization* trick: before the slow cross-pod
(DCI) all-reduce, each pod compresses its gradient block ``g`` to ``S^T g``
with a shared CountSketch S ∈ R^{n×s} (s = n/ratio), all-reduces the sketch,
and unsketches with ``S (S^T g)``.  Error feedback (Seide et al.; Karimireddy
et al.) keeps the residual ``e = g − S Sᵀ g`` locally and adds it to the next
step's gradient, so the compression error does not accumulate.

CountSketch is linear, so ``allreduce(Sᵀ g_i) = Sᵀ (Σ g_i)`` — the sketch
commutes with the collective, which is what makes this sound.  All hash/sign
tables are derived from a step-independent key so every pod agrees on S
without communication.

Why the *damped* unsketch: ``S Sᵀ`` is unbiased but NOT a contraction
(bucket collisions give E||S Sᵀ e||² = (1 + n/s)||e||²), so naive error
feedback diverges.  Applying δ·S Sᵀ with δ = 1/(1 + ratio) makes the error
operator I − δ·S Sᵀ a contraction in expectation with factor
ratio/(1 + ratio); the residual feedback then delivers the full gradient
over ~(1+ratio) steps — the sketched-SGD trade (Ivkin et al., 2019, who
instead extract heavy hitters; damping is the streaming-friendly variant).

This is an *opt-in* knob on the 'pod' axis (train.py --compress-pod-grads);
within a pod the full-precision psum over ICI stays untouched.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressorState(NamedTuple):
    error: dict          # per-leaf residual feedback (same shapes as grads)
    key: jax.Array       # PRNG key the hash tables derive from


def _leaf_tables(key: jax.Array, n: int, s: int):
    kh, ks = jax.random.split(key)
    hashes = jax.random.randint(kh, (n,), 0, s)
    signs = jax.random.rademacher(ks, (n,), dtype=jnp.float32)
    return hashes, signs


def countsketch_compress(g: jnp.ndarray, key: jax.Array, ratio: int
                         ) -> Tuple[jnp.ndarray, Tuple]:
    """g (any shape) -> sketch (s,) with s = ceil(n/ratio)."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    s = max(1, n // ratio)
    hashes, signs = _leaf_tables(key, n, s)
    sk = jax.ops.segment_sum(flat * signs, hashes, num_segments=s)
    return sk, (hashes, signs, g.shape, g.dtype)


def countsketch_decompress(sk: jnp.ndarray, meta) -> jnp.ndarray:
    hashes, signs, shape, dtype = meta
    rec = jnp.take(sk, hashes) * signs
    return rec.reshape(shape).astype(dtype)


def make_gradient_compressor(ratio: int = 8):
    """Returns (init, apply).

    apply(grads, state, allreduce_fn) -> (grads_hat, new_state) where
    ``allreduce_fn`` is e.g. ``lambda x: jax.lax.pmean(x, 'pod')`` (or identity
    in single-pod runs/tests).  Error feedback is carried in ``state``.
    """
    def init(grads_like, key: jax.Array) -> CompressorState:
        err = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
        return CompressorState(error=err, key=key)

    delta = 1.0 / (1.0 + ratio)                # contraction damping

    def apply(grads, state: CompressorState, allreduce_fn):
        flat, treedef = jax.tree_util.tree_flatten(grads)
        eflat = jax.tree_util.tree_flatten(state.error)[0]
        keys = jax.random.split(state.key, len(flat) + 1)
        out, new_err = [], []
        for i, (g, e) in enumerate(zip(flat, eflat)):
            gc = g.astype(jnp.float32) + e                     # error feedback
            sk, meta = countsketch_compress(gc, keys[i], ratio)
            sk = allreduce_fn(sk)
            rec = delta * countsketch_decompress(sk, meta).astype(jnp.float32)
            local_rec = delta * countsketch_decompress(
                countsketch_compress(gc, keys[i], ratio)[0],
                meta).astype(jnp.float32)
            new_err.append(gc - local_rec)
            out.append(rec.astype(g.dtype))
        return (jax.tree_util.tree_unflatten(treedef, out),
                CompressorState(
                    error=jax.tree_util.tree_unflatten(treedef, new_err),
                    key=keys[-1]))

    return init, apply
