"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup_steps, 1)
    t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)


def warmup_linear(step, *, peak: float, warmup_steps: int, total_steps: int):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup_steps, 1)
    t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    lin = peak * (1.0 - jnp.clip(t, 0.0, 1.0))
    return jnp.where(step < warmup_steps, warm, lin)
