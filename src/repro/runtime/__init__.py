from repro.runtime.fault_tolerance import (  # noqa: F401
    ElasticPlan,
    HeartbeatMonitor,
    PreemptionHandler,
    StragglerDetector,
    plan_elastic_remesh,
)
