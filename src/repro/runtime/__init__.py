from repro.runtime.fault_tolerance import (  # noqa: F401
    ArtifactRecovery,
    ElasticPlan,
    HeartbeatMonitor,
    PreemptionHandler,
    RecoveryEvent,
    StragglerDetector,
    plan_elastic_remesh,
)
