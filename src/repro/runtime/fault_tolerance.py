"""Fault tolerance & elasticity for thousand-node runs.

Four cooperating pieces, all deterministic and unit-testable (no wall-clock
dependence in the decision logic — callers inject timestamps):

- ``HeartbeatMonitor``   : hosts report (host_id, step, t); a host whose last
                           heartbeat is older than ``timeout`` is declared
                           dead.  The runtime's reaction to a death is always
                           the same: stop, checkpoint-restore on the surviving
                           topology (see ``plan_elastic_remesh``).
- ``PreemptionHandler``  : turns a SIGTERM (or cloud preemption notice) into a
                           'save-and-exit-at-next-step-boundary' flag — the
                           train loop polls ``should_exit`` once per step so
                           the final checkpoint is always at a step boundary.
- ``StragglerDetector``  : per-step wall times per host; a host slower than
                           ``threshold`` × the rolling median for ``patience``
                           consecutive steps is flagged.  Mitigation is a
                           *policy* returned to the caller: 'reseat' (swap in
                           a hot spare) or 'exclude' (shrink via elastic
                           remesh) — on TPU pods one cannot drop a single chip
                           from a ring, so mitigation granularity is a pod.
- ``plan_elastic_remesh``: given surviving pod count and the model's sharding
                           needs, produce the largest valid mesh (data-axis
                           shrink first — the model axis is fixed by the
                           checkpointed layout, which restores elastically
                           because checkpoints are resharding-on-read).
- ``ArtifactRecovery``   : restore-or-recompute for serving replicas — a
                           corrupt/missing precomputed artifact (factor
                           store) is rebuilt from source instead of crashing
                           the replica, with every decision recorded for the
                           smoke tests to assert on.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# heartbeats
# ---------------------------------------------------------------------------

class HeartbeatMonitor:
    def __init__(self, hosts: List[str], timeout: float):
        self.timeout = timeout
        self.last: Dict[str, Tuple[int, float]] = {h: (-1, 0.0) for h in hosts}

    def beat(self, host: str, step: int, t: float):
        self.last[host] = (step, t)

    def dead_hosts(self, now: float) -> List[str]:
        return [h for h, (_, t) in self.last.items()
                if now - t > self.timeout]

    def min_step(self) -> int:
        return min(s for s, _ in self.last.values())


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------

class PreemptionHandler:
    """SIGTERM -> graceful save-and-exit at the next step boundary."""

    def __init__(self, install_signal: bool = False):
        self._flag = threading.Event()
        if install_signal:
            signal.signal(signal.SIGTERM, lambda *_: self.notify())

    def notify(self):
        self._flag.set()

    @property
    def should_exit(self) -> bool:
        return self._flag.is_set()


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerReport:
    host: str
    ratio: float
    action: str                      # 'reseat' | 'exclude'


class StragglerDetector:
    def __init__(self, threshold: float = 1.5, patience: int = 5,
                 window: int = 50):
        self.threshold = threshold
        self.patience = patience
        self.times: Dict[str, deque] = {}
        self.strikes: Dict[str, int] = {}
        self.window = window

    def record(self, host: str, step_time: float):
        self.times.setdefault(host, deque(maxlen=self.window)).append(
            step_time)

    def _median_of_medians(self) -> float:
        """Lower median of per-host medians: assumes a majority of hosts is
        healthy, so a straggler can never drag the reference upward."""
        meds = []
        for dq in self.times.values():
            xs = sorted(dq)
            meds.append(xs[(len(xs) - 1) // 2])
        xs = sorted(meds)
        return xs[(len(xs) - 1) // 2] if xs else 0.0

    def check(self) -> List[StragglerReport]:
        """Call once per step after all hosts reported."""
        med = self._median_of_medians()
        out = []
        if med <= 0:
            return out
        for host, dq in self.times.items():
            ratio = dq[-1] / med
            if ratio > self.threshold:
                self.strikes[host] = self.strikes.get(host, 0) + 1
            else:
                self.strikes[host] = 0
            if self.strikes.get(host, 0) >= self.patience:
                action = "reseat" if ratio < 3.0 else "exclude"
                out.append(StragglerReport(host=host, ratio=ratio,
                                           action=action))
        return out


# ---------------------------------------------------------------------------
# recompute-on-corruption (serving warm boot)
# ---------------------------------------------------------------------------

class ArtifactStaleError(RuntimeError):
    """A stored/served artifact is VALID but no longer trustworthy.

    Raised by the incremental-maintenance staleness policy
    (``repro.serve.incremental.StalenessPolicy``) when the tracked
    per-generation error estimate drifts past its threshold: the factor
    store decodes fine, but the model it encodes has fallen behind the
    grown corpus.  ``ArtifactRecovery`` treats it like corruption — rebuild
    from source, persist, keep serving — but records the distinct 'stale'
    event kind so re-sketches are attributable separately from damage.
    """


@dataclasses.dataclass(frozen=True)
class RecoveryEvent:
    kind: str           # 'restored' | 'missing' | 'corrupt' | 'stale' | 'rebuilt'
    detail: str = ""


class ArtifactRecovery:
    """Restore-or-recompute policy for precomputed serving artifacts.

    A replica booting from the factor store must never crash on a damaged
    checkpoint — a truncated manifest or half-deleted step dir is an
    *expected* failure mode (preemption mid-write, concurrent gc) whose
    correct reaction is to recompute the artifact from source and persist a
    fresh copy.  ``run`` encodes that policy; every decision lands in
    ``events`` so tests (and the serve-smoke CI job) can assert whether a
    boot was warm (``restored``) or cold (``missing``/``corrupt``/``stale``
    → ``rebuilt``).  Like the rest of this module the logic is
    deterministic and injectable: what counts as corruption is the
    ``corruption_types`` tuple (``checkpoint.CheckpointCorruptionError`` in
    production), and ``stale_types`` (``ArtifactStaleError``) marks
    drift-triggered full re-sketches — same rebuild path, distinct event.
    """

    def __init__(self, corruption_types: Tuple[type, ...] = (RuntimeError,),
                 stale_types: Tuple[type, ...] = (ArtifactStaleError,)):
        self.corruption_types = corruption_types
        self.stale_types = stale_types
        self.events: List[RecoveryEvent] = []

    @property
    def warm(self) -> bool:
        """True when the last ``run`` served the restored artifact as-is."""
        return bool(self.events) and self.events[-1].kind == "restored"

    def run(self, load: Callable[[], object], rebuild: Callable[[], object],
            save: Optional[Callable[[object], None]] = None):
        """``load()`` (returning None when nothing is stored), falling back
        to ``rebuild()`` on a missing, corrupt, or stale store; ``save``
        persists the rebuilt artifact so the NEXT boot is warm again."""
        try:
            out = load()
        except self.stale_types as e:
            self.events.append(RecoveryEvent(
                "stale", f"{type(e).__name__}: {e}"))
            out = None
        except self.corruption_types as e:
            self.events.append(RecoveryEvent(
                "corrupt", f"{type(e).__name__}: {e}"))
            out = None
        else:
            if out is not None:
                self.events.append(RecoveryEvent("restored"))
                return out
            self.events.append(RecoveryEvent("missing"))
        out = rebuild()
        if save is not None:
            save(out)
        self.events.append(RecoveryEvent("rebuilt"))
        return out


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    global_batch: int                # rescaled to keep per-chip batch fixed
    note: str


def plan_elastic_remesh(surviving_pods: int, chips_per_pod: int,
                        model_parallel: int, global_batch: int,
                        original_pods: int) -> ElasticPlan:
    """Largest valid mesh on the survivors.

    The 'model' axis is pinned (the param layout in the checkpoint shards over
    it); the 'data' axis absorbs the shrink; the global batch is rescaled
    proportionally (keeping per-chip batch, i.e. throughput-optimal — the
    loss-scale consequences are the trainer's documented policy).
    """
    if surviving_pods < 1:
        raise ValueError("no survivors")
    data = chips_per_pod // model_parallel
    if data < 1:
        raise ValueError(
            f"model_parallel={model_parallel} exceeds a pod "
            f"({chips_per_pod} chips)")
    batch = max(1, global_batch * surviving_pods // original_pods)
    if surviving_pods == 1:
        return ElasticPlan(mesh_shape=(data, model_parallel),
                           mesh_axes=("data", "model"),
                           global_batch=batch,
                           note="single-pod mesh (pod axis dropped)")
    return ElasticPlan(mesh_shape=(surviving_pods, data, model_parallel),
                       mesh_axes=("pod", "data", "model"),
                       global_batch=batch,
                       note=f"elastic shrink {original_pods}->"
                            f"{surviving_pods} pods")
