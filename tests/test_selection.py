"""Selection-policy subsystem: registry behavior, masked (ragged) selection,
per-spec streaming calibration parity, ragged auto-bucketing, and the
streaming fast_cur selection acceptance case (n=3k, memory-guarded)."""
import unittest.mock as mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cur, selection, spsd
from repro.core.instrument import CountingOperator
from repro.core.kernelop import PairwiseKernel, RBFKernel
from repro.core.leverage import row_leverage_scores
from repro.kernels.pairwise import calibrate as pw_cal
from repro.kernels.pairwise import specs as pw_specs


def _clustered(seed, n=400, d=8, k=8):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 2.5
    labels = rng.integers(0, k, size=n)
    X = centers[labels] + rng.normal(size=(n, d)) * 0.4
    return jnp.asarray(X, jnp.float32)


def _rbf(seed, n=400, sigma=2.0, **kw):
    return RBFKernel(_clustered(seed, n=n), sigma=sigma, **kw)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_policies():
    names = selection.registered_policies()
    for required in ("uniform", "leverage", "uniform_adaptive2"):
        assert required in names
    with pytest.raises(ValueError, match="unknown selection policy"):
        selection.get_policy("nope")


def test_policy_instance_passes_through():
    pol = selection.LeveragePolicy(pilot=40)
    assert selection.get_policy(pol) is pol


def test_register_custom_policy_end_to_end():
    class FirstK(selection.SelectionPolicy):
        name, rounds, sweeps_per_round, gathers = "first_k", 1, 0, 0

        def select(self, K, key, c, **kw):
            return jnp.arange(c)

    selection.register_policy("first_k")(FirstK)
    try:
        Kop = _rbf(0, n=200)
        ap = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=10, s=40,
                             s_sketch="gaussian", selection="first_k")
        np.testing.assert_array_equal(np.asarray(ap.P_indices),
                                      np.arange(10))
        assert np.isfinite(float(spsd.relative_error(Kop, ap,
                                                     method="dense")))
    finally:
        selection._POLICIES.pop("first_k", None)


def test_leverage_policy_tracks_dense_svd_scores():
    """The blocked-Gram pilot leverage must match the dense SVD leverage of
    the same pilot panel — identical probabilities, same selections."""
    Kop = _rbf(1, n=300)
    pol = selection.LeveragePolicy()
    kp, ks = jax.random.split(jax.random.PRNGKey(7))
    pilot_idx = selection._uniform_indices(kp, Kop.n, 24, None)
    Cp = Kop.columns(pilot_idx)
    lev_dense = row_leverage_scores(Cp)
    idx_pol = np.asarray(pol.select(Kop, jax.random.PRNGKey(7), 12,
                                    block_size=64))
    idx_ref = np.asarray(selection._weighted_indices_without_replacement(
        ks, lev_dense, 12, jnp.ones((Kop.n,), jnp.float32)))
    np.testing.assert_array_equal(idx_pol, idx_ref)


# ---------------------------------------------------------------------------
# masked (ragged) selection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["uniform", "leverage", "uniform_adaptive2"])
def test_policies_respect_mask(name):
    """Padded operators: every policy must select from valid rows only, even
    with poisoned padding entries dominating the kernel."""
    n, nv = 200, 150
    X = np.array(_clustered(2, n=n))
    X[nv:] = 99.0                                 # poison the padding rows
    Kop = RBFKernel(jnp.asarray(X, jnp.float32), sigma=2.0)
    mask = (jnp.arange(n) < nv).astype(jnp.float32)
    pol = selection.get_policy(name)
    idx = np.asarray(pol.select(Kop, jax.random.PRNGKey(0), 12, mask=mask))
    assert idx.max() < nv, (name, idx)
    assert len(set(idx.tolist())) == 12


def test_adaptive2_sees_rows_appended_between_rounds():
    """Regression: the incremental maintainer can grow an operator's n
    between uniform_adaptive2's rounds (append_rows rebinding the live
    operator).  The policy used to size per-round masks from an n captured
    at entry — a broadcast crash against the grown round's norms, and the
    appended rows were invisible to the adaptive draw.  Budgets must hold
    unchanged: growth adds rows, never kernel passes."""
    X_full = np.array(_clustered(12, n=320))
    n0, grow, c = 200, 60, 24
    spec = pw_specs.suggested_spec("rbf", X_full.shape[1])

    class Growing(CountingOperator):
        def __init__(self):
            self.live_n = n0
            super().__init__(PairwiseKernel(
                jnp.asarray(X_full[:n0], jnp.float32), spec,
                use_pallas=False))

        def sweep(self, plans, block_size=None, mesh=None):
            out = super().sweep(plans, block_size=block_size, mesh=mesh)
            self.live_n = min(self.live_n + grow, X_full.shape[0])
            self.rebind(PairwiseKernel(
                jnp.asarray(X_full[:self.live_n], jnp.float32), spec,
                use_pallas=False))
            return out

    op = Growing()
    pol = selection.get_policy("uniform_adaptive2")
    idx = np.asarray(pol.select(op, jax.random.PRNGKey(3), c))
    assert op.live_n == n0 + pol.adaptive_rounds * grow   # growth happened
    assert len(set(idx.tolist())) == c
    assert idx.max() < op.live_n
    # rows appended after entry are eligible for the adaptive draws
    assert idx.max() >= n0, idx
    assert op.counts["sweeps"] == pol.sweep_budget()
    assert op.counts["fulls"] == 0


def test_leverage_pilot_clamps_to_valid_rows():
    """Regression: a pilot wider than the valid-row count must clamp instead
    of silently pulling zero-probability padding columns into the panel
    (n_valid < max(2c, c+8) — the overflow class PR 3 hardened
    uniform_column_sketch against)."""
    n, nv, c = 64, 20, 16                 # default pilot 2c = 32 > nv = 20
    X = np.array(_clustered(7, n=n))
    X[nv:] = np.nan                       # poisoned padding: NaN kernel rows
    Kop = RBFKernel(jnp.asarray(X, jnp.float32), sigma=2.0)
    mask = (jnp.arange(n) < nv).astype(jnp.float32)
    idx = np.asarray(selection.LeveragePolicy().select(
        Kop, jax.random.PRNGKey(0), c, mask=mask))
    assert idx.max() < nv and len(set(idx.tolist())) == c


def test_leverage_traced_mask_overflow_remaps_onto_valid_rows():
    """Under vmap the mask is traced (no clamp possible): overflow picks must
    be remapped onto valid columns, never onto padding."""
    n, c = 64, 16
    n_valid = np.array([20, 64])          # item 0 overflows the 2c=32 pilot
    Xb = np.stack([np.array(_clustered(8, n=n)) for _ in range(2)])
    Xb[0, 20:] = 99.0
    Xb = jnp.asarray(Xb, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(1), 2)

    def one(Xi, key, nvi):
        mask = (jnp.arange(n) < nvi).astype(jnp.float32)
        return selection.LeveragePolicy().select(
            RBFKernel(Xi, sigma=2.0), key, c, mask=mask)

    idx = np.asarray(jax.vmap(one)(Xb, keys, jnp.asarray(n_valid)))
    for b, nvi in enumerate(n_valid):
        assert idx[b].max() < nvi, (b, idx[b])
        assert len(set(idx[b].tolist())) == c


def test_fast_model_batched_selection_policies_vmap():
    """Non-uniform policies must trace under the batched vmap (pilot gathers,
    residual sweeps and all) and keep padding out of the model."""
    rng = np.random.default_rng(3)
    n_valid = np.array([150, 200])
    npad = 200
    Xb = rng.normal(size=(2, npad, 6))
    for b, nv in enumerate(n_valid):
        Xb[b, nv:] = 99.0
    Xb = jnp.asarray(Xb, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(4), 2)
    for name in ("leverage", "uniform_adaptive2"):
        bat = spsd.fast_model_batched(RBFKernel(Xb, sigma=1.5), keys, c=12,
                                      s=48, s_sketch="gaussian",
                                      n_valid=jnp.asarray(n_valid),
                                      selection=name)
        assert np.all(np.isfinite(np.asarray(bat.U))), name
        for b, nv in enumerate(n_valid):
            assert int(jnp.max(bat.P_indices[b])) < nv, name
            Ktrue = RBFKernel(Xb[b, :nv], sigma=1.5)
            ap = spsd.SPSDApprox(C=bat.C[b][:nv], U=bat.U[b])
            err = float(spsd.relative_error(Ktrue, ap, method="dense"))
            assert np.isfinite(err) and err < 0.5, (name, b, err)


# ---------------------------------------------------------------------------
# ragged auto-bucketing
# ---------------------------------------------------------------------------

def test_bucket_by_size_bounds_padding_waste():
    sizes = [3000, 2900, 1000, 950, 120, 110, 100]
    buckets = spsd.bucket_by_size(sizes, waste=0.25)
    seen = sorted(i for b in buckets for i in b)
    assert seen == list(range(len(sizes)))        # a partition
    for b in buckets:
        cap = max(sizes[i] for i in b)
        for i in b:
            assert cap <= sizes[i] * 1.25 + 1e-9  # ≤ 25% padding each
    # wildly different sizes must NOT share a bucket
    by_item = {i: tuple(b) for b in buckets for i in b}
    assert by_item[0] != by_item[4]


def test_fast_model_ragged_matches_per_item():
    rng = np.random.default_rng(5)
    sizes = [150, 160, 90, 300]
    Xs = [jnp.asarray(rng.normal(size=(n, 6)), jnp.float32) for n in sizes]
    keys = jax.random.split(jax.random.PRNGKey(6), len(sizes))
    outs = spsd.fast_model_ragged(Xs, lambda Xb: RBFKernel(Xb, sigma=1.5),
                                  keys, c=12, s=48, s_sketch="gaussian",
                                  waste=0.25)
    assert [o.C.shape for o in outs] == [(n, 12) for n in sizes]
    for o, X, n in zip(outs, Xs, sizes):
        err = float(spsd.relative_error(RBFKernel(X, sigma=1.5), o,
                                        method="dense"))
        assert np.isfinite(err) and err < 0.5, (n, err)


# ---------------------------------------------------------------------------
# per-spec streaming calibration: parity vs a dense quantile oracle + budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", pw_specs.registered_kernels())
def test_calibrate_sigma_parity_and_single_sweep(name):
    """calibrate_sigma(spec=...) for EVERY registered spec: parameters match
    the dense-quantile oracle over the same anchor pairs to ≤ 1e-5, at a
    metered budget of ONE n×m statistic gather — exactly n·m evaluated
    entries, zero full-operator sweeps (stricter than the 1-sweep bound)."""
    n, d = 257, 8
    X = _clustered(10, n=n, d=d)
    spec = pw_specs.suggested_spec(name, d)
    anchor_idx = jnp.arange(3, n, 11)

    stat_op = CountingOperator(PairwiseKernel(X, pw_specs.stat_only(spec)))
    cal = pw_cal.calibrate_sigma(X, spec=spec, anchor_idx=anchor_idx,
                                 stat_op=stat_op)
    rule = pw_cal._RULES[spec.name]
    # budget: one n×m gather (parameterless families skip even that)
    assert stat_op.counts["sweeps"] == 0
    if rule.needs_stat:
        assert stat_op.counts["columns"] == 1
        assert stat_op.counts["entries"] == n * int(anchor_idx.shape[0])
    else:
        assert stat_op.counts["columns"] == 0 and stat_op.counts["entries"] == 0
    assert stat_op.counts["fulls"] == 0

    # dense oracle: the raw statistic over the SAME pairs, full quantile
    S = pw_specs.stat_block(spec.stat, X, jnp.take(X, anchor_idx, axis=0))
    if rule.transform is not None:
        S = rule.transform(S)
    expected = rule.apply(float(jnp.quantile(S.astype(jnp.float32), 0.5)),
                          spec)
    assert cal.name == expected.name
    for (k1, v1), (k2, v2) in zip(cal.params, expected.params):
        assert k1 == k2
        if v1 is None or v2 is None:
            assert v1 == v2
        else:
            assert float(v1) == pytest.approx(float(v2), rel=1e-5), (name, k1)


def test_calibrated_specs_are_usable_end_to_end():
    """A calibrated spec must drop straight into fast_model for every
    registered family (principled bandwidths, not just plumbing)."""
    X = _clustered(11, n=300, d=6)
    # only the families with a calibration rule: other test modules register
    # ad-hoc kernels in the (process-global) spec registry, and those have no
    # streaming calibration to exercise here
    for name in sorted(set(pw_specs.registered_kernels())
                       & set(pw_cal.registered_calibrations())):
        cal = pw_cal.calibrate_sigma(X, spec=pw_specs.suggested_spec(name, 6),
                                     key=jax.random.PRNGKey(0))
        Kop = PairwiseKernel(X, cal)
        ap = spsd.fast_model(Kop, jax.random.PRNGKey(1), c=24, s=96,
                             s_sketch="gaussian")
        err = float(spsd.relative_error(Kop, ap, method="dense"))
        assert np.isfinite(err) and err < 0.6, (name, err)


def test_calibrate_unknown_kernel_raises():
    @pw_specs.register_kernel("_test_cal_missing")
    def _missing(gamma: float = 1.0):
        return pw_specs.KernelSpec("_test_cal_missing", "sqdist",
                                   lambda sq: jnp.exp(-gamma * sq),
                                   params=(("gamma", gamma),))
    try:
        with pytest.raises(ValueError, match="no calibration rule"):
            pw_cal.calibrate_sigma(_clustered(12, n=64),
                                   spec="_test_cal_missing")
    finally:
        pw_specs._REGISTRY.pop("_test_cal_missing", None)


def test_register_custom_calibration_rule():
    @pw_specs.register_kernel("_test_cauchy")
    def _cauchy(gamma: float = 1.0):
        return pw_specs.KernelSpec("_test_cauchy", "sqdist",
                                   lambda sq: 1.0 / (1.0 + gamma * sq),
                                   params=(("gamma", gamma),))

    @pw_cal.register_calibration("_test_cauchy")
    def _cal(stat_q, base):
        return pw_specs.get_spec("_test_cauchy", gamma=1.0 / max(stat_q,
                                                                 1e-12))
    try:
        X = _clustered(13, n=128, d=5)
        cal = pw_cal.calibrate_sigma(X, spec="_test_cauchy",
                                     key=jax.random.PRNGKey(0))
        assert cal.param("gamma") > 0.0
    finally:
        pw_specs._REGISTRY.pop("_test_cauchy", None)
        pw_cal._RULES.pop("_test_cauchy", None)


# ---------------------------------------------------------------------------
# acceptance: streaming fast_cur selection at n=3k, memory-guarded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["leverage", "uniform_adaptive2"])
def test_streaming_cur_selection_never_densifies_n3k(name):
    """fast_cur(streaming) on an implicit PairwiseKernel at n=3000: C/R
    selection streams (full() booby-trapped — the memory-guard pattern of
    tests/test_streaming.py), direct kernel accesses stay O(n·(c+r+pilot))
    (no O(n·r)-sized densify beyond the C/R panels), and the result matches
    the dense-selection route's relative error within 10%."""
    n, c, r, sc, sr = 3000, 48, 48, 96, 96
    X = _clustered(20, n=n, d=8)
    Kop = PairwiseKernel(X, pw_specs.rbf(2.0))
    Kc = CountingOperator(Kop)
    key = jax.random.PRNGKey(0)
    pol = selection.get_policy(name)
    with mock.patch.object(PairwiseKernel, "full",
                           side_effect=AssertionError(
                               "streaming CUR selection densified K")):
        ap_s = cur.fast_cur(Kc, key, c=c, r=r, sc=sc, sr=sr,
                            sketch_kind="gaussian", selection=name)
    # sweep budget: 1 (A S_R) + 2 policy selections, nothing hidden
    assert Kc.counts["sweeps"] == 1 + 2 * pol.sweep_budget()
    # direct gathers: C + R panels + policy pilots/gathers only — every one
    # an O(n · width) panel with widths summing to a few × (c + r), so no
    # O(n·r)-sized selection intermediate can hide in the access pattern
    direct = sum(Kc.counts[k] for k in ("columns", "blocks"))
    assert direct <= 2 + 2 * pol.gathers
    sweep_entries = Kc.counts["sweeps"] * int(1.02 * n * n)
    assert Kc.counts["entries"] - sweep_entries <= 8 * n * (c + r)

    # dense-selection reference: same keys, selection scored from the
    # materialized matrix through DenseSPSD gathers
    Kd = jnp.asarray(np.asarray(Kop.full(), np.float32))
    ap_d = cur.fast_cur(Kd, key, c=c, r=r, sc=sc, sr=sr,
                        sketch_kind="gaussian", streaming=False,
                        selection=name)
    e_s = float(cur.relative_error(Kd, ap_s))
    e_d = float(cur.relative_error(Kd, ap_d))
    assert np.isfinite(e_s) and np.isfinite(e_d)
    assert abs(e_s - e_d) <= 0.10 * max(e_d, 1e-6), (name, e_s, e_d)
