"""Unit tests for the roofline HLO miners and dry-run helpers."""

import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rl
from repro.launch.dryrun import _reduced_cfg, scan_reps

HLO = """\
HloModule test, is_scheduled=true

%fused_computation (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %c = f32[] constant(2)
  %b = f32[128,128]{1,0} broadcast(%c), dimensions={}
  ROOT %m = f32[128,128]{1,0} multiply(%p0, %b)
}

ENTRY %main (a: bf16[128,256], b: bf16[256,128]) -> f32[128,128] {
  %a = bf16[128,256]{1,0} parameter(0)
  %b = bf16[256,128]{1,0} parameter(1)
  %dot.1 = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[128,128]{1,0} all-gather(%dot.1), replica_groups={}, dimensions={0}
  %ar = f32[128,128]{1,0} all-reduce(%ag), to_apply=%add
  %fusion.1 = f32[128,128]{1,0} fusion(%ar), kind=kLoop, calls=%fused_computation
  ROOT %copy.1 = f32[128,128]{1,0} copy(%fusion.1)
}
"""

F32_128 = 128 * 128 * 4
BF16_A = 128 * 256 * 2


def test_collective_bytes():
    got = rl.collective_bytes(HLO)
    assert got["all-gather"] == F32_128
    assert got["all-reduce"] == F32_128
    assert got["all-to-all"] == 0


def test_hbm_bytes_counts_memory_ops_only():
    got = rl.hbm_bytes(HLO)
    # dot: result + 2 operands; ag/ar: result+operand each; copy: res+operand
    # kLoop fusion skipped (not wrapped_*); interior of %fused skipped
    expect = (F32_128 + 2 * BF16_A) + 2 * (2 * F32_128) + 2 * F32_128
    assert got == expect, (got, expect)


def test_shape_bytes():
    assert rl._shape_bytes("bf16", "4,8") == 64
    assert rl._shape_bytes("f32", "") == 4


def test_model_flops_conventions():
    cfg = get_config("yi-6b")
    tr = rl.model_flops(cfg, SHAPES["train_4k"])
    pf = rl.model_flops(cfg, SHAPES["prefill_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    w = get_config("whisper-large-v3")
    tw = rl.model_flops(w, SHAPES["train_4k"])
    assert tw == pytest.approx(3 * w.param_count() * 256 * (4096 + 512))


def test_reduced_cfg_and_scan_reps():
    cfg = get_config("deepseek-v3-671b")
    assert scan_reps(cfg) == 58
    r1 = _reduced_cfg(cfg, 1)
    assert r1.n_layers == 4 and not r1.scan_layers and r1.unroll_scans
    rg = get_config("recurrentgemma-2b")
    assert scan_reps(rg) == 8
    assert _reduced_cfg(rg, 2).n_layers == 3 + 2 * 3 + 2 - 3  # 3*2 + rem 2
    w = get_config("whisper-large-v3")
    assert scan_reps(w) == 32
    assert _reduced_cfg(w, 2).n_enc_layers == 2


def test_roofline_finalize_bottleneck():
    r = rl.Roofline(arch="a", shape="s", mesh="m", chips=256,
                    hlo_gflops=197_000.0, hlo_gbytes=10.0,
                    coll_gbytes=100_000.0, coll_by_kind={},
                    model_gflops=197_000.0 * 256,
                    bytes_per_chip=0.0).finalize()
    assert r.compute_s == pytest.approx(1.0)
    assert r.bottleneck == "collective"
    assert r.useful_flops_frac == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# hardware profiles + the pairwise-launch scoring model
# ---------------------------------------------------------------------------

def test_finalize_accepts_a_hardware_profile():
    """The peak rates are a parameter: the same counted terms score
    differently (and are labeled differently) under another profile."""
    toy = rl.HardwareProfile("toy", peak_flops=1e12, hbm_bw=1e11,
                             link_bw=1e10)
    kw = dict(arch="a", shape="s", mesh="m", chips=1,
              hlo_gflops=1000.0, hlo_gbytes=50.0, coll_gbytes=0.0,
              coll_by_kind={}, model_gflops=1000.0, bytes_per_chip=0.0)
    r = rl.Roofline(**kw).finalize(toy)
    assert r.profile_name == "toy"
    assert r.compute_s == pytest.approx(1.0)       # 1000 GFLOP / 1 TFLOP/s
    assert r.memory_s == pytest.approx(0.5)        # 50 GB / 100 GB/s
    # default stays v5e (the pre-profile behavior, relied on above)
    assert rl.Roofline(**kw).finalize().profile_name == "v5e"


def test_default_profile_is_honest_about_cpu():
    prof = rl.default_profile()
    import jax
    expected = rl.V5E if jax.default_backend() == "tpu" else rl.CPU_INTERPRET
    assert prof is expected
    # module aliases stay pinned to v5e for back-compat
    assert rl.PEAK_FLOPS == rl.V5E.peak_flops


def test_pairwise_launch_model_flop_split():
    """The unit split is the point: sign-split moves l1dist work from the
    VPU bucket to the MXU bucket; the VPU loop has zero MXU stat FLOPs."""
    from repro.kernels.pairwise import specs as pw_specs
    nr = nc = 256
    d, m, B = 8, 16, 7
    lap = pw_specs.suggested_spec("laplacian", d)
    mxu_form = rl.pairwise_launch_model(lap, nr, nc, d, m,
                                        l1_route="mxu_signsplit", segments=B)
    vpu_form = rl.pairwise_launch_model(lap, nr, nc, d, m,
                                        l1_route="vpu_loop")
    entries = nr * nc
    inner = 2 * d * B
    assert mxu_form["mxu_gflops"] * 1e9 == pytest.approx(
        (4 * inner + 2 * m) * entries)
    assert vpu_form["vpu_gflops"] * 1e9 == pytest.approx(
        (4 * d + 8) * entries)
    assert vpu_form["mxu_gflops"] * 1e9 == pytest.approx(2 * m * entries)
    # dot: pure MXU statistic
    lin = pw_specs.suggested_spec("linear", d)
    lin_model = rl.pairwise_launch_model(lin, nr, nc, d, m)
    assert lin_model["mxu_gflops"] * 1e9 == pytest.approx(
        (2 * d + 2 * m) * entries)
    # bf16 tiles halve the point bytes on the HBM floor
    rbf = pw_specs.suggested_spec("rbf", d)
    f32b = rl.pairwise_launch_model(rbf, nr, nc, d, m)["hbm_gbytes"]
    bf16b = rl.pairwise_launch_model(
        rbf.with_precision("bf16_f32acc"), nr, nc, d, m)["hbm_gbytes"]
    assert bf16b < f32b


def test_achieved_vs_roofline_report():
    from repro.kernels.pairwise import specs as pw_specs
    toy = rl.HardwareProfile("toy", peak_flops=1e12, hbm_bw=1e11,
                             link_bw=1e10)
    spec = pw_specs.suggested_spec("rbf", 8)
    rep = rl.achieved_vs_roofline(spec, (256, 256, 8), None,
                                  measured_s=1.0, m_total=16, profile=toy)
    assert rep["kernel"] == "rbf" and rep["precision"] == "f32"
    assert rep["profile"] == "toy" and rep["chips"] == 1
    assert rep["bottleneck"] in ("compute", "memory")
    assert rep["roofline_s"] == pytest.approx(
        max(rep["compute_s"], rep["memory_s"]))
    assert rep["achieved_frac"] == pytest.approx(rep["roofline_s"])
    # a 4x faster launch achieves 4x the fraction
    rep4 = rl.achieved_vs_roofline(spec, (256, 256, 8), None,
                                   measured_s=0.25, m_total=16, profile=toy)
    assert rep4["achieved_frac"] == pytest.approx(4 * rep["achieved_frac"])
