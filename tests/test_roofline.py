"""Unit tests for the roofline HLO miners and dry-run helpers."""

import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rl
from repro.launch.dryrun import _reduced_cfg, scan_reps

HLO = """\
HloModule test, is_scheduled=true

%fused_computation (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %c = f32[] constant(2)
  %b = f32[128,128]{1,0} broadcast(%c), dimensions={}
  ROOT %m = f32[128,128]{1,0} multiply(%p0, %b)
}

ENTRY %main (a: bf16[128,256], b: bf16[256,128]) -> f32[128,128] {
  %a = bf16[128,256]{1,0} parameter(0)
  %b = bf16[256,128]{1,0} parameter(1)
  %dot.1 = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[128,128]{1,0} all-gather(%dot.1), replica_groups={}, dimensions={0}
  %ar = f32[128,128]{1,0} all-reduce(%ag), to_apply=%add
  %fusion.1 = f32[128,128]{1,0} fusion(%ar), kind=kLoop, calls=%fused_computation
  ROOT %copy.1 = f32[128,128]{1,0} copy(%fusion.1)
}
"""

F32_128 = 128 * 128 * 4
BF16_A = 128 * 256 * 2


def test_collective_bytes():
    got = rl.collective_bytes(HLO)
    assert got["all-gather"] == F32_128
    assert got["all-reduce"] == F32_128
    assert got["all-to-all"] == 0


def test_hbm_bytes_counts_memory_ops_only():
    got = rl.hbm_bytes(HLO)
    # dot: result + 2 operands; ag/ar: result+operand each; copy: res+operand
    # kLoop fusion skipped (not wrapped_*); interior of %fused skipped
    expect = (F32_128 + 2 * BF16_A) + 2 * (2 * F32_128) + 2 * F32_128
    assert got == expect, (got, expect)


def test_shape_bytes():
    assert rl._shape_bytes("bf16", "4,8") == 64
    assert rl._shape_bytes("f32", "") == 4


def test_model_flops_conventions():
    cfg = get_config("yi-6b")
    tr = rl.model_flops(cfg, SHAPES["train_4k"])
    pf = rl.model_flops(cfg, SHAPES["prefill_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    w = get_config("whisper-large-v3")
    tw = rl.model_flops(w, SHAPES["train_4k"])
    assert tw == pytest.approx(3 * w.param_count() * 256 * (4096 + 512))


def test_reduced_cfg_and_scan_reps():
    cfg = get_config("deepseek-v3-671b")
    assert scan_reps(cfg) == 58
    r1 = _reduced_cfg(cfg, 1)
    assert r1.n_layers == 4 and not r1.scan_layers and r1.unroll_scans
    rg = get_config("recurrentgemma-2b")
    assert scan_reps(rg) == 8
    assert _reduced_cfg(rg, 2).n_layers == 3 + 2 * 3 + 2 - 3  # 3*2 + rem 2
    w = get_config("whisper-large-v3")
    assert scan_reps(w) == 32
    assert _reduced_cfg(w, 2).n_enc_layers == 2


def test_roofline_finalize_bottleneck():
    r = rl.Roofline(arch="a", shape="s", mesh="m", chips=256,
                    hlo_gflops=197_000.0, hlo_gbytes=10.0,
                    coll_gbytes=100_000.0, coll_by_kind={},
                    model_gflops=197_000.0 * 256,
                    bytes_per_chip=0.0).finalize()
    assert r.compute_s == pytest.approx(1.0)
    assert r.bottleneck == "collective"
    assert r.useful_flops_frac == pytest.approx(1.0)
