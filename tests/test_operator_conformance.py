"""Operator conformance harness: ONE parameterized contract run over every
(operator construction × registered KernelSpec) pair.

For each registered kernel the harness builds the operator three ways —
``PairwiseKernel`` (jnp panel route), ``PairwiseKernel(use_pallas=True)``
(fused template, interpret mode on CPU), and ``DenseSPSD`` over the
independent ``pairwise/ref.py`` oracle — plus the factored ``LinearKernel``
for the linear spec, and asserts the full ``SPSDOperator`` protocol against
the oracle to ≤ 1e-5 (scale-normalized): matmat / columns / block / diag /
frobenius / multi-plan sweep parity, recorded sweep routes, and pytree
round-trips.  Hypothesis drives extra shape coverage; the forced-8-device CI
job re-runs the file so the sharded sweep cases execute too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from tests._hypothesis_compat import given, settings, st

from repro.core import sweep as sw
from repro.core.instrument import CountingOperator
from repro.core.kernelop import (DenseSPSD, LinearKernel, PairwiseKernel,
                                 SPSDOperator)
from repro.kernels.pairwise import ref as pw_ref
from repro.kernels.pairwise import specs as pw_specs

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

N, D = 131, 6


def _data(seed=0, n=N, d=D):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)), jnp.float32)


def _parity(got, ref, tol=1e-5):
    """max|got − ref| ≤ tol · max(1, max|ref|) — tol-level parity relative to
    the result scale (f32 contractions reassociate across routes)."""
    got, ref = np.asarray(got), np.asarray(ref)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    scale = max(1.0, float(np.max(np.abs(ref))))
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol * scale)


def _build(op_kind: str, X, spec) -> SPSDOperator:
    if op_kind == "pairwise":
        return PairwiseKernel(X, spec, use_pallas=False)
    if op_kind == "pairwise_pallas":
        return PairwiseKernel(X, spec, use_pallas=True)
    if op_kind == "dense":
        return DenseSPSD(jnp.asarray(pw_ref.kernel_block(spec, X, X)))
    if op_kind == "linear_factored":
        return LinearKernel(X)
    raise ValueError(op_kind)


OP_KINDS = ("pairwise", "pairwise_pallas", "dense")
CASES = [(name, kind) for name in pw_specs.registered_kernels()
         for kind in OP_KINDS] + [("linear", "linear_factored")]


@pytest.mark.parametrize("name,op_kind", CASES,
                         ids=[f"{n}-{k}" for n, k in CASES])
def test_operator_protocol_conformance(name, op_kind):
    """The whole pointwise + streaming protocol against the ref.py oracle."""
    X = _data(0)
    spec = pw_specs.suggested_spec(name, D)
    op = _build(op_kind, X, spec)
    Kd = np.asarray(pw_ref.kernel_block(spec, X, X), np.float64)
    n = op.n
    assert n == N

    rng = np.random.default_rng(1)
    V = jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)

    # matmat / frobenius (streaming protocol)
    _parity(op.matmat(V), Kd @ np.asarray(V, np.float64))
    got_fro = float(op.frobenius_norm_sq(block_size=48))
    assert got_fro == pytest.approx(float((Kd ** 2).sum()), rel=1e-4)

    # pointwise access: columns / block / diag
    cidx = jnp.asarray([0, 7, n // 2, n - 1])
    _parity(op.columns(cidx), Kd[:, np.asarray(cidx)])
    ridx = jnp.asarray([3, 50, n - 1])
    bidx = jnp.asarray([1, 4, n // 3])
    _parity(op.block(ridx, bidx), Kd[np.asarray(ridx)][:, np.asarray(bidx)])
    _parity(op.diag(), np.diagonal(Kd))

    # multi-plan sweep from one pass: matmul-shaped bundle + recorded route
    got_mat, got_gat = op.sweep([sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx)],
                                block_size=48)
    _parity(got_mat, Kd @ np.asarray(V, np.float64))
    _parity(got_gat, Kd[:, np.asarray(cidx)])
    expected_route = ("pallas_fused" if op.supports_fused_matmat()
                      else "panel")
    assert op._last_sweep_route == expected_route

    # a non-matmul plan forces (and records) the panel route for everyone
    got_fro2, = op.sweep([sw.FrobeniusPlan()], block_size=48)
    assert op._last_sweep_route == "panel"
    assert float(got_fro2) == pytest.approx(float((Kd ** 2).sum()), rel=1e-4)


@pytest.mark.parametrize("name,op_kind", CASES,
                         ids=[f"{n}-{k}" for n, k in CASES])
def test_operator_pytree_round_trip(name, op_kind):
    """flatten→unflatten preserves class, metadata, and operator behavior."""
    X = _data(2)
    spec = pw_specs.suggested_spec(name, D)
    op = _build(op_kind, X, spec)
    leaves, treedef = jax.tree_util.tree_flatten(op)
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(op2) is type(op)
    assert op2.n == op.n
    if isinstance(op, PairwiseKernel):
        assert op2.spec is op.spec          # registry-cached spec identity
        assert op2.use_pallas == op.use_pallas
    V = jnp.asarray(np.random.default_rng(3).normal(size=(op.n, 3)),
                    jnp.float32)
    np.testing.assert_array_equal(np.asarray(op.matmat(V)),
                                  np.asarray(op2.matmat(V)))


@pytest.mark.parametrize("name", pw_specs.registered_kernels())
def test_counting_operator_transparency(name):
    """CountingOperator must not perturb results and must record the route
    the inner operator took, for every spec."""
    X = _data(4)
    spec = pw_specs.suggested_spec(name, D)
    inner = PairwiseKernel(X, spec, use_pallas=True)
    Kc = CountingOperator(inner)
    V = jnp.asarray(np.random.default_rng(5).normal(size=(N, 4)), jnp.float32)
    got = Kc.matmat(V)
    ref = PairwiseKernel(X, spec, use_pallas=True).matmat(V)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert Kc.last_route == "pallas_fused"
    assert Kc.counts["sweeps"] == 1 and Kc.counts["fused_sweeps"] == 1


@settings(max_examples=5, deadline=None)
@given(n=st.integers(65, 180), d=st.integers(2, 8),
       seed=st.integers(0, 2 ** 16))
def test_conformance_shapes_hypothesis(n, d, seed):
    """Random (n, d): matmat + columns parity for a seed-chosen spec on both
    the jnp and dense constructions (tile-alignment must never matter)."""
    names = pw_specs.registered_kernels()
    spec = pw_specs.suggested_spec(names[seed % len(names)], d)
    X = _data(seed, n=n, d=d)
    Kd = np.asarray(pw_ref.kernel_block(spec, X, X), np.float64)
    V = jnp.asarray(np.random.default_rng(seed + 1).normal(size=(n, 3)),
                    jnp.float32)
    cidx = jnp.asarray([0, n // 2, n - 1])
    for op in (PairwiseKernel(X, spec), DenseSPSD(jnp.asarray(Kd, jnp.float32))):
        _parity(op.matmat(V, block_size=37), Kd @ np.asarray(V, np.float64))
        _parity(op.columns(cidx), Kd[:, np.asarray(cidx)])


# ---------------------------------------------------------------------------
# the precision axis: both tile policies, both routes, every registered spec
# ---------------------------------------------------------------------------

#: f32 at parity tol; bf16_f32acc within the quantization budget
PREC_TOL = {"f32": 1e-5, "bf16_f32acc": 5e-2}


@pytest.mark.parametrize("precision", pw_specs.PRECISIONS)
@pytest.mark.parametrize("name", pw_specs.registered_kernels())
def test_conformance_precision_policy(name, precision):
    """matmat / block / sweep under each tile policy vs the f32 oracle,
    plus the recorded route suffix and CountingOperator attribution."""
    X = _data(8)
    spec = pw_specs.suggested_spec(name, D).with_precision(precision)
    tol = PREC_TOL[precision]
    Kd = np.asarray(pw_ref.kernel_block(spec.with_precision("f32"), X, X),
                    np.float64)
    rng = np.random.default_rng(9)
    V = jnp.asarray(rng.normal(size=(N, 4)), jnp.float32)
    ridx = jnp.asarray([0, 17, N - 1])
    cidx = jnp.asarray([3, N // 2, N - 2])
    for use_pallas in (True, False):
        op = PairwiseKernel(X, spec, use_pallas=use_pallas)
        assert op.precision == precision
        _parity(op.matmat(V), Kd @ np.asarray(V, np.float64), tol=tol)
        _parity(op.block(ridx, cidx),
                Kd[np.asarray(ridx)][:, np.asarray(cidx)], tol=tol)
    Kc = CountingOperator(PairwiseKernel(X, spec, use_pallas=True))
    (got,) = Kc.sweep([sw.MatmulPlan(V)])
    _parity(got, Kd @ np.asarray(V, np.float64), tol=tol)
    suffix = "" if precision == "f32" else "+" + precision
    assert Kc.last_route == "pallas_fused" + suffix
    assert Kc.last_precision == precision
    assert Kc.counts["bf16_sweeps"] == (0 if precision == "f32" else 1)
    assert Kc.counts["fused_sweeps"] == 1     # suffix must not break metering


def test_with_precision_preserves_spec_identity_invariants():
    """One object per (spec, precision) — the jit-cache invariant — and the
    f32 round-trip is the original factory object."""
    spec = pw_specs.suggested_spec("rbf", D)
    bf = spec.with_precision("bf16_f32acc")
    assert bf is spec.with_precision("bf16_f32acc")
    assert spec.with_precision("f32") is spec
    assert bf.with_precision("f32") is spec
    assert bf.name == spec.name and bf.params == spec.params
    with pytest.raises(ValueError, match="precision"):
        spec.with_precision("f16")


# ---------------------------------------------------------------------------
# forced-8-device path (the CI multidevice job re-runs this file)
# ---------------------------------------------------------------------------

def _mesh():
    return Mesh(np.asarray(jax.devices()), ("data",))


@multidevice
@pytest.mark.parametrize("name", pw_specs.registered_kernels())
@pytest.mark.parametrize("use_pallas", [True, False],
                         ids=["pallas", "jnp"])
def test_conformance_sharded_sweep(name, use_pallas):
    """Sharded sweeps for every spec: parity vs the oracle AND the recorded
    route ('pallas_fused_sharded' for fused-capable, 'panel' otherwise)."""
    n = 259
    X = _data(6, n=n)
    spec = pw_specs.suggested_spec(name, D)
    Kc = CountingOperator(PairwiseKernel(X, spec, use_pallas=use_pallas))
    Kd = np.asarray(pw_ref.kernel_block(spec, X, X), np.float64)
    V = jnp.asarray(np.random.default_rng(7).normal(size=(n, 4)), jnp.float32)
    cidx = jnp.asarray([2, n // 2, n - 1])
    got = Kc.sweep([sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx)],
                   mesh=_mesh())
    assert Kc.last_route == ("pallas_fused_sharded" if use_pallas
                             else "panel")
    _parity(got[0], Kd @ np.asarray(V, np.float64))
    _parity(got[1], Kd[:, np.asarray(cidx)])
