"""Per-Pallas-kernel shape/dtype sweeps against the pure-jnp ref oracles
(interpret mode on CPU; the kernels themselves target TPU BlockSpecs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.landmark_attention import ops as lm_ops, ref as lm_ref
from repro.kernels.rbf_sketch import ops as rbf_ops, ref as rbf_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D", [
    (1, 4, 4, 128, 128, 64),      # MHA square
    (2, 8, 2, 128, 128, 32),      # GQA 4:1
    (1, 4, 1, 256, 256, 64),      # MQA
    (2, 4, 2, 100, 100, 32),      # non-multiple seq (padding path)
    (1, 2, 2, 1, 256, 64),        # decode: Sq=1 right-aligned
    (1, 4, 2, 64, 256, 32),       # chunked prefill continuation
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, Hq, Hkv, Sq, Sk, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(ks[0], (B, Hq, Sq, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, Hkv, Sk, D)) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, D)).astype(dtype)
    out = fa_ops.flash_attention(q, k, v, causal=True)
    ref = fa_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 64, 200])
def test_flash_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 32)) * 0.5
    k = jax.random.normal(ks[1], (1, 2, 256, 32)) * 0.5
    v = jax.random.normal(ks[2], (1, 2, 256, 32))
    out = fa_ops.flash_attention(q, k, v, causal=True, window=window)
    ref = fa_ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_flash_block_shapes():
    """block sizes sweep (VMEM tiling knobs)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 512, 64)) * 0.5
    k = jax.random.normal(ks[1], (1, 2, 512, 64)) * 0.5
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    ref = fa_ref.attention(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 256), (256, 128)]:
        out = fa_ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                     block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# landmark (fast-SPSD) read
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,c,d,dv", [
    (128, 16, 64, 64), (200, 32, 32, 16), (64, 8, 128, 128), (1, 16, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_landmark_read_vs_ref(m, c, d, dv, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    Q = (jax.random.normal(ks[0], (m, d)) * 0.5).astype(dtype)
    kl = (jax.random.normal(ks[1], (c, d)) * 0.5).astype(dtype)
    UV = jax.random.normal(ks[2], (c, dv)).astype(dtype)
    U1 = jnp.abs(jax.random.normal(ks[3], (c,))) + 0.5
    off = jnp.asarray(0.3)
    out = lm_ops.landmark_read(Q, kl, UV, U1, off)
    ref = lm_ref.landmark_read(Q, kl, UV, U1, off)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# fused RBF sketch blocks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nr,nc,d", [(128, 128, 16), (96, 64, 8),
                                     (200, 50, 32), (17, 33, 4)])
@pytest.mark.parametrize("sigma", [0.5, 2.0])
def test_rbf_block_vs_ref(nr, nc, d, sigma):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    X = jax.random.normal(ks[0], (nr, d))
    Y = jax.random.normal(ks[1], (nc, d))
    out = rbf_ops.rbf_block(X, Y, sigma)
    ref = rbf_ref.rbf_block(X, Y, sigma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_rbf_block_diag_is_one():
    X = jax.random.normal(jax.random.PRNGKey(5), (64, 8))
    K = rbf_ops.rbf_block(X, X, 1.3)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(K)), 1.0, atol=1e-4)


def test_sketched_gram_vs_ref():
    X = jax.random.normal(jax.random.PRNGKey(6), (150, 12))
    g1 = rbf_ops.sketched_gram(X, 1.1)
    g2 = rbf_ref.sketched_gram(X, 1.1)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-4,
                               atol=2e-4)
    # SPSD check
    ev = np.linalg.eigvalsh(np.asarray(g2, np.float64))
    assert ev.min() > -1e-4


def test_rbf_kernel_operator_uses_pallas_path():
    """RBFKernel(use_pallas=True) must agree with the jnp path."""
    from repro.core.kernelop import RBFKernel
    X = jax.random.normal(jax.random.PRNGKey(7), (100, 10))
    idx = jnp.arange(20)
    a = RBFKernel(X, sigma=1.7, use_pallas=False).block(idx, idx + 5)
    b = RBFKernel(X, sigma=1.7, use_pallas=True).block(idx, idx + 5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("n,d,m", [(128, 8, 128), (300, 16, 7), (130, 5, 1),
                                   (256, 32, 200)])
def test_rbf_matmat_vs_ref(n, d, m):
    """Fused streaming K @ V (kernel tiles stay in VMEM) vs dense oracle."""
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    X = jax.random.normal(ks[0], (n, d))
    V = jax.random.normal(ks[1], (n, m))
    out = rbf_ops.rbf_matmat(X, V, 1.3)
    ref = rbf_ref.rbf_matmat(X, V, 1.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("nr,nc,d", [(128, 256, 8), (67, 533, 6), (40, 40, 4)])
def test_rbf_matmat_multi_rows_vs_ref(nr, nc, d):
    """Rectangular row-slab multi-RHS launch (the shard_map fast path) vs
    the dense oracle: K[r0:r1, :] @ [V...] with one K-tile evaluation."""
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    Xc = jax.random.normal(ks[0], (nc, d))
    Xr = Xc[:nr]                             # a row slab of the point set
    Vs = (jax.random.normal(ks[1], (nc, 5)),
          jax.random.normal(ks[2], (nc, 130)))
    outs = rbf_ops.rbf_matmat_multi_rows(Xr, Xc, Vs, 1.3)
    refs = rbf_ref.rbf_matmat_multi_rows(Xr, Xc, Vs, 1.3)
    assert len(outs) == 2
    for out, ref in zip(outs, refs):
        assert out.shape == ref.shape
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)


def test_rbf_matmat_multi_square_delegates_to_rows():
    """The square multi-RHS path and the rows path agree exactly."""
    ks = jax.random.split(jax.random.PRNGKey(12), 2)
    X = jax.random.normal(ks[0], (150, 8))
    Vs = (jax.random.normal(ks[1], (150, 9)),)
    a = rbf_ops.rbf_matmat_multi(X, Vs, 0.8)
    b = rbf_ops.rbf_matmat_multi_rows(X, X, Vs, 0.8)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


def test_rbf_matmat_vector_rhs_and_operator_wiring():
    from repro.core.kernelop import RBFKernel
    X = jax.random.normal(jax.random.PRNGKey(9), (100, 6))
    v = jax.random.normal(jax.random.PRNGKey(10), (100,))
    out = rbf_ops.rbf_matmat(X, v, 0.9)
    assert out.shape == (100,)
    Kop = RBFKernel(X, sigma=0.9, use_pallas=True)
    np.testing.assert_allclose(np.asarray(Kop.matmat(v[:, None])[:, 0]),
                               np.asarray(out), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(Kop.full() @ v), np.asarray(out),
                               rtol=2e-3, atol=2e-3)
