"""Per-arch smoke tests (reduced configs) + decode-path consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.configs.base import ModelConfig
from repro.models.model import build_model
import repro.models.attention as A


def _batch_for(cfg, key, B=2, S=64):
    if cfg.is_encdec:
        toks = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)
        return {"frames": jax.random.normal(key, (B, S, cfg.frontend_dim)),
                "tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family == "vlm":
        toks = jax.random.randint(key, (B, 300), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
                "patches": jax.random.normal(
                    key, (B, 256, cfg.d_model)).astype(cfg.cdtype)}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(m.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0

    logits, _ = m.forward(params, batch)
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one gradient step moves the loss
    grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode(arch):
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    key = jax.random.PRNGKey(2)
    if cfg.is_encdec:
        batch = {"frames": jax.random.normal(key, (B, 32, cfg.frontend_dim)),
                 "tokens": jnp.ones((B, 1), jnp.int32)}
    else:
        batch = {"tokens": jax.random.randint(key, (B, S), 0,
                                              cfg.vocab_size)}
    logits, cache = m.prefill(params, batch, jax.random.PRNGKey(3), 32)
    assert logits.shape == (B, cfg.vocab_size)
    pos = jnp.asarray(1 if cfg.is_encdec else S, jnp.int32)
    lg2, cache = m.decode_step(params, cache,
                               jnp.ones((B, 1), jnp.int32), pos)
    assert lg2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg2.astype(jnp.float32)))), arch


# ---------------------------------------------------------------------------
# decode == teacher-forced forward (the KV-cache correctness invariant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern,window", [
    (("attn",), None),
    (("local", "global"), 8),
])
def test_decode_matches_forward(pattern, window):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, layer_pattern=pattern, window=window,
                      dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 128)

    full_logits, _ = m.forward(params, {"tokens": toks})

    npre = 8
    _, cache = m.prefill(params, {"tokens": toks[:, :npre]},
                         jax.random.PRNGKey(2), S)
    outs = []
    for t in range(npre, S):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.asarray(t, jnp.int32))
        outs.append(lg)
    # decode_step at position t sees tokens[:, :t+1]; compare to forward
    for i, t in enumerate(range(npre, S)):
        np.testing.assert_allclose(
            np.asarray(outs[i]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3)


def test_mla_decode_matches_forward():
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32",
                      use_mla=True, q_lora_rank=32, kv_lora_rank=32,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 128)
    full_logits, _ = m.forward(params, {"tokens": toks})
    _, cache = m.prefill(params, {"tokens": toks[:, :4]},
                         jax.random.PRNGKey(2), S)
    for t in range(4, S):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, t]),
                                   rtol=5e-3, atol=5e-3)


def test_mla_absorb_matches_materialized():
    """Absorbed (latent) MLA decode == materializing K/V then attending."""
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32",
                      use_mla=True, q_lora_rank=32, kv_lora_rank=32,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    cfg2 = dataclasses.replace(cfg, mla_absorb=False)
    m, m2 = build_model(cfg), build_model(cfg2)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 128)
    _, c1 = m.prefill(params, {"tokens": toks}, jax.random.PRNGKey(2), 16)
    _, c2 = m2.prefill(params, {"tokens": toks}, jax.random.PRNGKey(2), 16)
    l1, _ = m.decode_step(params, c1, toks[:, :1], jnp.asarray(10, jnp.int32))
    l2, _ = m2.decode_step(params, c2, toks[:, :1],
                           jnp.asarray(10, jnp.int32))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-3,
                               atol=2e-3)


def test_recurrent_decode_matches_full():
    """rglru / mlstm / slstm decode states reproduce the full pass."""
    cfg = ModelConfig(name="t", family="hybrid", n_layers=3, d_model=32,
                      n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                      vocab_size=64, dtype="float32",
                      layer_pattern=("rglru", "mlstm", "slstm"),
                      window=8, lru_width=32, mlstm_chunk=4)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 64)
    full_logits, _ = m.forward(params, {"tokens": toks})
    _, cache = m.prefill(params, {"tokens": toks[:, :4]},
                         jax.random.PRNGKey(2), S)
    for t in range(4, S):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, t]),
                                   rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# chunked attention internal consistency
# ---------------------------------------------------------------------------

def test_chunked_equals_dense_sdpa():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=64, dtype="float32")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    S = 4096                               # > DENSE_LIMIT -> chunked
    q = jax.random.normal(ks[0], (1, S, 4, 32)) * 0.3
    k = jax.random.normal(ks[1], (1, S, 2, 32)) * 0.3
    v = jax.random.normal(ks[2], (1, S, 2, 32))
    chunked = A._sdpa(q, k, v, cfg, causal=True)
    rows = jnp.arange(S)
    dense = A._blk_attend(
        jnp.repeat(q, 1, 2), jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2),
        rows, rows, scale=32 ** -0.5, causal=True, window=None,
        kv_valid=None)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_unroll_scans_matches_scan():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=64, dtype="float32")
    cfg_u = dataclasses.replace(cfg, unroll_scans=True)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    S = 4096
    q = jax.random.normal(ks[0], (1, S, 2, 32)) * 0.3
    k = jax.random.normal(ks[1], (1, S, 2, 32)) * 0.3
    v = jax.random.normal(ks[2], (1, S, 2, 32))
    a = A._sdpa(q, k, v, cfg, causal=True)
    b = A._sdpa(q, k, v, cfg_u, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
