"""Pluggable kernel-operator subsystem: every registered KernelSpec through
the shared Pallas sweep template vs its independent dense oracle, the
PairwiseKernel operator protocol, and registry round-trips (including a
user-registered custom kernel riding the full fused machinery)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spsd
from repro.core import sweep as sw
from repro.core.instrument import CountingOperator
from repro.core.kernelop import LinearKernel, PairwiseKernel, RBFKernel
from repro.kernels.pairwise import ops as pw_ops
from repro.kernels.pairwise import ref as pw_ref
from repro.kernels.pairwise import specs

# the shared registry-sweep parameterization (specs.suggested_params keeps
# entries O(1) on unit-scale data; custom kernels get factory defaults)
_spec = specs.suggested_spec


def _points(seed, n, d=8):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)), jnp.float32)


def assert_parity(got, ref, tol=1e-5):
    """max|got − ref| ≤ tol · max(1, max|ref|): parity at tol relative to the
    result scale (contractions legitimately reassociate f32 sums, so a plain
    elementwise rtol explodes on near-zero entries of sign-mixed products)."""
    got, ref = np.asarray(got), np.asarray(ref)
    assert got.shape == ref.shape
    scale = max(1.0, float(np.max(np.abs(ref))) if ref.size else 0.0)
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol * scale)


ALL_KERNELS = specs.registered_kernels()


def test_registry_covers_the_paper_suite():
    for name in ("rbf", "laplacian", "matern32", "polynomial", "linear"):
        assert name in ALL_KERNELS
    with pytest.raises(ValueError, match="unknown kernel"):
        specs.get_spec("no-such-kernel")


def test_spec_factories_cache_one_object_per_parameter_set():
    """jit caches key on the spec object, so factories must dedup."""
    assert specs.get_spec("rbf", sigma=2.0) is specs.get_spec("rbf", sigma=2.0)
    assert specs.get_spec("rbf", sigma=2.0) is specs.get_spec("rbf", sigma=2)
    assert specs.get_spec("rbf", sigma=2.0) is not specs.get_spec("rbf",
                                                                  sigma=3.0)


# ---------------------------------------------------------------------------
# the shared Pallas template vs the independent dense oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_KERNELS)
@pytest.mark.parametrize("nr,nc", [(128, 128), (96, 64), (137, 51)])
def test_pairwise_block_vs_ref(name, nr, nc):
    spec = _spec(name)
    X = _points(0, nr)
    Y = _points(1, nc)
    out = pw_ops.kernel_block(spec, X, Y)
    ref = pw_ref.kernel_block(spec, X, Y)
    assert out.shape == (nr, nc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_pairwise_matmat_multi_rows_vs_ref(name):
    """Rectangular row-slab multi-RHS launch (the shard_map fast path)."""
    spec = _spec(name)
    Xc = _points(2, 300)
    Xr = Xc[:70]                               # a row slab of the point set
    rng = np.random.default_rng(3)
    Vs = (jnp.asarray(rng.normal(size=(300, 5)), jnp.float32),
          jnp.asarray(rng.normal(size=(300, 130)), jnp.float32))
    outs = pw_ops.kernel_matmat_multi_rows(spec, Xr, Xc, Vs)
    refs = pw_ref.kernel_matmat_multi_rows(spec, Xr, Xc, Vs)
    assert len(outs) == 2
    for out, ref in zip(outs, refs):
        assert_parity(out, ref)


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_dense_fallback_matches_oracle(name):
    """The non-Pallas route (specs.apply) agrees with the independent ref."""
    spec = _spec(name)
    X = _points(4, 90)
    np.testing.assert_allclose(
        np.asarray(pw_ops.kernel_block(spec, X, X, use_pallas=False)),
        np.asarray(pw_ref.kernel_block(spec, X, X)), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# PairwiseKernel operator protocol
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_KERNELS)
def test_pairwise_kernel_block_columns_diag(name):
    spec = _spec(name)
    X = _points(5, 120)
    Kp = PairwiseKernel(X, spec, use_pallas=True)
    Kg = PairwiseKernel(X, spec, use_pallas=False)
    Kd = np.asarray(pw_ref.kernel_block(spec, X, X))
    idx = jnp.asarray([0, 7, 63, 119])
    for K in (Kp, Kg):
        np.testing.assert_allclose(np.asarray(K.columns(idx)),
                                   Kd[:, np.asarray(idx)],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(K.block(idx, idx)),
                                   Kd[np.ix_(np.asarray(idx),
                                             np.asarray(idx))],
                                   rtol=1e-5, atol=1e-5)
        # diag shortcut touches no off-diagonal entry but must match them
        np.testing.assert_allclose(np.asarray(K.diag()), np.diagonal(Kd),
                                   rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_every_kernel_rides_the_fused_sweep(name):
    """fast_model on any registered kernel: ONE fused sweep, finite error —
    the zero-per-call-site promise of the capability protocol."""
    spec = _spec(name)
    rng = np.random.default_rng(6)
    centers = rng.normal(size=(4, 8)) * 1.5           # low-rank-ish structure
    X = jnp.asarray(centers[rng.integers(0, 4, size=150)]
                    + rng.normal(size=(150, 8)) * 0.2, jnp.float32)
    Kc = CountingOperator(PairwiseKernel(X, spec, use_pallas=True))
    ap = spsd.fast_model(Kc, jax.random.PRNGKey(0), c=10, s=40,
                         s_sketch="gaussian", streaming=True)
    assert Kc.last_route == "pallas_fused"
    assert Kc.counts["fused_sweeps"] == 1 and Kc.counts["sweeps"] == 1
    err = float(spsd.relative_error(
        PairwiseKernel(X, spec, use_pallas=False), ap, method="dense"))
    assert np.isfinite(err) and 0.0 <= err < 1.0, err


def test_custom_registered_kernel_end_to_end():
    """The docstring integration story: register a spec, get the fused path."""
    name = "cauchy-test"
    if name not in specs.registered_kernels():
        @specs.register_kernel(name)
        def cauchy(gamma: float = 1.0) -> specs.KernelSpec:
            g = float(gamma)
            return specs.KernelSpec(
                name=name, stat="sqdist",
                entry_fn=lambda sq: 1.0 / (1.0 + g * sq),
                params=(("gamma", g),))

    spec = specs.get_spec(name, gamma=0.5)
    X = _points(7, 140)
    Kc = CountingOperator(PairwiseKernel(X, spec, use_pallas=True))
    V = jnp.asarray(np.random.default_rng(8).normal(size=(140, 4)),
                    jnp.float32)
    (got,) = Kc.sweep([sw.MatmulPlan(V)])
    assert Kc.last_route == "pallas_fused"
    Kd = 1.0 / (1.0 + 0.5 * np.asarray(
        specs.stat_block("sqdist", X, X)))
    assert_parity(got, Kd @ np.asarray(V))


# ---------------------------------------------------------------------------
# mixed-precision policy through the shared template
# ---------------------------------------------------------------------------

#: f32 at template parity; bf16 tiles within the quantization budget
PREC_TOL = {"f32": 1e-5, "bf16_f32acc": 5e-2}


@pytest.mark.parametrize("name", ALL_KERNELS)
@pytest.mark.parametrize("precision", specs.PRECISIONS)
def test_pairwise_block_precision_vs_oracle(name, precision):
    """Both tile policies against the f32 oracle, Pallas and dense routes —
    and the two routes agree with each other bit-for-policy (both quantize
    identically, so their mutual gap stays at f32 parity)."""
    spec = _spec(name).with_precision(precision)
    X = _points(12, 100)
    Y = _points(13, 90)
    out = pw_ops.kernel_block(spec, X, Y)
    dense = pw_ops.kernel_block(spec, X, Y, use_pallas=False)
    ref = pw_ref.kernel_block(_spec(name), X, Y)
    assert_parity(out, ref, tol=PREC_TOL[precision])
    assert_parity(out, dense)


@pytest.mark.parametrize("precision", specs.PRECISIONS)
def test_fast_model_end_to_end_precision(precision):
    """fast_model_with_error runs the whole fused pipeline under each policy;
    bf16_f32acc may degrade the approximation by at most 5e-2."""
    spec = _spec("rbf").with_precision(precision)
    rng = np.random.default_rng(14)
    centers = rng.normal(size=(4, 8)) * 1.5
    X = jnp.asarray(centers[rng.integers(0, 4, size=150)]
                    + rng.normal(size=(150, 8)) * 0.2, jnp.float32)
    Kc = CountingOperator(PairwiseKernel(X, spec, use_pallas=True))
    ap, err = spsd.fast_model_with_error(Kc, jax.random.PRNGKey(1), c=10,
                                         s=40, s_sketch="gaussian", probes=16)
    suffix = "" if precision == "f32" else "+" + precision
    assert Kc.last_route == "pallas_fused" + suffix
    assert np.isfinite(float(err))
    ref_err = float(spsd.relative_error(
        PairwiseKernel(X, _spec("rbf"), use_pallas=False), ap,
        method="dense"))
    assert ref_err < 1.0
    # the bf16 model's true error may exceed the f32 pipeline's by at most
    # the quantization budget (both are ~0.2 at these shapes)
    f32_ap = spsd.fast_model(
        PairwiseKernel(X, _spec("rbf"), use_pallas=True),
        jax.random.PRNGKey(1), c=10, s=40, s_sketch="gaussian")
    f32_err = float(spsd.relative_error(
        PairwiseKernel(X, _spec("rbf"), use_pallas=False), f32_ap,
        method="dense"))
    assert ref_err <= f32_err + 5e-2


# ---------------------------------------------------------------------------
# back-compat constructors
# ---------------------------------------------------------------------------

def test_rbf_kernel_is_thin_pairwise_constructor():
    X = _points(9, 80)
    K = RBFKernel(X, sigma=1.7, use_pallas=True)
    assert isinstance(K, PairwiseKernel)
    assert K.spec is specs.get_spec("rbf", sigma=1.7)
    assert K.sigma == pytest.approx(1.7)
    # pytree round-trip (what vmap/jit do) preserves the spec
    leaves, treedef = jax.tree_util.tree_flatten(K)
    K2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(K2, RBFKernel) and K2.spec is K.spec


def test_linear_kernel_keeps_factored_fast_paths():
    X = _points(10, 80, d=5)
    K = LinearKernel(X)
    assert isinstance(K, PairwiseKernel)
    assert K.spec is specs.get_spec("linear")
    Kd = np.asarray(X @ X.T, np.float32)
    V = jnp.asarray(np.random.default_rng(11).normal(size=(80, 3)),
                    jnp.float32)
    np.testing.assert_allclose(np.asarray(K.matmat(V)), Kd @ np.asarray(V),
                               rtol=1e-4, atol=1e-4)
    assert float(K.frobenius_norm_sq()) == pytest.approx(
        float((Kd ** 2).sum()), rel=1e-4)
