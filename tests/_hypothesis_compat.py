"""Graceful degradation when ``hypothesis`` is absent.

``pip install -r requirements-dev.txt`` provides hypothesis in CI; on bare
environments the property-based tests must *skip*, not kill collection of
their entire module (most tests in those modules are plain pytest).  A
module-level ``pytest.importorskip("hypothesis")`` would throw away the whole
module, so instead we export drop-in shims: ``@given`` wraps the test into an
immediate skip, ``@settings`` is a no-op, and ``st.<anything>(...)`` returns
inert placeholders.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _InertStrategies:
        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _InertStrategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # No functools.wraps: the zero-arg signature must be what pytest
            # sees, or it would treat the strategy params as missing fixtures.
            def wrapper():
                pytest.importorskip("hypothesis")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
