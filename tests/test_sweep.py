"""Single-sweep panel engine: plan parity vs dense, entry-count guarantees
(CountingOperator), the fused Pallas multi-RHS path, padding masks, and the
blocked-Gram CUR leverage scores."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cur, selection, spsd
from repro.core import sketch as sk
from repro.core import sweep as sw
from repro.core.adaptive import _residual_column_norms, uniform_adaptive2_indices
from repro.core.instrument import CountingOperator
from repro.core.kernelop import (DenseSPSD, LinearKernel, PairwiseKernel,
                                 RBFKernel, SPSDOperator)
from repro.kernels.pairwise import specs as pw_specs
from repro.core.leverage import (column_leverage_scores_gram, pinv,
                                 row_leverage_scores, row_leverage_scores_gram)


def _clustered(seed, n=400, d=8, k=8):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 2.5
    labels = rng.integers(0, k, size=n)
    X = centers[labels] + rng.normal(size=(n, d)) * 0.4
    return jnp.asarray(X, jnp.float32)


def _rbf(seed, n=400, sigma=2.0, **kw):
    return RBFKernel(_clustered(seed, n=n), sigma=sigma, **kw)


# ---------------------------------------------------------------------------
# engine: every plan from one pass matches the dense reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_size", [64, 100, None])
def test_multi_plan_sweep_matches_dense(block_size):
    """One sweep, five plans — each result equals its dense counterpart."""
    Kop = _rbf(0, n=333)
    Kd = np.asarray(Kop.full(), np.float32)
    V = jax.random.normal(jax.random.PRNGKey(1), (Kop.n, 7), jnp.float32)
    cidx = jnp.asarray([3, 50, 200, 331])
    C32 = jnp.asarray(Kd[:, :5])
    M = jnp.asarray(np.linalg.pinv(np.asarray(C32)) @ Kd)

    mat, gat, fro, diag, (num, den) = Kop.sweep(
        [sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx), sw.FrobeniusPlan(),
         sw.DiagPlan(), sw.ResidualFroPlan(C32, M)],
        block_size=block_size)
    np.testing.assert_allclose(np.asarray(mat), Kd @ np.asarray(V),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gat), Kd[:, np.asarray(cidx)],
                               rtol=1e-5, atol=1e-6)
    assert float(fro) == pytest.approx(float((Kd ** 2).sum()), rel=1e-4)
    np.testing.assert_allclose(np.asarray(diag), np.diagonal(Kd),
                               rtol=1e-5, atol=1e-6)
    resid = Kd - np.asarray(C32) @ np.asarray(M)
    assert float(num) == pytest.approx(float((resid ** 2).sum()), rel=1e-3)
    assert float(den) == pytest.approx(float((Kd ** 2).sum()), rel=1e-4)


def test_sketch_right_plan_matches_dense():
    Kop = _rbf(1)
    Kd = np.asarray(Kop.full(), np.float32)
    for kind in ("srht", "countsketch"):
        S = sk.make_sketch(kind, jax.random.PRNGKey(2), Kop.n, 48)
        (KS,) = Kop.sweep([sk.plan_for_sketch(S)], block_size=128)
        ref = np.asarray(S.right(jnp.asarray(Kd)))
        np.testing.assert_allclose(np.asarray(KS), ref, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# fused fast_model: same numbers as the unfused routes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gaussian", "srht", "countsketch"])
def test_fused_fast_model_matches_dense_route(kind):
    """Same key -> same sketch -> the one-sweep model equals the dense one."""
    Kop = _rbf(2)
    ap_f = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=20, s=80,
                           s_sketch=kind, streaming=True)
    ap_d = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=20, s=80,
                           s_sketch=kind, streaming=False)
    np.testing.assert_allclose(np.asarray(ap_f.C), np.asarray(ap_d.C),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ap_f.U), np.asarray(ap_d.U),
                               rtol=2e-2, atol=1e-3)
    e_f = float(spsd.relative_error(Kop, ap_f, method="dense"))
    e_d = float(spsd.relative_error(Kop, ap_d, method="dense"))
    assert abs(e_f - e_d) < 1e-3


def test_fast_model_with_error_matches_hutchinson():
    """The fused model+error sweep returns exactly the Hutchinson estimate."""
    Kop = _rbf(3)
    ekey = jax.random.PRNGKey(11)
    ap, err = spsd.fast_model_with_error(Kop, jax.random.PRNGKey(0), c=20,
                                         s=80, probes=64, error_key=ekey)
    ref = float(spsd.relative_error(Kop, ap, method="hutchinson", probes=64,
                                    key=ekey))
    assert float(err) == pytest.approx(ref, rel=1e-4)
    e_dense = float(spsd.relative_error(Kop, ap, method="dense"))
    assert float(err) == pytest.approx(e_dense, rel=0.5)


# ---------------------------------------------------------------------------
# the entry-count guarantee (CountingOperator)
# ---------------------------------------------------------------------------

def test_fast_model_plus_error_two_sweeps_max():
    """fast_model evaluates each row panel once; + streaming error ≤ 2×."""
    Kc = CountingOperator(_rbf(4))
    ap = spsd.fast_model(Kc, jax.random.PRNGKey(0), c=20, s=80,
                         s_sketch="gaussian", streaming=True)
    assert Kc.counts["sweeps"] == 1          # C and K S from ONE pass
    assert Kc.counts["columns"] == 0         # no separate C gather
    assert Kc.counts["fulls"] == 0
    n = Kc.n
    assert Kc.counts["entries"] <= 1.1 * n * n

    float(spsd.relative_error(Kc, ap, method="blocked"))
    assert Kc.counts["sweeps"] == 2          # model + error ≤ 2 panel passes
    assert Kc.counts["entries"] <= 2.2 * n * n


def test_fused_model_with_error_single_sweep():
    Kc = CountingOperator(_rbf(5))
    ap, err = spsd.fast_model_with_error(Kc, jax.random.PRNGKey(0), c=20,
                                         s=80, probes=32)
    assert Kc.counts["sweeps"] == 1
    assert Kc.counts["fulls"] == 0 and Kc.counts["columns"] == 0
    assert np.isfinite(float(err))


def test_column_sketch_fast_model_needs_no_sweep():
    """uniform/leverage S: C is an n×c gather, StKS an s×s block — 0 sweeps."""
    Kc = CountingOperator(_rbf(6))
    spsd.fast_model(Kc, jax.random.PRNGKey(0), c=20, s=80, s_sketch="leverage")
    assert Kc.counts["sweeps"] == 0
    assert Kc.counts["columns"] == 1 and Kc.counts["blocks"] == 1


def test_adaptive_single_sweep_per_round():
    """PR-1 did 2 full passes per adaptive round; the Q-projection plan does 1."""
    Kc = CountingOperator(_rbf(7))
    idx = uniform_adaptive2_indices(Kc, jax.random.PRNGKey(0), 12)
    assert idx.shape == (12,)
    assert Kc.counts["sweeps"] == 2          # one per adaptive round
    assert Kc.counts["columns"] == 2         # the n×(c/3) C gathers


@pytest.mark.parametrize("name", selection.registered_policies())
def test_selection_policy_meets_declared_budget(name):
    """Every registered SelectionPolicy costs EXACTLY its declared kernel
    sweeps and column gathers — metered, not trusted."""
    pol = selection.get_policy(name)
    Kc = CountingOperator(_rbf(40))
    idx = np.asarray(pol.select(Kc, jax.random.PRNGKey(0), 12))
    assert idx.shape == (12,)
    assert len(set(idx.tolist())) == 12          # without replacement, always
    assert Kc.counts["sweeps"] == pol.sweep_budget()
    assert Kc.counts["columns"] == pol.gathers
    assert Kc.counts["fulls"] == 0


@pytest.mark.parametrize("name", selection.registered_policies())
def test_fast_model_selection_budget_is_model_plus_policy(name):
    """fast_model with any policy: 1 model sweep + exactly the policy's
    declared selection sweeps — policies never leak extra passes."""
    pol = selection.get_policy(name)
    Kc = CountingOperator(_rbf(41))
    ap = spsd.fast_model(Kc, jax.random.PRNGKey(0), c=18, s=72,
                         s_sketch="gaussian", streaming=True, selection=name)
    assert Kc.counts["sweeps"] == 1 + pol.sweep_budget()
    assert Kc.counts["fulls"] == 0
    e = float(spsd.relative_error(Kc, ap, method="dense"))
    assert np.isfinite(e) and e < 0.5


@pytest.mark.parametrize("name", selection.registered_policies())
def test_streaming_fast_cur_selection_adds_zero_extra_sweeps(name):
    """Streaming fast_cur on an implicit operator: the PR 2/3 budget was ONE
    sweep (A S_R); policy selection for C and R adds exactly 2× the policy's
    declared budget and nothing else."""
    pol = selection.get_policy(name)
    Kc = CountingOperator(_rbf(42, n=300))
    ap = cur.fast_cur(Kc, jax.random.PRNGKey(3), c=12, r=12, sc=48, sr=48,
                      sketch_kind="gaussian", selection=name)
    assert Kc.counts["sweeps"] == 1 + 2 * pol.sweep_budget()
    assert Kc.counts["fulls"] == 0
    Kd = jnp.asarray(np.asarray(_rbf(42, n=300).full(), np.float32))
    err = float(cur.relative_error(Kd, ap))
    assert np.isfinite(err) and err < 1.0


def test_adaptive_rounds_never_duplicate_columns():
    """Regression (PR 5): the pre-fix adaptive draw used ``replace=True``
    without zeroing selected indices, so a dominant residual column filled
    EVERY slot of an adaptive round (duplicate columns in C, wasted budget).
    K = identity with one huge diagonal entry reproduces it deterministically
    for any key whose uniform round misses that entry."""
    n = 40
    K = DenseSPSD(jnp.diag(jnp.ones((n,)).at[n - 1].set(1e4)))
    for seed in range(4):
        idx = np.asarray(uniform_adaptive2_indices(K, jax.random.PRNGKey(seed),
                                                   12))
        assert len(set(idx.tolist())) == 12, idx


def test_adaptive_rejects_c_below_round_count():
    """c too small for one draw per adaptive round must raise, not silently
    degrade to uniform while still declaring a 2-sweep budget."""
    with pytest.raises(ValueError, match="uniform_adaptive2 needs c"):
        uniform_adaptive2_indices(_rbf(43, n=60), jax.random.PRNGKey(0), 2)


def test_adaptive_zeroes_selected_probabilities():
    """Once a column is selected, later rounds may never re-draw it even when
    every residual norm is numerically zero (rank-deficient K: the floor
    falls back to uniform over the UNSELECTED set only)."""
    n = 30
    u = jnp.asarray(np.random.default_rng(0).normal(size=(n, 1)), jnp.float32)
    K = DenseSPSD(u @ u.T)                       # rank 1: residuals ~ 0
    for seed in range(4):
        idx = np.asarray(uniform_adaptive2_indices(K, jax.random.PRNGKey(seed),
                                                   12))
        assert len(set(idx.tolist())) == 12, idx


def test_adaptive_norms_match_projection_formula():
    Kop = _rbf(8)
    idx = jnp.arange(12)
    Kd = np.asarray(Kop.full(), np.float32)
    C = np.asarray(Kop.columns(idx), np.float32)
    resid = Kd - C @ (np.asarray(pinv(jnp.asarray(C))) @ Kd)
    ref = (resid ** 2).sum(axis=0)
    got = np.asarray(_residual_column_norms(Kop, idx))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# fused Pallas multi-RHS path
# ---------------------------------------------------------------------------

def test_pallas_sweep_fast_path_matches_generic():
    X = _clustered(9, n=300)
    Kp = RBFKernel(X, sigma=2.0, use_pallas=True)
    Kg = RBFKernel(X, sigma=2.0, use_pallas=False)
    V = jax.random.normal(jax.random.PRNGKey(3), (300, 5), jnp.float32)
    cidx = jnp.asarray([0, 17, 255])
    plans = lambda: [sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx)]
    got = Kp.sweep(plans())
    ref = Kg.sweep(plans(), block_size=128)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(ref[1]),
                               rtol=1e-5, atol=1e-5)


def test_sweep_route_recorder_single_device():
    """RBFKernel records which route a sweep took; CountingOperator meters it."""
    Kc = CountingOperator(_rbf(20, use_pallas=True))
    V = jax.random.normal(jax.random.PRNGKey(4), (Kc.n, 3), jnp.float32)
    Kc.sweep([sw.MatmulPlan(V)])
    assert Kc.last_route == "pallas_fused"
    assert Kc.counts["fused_sweeps"] == 1
    Kc.sweep([sw.MatmulPlan(V), sw.FrobeniusPlan()])   # not matmul-shaped
    assert Kc.last_route == "panel"
    assert Kc.counts["fused_sweeps"] == 1 and Kc.counts["sweeps"] == 2


def test_slab_hook_single_device_matches_scan():
    """The engine's slab_fn hook (claimed row slabs) equals the panel scan."""
    Kop = _rbf(21, n=217)
    Kd = np.asarray(Kop.full(), np.float32)
    V = jax.random.normal(jax.random.PRNGKey(5), (217, 4), jnp.float32)
    plan = sw.MatmulPlan(V)
    cols = jnp.arange(217)

    def slab_fn(row_idx, valid):
        panel = Kop.block(row_idx, cols)
        return (plan.update(plan.init(217, 217), panel, row_idx, valid),)

    (got,) = sw.sweep_panels(lambda idx: Kop.block(idx, cols), 217, 217,
                             [plan], block_size=64, slab_fn=slab_fn)
    np.testing.assert_allclose(np.asarray(got), Kd @ np.asarray(V),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# error_vs_best_rank_k: the first subspace-iteration matmat shares the
# residual sweep (ROADMAP item: drop one of the 2 + power_iters passes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["blocked", "hutchinson"])
def test_error_vs_best_rank_k_budget_shares_first_eig_pass(method):
    """Y = K Ω rides the residual/probe sweep: (2 + power_iters) sweeps
    total, not (3 + power_iters)."""
    Kc = CountingOperator(_rbf(22))
    ap = spsd.fast_model(Kc, jax.random.PRNGKey(0), c=20, s=80,
                         s_sketch="gaussian", streaming=True)
    Kc.reset()
    rho = float(spsd.error_vs_best_rank_k(Kc, ap, k=8, method=method,
                                          probes=16,
                                          key=jax.random.PRNGKey(1)))
    assert np.isfinite(rho) and rho > 0.0
    assert Kc.counts["sweeps"] == 2 + 2      # fused first pass + 2 power + QKQ
    assert Kc.counts["fulls"] == 0


def test_error_vs_best_rank_k_shared_pass_matches_dense():
    """Sharing the pass must not move the streaming estimate away from the
    dense reference."""
    Kop = _rbf(23)
    ap = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=20, s=80,
                         s_sketch="uniform")
    dense = float(spsd.error_vs_best_rank_k(Kop, ap, k=8, method="dense"))
    blocked = float(spsd.error_vs_best_rank_k(Kop, ap, k=8, method="blocked"))
    assert blocked == pytest.approx(dense, rel=0.05)


# ---------------------------------------------------------------------------
# columns(): the base default routes through a ColumnGatherPlan sweep;
# pairwise kernels gather n×c entries straight from the data
# ---------------------------------------------------------------------------

class _BlockOnlyOperator(SPSDOperator):
    """A minimal implicit operator: block() is the ONLY access pattern."""

    def __init__(self, K):
        self.K = K
        self.block_elements = 0              # entries requested via block()

    @property
    def n(self):
        return int(self.K.shape[0])

    def block(self, row_idx, col_idx):
        self.block_elements += int(row_idx.shape[0]) * int(col_idx.shape[0])
        return jnp.take(jnp.take(self.K, row_idx, axis=0), col_idx, axis=1)


def test_default_columns_routes_through_gather_sweep():
    """The base-class gather sweeps the n×c selected-column view: correct
    values, and only ~n·c entries requested (never b×n panels)."""
    n = 217
    Kd = np.asarray(_rbf(24, n=n).full(), np.float32)
    op = _BlockOnlyOperator(jnp.asarray(Kd))
    idx = jnp.asarray([3, 50, 216])
    got = np.asarray(op.columns(idx))
    np.testing.assert_allclose(got, Kd[:, np.asarray(idx)],
                               rtol=1e-5, atol=1e-6)
    # clamp padding can add at most one thin panel's worth of rows
    bs = sw.resolved_block_size(n, 3, None)
    assert op.block_elements <= (n + bs) * 3
    assert op.block_elements < n * n


def test_pairwise_columns_is_direct_nc_block():
    """PairwiseKernel overrides the sweep default: an n×c gather stays one
    direct block (no sweep, no n-length row index)."""
    Kc = CountingOperator(_rbf(25))
    idx = jnp.asarray([1, 7, 100])
    C = Kc.columns(idx)
    assert C.shape == (Kc.n, 3)
    assert Kc.counts["columns"] == 1 and Kc.counts["sweeps"] == 0
    assert Kc.counts["entries"] == Kc.n * 3


# ---------------------------------------------------------------------------
# LinearKernel / PairwiseKernel(linear) through the sweep engine
# ---------------------------------------------------------------------------

def _linear_pair(seed, n=260, d=6):
    X = _clustered(seed, n=n, d=d)
    return X, DenseSPSD(X @ X.T)


def test_linear_kernel_fused_route_parity_vs_dense():
    """PairwiseKernel(linear, use_pallas=True): matmul-shaped sweeps claim
    the fused Pallas route and match DenseSPSD(X Xᵀ) to ≤ 1e-5."""
    X, Kd = _linear_pair(30)
    Kc = CountingOperator(PairwiseKernel(X, pw_specs.get_spec("linear"),
                                         use_pallas=True))
    V = jax.random.normal(jax.random.PRNGKey(1), (Kc.n, 5), jnp.float32)
    cidx = jnp.asarray([2, 100, 259])
    plans = lambda: [sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx)]
    got = Kc.sweep(plans())
    assert Kc.last_route == "pallas_fused"
    assert Kc.counts["fused_sweeps"] == 1
    ref = Kd.sweep(plans(), block_size=64)
    for a, b in zip(got, ref):
        scale = max(1.0, float(np.max(np.abs(np.asarray(b)))))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5 * scale)


def test_linear_kernel_panel_route_parity_vs_dense():
    """use_pallas=False: the same bundle walks the panel scan — and a
    non-matmul plan forces the panel route even when fused-capable."""
    X, Kd = _linear_pair(31)
    Kp = CountingOperator(PairwiseKernel(X, pw_specs.get_spec("linear"),
                                         use_pallas=False))
    V = jax.random.normal(jax.random.PRNGKey(2), (Kp.n, 4), jnp.float32)
    got = Kp.sweep([sw.MatmulPlan(V), sw.FrobeniusPlan()], block_size=64)
    assert Kp.last_route == "panel" and Kp.counts["fused_sweeps"] == 0
    ref = Kd.sweep([sw.MatmulPlan(V), sw.FrobeniusPlan()], block_size=64)
    scale = max(1.0, float(np.max(np.abs(np.asarray(ref[0])))))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                               rtol=1e-5, atol=1e-5 * scale)
    assert float(got[1]) == pytest.approx(float(ref[1]), rel=1e-5)
    Kf = CountingOperator(PairwiseKernel(X, pw_specs.get_spec("linear"),
                                         use_pallas=True))
    Kf.sweep([sw.MatmulPlan(V), sw.FrobeniusPlan()], block_size=64)
    assert Kf.last_route == "panel"          # bundle not matmul-shaped


def test_linear_kernel_masked_sketch_ragged_batch():
    """Ragged LinearKernel batch: MaskedSketch keeps poisoned padding rows
    out of Sᵀ K S, per-item results match the unpadded kernels."""
    rng = np.random.default_rng(32)
    n_valid = np.array([150, 200])
    npad = 200
    Xb = rng.normal(size=(2, npad, 6))
    for b, nv in enumerate(n_valid):
        Xb[b, nv:] = 99.0                    # poison the padding rows
    Xb = jnp.asarray(Xb, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(33), 2)
    bat = spsd.fast_model_batched(LinearKernel(Xb), keys, c=12, s=48,
                                  s_sketch="gaussian",
                                  n_valid=jnp.asarray(n_valid))
    assert bat.C.shape == (2, npad, 12) and bat.U.shape == (2, 12, 12)
    assert np.all(np.isfinite(np.asarray(bat.U)))
    for b, nv in enumerate(n_valid):
        np.testing.assert_array_equal(np.asarray(bat.C[b][nv:]), 0.0)
        assert int(jnp.max(bat.P_indices[b])) < nv
        Ktrue = LinearKernel(Xb[b, :nv])
        ap = spsd.SPSDApprox(C=bat.C[b][:nv], U=bat.U[b])
        err = float(spsd.relative_error(Ktrue, ap, method="dense"))
        assert np.isfinite(err) and err < 0.5, (b, err)


# ---------------------------------------------------------------------------
# padding masks (ragged batches)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gaussian", "srht", "countsketch"])
def test_masked_sketch_sym_is_unbiased(kind):
    """Sᵀ M K_pad M S must equal the sketch applied to the unpadded K."""
    n, npad = 150, 200
    Ksmall = np.asarray(_rbf(10, n=n).full(), np.float32)
    Kpad = np.full((npad, npad), 7.7, np.float32)   # junk padding entries
    Kpad[:n, :n] = Ksmall
    mask = (jnp.arange(npad) < n).astype(jnp.float32)
    S = sk.make_sketch(kind, jax.random.PRNGKey(5), npad, 40)
    Sm = sk.MaskedSketch(S, mask)
    got = np.asarray(Sm.sym(jnp.asarray(Kpad)))
    Kmasked = np.zeros_like(Kpad)
    Kmasked[:n, :n] = Ksmall
    ref = np.asarray(S.sym(jnp.asarray(Kmasked)))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    assert np.all(np.isfinite(got))


def test_fast_model_batched_ragged_padding():
    """Ragged batch padded to a common n: junk rows must not bias the model."""
    rng = np.random.default_rng(11)
    n_valid = np.array([150, 200])
    npad = 200
    Xb = rng.normal(size=(2, npad, 6))
    for b, nv in enumerate(n_valid):
        Xb[b, nv:] = 99.0                    # poison the padding rows
    Xb = jnp.asarray(Xb, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    bat = spsd.fast_model_batched(RBFKernel(Xb, sigma=1.5), keys, c=12, s=48,
                                  s_sketch="gaussian",
                                  n_valid=jnp.asarray(n_valid))
    assert bat.C.shape == (2, npad, 12) and bat.U.shape == (2, 12, 12)
    assert np.all(np.isfinite(np.asarray(bat.U)))
    for b, nv in enumerate(n_valid):
        # padding rows of C are masked to exactly zero
        np.testing.assert_array_equal(np.asarray(bat.C[b][nv:]), 0.0)
        # P sampled the valid range only
        assert int(jnp.max(bat.P_indices[b])) < nv
        # and the model approximates the TRUE (unpadded) kernel
        Ktrue = RBFKernel(Xb[b, :nv], sigma=1.5)
        ap = spsd.SPSDApprox(C=bat.C[b][:nv], U=bat.U[b])
        err = float(spsd.relative_error(Ktrue, ap, method="dense"))
        assert np.isfinite(err) and err < 0.5, (b, err)


# ---------------------------------------------------------------------------
# CUR: blocked-Gram leverage scores + streaming routing
# ---------------------------------------------------------------------------

def test_gram_leverage_scores_match_svd_route():
    rng = np.random.default_rng(12)
    R = jnp.asarray(rng.normal(size=(15, 300)), jnp.float32)
    np.testing.assert_allclose(np.asarray(column_leverage_scores_gram(R, 64)),
                               np.asarray(row_leverage_scores(R.T)),
                               rtol=1e-3, atol=1e-4)
    C = jnp.asarray(rng.normal(size=(300, 12)), jnp.float32)
    np.testing.assert_allclose(np.asarray(row_leverage_scores_gram(C, 64)),
                               np.asarray(row_leverage_scores(C)),
                               rtol=1e-3, atol=1e-4)


def test_gram_leverage_rank_deficient():
    rng = np.random.default_rng(13)
    B = rng.normal(size=(4, 200)).astype(np.float32)
    R = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32) @ B)  # rank 4
    lev = np.asarray(column_leverage_scores_gram(R, 64))
    assert np.all(np.isfinite(lev)) and np.all(lev >= -1e-5)
    assert float(lev.sum()) == pytest.approx(4.0, rel=0.05)   # sum == rank


def test_fast_cur_streaming_leverage_runs():
    rng = np.random.default_rng(14)
    A = jnp.asarray(rng.normal(size=(250, 180)), jnp.float32)
    kw = dict(c=12, r=12, sc=48, sr=48, sketch_kind="leverage")
    ap_s = cur.fast_cur(A, jax.random.PRNGKey(3), streaming=True, **kw)
    ap_d = cur.fast_cur(A, jax.random.PRNGKey(3), streaming=False, **kw)
    # identical sampling keys + (near-)identical scores -> same error regime
    e_s = float(cur.relative_error(A, ap_s))
    e_d = float(cur.relative_error(A, ap_d))
    assert np.isfinite(e_s) and np.isfinite(e_d)
    assert abs(e_s - e_d) < 0.25


def test_fast_cur_on_implicit_operator_matches_dense_route():
    """Kernel CUR through the operator protocol: same keys as the dense
    route -> same C/R panels, no densification, fused Pallas sweep."""
    Kp = _rbf(15, n=260, use_pallas=True)
    Kd = jnp.asarray(np.asarray(_rbf(15, n=260).full(), np.float32))
    kw = dict(c=12, r=12, sc=48, sr=48, sketch_kind="gaussian")
    Kc = CountingOperator(Kp)
    ap_o = cur.fast_cur(Kc, jax.random.PRNGKey(3), **kw)
    assert Kc.counts["fulls"] == 0                  # never densified
    assert Kc.counts["fused_sweeps"] == 1           # A S_R claimed by Pallas
    ap_d = cur.fast_cur(Kd, jax.random.PRNGKey(3), streaming=True, **kw)
    np.testing.assert_allclose(np.asarray(ap_o.C), np.asarray(ap_d.C),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ap_o.R), np.asarray(ap_d.R),
                               rtol=1e-4, atol=1e-4)
    e_o = float(cur.relative_error(Kd, ap_o))
    e_d = float(cur.relative_error(Kd, ap_d))
    assert np.isfinite(e_o) and abs(e_o - e_d) < 0.1
