"""Sign-split l1dist (the MXU segment decomposition) and the scalar-prefetch
slab launch: plan construction, MXU-vs-VPU route equivalence (including
adversarial sign patterns and odd feature counts), and slab-vs-gather launch
parity at every alignment the sharded sweep produces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import sweep as sw
from repro.core.instrument import CountingOperator
from repro.core.kernelop import PairwiseKernel
from repro.kernels.pairwise import ops as pw_ops
from repro.kernels.pairwise import signsplit, specs

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _quantized(seed, n, d=8, levels=9, scale=0.5):
    """Points on a small lattice — per-feature cardinality ≤ ``levels``, so
    the sign-split plan is buildable and the decomposition is EXACT."""
    rng = np.random.default_rng(seed)
    v = rng.integers(-(levels // 2), levels // 2 + 1, size=(n, d))
    return jnp.asarray(v * scale, jnp.float32)


def _l1_oracle(X, Y):
    X64 = np.asarray(X, np.float64)
    Y64 = np.asarray(Y, np.float64)
    return np.abs(X64[:, None, :] - Y64[None, :, :]).sum(-1)


def _parity(got, ref, tol=1e-5):
    got, ref = np.asarray(got), np.asarray(ref)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    scale = max(1.0, float(np.max(np.abs(ref))))
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol * scale)


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------

def test_build_plan_on_lattice_data():
    X = _quantized(0, 200, d=6, levels=7)
    plan = signsplit.build_plan(X)
    assert plan is not None
    assert plan.edges.shape[0] == 6
    assert 2 <= plan.segments <= signsplit.MAX_SEGMENTS


def test_build_plan_refuses_continuous_data():
    """Cardinality beyond the segment budget -> None (the VPU route)."""
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(100, 4)), jnp.float32)
    assert signsplit.build_plan(X) is None


def test_build_plan_refuses_tracers():
    X = _quantized(2, 64, d=4)
    seen = []

    @jax.jit
    def f(x):
        seen.append(signsplit.build_plan(x))
        return x

    f(X)
    assert seen == [None]


# ---------------------------------------------------------------------------
# MXU-vs-VPU equivalence: the decomposition is exact on in-plan data
# ---------------------------------------------------------------------------

def test_l1dist_signsplit_matches_oracle_exactly():
    X = _quantized(3, 150, d=8)
    Y = _quantized(4, 90, d=8)
    plan = signsplit.build_plan(jnp.concatenate([X, Y]))
    got = signsplit.l1dist(X, Y, plan.edges)
    _parity(got, _l1_oracle(X, Y))


def test_l1dist_adversarial_signs():
    """Every sign pattern per feature — the decomposition's hard case is
    values straddling zero in both operands."""
    X = jnp.asarray([[-2.0, -0.5, 0.0, 1.5],
                     [2.0, 0.5, -1.0, -1.5],
                     [0.0, 0.0, 1.0, 0.0],
                     [-2.0, 0.5, 1.0, 1.5]], jnp.float32)
    plan = signsplit.build_plan(X)
    got = signsplit.l1dist(X, X, plan.edges)
    np.testing.assert_allclose(np.asarray(got), _l1_oracle(X, X), atol=1e-6)


def test_l1dist_odd_feature_count_and_ragged_cardinality():
    """d=5 (no tile alignment) with a different cardinality per feature —
    the padded +inf edges must not contribute."""
    rng = np.random.default_rng(5)
    cols = [rng.choice(np.linspace(-1.0, 1.0, card), size=120)
            for card in (2, 3, 5, 11, 29)]
    X = jnp.asarray(np.stack(cols, axis=1), jnp.float32)
    plan = signsplit.build_plan(X)
    assert plan is not None and plan.segments <= signsplit.MAX_SEGMENTS
    _parity(signsplit.l1dist(X, X, plan.edges), _l1_oracle(X, X))


def test_l1dist_bf16_within_quantization_budget():
    X = _quantized(6, 128, d=8)
    plan = signsplit.build_plan(X)
    got = signsplit.l1dist(X, X, plan.edges, compute_dtype=jnp.bfloat16)
    _parity(got, _l1_oracle(X, X), tol=5e-2)


@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "dense"])
def test_ops_block_mxu_vs_vpu_routes(use_pallas):
    """The same tile template with and without an edge table: the MXU form
    must reproduce the VPU loop to f32 parity on both evaluation routes."""
    spec = specs.suggested_spec("laplacian", 8)
    X = _quantized(7, 140)
    Y = _quantized(8, 70)
    edges = signsplit.build_plan(jnp.concatenate([X, Y])).edges
    mxu = pw_ops.kernel_block(spec, X, Y, use_pallas=use_pallas, edges=edges)
    vpu = pw_ops.kernel_block(spec, X, Y, use_pallas=use_pallas, edges=None)
    _parity(mxu, vpu)


# ---------------------------------------------------------------------------
# operator-level routing
# ---------------------------------------------------------------------------

def test_pairwise_kernel_l1_route_selection():
    spec = specs.suggested_spec("laplacian", 8)
    assert PairwiseKernel(_quantized(9, 100), spec).l1_route() \
        == "mxu_signsplit"
    cont = jnp.asarray(np.random.default_rng(10).normal(size=(100, 8)),
                       jnp.float32)
    assert PairwiseKernel(cont, spec).l1_route() == "vpu_loop"
    rbf = specs.suggested_spec("rbf", 8)
    assert PairwiseKernel(_quantized(9, 100), rbf).l1_route() is None


def test_laplacian_full_parity_across_routes():
    """full() on lattice data (MXU route) vs the dense VPU evaluation."""
    spec = specs.suggested_spec("laplacian", 8)
    X = _quantized(11, 130)
    K_mxu = PairwiseKernel(X, spec, use_pallas=True).full()
    dist = _l1_oracle(X, X)
    gamma = spec.param("gamma")
    np.testing.assert_allclose(np.asarray(K_mxu), np.exp(-gamma * dist),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# query-side routing: cross() takes the MXU form for on-lattice queries
# ---------------------------------------------------------------------------

def _on_lattice_queries(X, m, seed):
    """Out-of-sample rows whose every feature value is drawn from the
    realized per-feature values of ``X`` — on-lattice by construction."""
    rng = np.random.default_rng(seed)
    Xh = np.asarray(X)
    cols = [rng.choice(np.unique(Xh[:, k]), size=m)
            for k in range(Xh.shape[1])]
    return jnp.asarray(np.stack(cols, axis=1), jnp.float32)


def test_query_in_plan_membership():
    X = _quantized(20, 120, d=6)
    assert signsplit.query_in_plan(X, _on_lattice_queries(X, 9, 21))
    cont = np.random.default_rng(22).normal(size=(9, 6)).astype(np.float32)
    assert not signsplit.query_in_plan(X, cont)
    # one off-lattice value in one feature poisons the whole batch
    almost = np.asarray(_on_lattice_queries(X, 9, 23)).copy()
    almost[3, 2] += 1e-3
    assert not signsplit.query_in_plan(X, almost)
    # shape mismatch / non-finite values are conservatively off-plan
    assert not signsplit.query_in_plan(X, np.zeros((4, 5), np.float32))
    bad = np.asarray(_on_lattice_queries(X, 4, 24)).copy()
    bad[0, 0] = np.nan
    assert not signsplit.query_in_plan(X, bad)
    # tracers (jit-abstract queries) are off-plan, never an error
    seen = []

    @jax.jit
    def f(q):
        seen.append(signsplit.query_in_plan(X, q))
        return q

    f(_on_lattice_queries(X, 4, 25))
    assert seen == [False]


@pytest.mark.parametrize("use_pallas", [False, True])
def test_cross_mxu_route_for_on_lattice_queries_is_exact(use_pallas):
    """On-lattice queries route through the sign-split MXU form and must
    reproduce the f64 l1 oracle — the exactness contract that justifies
    the routing."""
    spec = specs.suggested_spec("laplacian", 8)
    X = _quantized(26, 140)
    op = PairwiseKernel(X, spec, use_pallas=use_pallas)
    assert op.l1_edges() is not None
    Xq = _on_lattice_queries(X, 33, 27)
    assert op.l1_route(Xq) == "mxu_signsplit"
    V = jnp.asarray(np.random.default_rng(28).normal(size=(140, 5)),
                    jnp.float32)
    (got,) = op.cross(Xq, (V,))
    assert op._last_cross_l1_route == "mxu_signsplit"
    assert "+mxu_signsplit" in op._last_sweep_route
    gamma = spec.param("gamma")
    ref = np.exp(-gamma * _l1_oracle(Xq, X)) @ np.asarray(V, np.float64)
    _parity(got, ref)


def test_cross_vpu_route_for_off_lattice_queries():
    """Off-lattice queries keep the always-exact VPU loop: no MXU suffix
    on the recorded route, same answer as the oracle."""
    spec = specs.suggested_spec("laplacian", 8)
    X = _quantized(29, 140)
    op = PairwiseKernel(X, spec, use_pallas=False)
    Xq = jnp.asarray(np.random.default_rng(30).normal(size=(17, 8)),
                     jnp.float32)
    assert op.l1_route(Xq) == "vpu_loop"
    V = jnp.asarray(np.random.default_rng(31).normal(size=(140, 3)),
                    jnp.float32)
    (got,) = op.cross(Xq, (V,))
    assert op._last_cross_l1_route == "vpu_loop"
    assert "+mxu_signsplit" not in op._last_sweep_route
    gamma = spec.param("gamma")
    ref = np.exp(-gamma * _l1_oracle(Xq, X)) @ np.asarray(V, np.float64)
    _parity(got, ref)


def test_cross_route_is_none_for_non_l1_stats():
    rbf = specs.suggested_spec("rbf", 8)
    op = PairwiseKernel(_quantized(32, 100), rbf, use_pallas=False)
    Xq = _on_lattice_queries(op.X, 7, 33)
    assert op.l1_route(Xq) is None
    op.cross(Xq, (jnp.ones((100, 2), jnp.float32),))
    assert op._last_cross_l1_route is None
    assert "+mxu_signsplit" not in op._last_sweep_route


# ---------------------------------------------------------------------------
# scalar-prefetch slab launches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["rbf", "laplacian"])
@pytest.mark.parametrize("start,slab", [(0, 64), (64, 64), (37, 80),
                                        (250, 64)])
def test_fused_slab_matches_fused_rows(name, start, slab):
    """The prefetch slab launch answers exactly what the gather launch
    answers, at aligned, unaligned, and past-the-end (clamp-duplicate)
    starts — only in-range rows are compared (the sweep masks the rest)."""
    n = 300
    spec = specs.suggested_spec(name, 8)
    X = _quantized(12, n)
    op = PairwiseKernel(X, spec, use_pallas=True)
    assert op.supports_prefetch_slab()
    rng = np.random.default_rng(13)
    Vs = (jnp.asarray(rng.normal(size=(n, 5)), jnp.float32),
          jnp.asarray(rng.normal(size=(n, 17)), jnp.float32))
    got = op.fused_slab(jnp.int32(start), slab, Vs)
    idx = jnp.clip(jnp.arange(start, start + slab), 0, n - 1)
    ref = op.fused_rows(idx, Vs)
    valid = min(slab, n - start)
    for g, r in zip(got, ref):
        _parity(g[:valid], r[:valid])


def test_fused_slab_traced_start():
    """start_row may be a tracer (it is, inside the sharded sweep)."""
    n = 256
    spec = specs.suggested_spec("rbf", 8)
    X = _quantized(14, n)
    op = PairwiseKernel(X, spec, use_pallas=True)
    V = jnp.asarray(np.random.default_rng(15).normal(size=(n, 4)),
                    jnp.float32)

    out = jax.jit(lambda s: op.fused_slab(s, 64, (V,))[0])(jnp.int32(128))
    ref = op.fused_rows(jnp.arange(128, 192), (V,))[0]
    _parity(out, ref)


@multidevice
@pytest.mark.parametrize("precision", specs.PRECISIONS)
def test_sharded_sweep_takes_prefetch_slab_route(precision):
    """The sharded sweep dispatches prefetch slabs (no gathered row copy),
    records the mode, and stays at parity — under both tile policies."""
    n = 259
    spec = specs.suggested_spec("rbf", 8).with_precision(precision)
    X = _quantized(16, n)
    Kc = CountingOperator(PairwiseKernel(X, spec, use_pallas=True))
    V = jnp.asarray(np.random.default_rng(17).normal(size=(n, 4)),
                    jnp.float32)
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    (got,) = Kc.sweep([sw.MatmulPlan(V)], mesh=mesh)
    suffix = "" if precision == "f32" else "+bf16_f32acc"
    assert Kc.last_route == "pallas_fused_sharded" + suffix
    assert Kc.last_slab_mode == "prefetch"
    ref = PairwiseKernel(X, spec.with_precision("f32"),
                         use_pallas=False).matmat(V)
    _parity(got, ref, tol=1e-5 if precision == "f32" else 5e-2)


@multidevice
def test_sharded_sweep_gather_fallback_for_slabless_operators():
    """Fused-capable operators without the slab capability still sweep
    sharded through the gathered-rows path (and the mode says so)."""
    n = 259
    spec = specs.suggested_spec("rbf", 8)
    X = _quantized(18, n)
    op = PairwiseKernel(X, spec, use_pallas=True)
    op.supports_prefetch_slab = lambda: False
    Kc = CountingOperator(op)
    V = jnp.asarray(np.random.default_rng(19).normal(size=(n, 4)),
                    jnp.float32)
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    (got,) = Kc.sweep([sw.MatmulPlan(V)], mesh=mesh)
    assert Kc.last_route == "pallas_fused_sharded"
    assert Kc.last_slab_mode == "gather"
    _parity(got, PairwiseKernel(X, spec, use_pallas=False).matmat(V))
