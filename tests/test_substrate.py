"""Substrate unit tests: optimizers, schedules, compression, data pipeline,
checkpointing, fault tolerance, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.checkpoint.checkpoint import gc_tmp, latest_step
from repro.data import SyntheticLM, host_batch_slice, make_pipeline
from repro.distributed import sharding as shd
from repro.optim import (adafactor, adamw, lion, make_gradient_compressor,
                         warmup_cosine, warmup_linear)
from repro.optim.compress import countsketch_compress, countsketch_decompress
from repro.optim.optimizers import clip_by_global_norm, global_norm
from repro.runtime import (HeartbeatMonitor, PreemptionHandler,
                           StragglerDetector, plan_elastic_remesh)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _params():
    return {"w": jnp.ones((4, 8)), "nest": {"b": jnp.full((3,), 2.0)},
            "empty": ()}           # structural empty node must survive


@pytest.mark.parametrize("make", [adamw, lion,
                                  lambda: adafactor(momentum=True),
                                  lambda: adafactor(momentum=False)])
def test_optimizer_structure_and_descent(make):
    opt = make()
    params = _params()

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["nest"]["b"] ** 2)

    st_ = opt.init(params)
    p = params
    for _ in range(25):
        g = jax.grad(loss)(p)
        p, st_, met = opt.update(g, st_, p, 0.05)
    assert jax.tree.structure(p) == jax.tree.structure(params)
    assert float(loss(p)) < float(loss(params))
    assert np.isfinite(float(met["grad_norm"]))


def test_adamw_matches_manual_first_step():
    opt = adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                clip_norm=None)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    st_ = opt.init(p)
    p2, _, _ = opt.update(g, st_, p, 0.1)
    # bias-corrected first step == -lr * sign-ish g / (|g| + eps)
    expect = np.asarray([1.0, 2.0]) - 0.1 * np.asarray([0.5, -0.5]) / (
        np.abs([0.5, -0.5]) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-4)


def test_global_norm_clip():
    t = {"a": jnp.full((10,), 3.0)}
    clipped, gn = clip_by_global_norm(t, 1.0)
    assert abs(float(gn) - np.sqrt(90.0)) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_schedules():
    lr0 = float(warmup_cosine(0, peak=1.0, warmup_steps=10, total_steps=100))
    lr10 = float(warmup_cosine(10, peak=1.0, warmup_steps=10,
                               total_steps=100))
    lr100 = float(warmup_cosine(100, peak=1.0, warmup_steps=10,
                                total_steps=100))
    assert lr0 == 0.0 and abs(lr10 - 1.0) < 1e-6 and lr100 < 1e-6
    assert float(warmup_linear(55, peak=1.0, warmup_steps=10,
                               total_steps=100)) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# sketched gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_compression_commutes_with_allreduce(seed):
    """sketch(sum_i g_i) == sum_i sketch(g_i) — the soundness condition."""
    key = jax.random.PRNGKey(seed)
    g1 = jax.random.normal(key, (64,))
    g2 = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    kk = jax.random.fold_in(key, 2)
    s1, meta = countsketch_compress(g1, kk, ratio=4)
    s2, _ = countsketch_compress(g2, kk, ratio=4)
    s12, _ = countsketch_compress(g1 + g2, kk, ratio=4)
    np.testing.assert_allclose(np.asarray(s1 + s2), np.asarray(s12),
                               rtol=1e-4, atol=1e-5)
    rec = countsketch_decompress(s12, meta)
    assert rec.shape == g1.shape


def test_error_feedback_accumulates_signal():
    """With constant grads, the mean reconstructed gradient converges to the
    true gradient direction (error feedback reinjects the residual)."""
    init, apply = make_gradient_compressor(ratio=4)
    g = {"w": jnp.ones((128,))}
    state = init(g, jax.random.PRNGKey(0))
    acc = jnp.zeros((128,))
    n = 30
    for _ in range(n):
        gh, state = apply(g, state, lambda x: x)
        acc = acc + gh["w"]
    mean = acc / n
    # cosine similarity with the true gradient close to 1
    cos = float(jnp.dot(mean, g["w"]) /
                (jnp.linalg.norm(mean) * jnp.linalg.norm(g["w"])))
    assert cos > 0.7, cos


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_determinism_and_restart():
    pipe = make_pipeline("synthetic", vocab_size=100, seq_len=16,
                         global_batch=4, seed=7)
    a = pipe.batch_at(123)
    b = pipe.batch_at(123)            # "restarted" iterator
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipe.batch_at(124)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 100
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_host_batch_slice_partitions():
    pipe = SyntheticLM(vocab_size=50, seq_len=8, global_batch=8)
    b = pipe.batch_at(0)
    parts = [host_batch_slice(b, i, 4) for i in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(glued, b["tokens"])


def test_bin_corpus(tmp_path):
    toks = np.arange(1000, dtype=np.uint16) % 37
    path = tmp_path / "corpus.bin"
    toks.tofile(path)
    pipe = make_pipeline("bin", vocab_size=37, seq_len=16, global_batch=2,
                         path=str(path))
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, keep_period=100)
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "step": jnp.asarray(3)}
    for s in (100, 110, 120, 130):
        mgr.save(s, tree)
    names = sorted(os.listdir(tmp_path))
    # keep=2 -> 120,130 plus the keep_period multiple 100
    assert names == ["step_000000100", "step_000000120", "step_000000130"]
    got = mgr.restore(130, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_async_and_crash_cleanup(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.ones((4,))}
    mgr.save(1, tree, blocking=False)
    mgr.join()
    assert latest_step(str(tmp_path)) == 1
    # simulate a crash mid-write: orphan .tmp dir is GC'd on next startup
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert gc_tmp(str(tmp_path)) == 1
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        mgr.restore(5, {"w": jnp.ones((5,))})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_death_detection():
    hb = HeartbeatMonitor(["a", "b", "c"], timeout=5.0)
    for h in "abc":
        hb.beat(h, 10, 100.0)
    hb.beat("a", 11, 104.0)
    assert hb.dead_hosts(106.0) == ["b", "c"]
    assert hb.min_step() == 10


def test_preemption_flag():
    p = PreemptionHandler()
    assert not p.should_exit
    p.notify()
    assert p.should_exit


def test_straggler_detection_and_policy():
    sd = StragglerDetector(threshold=1.5, patience=3)
    reports = []
    for _ in range(4):
        for h in ("h0", "h1", "h2", "h3"):
            sd.record(h, 4.0 if h == "h3" else 1.0)
        reports = sd.check()
    assert [r.host for r in reports] == ["h3"]
    assert reports[0].action == "exclude"      # ratio 4 >= 3 -> shrink


def test_elastic_remesh_plan():
    plan = plan_elastic_remesh(surviving_pods=1, chips_per_pod=256,
                               model_parallel=16, global_batch=256,
                               original_pods=2)
    assert plan.mesh_shape == (16, 16)
    assert plan.global_batch == 128
    plan3 = plan_elastic_remesh(surviving_pods=3, chips_per_pod=256,
                                model_parallel=16, global_batch=512,
                                original_pods=4)
    assert plan3.mesh_shape == (3, 16, 16)
    with pytest.raises(ValueError):
        plan_elastic_remesh(0, 256, 16, 256, 2)
    with pytest.raises(ValueError):
        plan_elastic_remesh(1, 8, 16, 256, 2)


# ---------------------------------------------------------------------------
# sharding rules (pure metadata; no devices needed)
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)


def test_param_pspec_rules():
    mesh = _FakeMesh(data=16, model=16)
    # scanned attention wq (L, d, H, hd): heads -> model
    assert shd.param_pspec("stack/scanned/0/mixer/wq", (8, 1024, 32, 128),
                           mesh) == P(None, None, "model", None)
    # unrolled path (two numerics): no leading layer dim
    assert shd.param_pspec("stack/scanned/1/0/mixer/wq", (1024, 32, 128),
                           mesh) == P(None, "model", None)
    # GQA kv heads not divisible -> replicated heads dim
    assert shd.param_pspec("stack/scanned/0/mixer/wk", (8, 1024, 4, 128),
                           mesh) == P(None, None, None, None)
    # MoE expert bank: experts -> model (EP)
    assert shd.param_pspec("stack/scanned/0/moe/wi_gate", (8, 64, 1024, 2048),
                           mesh) == P(None, "model", None, None)
    # embeddings: vocab -> model only
    assert shd.param_pspec("embed/embedding", (256000, 4096), mesh) == \
        P("model", None)
    # norms replicated
    assert shd.param_pspec("stack/scanned/0/norm1/scale", (8, 4096), mesh) \
        == P(None, None)
    # fsdp adds data-sharding on the d dim of mlp
    assert shd.param_pspec("stack/scanned/0/mlp/wi_up", (8, 4096, 11008),
                           mesh, fsdp=True) == P(None, "data", "model")


def test_batch_pspec():
    mesh = _FakeMesh(pod=2, data=16, model=16)
    assert shd.batch_pspec((256, 4096), mesh) == P(("pod", "data"), None)
    assert shd.batch_pspec((1, 4096), mesh) == P(None, None)
    mesh1 = _FakeMesh(data=16, model=16)
    assert shd.batch_pspec((32, 128), mesh1) == P(("data",), None)


def test_is_stacked_detection():
    assert shd._is_stacked(["stack", "scanned", "0", "mixer", "wq"])
    assert not shd._is_stacked(["stack", "scanned", "1", "0", "mixer", "wq"])
    assert not shd._is_stacked(["stack", "prefix", "0", "mixer", "wq"])
    assert shd._is_stacked(["xattn", "xattn", "wq"])
