"""End-to-end integration: train loop, restore-resume, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import make_pipeline
from repro.launch.steps import build_cell, make_train_step
from repro.models.model import build_model
from repro.optim import adamw, make_gradient_compressor

CFG = ModelConfig(name="itiny", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=128)


def _run(steps, start=0, params=None, opt_state=None, accum=1):
    model = build_model(CFG)
    opt = adamw()
    step = jax.jit(make_train_step(model, opt, peak_lr=1e-2, warmup=2,
                                   total=steps or 1, accum=accum))
    pipe = make_pipeline("synthetic", vocab_size=128, seq_len=32,
                         global_batch=4, seed=3)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
    losses = []
    for s in range(start, steps):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(s))
        params, opt_state, met = step(params, opt_state, batch)
        losses.append(float(met["loss"]))
    return params, opt_state, losses


def test_loss_decreases():
    _, _, losses = _run(40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, (
        losses[:5], losses[-5:])


def test_grad_accum_equivalence():
    """accum=2 must match accum=1 on the same global batch (linearity).

    Tolerances are loose on params: bf16 forwards reduce in different orders
    for different microbatch shapes and Adam's rsqrt amplifies that near 0.
    """
    p1, _, l1 = _run(3, accum=1)
    p2, _, l2 = _run(3, accum=2)
    np.testing.assert_allclose(l1, l2, rtol=1e-3, atol=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2,
                                   atol=2e-3)


def test_checkpoint_resume_bitwise(tmp_path):
    params, opt_state, _ = _run(5)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"params": params, "opt_m": opt_state.inner["m"],
                 "opt_v": opt_state.inner["v"],
                 "step": opt_state.step})
    got = mgr.restore(5, {"params": params, "opt_m": opt_state.inner["m"],
                          "opt_v": opt_state.inner["v"],
                          "step": opt_state.step})
    # continue training from restored state == continue from live state
    from repro.optim.optimizers import OptState
    restored = OptState(step=jnp.asarray(got["step"]),
                        inner={"m": jax.tree.map(jnp.asarray, got["opt_m"]),
                               "v": jax.tree.map(jnp.asarray, got["opt_v"])})
    rp = jax.tree.map(jnp.asarray, got["params"])
    _, _, l_live = _run(8, start=5, params=params, opt_state=opt_state)
    _, _, l_rest = _run(8, start=5, params=rp, opt_state=restored)
    np.testing.assert_allclose(l_live, l_rest, rtol=1e-5)


def test_compressed_training_still_learns():
    model = build_model(CFG)
    opt = adamw()
    init_c, apply_c = make_gradient_compressor(ratio=4)
    pipe = make_pipeline("synthetic", vocab_size=128, seq_len=32,
                         global_batch=4, seed=3)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    cstate = init_c(params, jax.random.PRNGKey(9))

    @jax.jit
    def step(params, opt_state, cstate, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        grads, cstate = apply_c(grads, cstate, lambda x: x)  # 1-pod identity
        params, opt_state, _ = opt.update(grads, opt_state, params, 1e-2)
        return params, opt_state, cstate, loss

    losses = []
    for s in range(40):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(s))
        params, opt_state, cstate, loss = step(params, opt_state, cstate,
                                               batch)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, (
        losses[:5], losses[-5:])


def test_build_cell_on_debug_mesh():
    """build_cell lowers on a small real mesh (1 device) for each kind."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    shape_t = ShapeConfig("t", 32, 4, "train")
    shape_p = ShapeConfig("p", 32, 4, "prefill")
    shape_d = ShapeConfig("d", 32, 4, "decode")
    with mesh:
        for shape in (shape_t, shape_p, shape_d):
            cell = build_cell(CFG, shape, mesh)
            jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings)
            compiled = jitted.lower(*cell.abstract_args).compile()
            assert compiled is not None
