"""shard_map-parallel sweep: sharded == single-device to 1e-5, identical
shapes, automatic fallback on trivial meshes.

The multi-device cases need >1 local devices; CI runs them in a dedicated job
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (they skip on the
default single-CPU run, where only the fallback tests execute).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import cur, spsd
from repro.core import sweep as sw
from repro.core.adaptive import uniform_adaptive2_indices
from repro.core.instrument import CountingOperator
from repro.core.kernelop import PairwiseKernel, RBFKernel
from repro.core.sweep import mesh_data_size
from repro.kernels.pairwise import ref as pw_ref
from repro.kernels.pairwise import specs as pw_specs

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh():
    return Mesh(np.asarray(jax.devices()), ("data",))


def _rbf(seed, n=533, d=8, sigma=2.0, **kw):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)) * 2.5
    X = centers[rng.integers(0, 8, size=n)] + rng.normal(size=(n, d)) * 0.4
    return RBFKernel(jnp.asarray(X, jnp.float32), sigma=sigma, **kw)


# ---------------------------------------------------------------------------
# fallback: trivial meshes route through the sequential scan
# ---------------------------------------------------------------------------

def test_single_device_mesh_falls_back():
    Kop = _rbf(0, n=200)
    V = jax.random.normal(jax.random.PRNGKey(1), (200, 4), jnp.float32)
    mesh1 = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    assert mesh_data_size(None) == 1 and mesh_data_size(mesh1) == 1
    a = np.asarray(Kop.matmat(V, block_size=64))
    b = np.asarray(Kop.matmat(V, block_size=64, mesh=mesh1))
    np.testing.assert_array_equal(a, b)      # same code path, bitwise equal


def test_model_axis_only_mesh_is_trivial_for_sweeps():
    mesh = Mesh(np.asarray(jax.devices()), ("model",))
    assert mesh_data_size(mesh) == 1         # no data axis -> fallback


# ---------------------------------------------------------------------------
# multi-device parity
# ---------------------------------------------------------------------------

@multidevice
@pytest.mark.parametrize("n", [533, 512])    # panel-count not/divisible by 8
def test_sharded_sweep_matches_local(n):
    Kop = _rbf(1, n=n)
    V = jax.random.normal(jax.random.PRNGKey(2), (n, 6), jnp.float32)
    cidx = jnp.asarray([1, n // 2, n - 1])
    plans = lambda: [sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx),
                     sw.FrobeniusPlan()]
    loc = Kop.sweep(plans(), block_size=64)
    shd = Kop.sweep(plans(), block_size=64, mesh=_mesh())
    for a, b in zip(loc, shd):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@multidevice
def test_sharded_fast_model_matches_local():
    Kop = _rbf(2)
    key = jax.random.PRNGKey(0)
    ap_l = spsd.fast_model(Kop, key, c=20, s=80, s_sketch="gaussian",
                           streaming=True)
    ap_s = spsd.fast_model(Kop, key, c=20, s=80, s_sketch="gaussian",
                           streaming=True, mesh=_mesh())
    assert ap_s.C.shape == ap_l.C.shape and ap_s.U.shape == ap_l.U.shape
    np.testing.assert_allclose(np.asarray(ap_s.C), np.asarray(ap_l.C),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ap_s.U), np.asarray(ap_l.U),
                               rtol=1e-4, atol=1e-4)


@multidevice
def test_sharded_error_metrics_match_local():
    Kop = _rbf(3)
    ap = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=20, s=80,
                         s_sketch="gaussian", streaming=True)
    mesh = _mesh()
    e_l = float(spsd.relative_error(Kop, ap, method="blocked"))
    e_s = float(spsd.relative_error(Kop, ap, method="blocked", mesh=mesh))
    assert e_s == pytest.approx(e_l, abs=1e-5)
    h_l = float(spsd.relative_error(Kop, ap, method="hutchinson", probes=32,
                                    key=jax.random.PRNGKey(1)))
    h_s = float(spsd.relative_error(Kop, ap, method="hutchinson", probes=32,
                                    key=jax.random.PRNGKey(1), mesh=mesh))
    assert h_s == pytest.approx(h_l, abs=1e-5)


@multidevice
def test_sharded_fused_model_with_error_matches_local():
    Kop = _rbf(4)
    key = jax.random.PRNGKey(0)
    ap_l, e_l = spsd.fast_model_with_error(Kop, key, c=20, s=80, probes=32)
    ap_s, e_s = spsd.fast_model_with_error(Kop, key, c=20, s=80, probes=32,
                                           mesh=_mesh())
    np.testing.assert_allclose(np.asarray(ap_s.U), np.asarray(ap_l.U),
                               rtol=1e-4, atol=1e-4)
    assert float(e_s) == pytest.approx(float(e_l), abs=1e-5)


# ---------------------------------------------------------------------------
# fused shard_map × Pallas route (the PR-3 tentpole)
# ---------------------------------------------------------------------------

@multidevice
@pytest.mark.parametrize("n", [533, 512])    # panel-count not/divisible by 8
def test_sharded_pallas_sweep_stays_fused_and_matches_sequential(n):
    """Matmul-shaped sweeps on a non-trivial mesh must dispatch the fused
    multi-RHS Pallas slab launch per shard (not the panel fallback) and
    match the sequential sweep to ≤ 1e-5."""
    Kc = CountingOperator(_rbf(6, n=n, use_pallas=True))
    Kg = _rbf(6, n=n)                         # same points, jnp route
    V = jax.random.normal(jax.random.PRNGKey(4), (n, 6), jnp.float32)
    cidx = jnp.asarray([0, n // 3, n - 1])
    plans = lambda: [sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx)]
    got = Kc.sweep(plans(), mesh=_mesh())
    # routing assertion: the Pallas fast path stayed engaged under shard_map
    assert Kc.last_route == "pallas_fused_sharded"
    assert Kc.counts["fused_sweeps"] == 1 and Kc.counts["sweeps"] == 1
    ref = Kg.sweep(plans(), block_size=64)    # sequential panel sweep
    for a, b in zip(got, ref):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@multidevice
@pytest.mark.parametrize("n", [533, 512])
def test_sharded_pallas_entry_counts_within_one_thin_panel(n):
    """The sharded fused route's metered entry count must stay within one
    thin panel (per the rebalanced block size) of the sequential sweep's."""
    V = jax.random.normal(jax.random.PRNGKey(5), (n, 4), jnp.float32)
    dp = len(jax.devices())

    K_seq = CountingOperator(_rbf(7, n=n, use_pallas=True))
    K_seq.sweep([sw.MatmulPlan(V)])
    K_shd = CountingOperator(_rbf(7, n=n, use_pallas=True))
    K_shd.sweep([sw.MatmulPlan(V)], mesh=_mesh())
    assert K_shd.last_route == "pallas_fused_sharded"

    bs_seq = sw.resolved_block_size(n, n, None)
    bs_shd = sw.resolved_block_size(n, n, None, dp)
    one_panel = max(bs_seq, bs_shd) * n
    assert abs(K_shd.counts["entries"] - K_seq.counts["entries"]) <= one_panel
    # and the per-shard slab model agrees with the panel model exactly
    assert K_shd.counts["entries"] == dp * sw.local_slab_rows(n, n, None, dp) * n


@multidevice
def test_sharded_pallas_fast_model_matches_sequential():
    """RBFKernel(use_pallas=True).sweep via fast_model on the 8-device mesh:
    fused route engaged, results ≤ 1e-5 from the sequential sweep."""
    Kc = CountingOperator(_rbf(8, use_pallas=True))
    key = jax.random.PRNGKey(0)
    ap_s = spsd.fast_model(Kc, key, c=20, s=80, s_sketch="gaussian",
                           streaming=True, mesh=_mesh())
    assert Kc.counts["fused_sweeps"] == 1
    assert Kc.last_route == "pallas_fused_sharded"
    ap_l = spsd.fast_model(_rbf(8), key, c=20, s=80, s_sketch="gaussian",
                           streaming=True)
    np.testing.assert_allclose(np.asarray(ap_s.C), np.asarray(ap_l.C),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ap_s.U), np.asarray(ap_l.U),
                               rtol=1e-4, atol=1e-4)


@multidevice
def test_sharded_pallas_matmat_routes_through_fused_sweep():
    Kc = CountingOperator(_rbf(9, use_pallas=True))
    V = jax.random.normal(jax.random.PRNGKey(6), (Kc.n, 5), jnp.float32)
    got = Kc.matmat(V, mesh=_mesh())
    assert Kc.last_route == "pallas_fused_sharded"
    ref = _rbf(9).matmat(V, block_size=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@multidevice
def test_sharded_kernel_cur_uses_fused_route():
    """fast_cur on an implicit kernel operator: the projection sketches
    stream through the operator sweep and claim the fused sharded launch."""
    Kc = CountingOperator(_rbf(10, n=300, use_pallas=True))
    kw = dict(c=12, r=12, sc=48, sr=48, sketch_kind="gaussian")
    ap = cur.fast_cur(Kc, jax.random.PRNGKey(3), mesh=_mesh(), **kw)
    assert Kc.counts["fused_sweeps"] >= 1
    assert Kc.last_route == "pallas_fused_sharded"
    # same key through the dense route -> same selections, same error regime
    Kd = jnp.asarray(np.asarray(_rbf(10, n=300).full(), np.float32))
    ap_d = cur.fast_cur(Kd, jax.random.PRNGKey(3), streaming=True, **kw)
    err = float(cur.relative_error(Kd, ap))
    err_d = float(cur.relative_error(Kd, ap_d))
    assert np.isfinite(err) and abs(err - err_d) < 0.05


@multidevice
def test_sharded_dense_right_sketch_slab_claim_matches_panel_route():
    """CUR's rectangular A S sweep: the per-shard slab claim must equal the
    sequential panel route bit-for-bit-tolerance on a rectangular A."""
    from repro.core import sketch as sk
    rng = np.random.default_rng(15)
    A = jnp.asarray(rng.normal(size=(413, 170)), jnp.float32)
    for kind in ("srht", "countsketch"):
        S = sk.make_sketch(kind, jax.random.PRNGKey(4), 170, 48)
        ref = cur.blocked_right_sketch(A, S, block_size=64)
        got = cur.blocked_right_sketch(A, S, block_size=64, mesh=_mesh())
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


@multidevice
def test_sharded_pallas_non_matmul_plans_fall_back_to_panels():
    """A bundle with a non-matmul plan must NOT be claimed — the panel route
    runs (and still matches) so correctness never depends on the claim."""
    Kc = CountingOperator(_rbf(11, use_pallas=True))
    plans = lambda: [sw.MatmulPlan(jax.random.normal(jax.random.PRNGKey(7),
                                                     (Kc.n, 3), jnp.float32)),
                     sw.FrobeniusPlan()]
    got = Kc.sweep(plans(), block_size=64, mesh=_mesh())
    assert Kc.last_route == "panel" and Kc.counts["fused_sweeps"] == 0
    ref = _rbf(11).sweep(plans(), block_size=64)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                               rtol=1e-4, atol=1e-4)
    assert float(got[1]) == pytest.approx(float(ref[1]), rel=1e-4)


# ---------------------------------------------------------------------------
# the kernel-family guarantee (the PR-4 tentpole): EVERY registered spec
# rides the fused shard_map × Pallas route with the PR-3 routing contracts
# ---------------------------------------------------------------------------

# shared registry-sweep parameterization (entries O(1) on N(0,1) data;
# user-registered kernels fall back to factory defaults instead of erroring)
_family_spec = pw_specs.suggested_spec


def _parity(got, ref, tol=1e-5):
    """max|got − ref| ≤ tol · max(1, max|ref|): tol-level parity relative to
    the result scale (contractions reassociate f32 sums across shards)."""
    got, ref = np.asarray(got), np.asarray(ref)
    assert got.shape == ref.shape
    scale = max(1.0, float(np.max(np.abs(ref))))
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol * scale)


@multidevice
@pytest.mark.parametrize("name", pw_specs.registered_kernels())
def test_every_kernel_fused_sharded_parity_and_entries(name):
    """Acceptance: each registered KernelSpec through the 8-device mesh must
    (a) claim the fused route (last_route == 'pallas_fused_sharded'),
    (b) match its dense ref.py oracle to ≤ 1e-5, and
    (c) evaluate entry counts within one thin panel of the sequential sweep
    — i.e. the PR-3 routing guarantees, kernel-family-wide."""
    n, d = 413, 8
    rng = np.random.default_rng(16)
    X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    spec = _family_spec(name, d)
    V = jnp.asarray(rng.normal(size=(n, 6)), jnp.float32)
    cidx = jnp.asarray([0, n // 3, n - 1])
    plans = lambda: [sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx)]

    K_shd = CountingOperator(PairwiseKernel(X, spec, use_pallas=True))
    got = K_shd.sweep(plans(), mesh=_mesh())
    assert K_shd.last_route == "pallas_fused_sharded"
    assert K_shd.counts["fused_sweeps"] == 1 and K_shd.counts["sweeps"] == 1

    # (b) parity vs the kernel's independent dense oracle
    Kd = np.asarray(pw_ref.kernel_block(spec, X, X))
    _parity(got[0], Kd @ np.asarray(V))
    _parity(got[1], Kd[:, np.asarray(cidx)])

    # (c) metered entries within one thin panel of the sequential sweep
    K_seq = CountingOperator(PairwiseKernel(X, spec, use_pallas=True))
    K_seq.sweep(plans())
    assert K_seq.last_route == "pallas_fused"
    dp = len(jax.devices())
    bs_seq = sw.resolved_block_size(n, n, None)
    bs_shd = sw.resolved_block_size(n, n, None, dp)
    one_panel = max(bs_seq, bs_shd) * n
    assert abs(K_shd.counts["entries"] - K_seq.counts["entries"]) <= one_panel
    assert K_shd.counts["entries"] == dp * sw.local_slab_rows(n, n, None,
                                                              dp) * n


@multidevice
def test_sharded_adaptive_matches_local():
    Kop = _rbf(5)
    key = jax.random.PRNGKey(0)
    idx_l = np.asarray(uniform_adaptive2_indices(Kop, key, 12))
    idx_s = np.asarray(uniform_adaptive2_indices(Kop, key, 12, mesh=_mesh()))
    # residual norms match to 1e-5 -> identical sampling decisions
    np.testing.assert_array_equal(idx_l, idx_s)
