"""shard_map-parallel sweep: sharded == single-device to 1e-5, identical
shapes, automatic fallback on trivial meshes.

The multi-device cases need >1 local devices; CI runs them in a dedicated job
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (they skip on the
default single-CPU run, where only the fallback tests execute).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import spsd
from repro.core import sweep as sw
from repro.core.adaptive import uniform_adaptive2_indices
from repro.core.kernelop import RBFKernel
from repro.core.sweep import mesh_data_size

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh():
    return Mesh(np.asarray(jax.devices()), ("data",))


def _rbf(seed, n=533, d=8, sigma=2.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)) * 2.5
    X = centers[rng.integers(0, 8, size=n)] + rng.normal(size=(n, d)) * 0.4
    return RBFKernel(jnp.asarray(X, jnp.float32), sigma=sigma)


# ---------------------------------------------------------------------------
# fallback: trivial meshes route through the sequential scan
# ---------------------------------------------------------------------------

def test_single_device_mesh_falls_back():
    Kop = _rbf(0, n=200)
    V = jax.random.normal(jax.random.PRNGKey(1), (200, 4), jnp.float32)
    mesh1 = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    assert mesh_data_size(None) == 1 and mesh_data_size(mesh1) == 1
    a = np.asarray(Kop.matmat(V, block_size=64))
    b = np.asarray(Kop.matmat(V, block_size=64, mesh=mesh1))
    np.testing.assert_array_equal(a, b)      # same code path, bitwise equal


def test_model_axis_only_mesh_is_trivial_for_sweeps():
    mesh = Mesh(np.asarray(jax.devices()), ("model",))
    assert mesh_data_size(mesh) == 1         # no data axis -> fallback


# ---------------------------------------------------------------------------
# multi-device parity
# ---------------------------------------------------------------------------

@multidevice
@pytest.mark.parametrize("n", [533, 512])    # panel-count not/divisible by 8
def test_sharded_sweep_matches_local(n):
    Kop = _rbf(1, n=n)
    V = jax.random.normal(jax.random.PRNGKey(2), (n, 6), jnp.float32)
    cidx = jnp.asarray([1, n // 2, n - 1])
    plans = lambda: [sw.MatmulPlan(V), sw.ColumnGatherPlan(cidx),
                     sw.FrobeniusPlan()]
    loc = Kop.sweep(plans(), block_size=64)
    shd = Kop.sweep(plans(), block_size=64, mesh=_mesh())
    for a, b in zip(loc, shd):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@multidevice
def test_sharded_fast_model_matches_local():
    Kop = _rbf(2)
    key = jax.random.PRNGKey(0)
    ap_l = spsd.fast_model(Kop, key, c=20, s=80, s_sketch="gaussian",
                           streaming=True)
    ap_s = spsd.fast_model(Kop, key, c=20, s=80, s_sketch="gaussian",
                           streaming=True, mesh=_mesh())
    assert ap_s.C.shape == ap_l.C.shape and ap_s.U.shape == ap_l.U.shape
    np.testing.assert_allclose(np.asarray(ap_s.C), np.asarray(ap_l.C),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ap_s.U), np.asarray(ap_l.U),
                               rtol=1e-4, atol=1e-4)


@multidevice
def test_sharded_error_metrics_match_local():
    Kop = _rbf(3)
    ap = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=20, s=80,
                         s_sketch="gaussian", streaming=True)
    mesh = _mesh()
    e_l = float(spsd.relative_error(Kop, ap, method="blocked"))
    e_s = float(spsd.relative_error(Kop, ap, method="blocked", mesh=mesh))
    assert e_s == pytest.approx(e_l, abs=1e-5)
    h_l = float(spsd.relative_error(Kop, ap, method="hutchinson", probes=32,
                                    key=jax.random.PRNGKey(1)))
    h_s = float(spsd.relative_error(Kop, ap, method="hutchinson", probes=32,
                                    key=jax.random.PRNGKey(1), mesh=mesh))
    assert h_s == pytest.approx(h_l, abs=1e-5)


@multidevice
def test_sharded_fused_model_with_error_matches_local():
    Kop = _rbf(4)
    key = jax.random.PRNGKey(0)
    ap_l, e_l = spsd.fast_model_with_error(Kop, key, c=20, s=80, probes=32)
    ap_s, e_s = spsd.fast_model_with_error(Kop, key, c=20, s=80, probes=32,
                                           mesh=_mesh())
    np.testing.assert_allclose(np.asarray(ap_s.U), np.asarray(ap_l.U),
                               rtol=1e-4, atol=1e-4)
    assert float(e_s) == pytest.approx(float(e_l), abs=1e-5)


@multidevice
def test_sharded_adaptive_matches_local():
    Kop = _rbf(5)
    key = jax.random.PRNGKey(0)
    idx_l = np.asarray(uniform_adaptive2_indices(Kop, key, 12))
    idx_s = np.asarray(uniform_adaptive2_indices(Kop, key, 12, mesh=_mesh()))
    # residual norms match to 1e-5 -> identical sampling decisions
    np.testing.assert_array_equal(idx_l, idx_s)
