"""BAD: module-state randomness (RPR005)."""
import jax
import numpy as np

_SHARED_KEY = jax.random.PRNGKey(0)      # flagged: module-scope key minting


def leaky_global_draw(n):
    return np.random.rand(n)             # flagged: numpy global RNG state


def leaky_reseed(seed):
    np.random.seed(seed)                 # flagged: mutates global state


def seeded_ok(n, seed=0):
    rng = np.random.default_rng(seed)    # seeded generator: OK
    return rng.standard_normal(n)


def keyed_ok(key, n):
    return jax.random.normal(key, (n,))  # key taken as argument: OK
