"""BAD: low-precision dtype hard-coded instead of spec.precision (RPR004)."""
import jax.numpy as jnp


def leaky_tile_cast(K):
    return K.astype(jnp.bfloat16)                    # flagged: literal dtype


def leaky_string_dtype(K):
    return K.astype("float16")                       # flagged: literal dtype


def policy_routed_ok(K, spec):
    return K.astype(spec.tile_dtype())
