"""BAD: unannotated operator materializations (RPR001)."""
import jax.numpy as jnp


def leaky_error(Kop, approx):
    Kd = Kop.full()                       # flagged: no allow-dense reason
    R = Kd - approx.dense()               # flagged: same
    return jnp.sum(R * R)


def annotated_ok(Kop):
    return Kop.full()  # repro: allow-dense(fixture exemplar of a waived oracle)


def shape_call_ok():
    return jnp.full((4, 4), 0.0)          # takes args: not an operator oracle
