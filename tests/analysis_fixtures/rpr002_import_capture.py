"""BAD: the PR-3 bug class — backend captured at import time (RPR002).

Reconstruction of the original defect: a module constant freezes the
interpret decision when the module is imported, so tests (or launchers)
that select a platform afterwards silently run the stale choice.
"""
import jax

_INTERPRET = jax.default_backend() != "tpu"     # flagged: import-time capture
N_DEVICES = jax.device_count()                  # flagged
DEVICES = jax.devices()                         # flagged


def fine_per_call() -> bool:
    return jax.default_backend() != "tpu"       # resolved per call: OK


def kernel(x, interpret=None):
    if interpret is None:
        interpret = fine_per_call()
    return x
