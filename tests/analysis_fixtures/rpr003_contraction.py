"""BAD: contractions without preferred_element_type (RPR003)."""
import jax
import jax.numpy as jnp

_DN = (((1,), (0,)), ((), ()))


def leaky_matmul(K, V):
    return K @ V                                     # flagged: '@'


def leaky_dot_general(K, V):
    return jax.lax.dot_general(K, V, dimension_numbers=_DN)   # flagged


def leaky_einsum(K, V):
    return jnp.einsum("ij,jk->ik", K, V)             # flagged


def accumulated_ok(K, V):
    return jax.lax.dot_general(K, V, dimension_numbers=_DN,
                               preferred_element_type=jnp.float32)
