"""Incremental artifact maintenance (repro.serve.incremental): append-row
absorbs metered to ONE thin launch, grown-corpus parity vs dense f64
oracles, delta-checkpoint round trips (bitwise), GC of superseded deltas
under the junk-entry hardening, corrupt-delta classification, and the
staleness-triggered re-sketch through ArtifactRecovery."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core.instrument import CountingOperator
from repro.kernels.pairwise import specs as pw_specs
from repro.launch.serve_kernel import BatchPolicy, KernelServer
from repro.runtime.fault_tolerance import ArtifactRecovery, ArtifactStaleError
from repro.serve import (
    IncrementalMaintainer,
    StalenessPolicy,
    append_rows,
    build_artifact,
    compact,
    dense_krr_oracle,
    dense_oracle,
    gc_superseded_deltas,
    init_state,
    is_delta_step,
    load_artifact,
    load_chain,
    parity_gap,
    save_artifact,
    save_delta,
)

N, D, C, S = 240, 4, 32, 64
B = 16          # appended rows per batch


def _problem(seed=0, n=N, d=D):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    w = rng.standard_normal((d,)).astype(np.float32)
    y = np.tanh(X @ w)
    return X, y, w, rng


@pytest.fixture(scope="module")
def built():
    X, y, w, _ = _problem()
    spec = pw_specs.get_spec("rbf", sigma=3.0)   # smooth -> low drift
    art = build_artifact(jnp.asarray(X), jnp.asarray(y, jnp.float32), spec,
                         c=C, s=S, alpha=1.0, key=jax.random.PRNGKey(0))
    return art, X, y, w, spec


def _batches(w, rng, count, rows=B, d=D):
    out = []
    for _ in range(count):
        Xb = rng.standard_normal((rows, d)).astype(np.float32)
        out.append((Xb, np.tanh(Xb @ w)))
    return out


# ---------------------------------------------------------------------------
# the absorb: metering + parity
# ---------------------------------------------------------------------------

def test_append_is_one_thin_metered_launch(built):
    art, X, y, w, spec = built
    state = init_state(art, y)
    op = CountingOperator(art.landmark_operator())
    _, rng = None, np.random.default_rng(1)
    for i, (Xb, yb) in enumerate(_batches(w, rng, 3)):
        art, state, stats, _ = append_rows(art, state, Xb, yb, op=op)
        assert stats.generation == i + 1
        assert stats.n_after == N + (i + 1) * B
        assert op.counts["append_sweeps"] == i + 1
    # O(b·c): thin launches only — nothing else touched the kernel
    assert op.counts["sweeps"] == 0
    assert op.counts["fulls"] == 0
    assert op.counts["cross_sweeps"] == 0
    assert op.counts["columns"] == 0
    assert op.counts["entries"] == 3 * B * C


def test_grown_corpus_parity_vs_dense_oracles():
    # Serve-convention shapes (d=24, sigma=1): the dense oracle re-solves the
    # n-sized system from the f32-cast artifact.U, so the module fixture's
    # smooth sigma=3/d=4 kernel (near-singular, ‖K̂‖≈n) amplifies that cast
    # to ~1e-5 before any append happens — a well-conditioned spec isolates
    # the incremental path itself.
    dq = 24
    X, y, w, _ = _problem(seed=11, d=dq)
    spec = pw_specs.get_spec("rbf", sigma=1.0)
    art = build_artifact(jnp.asarray(X), jnp.asarray(y, jnp.float32), spec,
                         c=C, s=S, alpha=1.0, key=jax.random.PRNGKey(0))
    state = init_state(art, y)
    rng = np.random.default_rng(2)
    ys = [y[:, None]]
    for Xb, yb in _batches(w, rng, 3, d=dq):
        art, state, _, _ = append_rows(art, state, Xb, yb)
        ys.append(yb[:, None])
    y_full = np.concatenate(ys, axis=0)
    assert int(art.C.shape[0]) == y_full.shape[0]

    qop = art.landmark_operator()
    Xq = jnp.asarray(rng.standard_normal((19, dq)).astype(np.float32))
    # KRR: the refreshed head must match an INDEPENDENT dense f64 solve of
    # the grown system (C' U' C'ᵀ + αI) w = y_full
    expected = dense_krr_oracle(art, Xq, jnp.asarray(y_full, jnp.float32))
    (got,) = qop.cross(Xq, (art.heads["krr"],))
    assert parity_gap(got, expected) <= 1e-5
    # KPCA / features: refreshed heads must agree with the dense route over
    # the refreshed factors
    for task in ("kpca", "features"):
        expected = dense_oracle(art, Xq, task)
        (got,) = qop.cross(Xq, (art.heads[task],))
        assert parity_gap(got, expected) <= 1e-4


def test_no_build_artifact_rerun_and_c_grows_by_stacking(built):
    art, X, y, w, spec = built
    state = init_state(art, y)
    rng = np.random.default_rng(3)
    (Xb, yb), = _batches(w, rng, 1)
    art2, state2, stats, delta = append_rows(art, state, Xb, yb)
    # base rows of C are untouched (no recompute of the n-sized factor) and
    # the landmarks/selection are carried over unchanged
    assert np.array_equal(np.asarray(art2.C[:N]), np.asarray(art.C))
    assert art2.X_landmarks is art.X_landmarks
    assert np.array_equal(np.asarray(art2.C[N:]), np.asarray(delta.G))
    assert state2.n == N + B and stats.batch_rows == B


def test_drift_signal_discriminates(built):
    art, X, y, w, spec = built
    state = init_state(art, y)
    rng = np.random.default_rng(4)
    (Xb, yb), = _batches(w, rng, 1)
    _, _, stats_in, _ = append_rows(art, state, Xb, yb)
    assert stats_in.drift < 0.05
    X_ood = 10.0 + rng.standard_normal((B, D)).astype(np.float32)
    _, _, stats_ood, _ = append_rows(art, init_state(art, y), X_ood,
                                     np.zeros(B, np.float32))
    assert stats_ood.drift > 5 * stats_in.drift


def test_staleness_policy_thresholds():
    pol = StalenessPolicy(drift_threshold=0.3, error_budget=0.4,
                          max_generations=5)
    from repro.serve import GenerationStats

    def stats(**kw):
        base = dict(generation=1, n_before=10, batch_rows=2, n_after=12,
                    drift=0.0, error_est=0.0)
        base.update(kw)
        return GenerationStats(**base)

    assert pol.should_resketch(stats()) is None
    assert "drift" in pol.should_resketch(stats(drift=0.31))
    assert "error" in pol.should_resketch(stats(error_est=0.5))
    assert "generation" in pol.should_resketch(stats(generation=5))


# ---------------------------------------------------------------------------
# delta checkpoints: round trip, chain validation, GC, corruption
# ---------------------------------------------------------------------------

def test_delta_chain_roundtrip_is_bitwise(built, tmp_path):
    art, X, y, w, spec = built
    d = str(tmp_path)
    save_artifact(d, art, step=0)
    m = IncrementalMaintainer(art, y, directory=d, X=X)
    rng = np.random.default_rng(5)
    for Xb, yb in _batches(w, rng, 3):
        m.append(Xb, yb)
    steps = ckpt.committed_steps(d)
    assert steps == [0, 1, 2, 3]
    assert [is_delta_step(d, s) for s in steps] == [False, True, True, True]

    restored = load_artifact(d)
    live = m.artifact
    for f in ("C", "U", "woodbury_M", "kpca_eigvals"):
        a, b = np.asarray(getattr(restored, f)), np.asarray(getattr(live, f))
        assert a.dtype == b.dtype and np.array_equal(a, b), f
    for t in ("krr", "kpca", "features"):
        assert np.array_equal(np.asarray(restored.heads[t]),
                              np.asarray(live.heads[t])), t
    # bitwise factors + heads => bitwise predictions
    Xq = jnp.asarray(rng.standard_normal((9, D)).astype(np.float32))
    (p1,) = restored.landmark_operator().cross(Xq, (restored.heads["krr"],))
    (p2,) = live.landmark_operator().cross(Xq, (live.heads["krr"],))
    assert np.array_equal(np.asarray(p1), np.asarray(p2))


def test_mid_chain_restore_and_generation_gap_is_corruption(built, tmp_path):
    art, X, y, w, spec = built
    d = str(tmp_path)
    save_artifact(d, art, step=0)
    m = IncrementalMaintainer(art, y, directory=d, X=X)
    rng = np.random.default_rng(6)
    for Xb, yb in _batches(w, rng, 3):
        m.append(Xb, yb)
    mid, chain = load_chain(d, 2)
    assert int(mid.C.shape[0]) == N + 2 * B and len(chain) == 2
    # delete a middle link: the chain above it must be unreadable
    ckpt.remove_step(d, 2)
    with pytest.raises(ckpt.CheckpointCorruptionError):
        load_chain(d, 3)


def test_corrupt_delta_is_corruption_and_rebuild_path_recovers(
        built, tmp_path):
    art, X, y, w, spec = built
    d = str(tmp_path)
    save_artifact(d, art, step=0)
    m = IncrementalMaintainer(art, y, directory=d, X=X)
    rng = np.random.default_rng(7)
    (Xb, yb), = _batches(w, rng, 1)
    m.append(Xb, yb)
    # truncate the delta's manifest -> file-level damage
    with open(os.path.join(d, "step_000000001", "manifest.json"), "w") as f:
        f.write('{"leaf_00000": {"pa')
    with pytest.raises(ckpt.CheckpointCorruptionError):
        load_artifact(d)
    # load_or_rebuild turns that into a rebuild-from-source, not a crash
    from repro.serve import load_or_rebuild
    out, recovery = load_or_rebuild(d, lambda: art)
    assert [e.kind for e in recovery.events] == ["corrupt", "rebuilt"]


def test_undecodable_delta_tree_is_corruption(built, tmp_path):
    art, X, y, w, spec = built
    d = str(tmp_path)
    # a committed step that LOOKS like a delta (delta_json leaf) but whose
    # payload is garbage must classify as corruption, not KeyError
    ckpt.save(d, 0, artifact_to_tree_ok := {"delta_json": "not json {"})
    assert is_delta_step(d, 0)
    with pytest.raises(ckpt.CheckpointCorruptionError):
        load_chain(d, 0)


def test_gc_superseded_deltas_under_junk_hardening(built, tmp_path):
    art, X, y, w, spec = built
    d = str(tmp_path)
    save_artifact(d, art, step=0)
    m = IncrementalMaintainer(art, y, directory=d, X=X)
    rng = np.random.default_rng(8)
    for Xb, yb in _batches(w, rng, 2):
        m.append(Xb, yb)
    # junk the store the way crashes do: stray file, tmp dir, manifest-less
    # dir, torn manifest — GC must skip them all without crashing
    open(os.path.join(d, "step_junk"), "w").close()
    os.makedirs(os.path.join(d, "step_000000077.tmp"))
    os.makedirs(os.path.join(d, "step_000000088"))
    os.makedirs(os.path.join(d, "step_000000099"))
    with open(os.path.join(d, "step_000000099", "manifest.json"), "w") as f:
        f.write('{"truncat')

    # nothing superseded yet: the only full snapshot predates the deltas
    assert gc_superseded_deltas(d) == 0
    assert is_delta_step(d, 1) and is_delta_step(d, 2)

    # compact -> a newer full snapshot supersedes the chain
    step = compact(d, m.artifact)
    steps = ckpt.committed_steps(d)
    assert step in steps and not is_delta_step(d, step)
    assert 1 not in steps and 2 not in steps      # deltas GC'd
    # junk untouched, restore still lands on the live artifact
    assert os.path.exists(os.path.join(d, "step_junk"))
    restored = load_artifact(d)
    assert np.array_equal(np.asarray(restored.C), np.asarray(m.artifact.C))


def test_gc_keeps_deltas_based_on_latest_full(built, tmp_path):
    art, X, y, w, spec = built
    d = str(tmp_path)
    save_artifact(d, art, step=0)
    m = IncrementalMaintainer(art, y, directory=d, X=X)
    rng = np.random.default_rng(9)
    (Xb, yb), = _batches(w, rng, 1)
    m.append(Xb, yb)
    base = compact(d, m.artifact)                  # new base, old delta GC'd
    m.base_step = base
    m.state = init_state(m.artifact, m.y_full())
    (Xb, yb), = _batches(w, rng, 1)
    m.append(Xb, yb)                               # delta on the NEW base
    assert gc_superseded_deltas(d) == 0            # current chain survives
    assert is_delta_step(d, base + 1)


# ---------------------------------------------------------------------------
# staleness -> re-sketch through ArtifactRecovery
# ---------------------------------------------------------------------------

def test_stale_error_routes_to_stale_event():
    rec = ArtifactRecovery(stale_types=(ArtifactStaleError,))

    def load():
        raise ArtifactStaleError("generation 3: drift 0.9 > 0.5")

    out = rec.run(load=load, rebuild=lambda: "fresh")
    assert out == "fresh"
    assert [e.kind for e in rec.events] == ["stale", "rebuilt"]


def test_maintainer_resketch_compacts_and_continues(built, tmp_path):
    art, X, y, w, spec = built
    d = str(tmp_path)
    save_artifact(d, art, step=0)
    rebuilds = []

    def rebuild_fn(Xf, yf):
        rebuilds.append(int(Xf.shape[0]))
        return build_artifact(jnp.asarray(Xf), jnp.asarray(yf, jnp.float32),
                              spec, c=C, s=S, alpha=1.0,
                              key=jax.random.PRNGKey(1))

    op = CountingOperator(art.landmark_operator())
    m = IncrementalMaintainer(
        art, y, directory=d, X=X,
        staleness=StalenessPolicy(drift_threshold=0.3),
        rebuild_fn=rebuild_fn, op=op)
    rng = np.random.default_rng(10)
    (Xb, yb), = _batches(w, rng, 1)
    stats = m.append(Xb, yb)
    assert not stats.resketch

    X_ood = 10.0 + rng.standard_normal((B, D)).astype(np.float32)
    stats = m.append(X_ood, np.zeros(B, np.float32))
    assert stats.resketch and "drift" in stats.resketch_reason
    assert rebuilds == [N + 2 * B]
    assert [e.kind for e in m.recovery.events] == ["stale", "rebuilt"]
    # compacted: no deltas remain, the new base is the grown full snapshot
    steps = ckpt.committed_steps(d)
    assert not any(is_delta_step(d, s) for s in steps)
    assert int(load_artifact(d).C.shape[0]) == N + 2 * B
    # the metered operator was rebound to the NEW landmarks and appends
    # continue as generation 1 of the new base
    (Xb, yb), = _batches(w, rng, 1)
    stats = m.append(Xb, yb)
    assert stats.generation == 1 and not stats.resketch
    assert op.counts["append_sweeps"] == 3         # cumulative across rebind
    assert int(load_artifact(d).C.shape[0]) == N + 3 * B


# ---------------------------------------------------------------------------
# server integration: appends through the continuous-batching loop
# ---------------------------------------------------------------------------

def test_server_absorbs_appends_in_order_and_serves_grown(built, tmp_path):
    art, X, y, w, spec = built
    d = str(tmp_path)
    save_artifact(d, art, step=0)
    op = CountingOperator(art.landmark_operator())
    m = IncrementalMaintainer(art, y, directory=d, X=X, op=op)
    server = KernelServer(art, BatchPolicy(max_wait_s=0.005), op=op,
                          maintainer=m)
    rng = np.random.default_rng(11)
    try:
        batches = _batches(w, rng, 3)
        pending = [server.submit_append(Xb, yb) for Xb, yb in batches]
        stats = [p.wait(timeout=60.0) for p in pending]
        assert [s.generation for s in stats] == [1, 2, 3]
        assert server.appends_served == 3
        assert op.counts["append_sweeps"] == 3

        # the server now answers from the refreshed artifact
        assert int(server.artifact.C.shape[0]) == N + 3 * B
        y_full = np.concatenate([y[:, None]]
                                + [yb[:, None] for _, yb in batches], axis=0)
        Xq = rng.standard_normal((11, D)).astype(np.float32)
        expected = dense_krr_oracle(server.artifact, jnp.asarray(Xq),
                                    jnp.asarray(y_full, jnp.float32))
        res = server.submit(Xq, "krr").wait(timeout=60.0)
        # 1e-4, not 1e-5: the module fixture's smooth sigma=3/d=4 kernel
        # amplifies the oracle's f32 U cast to ~1e-5 on the BASE build
        # already; the strict 1e-5 grown-corpus gate runs on the
        # well-conditioned spec in
        # test_grown_corpus_parity_vs_dense_oracles and in the CI
        # serve-smoke append leg.
        assert parity_gap(res.out, expected) <= 1e-4
    finally:
        server.stop()
    # and the delta chain persisted every generation
    assert int(load_artifact(d).C.shape[0]) == N + 3 * B


def test_server_submit_append_requires_maintainer(built):
    art, *_ = built
    server = KernelServer(art, BatchPolicy(max_wait_s=0.005))
    try:
        with pytest.raises(RuntimeError, match="maintainer"):
            server.submit_append(np.zeros((2, D), np.float32),
                                 np.zeros(2, np.float32))
    finally:
        server.stop()
