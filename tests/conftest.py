"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the real single
CPU device; only launch/dryrun.py forces 512 host devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
