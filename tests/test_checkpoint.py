"""Checkpoint store hardening: junk-entry tolerance in latest_step/_retain
(regression for the serving warm-boot path), shape-free restore_tree, and
corruption classification."""
import json
import os

import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.checkpoint import (
    CheckpointCorruptionError,
    CheckpointManager,
    gc_tmp,
    latest_step,
    restore,
    restore_tree,
    save,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((4, 3)).astype(np.float32),
            "heads": {"krr": rng.standard_normal((3, 1)).astype(np.float32),
                      "kpca": rng.standard_normal((3, 2)).astype(np.float32)},
            "meta_json": np.asarray("hello")}


# ---------------------------------------------------------------------------
# latest_step/_retain must ignore junk directory entries (the regression:
# a stale .tmp dir or half-deleted step made boot crash or restore nothing)
# ---------------------------------------------------------------------------

def test_latest_step_ignores_stale_tmp_dir(tmp_path):
    save(str(tmp_path), 5, _tree())
    os.makedirs(tmp_path / "step_000000777.tmp")   # crash mid-write leftover
    assert latest_step(str(tmp_path)) == 5
    # and restore of the reported step works while the tmp dir exists
    out = restore(str(tmp_path), 5, _tree())
    assert np.array_equal(out["w"], _tree()["w"])


def test_latest_step_ignores_stray_file_and_manifestless_dir(tmp_path):
    save(str(tmp_path), 3, _tree())
    (tmp_path / "step_000000888").write_text("not a checkpoint")
    os.makedirs(tmp_path / "step_000000555")       # gc/retention race: empty
    assert latest_step(str(tmp_path)) == 3


def test_latest_step_concurrent_gc_tmp(tmp_path):
    """gc_tmp removing a stale write-in-flight never hides the committed
    step (the serving boot runs both on the same directory)."""
    save(str(tmp_path), 2, _tree())
    os.makedirs(tmp_path / "step_000000004.tmp")
    assert gc_tmp(str(tmp_path)) == 1
    assert latest_step(str(tmp_path)) == 2
    out = restore(str(tmp_path), 2, _tree())
    assert np.array_equal(out["heads"]["kpca"], _tree()["heads"]["kpca"])


def test_retain_survives_junk_entries(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    (tmp_path / "step_junkname").mkdir()           # int() used to crash here
    (tmp_path / "step_000000999").write_text("stray file")
    for step in (1, 2, 3, 4):
        mgr.save(step, _tree(step))
    kept = sorted(n for n in os.listdir(tmp_path)
                  if n.startswith("step_00000000"))
    assert kept == ["step_000000003", "step_000000004"]
    assert latest_step(str(tmp_path)) == 4


# ---------------------------------------------------------------------------
# restore_tree: shape-free reconstruction from the manifest
# ---------------------------------------------------------------------------

def test_restore_tree_nested_roundtrip(tmp_path):
    tree = _tree(9)
    save(str(tmp_path), 0, tree)
    out = restore_tree(str(tmp_path), 0)
    assert set(out) == {"w", "heads", "meta_json"}
    assert set(out["heads"]) == {"krr", "kpca"}
    assert np.array_equal(out["w"], tree["w"])
    assert np.array_equal(out["heads"]["krr"], tree["heads"]["krr"])
    assert str(np.asarray(out["meta_json"]).item()) == "hello"


# ---------------------------------------------------------------------------
# corruption classification
# ---------------------------------------------------------------------------

def test_truncated_manifest_raises_corruption_error(tmp_path):
    save(str(tmp_path), 1, _tree())
    (tmp_path / "step_000000001" / "manifest.json").write_text('{"leaf_')
    with pytest.raises(CheckpointCorruptionError):
        restore_tree(str(tmp_path), 1)
    with pytest.raises(CheckpointCorruptionError):
        restore(str(tmp_path), 1, _tree())


def test_missing_shards_raise_corruption_error(tmp_path):
    save(str(tmp_path), 1, _tree())
    step_dir = tmp_path / "step_000000001"
    for name in os.listdir(step_dir):
        if name.endswith(".npz"):
            os.remove(step_dir / name)
    with pytest.raises(CheckpointCorruptionError, match="no shard"):
        restore_tree(str(tmp_path), 1)


def test_healthy_mismatch_is_not_corruption(tmp_path):
    """A checkpoint that reads fine but doesn't match ``like`` keeps raising
    the plain structural errors — ArtifactRecovery must NOT swallow those."""
    save(str(tmp_path), 1, _tree())
    bad_like = _tree()
    bad_like["w"] = np.zeros((2, 2), np.float32)
    with pytest.raises(ValueError, match="shape"):
        restore(str(tmp_path), 1, bad_like)
    bad_like = _tree()
    bad_like["extra"] = np.zeros((1,), np.float32)
    with pytest.raises(KeyError, match="missing leaf"):
        restore(str(tmp_path), 1, bad_like)


def test_corruption_error_is_runtime_error():
    assert issubclass(ckpt.CheckpointCorruptionError, RuntimeError)


def test_manifest_mapping_mismatch_is_corruption(tmp_path):
    """Manifest whose keys don't cover the npz entries (torn write across
    the two files) classifies as corruption, not a KeyError leak."""
    save(str(tmp_path), 1, _tree())
    man = tmp_path / "step_000000001" / "manifest.json"
    with open(man) as f:
        manifest = json.load(f)
    manifest.pop(sorted(manifest)[0])
    man.write_text(json.dumps(manifest))
    with pytest.raises(CheckpointCorruptionError):
        restore_tree(str(tmp_path), 1)
