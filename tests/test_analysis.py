"""Regression suite for the static analysis gate (`repro.analysis`).

Every shipped lint rule must flag its known-bad fixture under
``tests/analysis_fixtures/`` (these tests FAIL if a rule is disabled or its
detection decays), and both jaxpr checks must catch deliberately broken
entry points: a densifying toy pipeline and a policy whose declared
``sweep_budget()`` lies about its metered sweeps.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import findings as findings_lib
from repro.analysis import jaxpr_check
from repro.analysis import lint
from repro.analysis.__main__ import main as analysis_main
from repro.core import selection
from repro.core import sweep as sweep_lib

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# rule id -> (fixture file, number of deliberate violations in it)
RULE_FIXTURES = {
    "RPR001": ("rpr001_densify.py", 2),
    "RPR002": ("rpr002_import_capture.py", 3),
    "RPR003": ("rpr003_contraction.py", 3),
    "RPR004": ("rpr004_dtype.py", 2),
    "RPR005": ("rpr005_randomness.py", 3),
}


# ---------------------------------------------------------------------------
# AST rules vs fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule_id", sorted(RULE_FIXTURES))
def test_rule_flags_its_fixture(rule_id):
    """Each rule finds exactly the deliberate violations in its fixture —
    this test fails if the rule is disabled, unregistered, or decays."""
    fname, expected = RULE_FIXTURES[rule_id]
    path = os.path.join(FIXTURES, fname)
    fs = lint.lint_file(path, rules=[lint.get_rule(rule_id)],
                        ignore_scope=True)
    flagged = [f for f in fs if f.rule == rule_id]
    assert len(flagged) == expected, [f.format() for f in fs]
    assert all(f.line > 0 and f.snippet for f in flagged)


@pytest.mark.parametrize("rule_id", sorted(RULE_FIXTURES))
def test_fixture_is_rule_specific(rule_id):
    """With the rule removed from the active set, its fixture goes quiet —
    the findings come from the rule, not engine side effects."""
    fname, _ = RULE_FIXTURES[rule_id]
    path = os.path.join(FIXTURES, fname)
    others = [r for r in lint.registered_rules() if r.rule_id != rule_id]
    fs = lint.lint_file(path, rules=others, ignore_scope=True)
    assert all(f.rule != rule_id for f in fs)


def test_all_five_rules_registered():
    ids = [r.rule_id for r in lint.registered_rules()]
    assert ids == sorted(RULE_FIXTURES)


def test_head_tree_is_lint_clean():
    """The acceptance bar: the shipped tree has zero lint findings (every
    intentional oracle is annotated with a reason)."""
    fs = lint.lint_paths([os.path.join(REPO_ROOT, "src")],
                         repo_root=REPO_ROOT)
    assert fs == [], [f.format() for f in fs]


# ---------------------------------------------------------------------------
# allow-annotation semantics
# ---------------------------------------------------------------------------

def test_annotation_waives_on_same_and_previous_line():
    same = "K = op.full()  # repro: allow-dense(oracle, n small)\n"
    above = "# repro: allow-dense(oracle, n small)\nK = op.full()\n"
    for src in (same, above):
        assert lint.lint_source(src, "src/repro/core/m.py") == []


def test_annotation_without_reason_is_itself_a_finding():
    src = "# repro: allow-dense()\nK = op.full()\n"
    rules = {f.rule for f in lint.lint_source(src, "src/repro/core/m.py")}
    assert rules == {"RPR000", "RPR001"}  # empty waiver AND the violation


def test_file_level_allow_names_one_rule():
    src = ("# repro: allow-file(RPR003: dense oracle module)\n"
           "import jax.numpy as jnp\n"
           "y = a @ b\n"
           "dt = jnp.bfloat16\n")
    fs = lint.lint_source(src, "src/repro/kernels/x/m.py")
    assert {f.rule for f in fs} == {"RPR004"}  # RPR003 waived, RPR004 not


def test_rule_scopes_limit_where_rules_fire():
    # '@' contractions are a kernels/-only concern
    src = "y = a @ b\n"
    assert lint.lint_source(src, "src/repro/core/m.py") == []
    assert [f.rule for f in lint.lint_source(
        src, "src/repro/kernels/m.py")] == ["RPR003"]


# ---------------------------------------------------------------------------
# baseline: grandfathered debt shrinks, never grows
# ---------------------------------------------------------------------------

def test_baseline_grandfathers_existing_but_blocks_new(tmp_path):
    path = "src/repro/core/m.py"
    fs1 = lint.lint_source("K = op.full()\n", path)
    bl = tmp_path / "baseline.json"
    findings_lib.write_baseline(str(bl), fs1)
    baseline = findings_lib.load_baseline(str(bl))

    new, stale = findings_lib.compare_to_baseline(fs1, baseline)
    assert new == [] and stale == []

    # a second occurrence of the same violation is NEW — debt cannot grow
    fs2 = lint.lint_source("K = op.full()\nJ = K2.full()\n", path)
    new2, _ = findings_lib.compare_to_baseline(fs2, baseline)
    assert len(new2) == 1

    # fixing the grandfathered finding leaves a shrinkable stale entry
    new3, stale3 = findings_lib.compare_to_baseline([], baseline)
    assert new3 == [] and len(stale3) == 1


def test_fingerprint_survives_line_shifts():
    path = "src/repro/core/m.py"
    (f1,) = lint.lint_source("K = op.full()\n", path)
    (f2,) = lint.lint_source("x = 1\n\n\nK = op.full()\n", path)
    assert f1.line != f2.line
    assert f1.fingerprint() == f2.fingerprint()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _write_bad_tree(tmp_path):
    mod = tmp_path / "src" / "repro" / "core"
    mod.mkdir(parents=True)
    (mod / "leak.py").write_text("K = op.full()\n")


def test_cli_exits_nonzero_on_findings(tmp_path, monkeypatch):
    _write_bad_tree(tmp_path)
    monkeypatch.chdir(tmp_path)
    assert analysis_main(["--paths", "src", "--no-jaxpr", "--quiet"]) == 1


def test_cli_baseline_and_json_report(tmp_path, monkeypatch):
    _write_bad_tree(tmp_path)
    monkeypatch.chdir(tmp_path)
    # grandfather the findings, then the gate passes and reports them
    assert analysis_main(["--paths", "src", "--no-jaxpr", "--quiet",
                          "--baseline", "bl.json",
                          "--write-baseline"]) == 0
    rc = analysis_main(["--paths", "src", "--no-jaxpr", "--quiet",
                        "--baseline", "bl.json",
                        "--json", "report.json"])
    assert rc == 0
    report = json.loads((tmp_path / "report.json").read_text())
    assert report["total"] == 1 and report["new"] == 0
    assert report["by_rule"] == {"RPR001": 1}


def test_cli_clean_tree_exits_zero(tmp_path, monkeypatch):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "clean.py").write_text("x = 1\n")
    monkeypatch.chdir(tmp_path)
    assert analysis_main(["--paths", "src", "--no-jaxpr", "--quiet"]) == 0


# ---------------------------------------------------------------------------
# jaxpr pass: densify detector + sweep-budget verifier + precision scan
# ---------------------------------------------------------------------------

def test_densify_detector_fails_toy_entry():
    """A deliberately densifying pipeline (K = full(), then K @ V) must
    trip RPRJ01 — the compile-time booby trap."""
    op = jaxpr_check.smoke_operator(n=256, use_pallas=False)

    def toy(key):
        K = op.inner.full()
        return K @ jax.random.normal(key, (op.n, 4), dtype=jnp.float32)

    closed = jax.make_jaxpr(toy)(jax.random.PRNGKey(0))
    fs = jaxpr_check.scan_densify(closed, op.n, "toy_dense")
    assert fs and all(f.rule == "RPRJ01" for f in fs)


def test_densify_detector_passes_streaming_entry():
    """The real streaming path at the same n stays under the threshold."""
    fs, rep = jaxpr_check.check_policy_select("uniform")
    assert fs == [], [f.format() for f in fs]
    assert rep["ok"]


def test_lying_sweep_budget_is_caught():
    """A policy that declares 0 sweeps but spends 1 must trip RPRJ02 —
    declarations are verified against the abstract trace, not trusted."""
    class LyingPolicy(selection.SelectionPolicy):
        name = "lying_fixture"
        rounds = 0           # declared budget: zero kernel sweeps

        def select(self, K, key, c, *, block_size=None, mesh=None,
                   mask=None):
            V = jnp.zeros((K.n, 4), jnp.float32)
            K.sweep([sweep_lib.MatmulPlan(V)], block_size=block_size)
            return jax.random.choice(key, K.n, shape=(c,), replace=False)

    selection.register_policy("lying_fixture")(LyingPolicy)
    try:
        fs, rep = jaxpr_check.check_policy_select("lying_fixture")
        assert any(f.rule == "RPRJ02" for f in fs), \
            [f.format() for f in fs]
        assert not rep["ok"]
    finally:
        selection._POLICIES.pop("lying_fixture")


def test_fast_model_one_sweep_contract_verified():
    """fast_model(uniform, gaussian) == exactly 1 sweep, statically."""
    fs, rep = jaxpr_check.check_fast_model("uniform")
    assert fs == [], [f.format() for f in fs]
    assert rep["expected"]["sweeps"] == 1
    assert rep["counts"]["sweeps"] == 1


def test_unaccumulated_bf16_contraction_is_caught():
    """dot_general with bf16 operands and no f32 accumulation -> RPRJ03."""
    dn = (((1,), (0,)), ((), ()))

    def bad(a, b):
        return jax.lax.dot_general(a.astype(jnp.bfloat16),
                                   b.astype(jnp.bfloat16),
                                   dimension_numbers=dn)

    closed = jax.make_jaxpr(bad)(jnp.zeros((8, 8)), jnp.zeros((8, 8)))
    fs = jaxpr_check.scan_contractions(closed, "toy_bf16")
    assert fs and fs[0].rule == "RPRJ03"


def test_bf16_policy_sweep_accumulates_f32_on_head():
    """The shipped bf16_f32acc sweep template passes the accumulation scan
    (and its trace contains at least one low-precision dot to scan)."""
    fs, rep = jaxpr_check.check_kernel_precision("rbf")
    assert fs == [], [f.format() for f in fs]

    opc = jaxpr_check.smoke_operator(precision="bf16_f32acc")
    closed = jax.make_jaxpr(
        lambda V: opc.sweep([sweep_lib.MatmulPlan(V)],
                            block_size=jaxpr_check.SMOKE_BLOCK))(
        jnp.zeros((opc.n, 8), jnp.float32))
    bf16_dots = [
        eqn for eqn in jaxpr_check.iter_eqns(closed)
        if eqn.primitive.name == "dot_general"
        and any(getattr(getattr(v, "aval", None), "dtype", None)
                == jnp.bfloat16 for v in eqn.invars)]
    assert bf16_dots, "expected the bf16 tile dots to appear in the trace"


def test_probe_key_default_is_documented_and_explicit_keys_differ():
    """Satellite: relative_error's key=None path uses the documented
    DEFAULT_PROBE_SEED, and two explicit keys give different estimates."""
    from repro.core import spsd
    from repro.core.kernelop import PairwiseKernel
    from repro.kernels.pairwise import specs as pw_specs

    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.standard_normal((300, 4)), jnp.float32)
    op = PairwiseKernel(X, pw_specs.get_spec("rbf", sigma=1.5), False)
    ap = spsd.fast_model(op, jax.random.PRNGKey(1), c=10, s=20,
                         s_sketch="gaussian", streaming=True)

    e_default = float(spsd.relative_error(op, ap, method="hutchinson",
                                          probes=8))
    e_seed0 = float(spsd.relative_error(
        op, ap, method="hutchinson", probes=8,
        key=jax.random.PRNGKey(spsd.DEFAULT_PROBE_SEED)))
    ka, kb = jax.random.PRNGKey(123), jax.random.PRNGKey(456)
    e_a = float(spsd.relative_error(op, ap, method="hutchinson", probes=8,
                                    key=ka))
    e_b = float(spsd.relative_error(op, ap, method="hutchinson", probes=8,
                                    key=kb))

    assert e_default == e_seed0          # key=None IS the documented seed
    assert e_a != e_b                    # explicit keys drive the probes
    assert np.isfinite([e_default, e_a, e_b]).all()
