"""The paper's technique applied to attention: quality + scaling laws."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketched_attention import (build_landmark_state,
                                           landmark_decode,
                                           sketched_attention)


def _qkv(key, S=256, D=32, scale=0.4):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (S, D)) * scale
    k = jax.random.normal(ks[1], (S, D)) * scale
    v = jax.random.normal(ks[2], (S, D))
    return q, k, v


def _exact(q, k, v):
    logits = (q @ k.T) / np.sqrt(q.shape[-1])
    w = jax.nn.softmax(logits, axis=-1)
    return w @ v


def _err(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))


def test_sketched_attention_error_decreases_with_c():
    q, k, v = _qkv(jax.random.PRNGKey(0))
    exact = _exact(q, k, v)
    errs = []
    for c in (8, 32, 128):
        outs = [sketched_attention(q, k, v, jax.random.PRNGKey(10 + i),
                                   c=c, theta=4)
                for i in range(3)]
        errs.append(np.mean([_err(o, exact) for o in outs]))
    assert errs[0] > errs[-1], errs
    assert errs[-1] < 0.15, errs


def test_fast_mode_beats_nystrom_mode():
    """The paper's core claim transplanted to the softmax Gram."""
    q, k, v = _qkv(jax.random.PRNGKey(1), S=384)
    exact = _exact(q, k, v)
    e_fast = np.mean([_err(sketched_attention(
        q, k, v, jax.random.PRNGKey(20 + i), c=24, theta=8, mode="fast"),
        exact) for i in range(5)])
    e_nys = np.mean([_err(sketched_attention(
        q, k, v, jax.random.PRNGKey(20 + i), c=24, theta=8, mode="nystrom"),
        exact) for i in range(5)])
    assert e_fast <= e_nys + 1e-3, (e_fast, e_nys)


def test_landmark_state_decode_read():
    """Prefill-built landmark state answers one-token reads close to exact
    attention over the full context."""
    key = jax.random.PRNGKey(2)
    S, D = 512, 32
    _, k, v = _qkv(key, S=S, D=D)
    state = build_landmark_state(k, v, jax.random.fold_in(key, 1), c=64,
                                 theta=4)
    q1 = jax.random.normal(jax.random.fold_in(key, 2), (4, D)) * 0.4
    got = jax.vmap(lambda qq: landmark_decode(state, qq))(q1)
    want = _exact(q1, k, v)
    assert _err(got, want) < 0.35, _err(got, want)


def test_landmark_read_kernel_path_matches_core():
    from repro.kernels.landmark_attention import ops as lm_ops
    key = jax.random.PRNGKey(3)
    S, D = 256, 32
    _, k, v = _qkv(key, S=S, D=D)
    state = build_landmark_state(k, v, jax.random.fold_in(key, 1), c=32,
                                 theta=4)
    q1 = jax.random.normal(jax.random.fold_in(key, 2), (8, D)) * 0.4
    a = jax.vmap(lambda qq: landmark_decode(state, qq))(q1)
    b = lm_ops.landmark_read(q1, state.k_land, state.UV, state.U1,
                             state.scale)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2,
                               atol=2e-2)
