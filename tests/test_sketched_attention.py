"""The paper's technique applied to attention: quality + scaling laws."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketched_attention import (build_landmark_state,
                                           landmark_decode,
                                           sketched_attention)


def _qkv(key, S=256, D=32, scale=0.4):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (S, D)) * scale
    k = jax.random.normal(ks[1], (S, D)) * scale
    v = jax.random.normal(ks[2], (S, D))
    return q, k, v


def _exact(q, k, v):
    logits = (q @ k.T) / np.sqrt(q.shape[-1])
    w = jax.nn.softmax(logits, axis=-1)
    return w @ v


def _err(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))


def test_sketched_attention_error_decreases_with_c():
    q, k, v = _qkv(jax.random.PRNGKey(0))
    exact = _exact(q, k, v)
    errs = []
    for c in (8, 32, 128):
        outs = [sketched_attention(q, k, v, jax.random.PRNGKey(10 + i),
                                   c=c, theta=4)
                for i in range(3)]
        errs.append(np.mean([_err(o, exact) for o in outs]))
    assert errs[0] > errs[-1], errs
    assert errs[-1] < 0.15, errs


def test_fast_mode_beats_nystrom_mode():
    """The paper's core claim transplanted to the softmax Gram."""
    q, k, v = _qkv(jax.random.PRNGKey(1), S=384)
    exact = _exact(q, k, v)
    e_fast = np.mean([_err(sketched_attention(
        q, k, v, jax.random.PRNGKey(20 + i), c=24, theta=8, mode="fast"),
        exact) for i in range(5)])
    e_nys = np.mean([_err(sketched_attention(
        q, k, v, jax.random.PRNGKey(20 + i), c=24, theta=8, mode="nystrom"),
        exact) for i in range(5)])
    assert e_fast <= e_nys + 1e-3, (e_fast, e_nys)


def test_landmark_state_decode_read():
    """Prefill-built landmark state answers one-token reads close to exact
    attention over the full context."""
    key = jax.random.PRNGKey(2)
    S, D = 512, 32
    _, k, v = _qkv(key, S=S, D=D)
    state = build_landmark_state(k, v, jax.random.fold_in(key, 1), c=64,
                                 theta=4)
    q1 = jax.random.normal(jax.random.fold_in(key, 2), (4, D)) * 0.4
    got = jax.vmap(lambda qq: landmark_decode(state, qq))(q1)
    want = _exact(q1, k, v)
    assert _err(got, want) < 0.35, _err(got, want)


def test_landmark_read_kernel_path_matches_core():
    from repro.kernels.landmark_attention import ops as lm_ops
    key = jax.random.PRNGKey(3)
    S, D = 256, 32
    _, k, v = _qkv(key, S=S, D=D)
    state = build_landmark_state(k, v, jax.random.fold_in(key, 1), c=32,
                                 theta=4)
    q1 = jax.random.normal(jax.random.fold_in(key, 2), (8, D)) * 0.4
    a = jax.vmap(lambda qq: landmark_decode(state, qq))(q1)
    b = lm_ops.landmark_read(q1, state.k_land, state.UV, state.U1,
                             state.scale)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2,
                               atol=2e-2)


# ---------------------------------------------------------------------------
# bugfix regression tests (each fails deterministically on the pre-PR code)
# ---------------------------------------------------------------------------

def _sk_module():
    # ``repro.core`` re-exports the *function* ``sketched_attention``, which
    # shadows the submodule attribute — route around it via importlib.
    import importlib
    return importlib.import_module("repro.core.sketched_attention")


def _spy_fast_U_cur(monkeypatch, captured):
    sk_mod = _sk_module()
    orig = sk_mod.fast_U_cur

    def spy(ScC, G_blk, RSr):
        captured["ScC"] = np.asarray(ScC)
        captured["RSr"] = np.asarray(RSr)
        return orig(ScC, G_blk, RSr)

    monkeypatch.setattr(sk_mod, "fast_U_cur", spy)


def test_rectangular_fast_sketch_rows_stay_in_bounds(monkeypatch):
    """m < c fast mode: the row sketch must index REAL rows of Q.

    The old code started ``sq`` from ``jnp.arange(c)``, which clamp-gathers
    out-of-bounds (duplicated) rows of an m-row Q whenever m < c, and padded
    it to s = θc rows regardless of m.
    """
    captured = {}
    _spy_fast_U_cur(monkeypatch, captured)
    m, n, D, c = 8, 256, 32, 32
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(jax.random.fold_in(key, 0), (m, D)) * 0.4
    k = jax.random.normal(jax.random.fold_in(key, 1), (n, D)) * 0.4
    v = jax.random.normal(jax.random.fold_in(key, 2), (n, D))
    out = sketched_attention(q, k, v, jax.random.fold_in(key, 3), c=c,
                             theta=4, mode="fast")
    assert np.all(np.isfinite(np.asarray(out)))
    ScC = captured["ScC"]
    assert ScC.shape[0] <= m, \
        f"row sketch has {ScC.shape[0]} rows from an m={m} query block"
    assert np.unique(ScC, axis=0).shape[0] == ScC.shape[0], \
        "duplicated rows in the sketched C panel"


def test_square_fast_sketch_is_duplicate_free(monkeypatch):
    """Square fast mode: sketch extensions must exclude the landmarks and
    sample without replacement (old code: replace=True over ALL of [0, n),
    so duplicated rows/columns biased fast_U_cur)."""
    captured = {}
    _spy_fast_U_cur(monkeypatch, captured)
    S, D, c, theta = 64, 16, 16, 4          # s = 64 = n: any dup is provable
    q, k, v = _qkv(jax.random.PRNGKey(5), S=S, D=D)
    sketched_attention(q, k, v, jax.random.PRNGKey(6), c=c, theta=theta,
                       mode="fast")
    ScC, RSr = captured["ScC"], captured["RSr"]
    assert np.unique(ScC, axis=0).shape[0] == ScC.shape[0], \
        "duplicated rows in S_qᵀĈ"
    assert np.unique(RSr.T, axis=0).shape[0] == RSr.shape[1], \
        "duplicated columns in R̂S_k"


def test_build_landmark_state_sketch_is_duplicate_free(monkeypatch):
    captured = {}
    _spy_fast_U_cur(monkeypatch, captured)
    _, k, v = _qkv(jax.random.PRNGKey(7), S=64, D=16)
    build_landmark_state(k, v, jax.random.PRNGKey(8), c=16, theta=4)
    RSr = captured["RSr"]
    assert np.unique(RSr.T, axis=0).shape[0] == RSr.shape[1], \
        "duplicated columns in the prefill sketch"


def test_landmark_indices_degenerate_request():
    """c >= n: old code computed seg = n // c == 0 and returned ALL-ZERO
    indices (every landmark the same token)."""
    import warnings as _warnings

    from repro.core.sketched_attention import landmark_indices
    with _warnings.catch_warnings(record=True) as w:
        _warnings.simplefilter("always")
        idx = np.asarray(landmark_indices(jax.random.PRNGKey(0), 16, 32))
    assert np.unique(idx).shape[0] == 16, idx
    assert any("clamping" in str(x.message) for x in w)
    # c == n is not degenerate: distinct, no warning needed
    idx_eq = np.asarray(landmark_indices(jax.random.PRNGKey(1), 16, 16))
    assert np.unique(idx_eq).shape[0] == 16


def test_denominator_sign_preserved_under_U_flip(monkeypatch):
    """out = (ĈŨR̂V)/(ĈŨR̂1) is invariant to Ũ → −Ũ *only* if the
    denominator floor preserves sign; the old maximum(den, 1e-6) clamped a
    negated (negative) denominator to +1e-6 and blew the output up."""
    sk_mod = _sk_module()
    q, k, v = _qkv(jax.random.PRNGKey(9), S=128, D=16)
    kr = jax.random.PRNGKey(10)
    out_pos = sketched_attention(q, k, v, kr, c=16, theta=4, mode="fast")
    orig = sk_mod.fast_U_cur
    monkeypatch.setattr(sk_mod, "fast_U_cur",
                        lambda *a: -orig(*a))
    out_neg = sketched_attention(q, k, v, kr, c=16, theta=4, mode="fast")
    np.testing.assert_allclose(np.asarray(out_neg), np.asarray(out_pos),
                               rtol=1e-4, atol=1e-5)


def test_decode_and_kernel_read_sign_preserved():
    """The decode cache and both fused-read paths (pallas + ref) share the
    sign-preserving floor: negating (UV, U1) must leave the read unchanged."""
    from repro.kernels.landmark_attention import ops as lm_ops
    _, k, v = _qkv(jax.random.PRNGKey(11), S=128, D=16)
    state = build_landmark_state(k, v, jax.random.PRNGKey(12), c=16)
    q1 = jax.random.normal(jax.random.PRNGKey(13), (4, 16)) * 0.4

    a = jax.vmap(lambda qq: landmark_decode(state, qq))(q1)
    neg = state._replace(UV=-state.UV, U1=-state.U1)
    b = jax.vmap(lambda qq: landmark_decode(neg, qq))(q1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)
    for use_pallas in (True, False):
        r1 = lm_ops.landmark_read(q1, state.k_land, state.UV, state.U1,
                                  state.scale, use_pallas=use_pallas)
        r2 = lm_ops.landmark_read(q1, neg.k_land, neg.UV, neg.U1, neg.scale,
                                  use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   rtol=1e-4, atol=1e-5)


def test_selection_policy_landmarks():
    """SelectionPolicy-chosen landmarks ride the attention path end-to-end:
    distinct indices from the softmax-Gram operator, finite output, and
    accuracy in the same band as strided landmarks."""
    from repro.core.sketched_attention import select_landmarks
    q, k, v = _qkv(jax.random.PRNGKey(14), S=192, D=16)
    exact = _exact(q, k, v)
    for sel in ("uniform", "leverage", "uniform_adaptive2"):
        idx = np.asarray(select_landmarks(k, jax.random.PRNGKey(15), 24,
                                          selection=sel))
        assert np.unique(idx).shape[0] == 24, (sel, idx)
        errs = [_err(sketched_attention(q, k, v, jax.random.PRNGKey(20 + i),
                                        c=24, theta=4, selection=sel),
                     exact) for i in range(3)]
        assert np.mean(errs) < 0.35, (sel, errs)
