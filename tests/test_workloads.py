"""Downstream workload suite: streaming guarantees, approx_eigh edge cases,
calibration parity, and the bench-row contract.

The tentpole invariant: ``bench_kpca`` / ``bench_spectral`` (and hence the
workload rows built on them) run with ZERO ``full()`` calls on the kernel
operator — booby-trapped here over the whole bench entry points.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eig
from repro.core.kernelop import PairwiseKernel

# ---------------------------------------------------------------------------
# zero-full() booby traps over the whole bench entry points
# ---------------------------------------------------------------------------


def _boom(self):
    raise AssertionError("workload bench materialized the n×n kernel")


def test_bench_kpca_never_calls_full(monkeypatch):
    from benchmarks import bench_kpca
    monkeypatch.setattr(PairwiseKernel, "full", _boom)
    rows = bench_kpca.run_misalignment("pendigit", k=3, cs=(16,), n=160,
                                       selections=("uniform",))
    assert rows and all(np.isfinite(r["misalignment"]) for r in rows)
    knn = bench_kpca.run_knn("pendigit", k=3, c=16, n=160,
                             selections=("uniform",))
    assert knn and all(np.isfinite(r["test_err"]) for r in knn)


def test_bench_spectral_never_calls_full(monkeypatch):
    from benchmarks import bench_spectral
    monkeypatch.setattr(PairwiseKernel, "full", _boom)
    rows = bench_spectral.run("pendigit", k=4, cs=(16,), n=160,
                              selections=("uniform",))
    assert rows
    for r in rows:
        assert np.isfinite(r["nmi"]) and np.isfinite(r["nmi_vs_dense"])


def test_streaming_subspace_eigh_matches_dense():
    X = jax.random.normal(jax.random.PRNGKey(0), (220, 8))
    from repro.kernels.pairwise import specs as pw_specs
    Kop = PairwiseKernel(X, pw_specs.get_spec("rbf", sigma=2.0))
    ref = eig.streaming_subspace_eigh(Kop, 4, power_iters=8)
    lam, V = jnp.linalg.eigh(Kop.full())
    np.testing.assert_allclose(np.asarray(ref.eigenvalues),
                               np.asarray(lam[::-1][:4]), rtol=1e-4)
    mis = float(eig.misalignment(V[:, ::-1][:, :4], ref.eigenvectors))
    assert mis < 1e-6, mis


# ---------------------------------------------------------------------------
# approx_eigh edge cases the workloads hit
# ---------------------------------------------------------------------------


def test_approx_eigh_rank_deficient_C():
    """c greater than the numerical rank of C: eigenvectors must stay
    finite and the sqrt(lam) feature map NaN-free."""
    key = jax.random.PRNGKey(1)
    n, r, c = 120, 5, 24                       # C has rank 5 << c = 24
    A = jax.random.normal(key, (n, r))
    B = jax.random.normal(jax.random.fold_in(key, 1), (r, c))
    C = A @ B
    U = jnp.eye(c)
    res = eig.approx_eigh(C, U, k=8)
    assert np.all(np.isfinite(np.asarray(res.eigenvalues)))
    assert np.all(np.isfinite(np.asarray(res.eigenvectors)))
    feats, _ = eig.kpca_features(C, U, k=8)
    assert np.all(np.isfinite(np.asarray(feats))), "sqrt(lam) features NaN"


def test_approx_eigh_negative_trailing_eigenvalues():
    """Indefinite U (the fast-CUR U can be): trailing eigenvalues of M go
    negative; downstream feature maps must clamp, not NaN."""
    key = jax.random.PRNGKey(2)
    n, c = 100, 12
    C = jax.random.normal(key, (n, c))
    neg = jnp.concatenate([jnp.ones(6), -0.5 * jnp.ones(6)])
    U = jnp.diag(neg)                          # explicitly indefinite
    res = eig.approx_eigh(C, U, k=c)
    assert float(res.eigenvalues[-1]) < 0.0, "test premise: M is indefinite"
    assert np.all(np.isfinite(np.asarray(res.eigenvectors)))
    feats, eres = eig.kpca_features(C, U, k=c)
    assert np.all(np.isfinite(np.asarray(feats))), "sqrt(-lam) leaked a NaN"
    # transform path (Λ^{-1/2}) must also stay finite on a test column
    k_x = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (n, 2)))
    te = eig.kpca_transform(eres, k_x)
    assert np.all(np.isfinite(np.asarray(te)))


def test_spectral_embedding_streamed_degrees():
    """degrees= must override the model-implied degree vector (exact
    streamed d = K1) and produce unit row norms."""
    X = jax.random.normal(jax.random.PRNGKey(3), (150, 6))
    from repro.core import spsd
    from repro.kernels.pairwise import specs as pw_specs
    Kop = PairwiseKernel(X, pw_specs.get_spec("rbf", sigma=1.5))
    ap = spsd.fast_model(Kop, jax.random.PRNGKey(4), c=24, s=48,
                         s_sketch="uniform")
    deg = Kop.matmat(jnp.ones((150, 1), jnp.float32))[:, 0]
    V = eig.spectral_embedding(ap.C, ap.U, 4, degrees=deg)
    assert np.all(np.isfinite(np.asarray(V)))
    np.testing.assert_allclose(np.linalg.norm(np.asarray(V), axis=1), 1.0,
                               atol=1e-4)
    # and it differs from the model-implied-degree route in general
    V0 = eig.spectral_embedding(ap.C, ap.U, 4)
    assert not np.allclose(np.asarray(V), np.asarray(V0), atol=1e-7)


# ---------------------------------------------------------------------------
# calibration dedupe: bench rule == library registry rule
# ---------------------------------------------------------------------------


def test_calibrate_sigma_delegates_to_registry():
    from benchmarks import common
    from repro.kernels.pairwise import calibrate as pw_cal
    X, _ = common.make_dataset("letters", seed=0, n=400)
    got = common.calibrate_sigma(X)
    spec = pw_cal.calibrate_sigma(jnp.asarray(X, jnp.float32), "rbf")
    assert got == pytest.approx(float(spec.param("sigma")), rel=1e-6)


def test_calibrate_sigma_parity_with_eta_rule():
    """The registry quantile rule lands in the same bandwidth regime as the
    old spectral-mass binary search at the smoke shape (same order of
    magnitude — the benches' accuracy numbers stay comparable)."""
    from benchmarks import common
    X, _ = common.make_dataset("letters", seed=0, n=400)
    s_new = common.calibrate_sigma(X)
    s_old = common.calibrate_sigma_eta(X, 0.9, 3)
    assert 0.4 < s_new / s_old < 2.5, (s_new, s_old)


# ---------------------------------------------------------------------------
# the bench-row contract: every workload emits accuracy-vs-dense + wall-clock
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_workload_rows_have_accuracy_and_wallclock():
    from benchmarks import bench_workloads
    rows = bench_workloads.run(seed=0)
    assert [r["workload"] for r in rows] == ["kpca", "spectral", "krr",
                                             "attention"]
    acc_key = {"kpca": "misalignment", "spectral": "nmi_vs_dense",
               "krr": "parity_vs_dense", "attention": "rel_err_vs_exact"}
    for r in rows:
        assert np.isfinite(r[acc_key[r["workload"]]]), r
        assert r["seconds"] > 0.0, r
    # accuracy sanity at the smoke shapes
    by = {r["workload"]: r for r in rows}
    assert by["kpca"]["misalignment"] < 0.5
    assert by["spectral"]["nmi_vs_dense"] > 0.2
    assert by["krr"]["parity_vs_dense"] < 1e-4
    assert by["attention"]["rel_err_vs_exact"] < 0.35
