"""Property tests for the paper's core claims (Thms 3/6/7, Corollary 5,
Appendix A), plus the sketch-operator algebra they depend on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cur, eig, kernelop, spsd
from repro.core import sketch as sk
from repro.core.leverage import pinv, row_leverage_scores

jax.config.update("jax_enable_x64", False)


def _lowrank_spsd(key, n, r):
    X = jax.random.normal(key, (n, r))
    return X @ X.T


def _clustered_rbf(seed, n=300, d=6, k=6, sigma=2.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 3
    X = np.concatenate([c + rng.normal(size=(n // k, d)) * 0.3
                        for c in centers])
    return kernelop.RBFKernel(jnp.asarray(X, jnp.float32), sigma=sigma)


# ---------------------------------------------------------------------------
# Theorem 6: exact recovery when rank(K) == rank(C)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(0, 1000))
def test_exact_recovery(r, seed):
    key = jax.random.PRNGKey(seed)
    n = 40
    K = _lowrank_spsd(key, n, r)
    c = r + 4                        # rank(C) = rank(K) w.p. 1
    ap = spsd.fast_model(K, jax.random.fold_in(key, 1), c=c, s=2 * c,
                         s_sketch="uniform")
    err = float(spsd.relative_error(K, ap))
    assert err < 1e-3, err


def test_exact_recovery_fails_when_rank_deficient():
    key = jax.random.PRNGKey(0)
    K = _lowrank_spsd(key, 40, 10)
    ap = spsd.fast_model(K, jax.random.fold_in(key, 1), c=3, s=8,
                         s_sketch="uniform")
    assert float(spsd.relative_error(K, ap)) > 1e-3


# ---------------------------------------------------------------------------
# Unified view: Nystrom and prototype are special cases of the fast model
# ---------------------------------------------------------------------------

def test_nystrom_is_fast_with_S_eq_P():
    K = np.asarray(_clustered_rbf(0).full())
    key = jax.random.PRNGKey(1)
    idx = jax.random.choice(key, K.shape[0], shape=(20,), replace=False)
    C = jnp.take(K, idx, axis=1)
    W = jnp.take(jnp.take(K, idx, axis=0), idx, axis=1)
    U_nys = spsd.nystrom_U(W)
    # fast U with S = P (selection of the same idx, unscaled)
    StC = jnp.take(C, idx, axis=0)
    U_fast = spsd.fast_U(StC, W)
    np.testing.assert_allclose(np.asarray(U_nys), np.asarray(U_fast),
                               rtol=2e-2, atol=2e-4)


def test_prototype_is_fast_with_S_eq_I():
    K = jnp.asarray(np.asarray(_clustered_rbf(1).full()))
    key = jax.random.PRNGKey(2)
    idx = jax.random.choice(key, K.shape[0], shape=(15,), replace=False)
    C = jnp.take(K, idx, axis=1)
    U_star = spsd.prototype_U(K, C)
    U_fast = spsd.fast_U(C, K)       # S = I_n
    np.testing.assert_allclose(np.asarray(U_star), np.asarray(U_fast),
                               rtol=2e-2, atol=2e-4)


# ---------------------------------------------------------------------------
# Theorem 3 (statistical): fast ~ prototype; accuracy ordering on average
# ---------------------------------------------------------------------------

def test_error_ordering_nystrom_fast_prototype():
    Kop = _clustered_rbf(2)
    kc = jax.random.PRNGKey(3)
    base = spsd.sample_C(Kop, kc, 15)
    proto = spsd.prototype_model(Kop, base.C, base.P_indices)
    e_proto = float(spsd.relative_error(Kop, proto))

    W = Kop.block(base.P_indices, base.P_indices)
    nys = spsd.SPSDApprox(C=base.C, U=spsd.nystrom_U(W),
                          P_indices=base.P_indices)
    e_nys = float(spsd.relative_error(Kop, nys))

    e_fast = np.mean([
        float(spsd.relative_error(Kop, spsd.fast_model_from_C(
            Kop, base.C, jax.random.PRNGKey(10 + i), 8 * 15,
            P_indices=base.P_indices, s_sketch="uniform")))
        for i in range(5)])

    # prototype is optimal for this C; fast with s=8c sits between
    assert e_proto <= e_fast + 1e-6
    assert e_fast <= e_nys + 1e-3, (e_fast, e_nys)


def test_fast_error_decreases_with_s():
    Kop = _clustered_rbf(3)
    base = spsd.sample_C(Kop, jax.random.PRNGKey(0), 12)
    errs = []
    for s_mult in (2, 8, 20):
        e = np.mean([float(spsd.relative_error(Kop, spsd.fast_model_from_C(
            Kop, base.C, jax.random.PRNGKey(50 + 7 * i + s_mult), s_mult * 12,
            P_indices=base.P_indices, s_sketch="uniform")))
            for i in range(5)])
        errs.append(e)
    assert errs[2] <= errs[0] + 1e-6, errs


@pytest.mark.parametrize("kind", ["uniform", "leverage", "gaussian",
                                  "srht", "countsketch"])
def test_fast_model_all_sketches(kind):
    """Every Table-4 sketch family produces a sane fast model."""
    Kop = _clustered_rbf(4)
    base = spsd.sample_C(Kop, jax.random.PRNGKey(0), 15)
    ap = spsd.fast_model_from_C(Kop, base.C, jax.random.PRNGKey(1), 90,
                                P_indices=base.P_indices, s_sketch=kind)
    e = float(spsd.relative_error(Kop, ap))
    proto = spsd.prototype_model(Kop, base.C, base.P_indices)
    e_proto = float(spsd.relative_error(Kop, proto))
    assert np.isfinite(e)
    assert e <= 3 * e_proto + 0.05, (kind, e, e_proto)


# ---------------------------------------------------------------------------
# Theorem 7 lower bound (adversarial block-diagonal case, Lemma 23)
# ---------------------------------------------------------------------------

def test_lower_bound_adversarial():
    n, k, c, s = 64, 4, 8, 16
    p = n // k
    alpha = 0.999
    B = (1 - alpha) * np.eye(p) + alpha * np.ones((p, p))
    K = jnp.asarray(np.kron(np.eye(k), B), jnp.float32)

    # uniform selection respecting P subset S, block-balanced
    rng = np.random.default_rng(0)
    ratios = []
    for trial in range(5):
        pidx = np.concatenate([rng.choice(p, c // k, replace=False) + i * p
                               for i in range(k)])
        extra = np.concatenate([rng.choice(p, (s - c) // k, replace=False)
                                + i * p for i in range(k)])
        sidx = np.unique(np.concatenate([pidx, extra]))
        C = jnp.take(K, pidx, axis=1)
        StC = jnp.take(C, sidx, axis=0)
        StKS = jnp.take(jnp.take(K, sidx, axis=0), sidx, axis=1)
        U = spsd.fast_U(StC, StKS)
        approx = spsd.SPSDApprox(C=C, U=U)
        Kk_err = float(jnp.sum(jnp.sort(jnp.linalg.eigvalsh(K) ** 2)[:n - k]))
        num = float(jnp.sum((K - approx.dense()) ** 2))
        ratios.append(num / Kk_err)
    s_eff = len(sidx)
    bound = ((n - c) / (n - k) * (1 + 2 * k / c)
             + (n - s_eff) / (n - k) * k * (n - s_eff) / s_eff ** 2)
    # Thm 7: no selection does better than the bound (up to alpha->1 limit)
    assert np.mean(ratios) >= 0.8 * bound, (np.mean(ratios), bound)


# ---------------------------------------------------------------------------
# Corollary 5 / S4.5 implementation details
# ---------------------------------------------------------------------------

def test_subset_union_contains_P():
    key = jax.random.PRNGKey(0)
    S = sk.uniform_column_sketch(key, 100, 20, scale=False)
    P_idx = jnp.arange(7)
    S2 = sk.subset_union_sketch(S, P_idx, 100)
    got = set(np.asarray(S2.indices).tolist())
    assert set(range(7)) <= got


# ---------------------------------------------------------------------------
# Appendix A solvers
# ---------------------------------------------------------------------------

def test_approx_eigh_matches_dense():
    key = jax.random.PRNGKey(0)
    C = jax.random.normal(key, (50, 8))
    U = jnp.eye(8) * jnp.arange(1, 9)
    lam, V = jnp.linalg.eigh(C @ U @ C.T)
    res = eig.approx_eigh(C, U, k=5)
    np.testing.assert_allclose(np.asarray(res.eigenvalues),
                               np.asarray(lam[::-1][:5]), rtol=1e-4,
                               atol=1e-4)
    # eigenvectors span check via projector difference
    Vt = np.asarray(V[:, ::-1][:, :5])
    Va = np.asarray(res.eigenvectors)
    np.testing.assert_allclose(Va.T @ Va, np.eye(5), atol=1e-4)
    np.testing.assert_allclose(Vt @ Vt.T, Va @ Va.T, atol=1e-3)


def test_woodbury_solve():
    key = jax.random.PRNGKey(1)
    C = jax.random.normal(key, (40, 6))
    U = jnp.eye(6)
    y = jax.random.normal(jax.random.fold_in(key, 1), (40,))
    alpha = 0.5
    w = eig.woodbury_solve(C, U, alpha, y)
    direct = jnp.linalg.solve(C @ U @ C.T + alpha * jnp.eye(40), y)
    np.testing.assert_allclose(np.asarray(w), np.asarray(direct), rtol=1e-3,
                               atol=1e-4)


def test_woodbury_solve_singular_U():
    key = jax.random.PRNGKey(2)
    C = jax.random.normal(key, (30, 5))
    U = jnp.diag(jnp.asarray([1.0, 1.0, 0.0, 0.0, 2.0]))   # singular
    y = jax.random.normal(jax.random.fold_in(key, 3), (30,))
    w = eig.woodbury_solve(C, U, 0.3, y)
    direct = jnp.linalg.solve(C @ U @ C.T + 0.3 * jnp.eye(30), y)
    np.testing.assert_allclose(np.asarray(w), np.asarray(direct), rtol=1e-3,
                               atol=1e-4)


def test_misalignment_bounds():
    key = jax.random.PRNGKey(3)
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (30, 10)))
    U_true, V = Q[:, :3], Q[:, 3:6]
    assert float(eig.misalignment(U_true, U_true)) < 1e-6
    m = float(eig.misalignment(U_true, V))
    assert 0.0 <= m <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# sketch operator algebra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gaussian", "srht", "countsketch"])
def test_projection_sym_consistency(kind):
    key = jax.random.PRNGKey(0)
    K = _lowrank_spsd(key, 33, 5)
    S = sk.make_sketch(kind, jax.random.fold_in(key, 1), 33, 16)
    sym = S.sym(K)
    via_left = S.left(S.left(K).T).T
    np.testing.assert_allclose(np.asarray(sym), np.asarray(via_left),
                               rtol=1e-4, atol=1e-4)
    assert sym.shape[0] == sym.shape[1]


def test_column_sketch_matches_dense_matrix():
    key = jax.random.PRNGKey(4)
    A = jax.random.normal(key, (20, 7))
    S = sk.uniform_column_sketch(jax.random.fold_in(key, 1), 20, 6,
                                 scale=True)
    dense_S = np.zeros((20, 6), np.float32)
    dense_S[np.asarray(S.indices), np.arange(6)] = np.asarray(S.scales)
    np.testing.assert_allclose(np.asarray(S.left(A)),
                               dense_S.T @ np.asarray(A), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_countsketch_linearity(seed):
    """S^T(a+b) == S^T a + S^T b — what makes sketch-then-allreduce sound."""
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (50, 3))
    b = jax.random.normal(jax.random.fold_in(key, 1), (50, 3))
    S = sk.count_sketch(jax.random.fold_in(key, 2), 50, 10)
    np.testing.assert_allclose(np.asarray(S.left(a + b)),
                               np.asarray(S.left(a) + S.left(b)), rtol=1e-4,
                               atol=1e-5)


def test_srht_orthogonal_part():
    """The DH/sqrt(n) part of SRHT is orthogonal: full S (s=n_pad) preserves
    norms exactly."""
    key = jax.random.PRNGKey(5)
    n = 32
    x = jax.random.normal(key, (n, 4))
    S = sk.srht_sketch(jax.random.fold_in(key, 1), n, n)   # s = n = n_pad
    y = S.left(x)
    # s = n_pad: sampling w/o replacement hits every row once; norms match
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-4)


def test_leverage_scores_sum_to_rank():
    key = jax.random.PRNGKey(6)
    A = jax.random.normal(key, (40, 5))
    lev = row_leverage_scores(A)
    assert abs(float(jnp.sum(lev)) - 5.0) < 1e-3
    assert float(jnp.max(lev)) <= 1.0 + 1e-5


def test_pinv_matches_numpy():
    key = jax.random.PRNGKey(7)
    A = jax.random.normal(key, (12, 5))
    np.testing.assert_allclose(np.asarray(pinv(A)),
                               np.linalg.pinv(np.asarray(A)), rtol=1e-3,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# CUR (S5): optimality, fast ~ optimal, drineas08 worst (Fig. 2 ordering)
# ---------------------------------------------------------------------------

def _lowrank_matrix(key, m, n, r, noise=0.01):
    a = jax.random.normal(key, (m, r))
    b = jax.random.normal(jax.random.fold_in(key, 1), (r, n))
    e = jax.random.normal(jax.random.fold_in(key, 2), (m, n)) * noise
    return a @ b + e


def test_cur_ordering():
    key = jax.random.PRNGKey(0)
    A = _lowrank_matrix(key, 80, 60, 5)
    fast_errs, opt_errs, dri_errs = [], [], []
    for i in range(5):
        f = cur.fast_cur(A, jax.random.fold_in(key, 10 + i), c=12, r=12,
                         sc=48, sr=48, sketch_kind="uniform")
        fast_errs.append(float(cur.relative_error(A, f)))
        # optimal U on the *same* C/R: Eq. 8 minimizes over U, so per draw
        # e_opt <= e_fast holds deterministically
        U_opt = cur.optimal_U(A, f.C, f.R)
        opt_errs.append(float(cur.relative_error(
            A, cur.CURApprox(C=f.C, U=U_opt, R=f.R))))
        C, R, cidx, ridx = cur.select_cur_sketches(
            A, jax.random.fold_in(key, 10 + i), 12, 12)
        U = cur.drineas08_U(A, cidx, ridx)
        dri_errs.append(float(cur.relative_error(
            A, cur.CURApprox(C=C, U=U, R=R))))
    e_opt, e_fast, e_dri = (np.mean(opt_errs), np.mean(fast_errs),
                            np.mean(dri_errs))
    assert e_opt <= e_fast + 1e-6
    assert e_fast <= e_dri + 1e-6, (e_fast, e_dri)
    # Thm 9 regime: fast is close to optimal
    assert e_fast <= 5 * e_opt + 0.02, (e_fast, e_opt)


def test_fast_cur_improves_with_sketch_size():
    key = jax.random.PRNGKey(1)
    A = _lowrank_matrix(key, 100, 70, 6)
    errs = []
    for s in (16, 30, 64):
        e = np.mean([float(cur.relative_error(A, cur.fast_cur(
            A, jax.random.PRNGKey(100 + 13 * i + s), c=12, r=12, sc=s, sr=s,
            sketch_kind="uniform"))) for i in range(5)])
        errs.append(e)
    assert errs[-1] <= errs[0] + 1e-6, errs


def test_adaptive_rows_reduce_residual():
    key = jax.random.PRNGKey(2)
    A = _lowrank_matrix(key, 60, 40, 8, noise=0.0)
    base = jnp.arange(4)
    idx = cur.adaptive_row_indices(A, base, jax.random.fold_in(key, 1), 8)
    R1 = jnp.take(A, base, axis=0)
    R2 = jnp.take(A, idx, axis=0)
    r1 = float(jnp.linalg.norm(A - (A @ pinv(R1)) @ R1))
    r2 = float(jnp.linalg.norm(A - (A @ pinv(R2)) @ R2))
    assert r2 <= r1 + 1e-5
