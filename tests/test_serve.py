"""Serving path: artifact heads vs dense oracles, bucketed fused launches,
warm-boot persistence through checkpoint/ + fault-tolerance recompute, and
the continuous-batching KernelServer."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.instrument import CountingOperator
from repro.kernels.pairwise import specs as pw_specs
from repro.launch.serve_kernel import (
    BatchPolicy,
    KernelServer,
    build_from_params,
    load_trace,
    replay_trace,
    synth_problem,
    write_trace,
)
from repro.serve import (
    QueryRequest,
    answer_batch,
    build_artifact,
    dense_krr_oracle,
    dense_oracle,
    load_artifact,
    load_or_rebuild,
    parity_gap,
    plan_buckets,
    save_artifact,
    serve_kernel_model,
)

N, D, C, S = 240, 24, 48, 96


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((N, D)).astype(np.float32)
    y = rng.standard_normal((N,)).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(y)


@pytest.fixture(scope="module")
def artifact(problem):
    X, y = problem
    spec = pw_specs.get_spec("rbf", sigma=1.0)
    return build_artifact(X, y, spec, c=C, s=S, alpha=1.0, n_components=8,
                          key=jax.random.PRNGKey(0), use_pallas=True)


@pytest.fixture(scope="module")
def queries():
    rng = np.random.default_rng(7)
    return jnp.asarray(rng.standard_normal((37, D)).astype(np.float32))


# ---------------------------------------------------------------------------
# parity vs the dense oracles
# ---------------------------------------------------------------------------

def test_krr_parity_vs_dense_solve_oracle(artifact, problem, queries):
    """The acceptance gate: the served prediction must match an INDEPENDENT
    dense KRR solve on the approximated kernel (no Woodbury identity, no
    artifact head) to <=1e-5."""
    _, y = problem
    res = serve_kernel_model(artifact, [QueryRequest(queries, "krr")])
    expected = dense_krr_oracle(artifact, queries, y)
    assert parity_gap(res[0].out, expected) <= 1e-5


def test_kpca_and_feature_parity_vs_dense_route(artifact, queries):
    res = serve_kernel_model(artifact, [QueryRequest(queries, "kpca"),
                                        QueryRequest(queries, "features")])
    assert parity_gap(res[0].out, dense_oracle(artifact, queries,
                                               "kpca")) <= 1e-5
    assert parity_gap(res[1].out, dense_oracle(artifact, queries,
                                               "features")) <= 1e-5


def test_feature_map_gram_matches_fast_model(artifact, queries):
    """phi(x)^T phi(y) must reproduce the Nystrom extension
    k_hat(x, y) = K(x, X_S) U K(y, X_S)^T."""
    res = serve_kernel_model(artifact, [QueryRequest(queries, "features")])
    phi = np.asarray(res[0].out, np.float64)
    G = np.asarray(pw_specs.apply(artifact.spec, queries,
                                  artifact.X_landmarks), np.float64)
    khat = G @ np.asarray(artifact.U, np.float64) @ G.T
    assert np.max(np.abs(phi @ phi.T - khat)) <= 1e-4


def test_train_points_round_trip(artifact, problem):
    """Rows of C are K(x_i, X_S), so serving the TRAIN points reproduces the
    fast model's fitted values exactly (same algebra, same precision)."""
    X, _ = problem
    res = serve_kernel_model(artifact, [QueryRequest(X[:50], "krr")])
    fitted = artifact.C[:50].astype(jnp.float32) @ artifact.heads["krr"]
    assert parity_gap(res[0].out, fitted) <= 1e-5


# ---------------------------------------------------------------------------
# bucketed batching: one fused launch per bucket
# ---------------------------------------------------------------------------

def test_one_cross_sweep_per_bucket(artifact):
    rng = np.random.default_rng(3)
    sizes = [100, 90, 20]
    reqs = [QueryRequest(rng.standard_normal((nq, D)).astype(np.float32),
                         task)
            for nq, task in zip(sizes, ("krr", "kpca", "features"))]
    buckets = plan_buckets(reqs, waste=0.25)
    assert len(buckets) == 2          # [100, 90] bucket + [20] bucket

    op = CountingOperator(artifact.landmark_operator())
    results = serve_kernel_model(artifact, reqs, waste=0.25, op=op)
    assert op.counts["cross_sweeps"] == len(buckets)
    assert op.last_route == "pallas_fused_rows"
    # results come back in input order with the right shapes/tasks
    for r, req in zip(results, reqs):
        assert r.task == req.task
        assert r.out.shape[0] == req.n_q


def test_heterogeneous_batch_matches_per_request_answers(artifact):
    rng = np.random.default_rng(4)
    reqs = [QueryRequest(rng.standard_normal((nq, D)).astype(np.float32),
                         task)
            for nq, task in [(5, "krr"), (33, "kpca"), (5, "features"),
                             (17, "krr")]]
    batched = serve_kernel_model(artifact, reqs)
    for req, got in zip(reqs, batched):
        solo = answer_batch(artifact, [req])[0]
        assert parity_gap(got.out, solo.out) <= 1e-6


def test_padding_rows_never_leak(artifact):
    """A size-1 request bucketed with a big one gets exactly its own row."""
    rng = np.random.default_rng(5)
    small = QueryRequest(rng.standard_normal((1, D)).astype(np.float32))
    big = QueryRequest(rng.standard_normal((4, D)).astype(np.float32))
    out = answer_batch(artifact, [big, small])
    assert out[1].out.shape[0] == 1
    assert parity_gap(out[1].out,
                      answer_batch(artifact, [small])[0].out) <= 1e-6


def test_unknown_task_rejected():
    with pytest.raises(ValueError, match="unknown task"):
        QueryRequest(np.zeros((3, D), np.float32), task="cluster")


# ---------------------------------------------------------------------------
# refit: new targets through the cached Woodbury workspace
# ---------------------------------------------------------------------------

def test_refit_matches_fresh_build(artifact, problem, queries):
    X, _ = problem
    rng = np.random.default_rng(11)
    y_new = jnp.asarray(rng.standard_normal((N,)).astype(np.float32))
    refitted = artifact.refit(y_new)
    served = serve_kernel_model(refitted, [QueryRequest(queries, "krr")])
    expected = dense_krr_oracle(artifact, queries, y_new)
    assert parity_gap(served[0].out, expected) <= 1e-4   # f32 workspace


# ---------------------------------------------------------------------------
# persistence: checkpoint roundtrip + recompute-on-corruption
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_bitwise_predictions(artifact, queries,
                                                  tmp_path):
    save_artifact(str(tmp_path), artifact, step=0)
    restored = load_artifact(str(tmp_path))
    assert restored is not None
    assert restored.spec.name == artifact.spec.name
    assert restored.alpha == artifact.alpha
    a = serve_kernel_model(artifact, [QueryRequest(queries, "krr")])
    b = serve_kernel_model(restored, [QueryRequest(queries, "krr")])
    assert np.array_equal(np.asarray(a[0].out), np.asarray(b[0].out))


def test_load_or_rebuild_warm_then_corrupt_then_rebuilt(artifact, queries,
                                                        tmp_path):
    d = str(tmp_path)
    save_artifact(d, artifact, step=0)
    builds = []

    def build_fn():
        builds.append(1)
        return artifact

    got, rec = load_or_rebuild(d, build_fn)
    assert rec.warm and not builds
    assert [e.kind for e in rec.events] == ["restored"]

    # truncate the manifest: corruption must rebuild + re-persist, not crash
    (tmp_path / "step_000000000" / "manifest.json").write_text('{"leaf')
    got, rec = load_or_rebuild(d, build_fn)
    assert [e.kind for e in rec.events] == ["corrupt", "rebuilt"]
    assert len(builds) == 1
    a = serve_kernel_model(got, [QueryRequest(queries, "kpca")])
    assert parity_gap(a[0].out, dense_oracle(got, queries, "kpca")) <= 1e-5

    # the rebuild re-persisted: next boot is warm again
    got, rec = load_or_rebuild(d, build_fn)
    assert rec.warm and len(builds) == 1


def test_load_or_rebuild_missing_store_builds_fresh(artifact, tmp_path):
    builds = []

    def build_fn():
        builds.append(1)
        return artifact

    got, rec = load_or_rebuild(str(tmp_path / "nowhere"), build_fn)
    assert [e.kind for e in rec.events] == ["missing", "rebuilt"]
    assert len(builds) == 1 and got is artifact


# ---------------------------------------------------------------------------
# continuous batching (KernelServer) + the canned trace
# ---------------------------------------------------------------------------

def test_kernel_server_batches_concurrent_clients(artifact):
    op = CountingOperator(artifact.landmark_operator())
    server = KernelServer(
        artifact, BatchPolicy(max_batch=16, max_wait_s=0.05), op=op)
    rng = np.random.default_rng(13)
    queries = [(rng.standard_normal((nq, D)).astype(np.float32), task)
               for nq, task in [(5, "krr"), (17, "kpca"), (5, "features"),
                                (33, "krr"), (17, "krr"), (5, "kpca")]]
    try:
        results = [None] * len(queries)

        def client(i):
            Xq, task = queries[i]
            results[i] = server.submit(Xq, task).wait(timeout=60.0)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(queries))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        server.stop()

    assert server.requests_served == len(queries)
    assert op.counts["cross_sweeps"] == server.buckets_served > 0
    assert len(server.latencies_s) == len(queries)
    assert all(lat > 0 for lat in server.latencies_s)
    for (Xq, task), res in zip(queries, results):
        assert res.task == task
        direct = answer_batch(artifact, [QueryRequest(Xq, task)])[0]
        assert parity_gap(res.out, direct.out) <= 1e-6


def test_kernel_server_submit_after_stop_raises(artifact):
    server = KernelServer(artifact)
    server.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        server.submit(np.zeros((2, D), np.float32))


def test_trace_write_replay_roundtrip(tmp_path):
    """The serve-smoke mechanics in-process: build params -> artifact ->
    trace with oracle expectations -> fresh server replays to <=1e-5."""
    params = {"n": 160, "d": 12, "c": 32, "s": 64, "alpha": 1.0,
              "n_components": 6, "kernel": "rbf",
              "spec_params": {"sigma": 1.0}, "seed": 3, "use_pallas": True}
    art = build_from_params(params)
    write_trace(str(tmp_path), art, params, n_queries=6, seed=3)
    trace = load_trace(str(tmp_path))
    assert len(trace) == 6

    op = CountingOperator(art.landmark_operator())
    server = KernelServer(art, BatchPolicy(max_wait_s=0.02), op=op)
    try:
        gap, lats = replay_trace(server, trace)
    finally:
        server.stop()
    assert gap <= 1e-5
    assert len(lats) == 6
    assert op.counts["cross_sweeps"] == server.buckets_served


def test_build_from_params_deterministic():
    params = {"n": 120, "d": 8, "c": 24, "s": 48, "alpha": 1.0,
              "n_components": 4, "kernel": "rbf",
              "spec_params": {"sigma": 1.0}, "seed": 5, "use_pallas": True}
    a = build_from_params(params)
    b = build_from_params(params)
    assert np.array_equal(np.asarray(a.heads["krr"]),
                          np.asarray(b.heads["krr"]))
    X, _ = synth_problem(params["n"], params["d"], params["seed"])
    assert np.array_equal(
        np.asarray(a.X_landmarks),
        np.asarray(jnp.take(X, a.landmark_indices, axis=0)))


# ---------------------------------------------------------------------------
# mixed-precision serving
# ---------------------------------------------------------------------------

def test_serve_bf16_cross_launches_within_budget(artifact, queries):
    """serve_kernel_model(precision='bf16_f32acc'): an f32-built artifact
    served with bf16 cross tiles must stay within the quantization budget of
    the f32 serving answers (scale-normalized), for every task head."""
    reqs = [QueryRequest(queries, t) for t in ("krr", "kpca", "features")]
    f32 = serve_kernel_model(artifact, reqs)
    bf16 = serve_kernel_model(artifact, reqs, precision="bf16_f32acc")
    for a, b in zip(bf16, f32):
        assert parity_gap(a.out, b.out) <= 5e-2


def test_serve_bf16_route_and_metering(artifact, queries):
    """The bf16 cross launch is attributed: route suffix + last_precision on
    the CountingOperator, one cross sweep per bucket as ever."""
    op = CountingOperator(
        artifact.landmark_operator(precision="bf16_f32acc"))
    serve_kernel_model(artifact, [QueryRequest(queries, "krr")], op=op)
    assert op.counts["cross_sweeps"] == 1
    assert op.last_route == "pallas_fused_rows+bf16_f32acc"
    assert op.last_precision == "bf16_f32acc"


def test_artifact_spec_precision_round_trips_through_checkpoint(
        artifact, tmp_path):
    """A bf16-spec'd artifact persists its tile policy: load_artifact hands
    back an operator that launches bf16 crosses without being asked."""
    import dataclasses as dc
    bf_art = dc.replace(
        artifact, spec=artifact.spec.with_precision("bf16_f32acc"))
    save_artifact(str(tmp_path / "ckpt"), bf_art)
    loaded = load_artifact(str(tmp_path / "ckpt"))
    assert loaded.spec is bf_art.spec          # registry-cached identity
    assert loaded.landmark_operator().precision == "bf16_f32acc"


def test_l1_signsplit_plan_cached_on_artifact_and_warm_boot(tmp_path):
    """An l1dist artifact persists its sign-split plan: every operator the
    artifact hands out shares the SAME edges array (no per-instance
    rebuilds), and a warm boot restores plan identity from the checkpoint."""
    rng = np.random.default_rng(11)
    X = jnp.asarray(rng.integers(0, 5, size=(120, 6)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(120), jnp.float32)
    spec = pw_specs.get_spec("laplacian", gamma=0.3)
    art = build_artifact(X, y, spec, c=24, s=48, alpha=1.0, n_components=4,
                         key=jax.random.PRNGKey(3), use_pallas=True)

    assert art.l1_route == "mxu_signsplit"
    assert art.l1_edges is not None
    op_a, op_b = art.landmark_operator(), art.landmark_operator()
    assert op_a.l1_edges() is art.l1_edges
    assert op_b.l1_edges() is art.l1_edges      # shared, not rebuilt

    save_artifact(str(tmp_path), art, step=0)

    def build_fn():  # warm boot must never fall back to a rebuild
        raise AssertionError("rebuild called on a warm store")

    loaded, rec = load_or_rebuild(str(tmp_path), build_fn)
    assert rec.warm
    assert loaded.l1_route == "mxu_signsplit"
    assert np.array_equal(np.asarray(loaded.l1_edges),
                          np.asarray(art.l1_edges))
    assert loaded.landmark_operator().l1_edges() is loaded.l1_edges

    # the restored plan serves: answers match the dense oracle
    q = jnp.asarray(rng.integers(0, 5, size=(17, 6)), jnp.float32)
    a = serve_kernel_model(loaded, [QueryRequest(q, "krr")])
    assert parity_gap(a[0].out, dense_oracle(loaded, q, "krr")) <= 1e-4


def test_rbf_artifact_has_no_l1_plan():
    """Non-l1dist specs carry no plan: route and edges stay None and the
    operator's lazy path is untouched."""
    rng = np.random.default_rng(12)
    X = jnp.asarray(rng.standard_normal((90, 5)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(90), jnp.float32)
    art = build_artifact(X, y, pw_specs.get_spec("rbf", sigma=1.0),
                         c=18, s=36, alpha=1.0, n_components=4,
                         key=jax.random.PRNGKey(4), use_pallas=True)
    assert art.l1_route is None and art.l1_edges is None
