"""Whole-system sanity: public API imports + the registry covers the
assigned 40-cell matrix."""
import importlib


def test_public_imports():
    for mod in [
        "repro.core.spsd", "repro.core.cur", "repro.core.sketch",
        "repro.core.eig", "repro.core.kernelop", "repro.core.leverage",
        "repro.core.adaptive", "repro.core.sketched_attention",
        "repro.models.model", "repro.models.transformer",
        "repro.models.attention", "repro.models.moe",
        "repro.models.recurrent", "repro.models.layers",
        "repro.optim", "repro.data", "repro.checkpoint", "repro.runtime",
        "repro.distributed", "repro.configs",
        "repro.launch.mesh", "repro.launch.steps", "repro.launch.roofline",
        "repro.kernels.flash_attention.ops",
        "repro.kernels.landmark_attention.ops",
        "repro.kernels.pairwise.ops",
        "repro.kernels.pairwise.specs",
        "repro.kernels.rbf_sketch.ops",
    ]:
        importlib.import_module(mod)


def test_cell_matrix():
    from repro.configs import ARCHS, cells, shapes_for, LONG_CONTEXT_OK
    assert len(ARCHS) == 10
    cs = list(cells())
    # 10 archs x 4 shapes - 7 long_500k skips = 33 runnable cells
    assert len(cs) == 33
    for a in ARCHS:
        names = [s.name for s in shapes_for(a)]
        assert "train_4k" in names and "prefill_32k" in names \
            and "decode_32k" in names
        assert ("long_500k" in names) == (a in LONG_CONTEXT_OK)


def test_param_counts_match_published():
    from repro.configs import get_config
    expect = {"xlstm-125m": (0.05e9, 0.2e9),
              "gemma3-12b": (10e9, 13e9),
              "minitron-4b": (3.5e9, 4.5e9),
              "yi-9b": (8e9, 9.5e9),
              "yi-6b": (5.5e9, 6.5e9),
              "deepseek-v3-671b": (650e9, 690e9),
              "qwen2-moe-a2.7b": (13e9, 15e9),
              "chameleon-34b": (32e9, 36e9),
              "whisper-large-v3": (1.4e9, 1.7e9),
              "recurrentgemma-2b": (2.5e9, 3.2e9)}
    for a, (lo, hi) in expect.items():
        n = get_config(a).param_count()
        assert lo <= n <= hi, (a, n)


def test_deepseek_active_params():
    from repro.configs import get_config
    cfg = get_config("deepseek-v3-671b")
    na = cfg.active_param_count()
    assert 34e9 <= na <= 40e9, na
