"""Streaming blockwise pipeline: parity with the dense paths, the no-n×n
memory guarantee, and the vmapped batched entry point."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cur, spsd
from repro.core import sketch as sk
from repro.core.kernelop import DenseSPSD, LinearKernel, RBFKernel


def _clustered(seed, n=400, d=8, k=8):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 2.5
    labels = rng.integers(0, k, size=n)
    X = centers[labels] + rng.normal(size=(n, d)) * 0.4
    return jnp.asarray(X, jnp.float32)


def _rbf(seed, n=400, sigma=2.0, **kw):
    return RBFKernel(_clustered(seed, n=n), sigma=sigma, **kw)


# ---------------------------------------------------------------------------
# operator protocol: matmat / frobenius / panels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_size", [None, 64, 1000])
def test_streaming_matmat_matches_dense(block_size):
    Kop = _rbf(0)
    V = jax.random.normal(jax.random.PRNGKey(1), (Kop.n, 5))
    out = Kop.matmat(V, block_size=block_size)
    ref = Kop.full() @ V
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_streaming_frobenius_matches_dense():
    for Kop in (_rbf(1), LinearKernel(_clustered(2)),
                DenseSPSD(_rbf(3, n=100).full())):
        got = float(Kop.frobenius_norm_sq(block_size=96))
        ref = float(jnp.sum(Kop.full().astype(jnp.float32) ** 2))
        assert got == pytest.approx(ref, rel=1e-4), type(Kop).__name__


def test_panel_padding_is_masked():
    """n not divisible by the block: clamped tail rows must not leak."""
    Kop = _rbf(4, n=333)
    got = float(Kop.frobenius_norm_sq(block_size=100))
    ref = float(jnp.sum(Kop.full() ** 2))
    assert got == pytest.approx(ref, rel=1e-4)


# ---------------------------------------------------------------------------
# projection sketches: streaming vs dense S^T K S, and through fast_model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gaussian", "srht", "countsketch"])
def test_sym_streaming_matches_dense(kind):
    Kop = _rbf(5)
    S = sk.make_sketch(kind, jax.random.PRNGKey(2), Kop.n, 60)
    dense = S.sym(Kop.full())
    stream = sk.sym_streaming(S, Kop, block_size=128)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(dense),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("kind", ["gaussian", "srht", "countsketch"])
def test_fast_model_projection_streaming_vs_dense(kind):
    """Same key -> same sketch -> the two StKS routes give the same U."""
    Kop = _rbf(6)
    base = spsd.sample_C(Kop, jax.random.PRNGKey(0), 20)
    kw = dict(P_indices=base.P_indices, s_sketch=kind)
    ap_s = spsd.fast_model_from_C(Kop, base.C, jax.random.PRNGKey(1), 80,
                                  streaming=True, **kw)
    ap_d = spsd.fast_model_from_C(Kop, base.C, jax.random.PRNGKey(1), 80,
                                  streaming=False, **kw)
    np.testing.assert_allclose(np.asarray(ap_s.U), np.asarray(ap_d.U),
                               rtol=2e-2, atol=1e-3)
    e_s = float(spsd.relative_error(Kop, ap_s))
    e_d = float(spsd.relative_error(Kop, ap_d))
    assert np.isfinite(e_s) and abs(e_s - e_d) < 1e-3


# ---------------------------------------------------------------------------
# error metrics
# ---------------------------------------------------------------------------

def test_blocked_error_metrics_match_dense():
    Kop = _rbf(7)
    ap = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=20, s=80,
                         s_sketch="uniform")
    e_dense = float(spsd.relative_error(Kop, ap, method="dense"))
    e_block = float(spsd.relative_error(Kop, ap, method="blocked",
                                        block_size=90))
    assert e_block == pytest.approx(e_dense, rel=1e-3)
    k = 8
    ek_dense = float(spsd.error_vs_best_rank_k(Kop, ap, k, method="dense"))
    ek_block = float(spsd.error_vs_best_rank_k(Kop, ap, k, method="blocked"))
    # streaming denominator uses randomized top-k eigenvalues
    assert ek_block == pytest.approx(ek_dense, rel=0.05)


def test_hutchinson_error_tracks_dense():
    Kop = _rbf(8)
    ap = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=20, s=80,
                         s_sketch="uniform")
    e_dense = float(spsd.relative_error(Kop, ap, method="dense"))
    e_hutch = float(spsd.relative_error(Kop, ap, method="hutchinson",
                                        probes=256,
                                        key=jax.random.PRNGKey(3)))
    assert e_hutch == pytest.approx(e_dense, rel=0.35)


def test_streaming_topk_eigvals():
    Kop = _rbf(9)
    lam = np.asarray(spsd.streaming_topk_eigvals(Kop, 6,
                                                 jax.random.PRNGKey(0)))
    ref = np.linalg.eigvalsh(np.asarray(Kop.full()))[::-1][:6]
    np.testing.assert_allclose(lam, ref, rtol=0.05)


# ---------------------------------------------------------------------------
# the memory guarantee: streaming paths never densify K
# ---------------------------------------------------------------------------

def test_streaming_pipeline_never_calls_full(monkeypatch):
    """End-to-end fast model + streaming metrics with ``full`` booby-trapped."""
    Kop = _rbf(10)

    def boom(self):
        raise AssertionError("streaming path materialized the n×n kernel")

    monkeypatch.setattr(RBFKernel, "full", boom)
    ap = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=20, s=80,
                         s_sketch="gaussian")        # auto-streams: implicit op
    e = float(spsd.relative_error(Kop, ap, method="hutchinson", probes=32,
                                  key=jax.random.PRNGKey(1)))
    eb = float(spsd.relative_error(Kop, ap, method="blocked"))
    ek = float(spsd.error_vs_best_rank_k(Kop, ap, 8, method="hutchinson",
                                         probes=32))
    U = spsd.prototype_U(Kop, ap.C)
    assert np.isfinite(e) and np.isfinite(eb) and np.isfinite(ek)
    assert np.all(np.isfinite(np.asarray(U)))


def test_adaptive_sampling_never_calls_full(monkeypatch):
    from repro.core.adaptive import uniform_adaptive2_indices
    Kop = _rbf(11)
    monkeypatch.setattr(RBFKernel, "full", lambda self: (_ for _ in ()).throw(
        AssertionError("adaptive sampling materialized K")))
    idx = uniform_adaptive2_indices(Kop, jax.random.PRNGKey(0), 12)
    assert idx.shape == (12,)


# ---------------------------------------------------------------------------
# batched entry point
# ---------------------------------------------------------------------------

def test_fast_model_batched_matches_per_item():
    rng = np.random.default_rng(0)
    Xb = jnp.asarray(rng.normal(size=(4, 200, 6)), jnp.float32)
    ops = RBFKernel(Xb, sigma=1.5)
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    bat = spsd.fast_model_batched(ops, keys, c=12, s=48, s_sketch="uniform")
    assert bat.C.shape == (4, 200, 12) and bat.U.shape == (4, 12, 12)
    for i in (0, 2):
        one = spsd.fast_model(RBFKernel(Xb[i], sigma=1.5), keys[i],
                              c=12, s=48, s_sketch="uniform")
        np.testing.assert_allclose(np.asarray(bat.U[i]), np.asarray(one.U),
                                   rtol=2e-3, atol=2e-4)


def test_fast_model_batched_dense_input():
    rng = np.random.default_rng(1)
    Y = jnp.asarray(rng.normal(size=(3, 100, 5)), jnp.float32)
    Kb = jnp.einsum("bnd,bmd->bnm", Y, Y)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    bat = spsd.fast_model_batched(Kb, keys, c=8, s=24, s_sketch="uniform")
    assert bat.U.shape == (3, 8, 8)
    assert np.all(np.isfinite(np.asarray(bat.U)))


# ---------------------------------------------------------------------------
# CUR streaming branch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gaussian", "srht", "countsketch"])
def test_fast_cur_streaming_matches_dense(kind):
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.normal(size=(250, 180)), jnp.float32)
    kw = dict(c=12, r=12, sc=48, sr=48, sketch_kind=kind)
    ap_s = cur.fast_cur(A, jax.random.PRNGKey(3), streaming=True, **kw)
    ap_d = cur.fast_cur(A, jax.random.PRNGKey(3), streaming=False, **kw)
    np.testing.assert_allclose(np.asarray(ap_s.U), np.asarray(ap_d.U),
                               rtol=5e-3, atol=5e-4)


# ---------------------------------------------------------------------------
# the acceptance-scale run (slow: one streaming pass over 2.5e9 entries)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fast_model_streaming_n50k():
    """n=50,000: Algorithm 1 with a gaussian projection sketch + streaming
    error metrics, with ``full`` booby-trapped — a dense K would be 10 GB."""
    n = 50_000
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(32, 16)) * 2.0
    labels = rng.integers(0, 32, size=n)
    X = jnp.asarray(centers[labels] + rng.normal(size=(n, 16)) * 0.5,
                    jnp.float32)
    Kop = RBFKernel(X, sigma=3.0)

    import unittest.mock as mock
    with mock.patch.object(RBFKernel, "full",
                           side_effect=AssertionError("densified 50k kernel")):
        c, s = 100, 400
        ap = spsd.fast_model(Kop, jax.random.PRNGKey(0), c=c, s=s,
                             s_sketch="gaussian")
        err = float(spsd.relative_error(Kop, ap, method="hutchinson",
                                        probes=8, key=jax.random.PRNGKey(1)))
        ek = float(spsd.error_vs_best_rank_k(Kop, ap, 32,
                                             method="hutchinson", probes=8,
                                             key=jax.random.PRNGKey(2)))
    assert np.isfinite(err) and 0.0 <= err < 1.0, err
    assert np.isfinite(ek) and ek > 0.0, ek
