"""Multi-device correctness of the §Perf code paths (shard_map MoE EP,
sequence-parallel attention, cache threshold rules).

These need >1 XLA device, which must be forced *before* jax initializes —
so they run in a subprocess with XLA_FLAGS set (the main pytest process
keeps the real single-device view).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
def test_shard_map_moe_matches_gather():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.models import moe as M
        cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=64,
                          n_heads=4, n_kv_heads=4, head_dim=16, d_ff=0,
                          vocab_size=128, n_experts=8, n_shared_experts=1,
                          moe_top_k=2, moe_d_ff=48, capacity_factor=8.0,
                          dtype="float32")
        params = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64)) * 0.5
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            og, ag = jax.jit(lambda p, x: M.moe_ffn(p, cfg, x))(params, x)
            c2 = dataclasses.replace(cfg, moe_impl="shard_map")
            os_, as_ = jax.jit(lambda p, x: M.moe_ffn(p, c2, x))(params, x)
        err = float(jnp.max(jnp.abs(og - os_)))
        assert err < 1e-4, err
        # aux is aggregated per EP rank then pmean'd (standard EP practice)
        # vs globally in the gather path: a small Jensen gap is expected
        assert abs(float(ag) - float(as_)) / float(ag) < 0.2, (
            float(ag), float(as_))
        print("OK", err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_seq_parallel_attention_matches_baseline():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.models.model import build_model
        # 6 heads % 4 devices != 0 -> SP path engages on the model axis
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=48,
                          n_heads=6, n_kv_heads=2, head_dim=8, d_ff=96,
                          vocab_size=64, dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 64)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            l0, _ = jax.jit(m.loss)(params, batch)
            c2 = dataclasses.replace(cfg, seq_parallel_attn=True)
            m2 = build_model(c2)
            l1, _ = jax.jit(m2.loss)(params, batch)
        assert abs(float(l0) - float(l1)) < 1e-4, (float(l0), float(l1))
        print("OK", float(l0), float(l1))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_decode_cell_lowers_on_multidevice_mesh():
    out = _run("""
        import jax
        from repro.configs.base import ModelConfig, ShapeConfig
        from repro.launch.steps import build_cell
        from repro.launch.mesh import make_mesh
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256)
        mesh = make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("d", 256, 4, "decode")
        with mesh:
            cell = build_cell(cfg, shape, mesh)
            compiled = jax.jit(cell.step_fn,
                               in_shardings=cell.in_shardings,
                               out_shardings=cell.out_shardings) \\
                .lower(*cell.abstract_args).compile()
        print("OK", compiled is not None)
    """)
    assert "OK" in out
