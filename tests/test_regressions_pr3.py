"""PR-3 satellite regression tests.

Each test here fails on the pre-PR code:

- ``error_vs_best_rank_k(method="dense")`` divided by an unguarded zero tail
  for kernels of rank ≤ k (inf/nan), while the streaming branch floored it.
- ``uniform_column_sketch(mask=...)`` silently sampled zero-weight padding
  rows whenever ``s`` exceeded the number of valid rows.
- ``woodbury_solve`` returned silent NaN at ``alpha = 0``.
- ``rbf_sketch.ops`` captured the backend's interpret-mode decision at import
  time (module constant ``_INTERPRET``) instead of per call.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core import spsd
from repro.core.eig import woodbury_solve


# ---------------------------------------------------------------------------
# error_vs_best_rank_k: rank-deficient dense branch
# ---------------------------------------------------------------------------

def test_error_vs_best_rank_k_dense_rank_deficient_is_finite():
    """rank(K) = 3 ≤ k = 5 -> the exact tail is 0 (a diagonal K keeps
    eigvalsh exact, so pre-PR this divided 0/0 or x/0 -> inf/nan); the
    floored ratio must stay finite."""
    K = jnp.diag(jnp.asarray([5.0, 3.0, 2.0] + [0.0] * 97, jnp.float32))
    ap = spsd.fast_model(K, jax.random.PRNGKey(0), c=8, s=24,
                         s_sketch="gaussian")
    rho = float(spsd.error_vs_best_rank_k(K, ap, k=5, method="dense"))
    assert np.isfinite(rho) and rho >= 0.0


def test_error_vs_best_rank_k_dense_floor_matches_streaming_branch():
    """Dense and blocked branches use the same 1e-12·||K||_F² floor, so a
    rank-deficient kernel gives finite ratios on both."""
    rng = np.random.default_rng(1)
    B = rng.normal(size=(120, 4)).astype(np.float32)
    K = jnp.asarray(B @ B.T)
    ap = spsd.fast_model(K, jax.random.PRNGKey(1), c=8, s=24,
                         s_sketch="gaussian")
    dense = float(spsd.error_vs_best_rank_k(K, ap, k=6, method="dense"))
    blocked = float(spsd.error_vs_best_rank_k(K, ap, k=6, method="blocked"))
    assert np.isfinite(dense) and np.isfinite(blocked)


def test_error_vs_best_rank_k_dense_full_rank_unchanged():
    """The floor must not perturb the well-conditioned case."""
    rng = np.random.default_rng(2)
    B = rng.normal(size=(80, 80)).astype(np.float32)
    K = jnp.asarray(B @ B.T + 80 * np.eye(80, dtype=np.float32))
    ap = spsd.fast_model(K, jax.random.PRNGKey(2), c=20, s=50,
                         s_sketch="gaussian")
    Kd = np.asarray(K, np.float32)
    evals = np.linalg.eigvalsh(Kd)
    tail = float(np.sort(evals ** 2)[: 80 - 10].sum())
    resid = Kd - np.asarray(ap.dense(), np.float32)
    ref = float((resid ** 2).sum()) / tail
    got = float(spsd.error_vs_best_rank_k(K, ap, k=10, method="dense"))
    assert got == pytest.approx(ref, rel=1e-4)


# ---------------------------------------------------------------------------
# uniform_column_sketch masked overflow
# ---------------------------------------------------------------------------

def test_masked_uniform_sketch_overflow_raises_on_concrete_mask():
    mask = (jnp.arange(50) < 10).astype(jnp.float32)
    with pytest.raises(ValueError, match="valid rows"):
        sk.uniform_column_sketch(jax.random.PRNGKey(0), 50, 20, mask=mask)


def test_masked_uniform_sketch_overflow_clamps_under_trace():
    """Traced masks (vmapped ragged batches) cannot raise; every sampled
    index must still land on a valid row (pre-PR: zero-weight padding rows
    leaked in)."""
    n, s, nv = 50, 20, 10

    @jax.jit
    def sample(mask):
        return sk.uniform_column_sketch(jax.random.PRNGKey(0), n, s,
                                        mask=mask).indices

    mask = (jnp.arange(n) < nv).astype(jnp.float32)
    idx = np.asarray(sample(mask))
    assert idx.shape == (s,)
    assert np.all(idx < nv), f"padding rows sampled: {idx}"


def test_masked_uniform_sketch_no_overflow_stays_valid_and_distinct():
    n, s, nv = 60, 8, 30
    mask = (jnp.arange(n) < nv).astype(jnp.float32)
    S = sk.uniform_column_sketch(jax.random.PRNGKey(3), n, s, mask=mask)
    idx = np.asarray(S.indices)
    assert np.all(idx < nv)
    assert len(np.unique(idx)) == s          # still without replacement


def test_fast_model_batched_ragged_uniform_overflow():
    """Ragged batch where s exceeds one item's valid rows: the uniform
    column-selection sketch must degrade to duplicated valid rows, never
    poisoned padding (pre-PR: junk columns of K entered Sᵀ K S)."""
    from repro.core.kernelop import RBFKernel
    rng = np.random.default_rng(4)
    n_valid = np.array([30, 200])
    npad = 200
    Xb = rng.normal(size=(2, npad, 6))
    for b, nv in enumerate(n_valid):
        Xb[b, nv:] = 99.0                    # poison the padding rows
    Xb = jnp.asarray(Xb, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    bat = spsd.fast_model_batched(RBFKernel(Xb, sigma=1.5), keys, c=12, s=48,
                                  s_sketch="uniform",
                                  n_valid=jnp.asarray(n_valid))
    assert np.all(np.isfinite(np.asarray(bat.U)))
    for b, nv in enumerate(n_valid):
        Ktrue = RBFKernel(Xb[b, :nv], sigma=1.5)
        ap = spsd.SPSDApprox(C=bat.C[b][:nv], U=bat.U[b])
        err = float(spsd.relative_error(Ktrue, ap, method="dense"))
        assert np.isfinite(err) and err < 0.5, (b, err)


# ---------------------------------------------------------------------------
# woodbury_solve alpha validation
# ---------------------------------------------------------------------------

def _cuy(seed=6, n=40, c=5):
    rng = np.random.default_rng(seed)
    C = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    G = rng.normal(size=(c, c)).astype(np.float32)
    U = jnp.asarray(G @ G.T)
    y = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    return C, U, y


@pytest.mark.parametrize("alpha", [0.0, -1.0, float("nan"), float("inf")])
def test_woodbury_solve_rejects_invalid_alpha(alpha):
    C, U, y = _cuy()
    with pytest.raises(ValueError, match="alpha"):
        woodbury_solve(C, U, alpha, y)


def test_woodbury_solve_traced_alpha_passes_through():
    """jit/vmap over the ridge cannot be validated at trace time and must
    keep working (the guard only fires for concrete alpha)."""
    C, U, y = _cuy()
    eager = np.asarray(woodbury_solve(C, U, 0.25, y))
    traced = np.asarray(jax.jit(lambda a: woodbury_solve(C, U, a, y))(0.25))
    np.testing.assert_allclose(traced, eager, rtol=1e-5, atol=1e-6)


def test_woodbury_solve_valid_alpha_matches_dense():
    C, U, y = _cuy()
    alpha = 0.37
    w = np.asarray(woodbury_solve(C, U, alpha, y), np.float64)
    A = np.asarray(C, np.float64) @ np.asarray(U, np.float64) \
        @ np.asarray(C, np.float64).T + alpha * np.eye(C.shape[0])
    ref = np.linalg.solve(A, np.asarray(y, np.float64))
    np.testing.assert_allclose(w, ref, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# call-time backend resolution in rbf_sketch.ops
# ---------------------------------------------------------------------------

def test_interpret_mode_resolved_at_call_time(monkeypatch):
    """Backend selection must be consulted per call, not frozen at import
    (pre-PR: a module-level ``_INTERPRET`` constant)."""
    from repro.kernels.rbf_sketch import ops

    assert not hasattr(ops, "_INTERPRET")
    calls = []
    real = ops._interpret_mode
    monkeypatch.setattr(ops, "_interpret_mode",
                        lambda: (calls.append(1), real())[1])

    X = jax.random.normal(jax.random.PRNGKey(0), (20, 4))
    V = jax.random.normal(jax.random.PRNGKey(1), (20, 3))
    ops.rbf_block(X, X, 1.1)
    ops.rbf_matmat(X, V, 1.1)
    ops.rbf_matmat_multi(X, (V,), 1.1)
    ops.sketched_gram(X[:8], 1.1)
    assert len(calls) == 4
