"""Fast-CUR weight compression for serving (paper §5 applied to an LM).

    PYTHONPATH=src python examples/cur_compress.py

Takes the FFN weight matrices of a trained smoke LM, compresses each as
W ~ C U R with the fast U (Eq. 9) — O(min(m,n)) instead of O(mn) — and
measures (a) reconstruction error vs the optimal U at the same (c, r),
(b) end-to-end perplexity drift of the compressed model.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import cur
from repro.data import make_pipeline
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.optim import adamw

# --- train a small LM briefly so the weights are not random -----------------
cfg = dataclasses.replace(get_smoke("yi-6b"), d_ff=256, d_model=128,
                          n_layers=2, n_heads=4, n_kv_heads=2, head_dim=32)
model = build_model(cfg)
opt = adamw()
step = jax.jit(make_train_step(model, opt, peak_lr=5e-3, warmup=5,
                               total=60))
pipe = make_pipeline("synthetic", vocab_size=cfg.vocab_size, seq_len=64,
                     global_batch=8, seed=0)
params = model.init(jax.random.PRNGKey(0))
state = opt.init(params)
for s in range(60):
    params, state, met = step(params, state,
                              jax.tree.map(jnp.asarray, pipe.batch_at(s)))
print(f"pre-compression loss: {float(met['loss']):.4f}")

# --- compress every FFN matrix with fast CUR --------------------------------
key = jax.random.PRNGKey(1)


def compress(W, c, r, mult=4):
    W = W.astype(jnp.float32)
    fast = cur.fast_cur(W, key, c=c, r=r, sc=min(mult * r, W.shape[0]),
                        sr=min(mult * c, W.shape[1]),
                        sketch_kind="uniform")
    opt_ = cur.optimal_cur(W, key, c=c, r=r)
    return fast, float(cur.relative_error(W, fast)), \
        float(cur.relative_error(W, opt_))


new_params = jax.tree_util.tree_map(lambda x: x, params)   # copy structure
tot_before = tot_after = 0
for slot in range(len(params["stack"]["scanned"])):
    mlp = params["stack"]["scanned"][slot]["mlp"]
    for name in ("wi_up", "wi_gate", "wo"):
        W = mlp[name][0] if mlp[name].ndim == 3 else mlp[name]
        stacked = mlp[name].ndim == 3
        mats = mlp[name] if stacked else mlp[name][None]
        outs = []
        for i in range(mats.shape[0]):
            m, n = mats[i].shape
            c, r = max(m // 4, 8), max(n // 4, 8)
            fast, e_fast, e_opt = compress(mats[i], c, r)
            outs.append(fast.dense().astype(mlp[name].dtype))
            tot_before += m * n
            tot_after += m * c + c * r + r * n
            gap = 100 * (e_fast - e_opt) / max(e_opt, 1e-9)
            print(f"layer{slot}/{name}[{i}] ({m}x{n} -> c={c},r={r}): "
                  f"fast err {e_fast:.4f} vs optimal {e_opt:.4f} "
                  f"(gap {gap:+.1f}%, Eq.9 cost O(min(m,n)) vs O(mn))")
        rec = jnp.stack(outs) if stacked else outs[0]
        new_params["stack"]["scanned"][slot]["mlp"][name] = rec

loss2, _ = jax.jit(model.loss)(new_params,
                               jax.tree.map(jnp.asarray, pipe.batch_at(99)))
loss1, _ = jax.jit(model.loss)(params,
                               jax.tree.map(jnp.asarray, pipe.batch_at(99)))
print(f"\nheld-out loss: {float(loss1):.4f} -> {float(loss2):.4f} "
      f"(params {tot_before:,} -> {tot_after:,} = "
      f"{100 * tot_after / tot_before:.0f}%)")
