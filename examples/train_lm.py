"""End-to-end LM training driver example.

CPU-sized by default (a ~15M-param xlstm); the same command scales to the
production mesh on real hardware:

    # this container (few minutes):
    PYTHONPATH=src python examples/train_lm.py

    # ~100M params, few hundred steps (single TPU host):
    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 300 --seq-len 1024 --global-batch 32 --mesh 1x4 \
        --ckpt-dir /tmp/ckpt

    # production 256-chip pod:
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-12b \
        --steps 10000 --seq-len 4096 --global-batch 256 --mesh 16x16 \
        --ckpt-dir gs://... --accum 16
"""
from repro.launch import train

losses = train.main([
    "--arch", "xlstm-125m", "--smoke",
    "--steps", "120",
    "--seq-len", "128",
    "--global-batch", "8",
    "--ckpt-dir", "/tmp/train_lm_example",
    "--ckpt-every", "50",
    "--log-every", "20",
])
assert losses[-1] < losses[0], "training should reduce the loss"
print("example complete: loss improved, checkpoints written")
