"""Serving with the paper's fast-SPSD landmark attention.

    PYTHONPATH=src python examples/serve_landmark.py

Runs batched generation twice with a gemma3-family smoke model: once with
exact KV-cache attention, once with the landmark decode path on the global
layers (local layers keep their ring buffers).  At 500k-token contexts the
landmark path is what makes gemma3 decode sub-quadratic (long_500k cell);
here we check the two paths agree early in the context where both are exact.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.launch.serve import generate
from repro.models.model import build_model

base = get_smoke("gemma3-12b")
prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 96), 0,
                             base.vocab_size, dtype=jnp.int32)

outs = {}
for mode, opts in (("exact KV", dict(use_landmark_decode=False)),
                   ("landmark strided", dict(use_landmark_decode=True,
                                             landmark_selection="strided")),
                   ("landmark adaptive", dict(
                       use_landmark_decode=True,
                       landmark_selection="uniform_adaptive2"))):
    cfg = dataclasses.replace(base, landmark_c=48, landmark_theta=4, **opts)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t0 = time.time()
    out = generate(model, params, prompts, gen=24, key=jax.random.PRNGKey(2))
    out.block_until_ready()
    outs[mode] = np.asarray(out)
    print(f"{mode:22s}: generated {out.shape} in {time.time() - t0:5.1f}s")

for mode in ("landmark strided", "landmark adaptive"):
    agree = float(np.mean(outs["exact KV"] == outs[mode]))
    print(f"token agreement exact vs {mode}: {100 * agree:.1f}% "
          f"(c=48 landmarks over 96-token context)")
print("landmark state per layer: O(c*(2d+1)) floats vs KV cache O(S*2d) — "
      "independent of context length")
