"""Quickstart: the fast SPSD model in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds an RBF kernel operator over 2,000 points (never materializing K),
sketches C = K P with c = 40 uniform columns, computes the paper's
U^fast = (S^T C)^+ (S^T K S) (C^T S)^+ with s = 8c leverage-sampled rows,
and uses the resulting (C, U) for the two downstream Appendix-A solvers:
rank-k eigendecomposition and a regularized kernel solve, both O(n c^2).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eig, spsd
from repro.core.kernelop import RBFKernel

# --- data + implicit kernel -------------------------------------------------
rng = np.random.default_rng(0)
centers = rng.normal(size=(12, 10)) * 2.5
X = jnp.asarray(np.concatenate(
    [c + rng.normal(size=(170, 10)) * 0.5 for c in centers]), jnp.float32)
n = X.shape[0]
K = RBFKernel(X, sigma=2.0)                     # entries computed on demand
print(f"n = {n} points; K is {n}x{n} but never materialized")

# --- Algorithm 1: C = KP, U^fast --------------------------------------------
key = jax.random.PRNGKey(0)
c, s = 40, 320
approx = spsd.fast_model(K, key, c=c, s=s, s_sketch="leverage")
err = float(spsd.relative_error(K, approx))
print(f"fast model   (c={c}, s={s}): ||K-CUC'||F^2/||K||F^2 = {err:.4f}")

nys = spsd.nystrom_model(K, key, c=c)
print(f"nystrom      (c={c}):        "
      f"{float(spsd.relative_error(K, nys)):.4f}")
proto = spsd.prototype_model(K, approx.C, approx.P_indices)
print(f"prototype    (c={c}, s=n):   "
      f"{float(spsd.relative_error(K, proto)):.4f}   <- best possible U")

# --- Appendix A: O(nc^2) downstream solvers ---------------------------------
k = 6
res = eig.approx_eigh(approx.C, approx.U, k)
lam_true = jnp.linalg.eigvalsh(K.full())[::-1][:k]
print(f"\ntop-{k} eigenvalues (approx) {np.round(np.asarray(res.eigenvalues), 2)}")
print(f"top-{k} eigenvalues (exact)  {np.round(np.asarray(lam_true), 2)}")

y = jax.random.normal(jax.random.PRNGKey(1), (n,))
w = eig.woodbury_solve(approx.C, approx.U, alpha=1.0, y=y)
resid = (approx.matmat(w[:, None])[:, 0] + w) - y
print(f"\nKRR solve (K̃+I)w=y: residual {float(jnp.linalg.norm(resid)):.2e} "
      f"(O(nc^2) via Woodbury)")
