"""Quickstart: the fast SPSD model in a few dozen lines.

    PYTHONPATH=src python examples/quickstart.py            # small-n tour
    PYTHONPATH=src python examples/quickstart.py --large-n 50000

Builds an RBF kernel operator (never materializing K), sketches C = K P with
c uniform columns, computes the paper's
U^fast = (S^T C)^+ (S^T K S) (C^T S)^+ with s = 8c leverage-sampled rows,
and uses the resulting (C, U) for the two downstream Appendix-A solvers:
rank-k eigendecomposition and a regularized kernel solve, both O(n c^2).

``--large-n`` runs the streaming pipeline at a size where no n×n array can
exist: the gaussian projection sketch goes through blocked K @ S and the
error metric through Hutchinson probes — everything O(n) memory.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eig, spsd
from repro.core.kernelop import RBFKernel


def small_tour():
    # --- data + implicit kernel ----------------------------------------------
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(12, 10)) * 2.5
    X = jnp.asarray(np.concatenate(
        [c + rng.normal(size=(170, 10)) * 0.5 for c in centers]), jnp.float32)
    n = X.shape[0]
    K = RBFKernel(X, sigma=2.0)                 # entries computed on demand
    print(f"n = {n} points; K is {n}x{n} but never materialized")

    # --- Algorithm 1: C = KP, U^fast -----------------------------------------
    key = jax.random.PRNGKey(0)
    c, s = 40, 320
    approx = spsd.fast_model(K, key, c=c, s=s, s_sketch="leverage")
    err = float(spsd.relative_error(K, approx))
    print(f"fast model   (c={c}, s={s}): ||K-CUC'||F^2/||K||F^2 = {err:.4f}")

    nys = spsd.nystrom_model(K, key, c=c)
    print(f"nystrom      (c={c}):        "
          f"{float(spsd.relative_error(K, nys)):.4f}")
    proto = spsd.prototype_model(K, approx.C, approx.P_indices)
    print(f"prototype    (c={c}, s=n):   "
          f"{float(spsd.relative_error(K, proto)):.4f}   <- best possible U")

    # --- Appendix A: O(nc^2) downstream solvers ------------------------------
    k = 6
    res = eig.approx_eigh(approx.C, approx.U, k)
    lam_true = jnp.linalg.eigvalsh(K.full())[::-1][:k]
    print(f"\ntop-{k} eigenvalues (approx) "
          f"{np.round(np.asarray(res.eigenvalues), 2)}")
    print(f"top-{k} eigenvalues (exact)  {np.round(np.asarray(lam_true), 2)}")

    y = jax.random.normal(jax.random.PRNGKey(1), (n,))
    w = eig.woodbury_solve(approx.C, approx.U, alpha=1.0, y=y)
    resid = (approx.matmat(w[:, None])[:, 0] + w) - y
    print(f"\nKRR solve (K̃+I)w=y: residual "
          f"{float(jnp.linalg.norm(resid)):.2e} (O(nc^2) via Woodbury)")


def large_n_demo(n: int):
    """Streaming pipeline at a scale the dense path cannot touch.

    An n=50,000 RBF kernel is 10 GB in f32; this demo's peak footprint is a
    single ~128 MB row panel plus the (n, c) sketch.
    """
    rng = np.random.default_rng(0)
    d = 16
    centers = rng.normal(size=(32, d)) * 2.0
    labels = rng.integers(0, 32, size=n)
    X = jnp.asarray(centers[labels] + rng.normal(size=(n, d)) * 0.5,
                    jnp.float32)
    K = RBFKernel(X, sigma=3.0)
    c = max(n // 250, 64)
    s = 4 * c
    print(f"\n=== streaming demo: n={n}, c={c}, s={s} "
          f"(K would be {4 * n * n / 1e9:.1f} GB dense — never built) ===")

    approx = spsd.fast_model(K, jax.random.PRNGKey(0), c=c, s=s,
                             s_sketch="gaussian", streaming=True)
    print("fast model [gaussian projection via blocked K @ S]: done")

    err = float(spsd.relative_error(K, approx, method="hutchinson",
                                    probes=16, key=jax.random.PRNGKey(2)))
    print(f"relative error (Hutchinson, 16 probes): {err:.4f}")

    lam = spsd.streaming_topk_eigvals(K, 5, jax.random.PRNGKey(3))
    print(f"top-5 eigenvalues (randomized subspace iteration): "
          f"{np.round(np.asarray(lam), 1)}")

    y = jax.random.normal(jax.random.PRNGKey(4), (n,))
    w = eig.woodbury_solve(approx.C, approx.U, alpha=1.0, y=y)
    resid = (approx.matmat(w[:, None])[:, 0] + w) - y
    print(f"KRR solve residual: {float(jnp.linalg.norm(resid)):.2e}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--large-n", type=int, default=None,
                   help="also run the streaming large-n demo at this size "
                        "(e.g. 50000)")
    args = p.parse_args()
    small_tour()
    if args.large_n:
        large_n_demo(args.large_n)
