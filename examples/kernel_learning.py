"""End-to-end kernel learning with the fast model (paper §6 pipeline).

    PYTHONPATH=src python examples/kernel_learning.py

Train/test split -> fast SPSD approximation of the train kernel -> KPCA
features -> 10-NN classification of held-out points, plus approximate
spectral clustering — the paper's two applications, on one synthetic
dataset, all through the public API.
"""
import sys

sys.path.insert(0, "benchmarks") if "benchmarks" not in sys.path else None

import jax
import jax.numpy as jnp
import numpy as np
from numpy.random import default_rng

from repro.core import eig, spsd
from repro.core.kernelop import RBFKernel


def make_data(n=1200, d=12, k=6, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 2.0
    labels = rng.integers(0, k, size=n)
    X = centers[labels] + rng.normal(size=(n, d)) * 0.6
    return jnp.asarray(X, jnp.float32), labels


def knn(train_x, train_y, test_x, k=10):
    d = ((np.asarray(test_x)[:, None] - np.asarray(train_x)[None]) ** 2
         ).sum(-1)
    nn = np.argsort(d, 1)[:, :k]
    votes = np.asarray(train_y)[nn]
    return np.asarray([np.bincount(r).argmax() for r in votes])


X, y = make_data()
ntr = X.shape[0] // 2
Xtr, ytr, Xte, yte = X[:ntr], y[:ntr], X[ntr:], y[ntr:]
sigma = 2.0
K = RBFKernel(Xtr, sigma=sigma)

# fast model on the train kernel
c, s, k_feat = 48, 384, 8
ap = spsd.fast_model(K, jax.random.PRNGKey(0), c=c, s=s, s_sketch="uniform")
print(f"fast model err: {float(spsd.relative_error(K, ap)):.4f} "
      f"(c={c}, s={s}, n={ntr})")

# KPCA features + classification
feats, eres = eig.kpca_features(ap.C, ap.U, k_feat)
d2 = (jnp.sum(Xte ** 2, 1)[None] + jnp.sum(Xtr ** 2, 1)[:, None]
      - 2 * Xtr @ Xte.T)
k_test = jnp.exp(-jnp.maximum(d2, 0) / (2 * sigma ** 2))
te_feats = eig.kpca_transform(eres, k_test).T
pred = knn(np.asarray(feats), ytr, np.asarray(te_feats))
print(f"KPCA(+fast) 10-NN test error: {float(np.mean(pred != yte)):.4f}")

# approximate spectral clustering on the full set
Kf = RBFKernel(X, sigma=sigma)
apf = spsd.fast_model(Kf, jax.random.PRNGKey(1), c=c, s=s)
V = eig.spectral_embedding(apf.C, apf.U, 6)
rngk = default_rng(0)
C0 = np.asarray(V)[rngk.choice(len(V), 6, replace=False)]
lab = None
Vn = np.asarray(V)
for _ in range(30):
    dist = ((Vn[:, None] - C0[None]) ** 2).sum(-1)
    lab = dist.argmin(1)
    for j in range(6):
        pts = Vn[lab == j]
        if len(pts):
            C0[j] = pts.mean(0)
def nmi(a, b):
    a, b = np.asarray(a), np.asarray(b)
    n = len(a)
    cont = np.array([[np.sum((a == x) & (b == y)) for y in np.unique(b)]
                     for x in np.unique(a)]) / n
    pi, pj = cont.sum(1, keepdims=True), cont.sum(0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        mi = np.nansum(cont * np.log(cont / (pi @ pj)))
        ha, hb = -np.nansum(pi * np.log(pi)), -np.nansum(pj * np.log(pj))
    return float(mi / max(np.sqrt(ha * hb), 1e-12))


print(f"spectral clustering (fast model, c={c}): "
      f"NMI vs true labels = {nmi(lab, y):.4f}")
