"""Paper Figures 3 & 4: kernel approximation error vs s/n.

For each dataset: C = ceil(n/100) columns (uniform, or uniform+adaptive^2
with --adaptive, Fig. 4), then the U matrix from
  - the Nystrom method,
  - the fast model (S = uniform / leverage sampling), s in {2c..40c},
  - the prototype model (s = n).
y-axis metric: ||K - C U C^T||_F^2 / ||K||_F^2.

``--streaming`` evaluates everything through the blockwise operator protocol
(Hutchinson error estimates, projection sketches via blocked K @ S, no n×n
allocations); ``--scaling-ns 5000 20000 50000`` runs the linear-in-n sweep
(Table 3's "#Entries" story at sizes the dense path cannot reach).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import calibrate_sigma, make_dataset, print_table
from repro.core import spsd
from repro.core.adaptive import uniform_adaptive2_indices
from repro.core.kernelop import RBFKernel


def run(dataset: str, eta: float, adaptive: bool, seed: int = 0,
        s_mults=(2, 4, 8, 20, 40), n=None, streaming: bool = False,
        probes: int = 64):
    X, _ = make_dataset(dataset, seed=seed, n=n)
    n_ = X.shape[0]
    k = max(n_ // 100, 3)
    sigma = calibrate_sigma(X, eta, k)
    Kop = RBFKernel(X, sigma=sigma)
    c = max(n_ // 100, 8)
    err_kw = (dict(method="hutchinson", probes=probes) if streaming
              else dict(method="dense"))

    def rel_err(ap, i=0):
        return float(spsd.relative_error(
            Kop, ap, key=jax.random.PRNGKey(777 + i), **err_kw))

    key = jax.random.PRNGKey(seed)
    if adaptive:
        idx = uniform_adaptive2_indices(Kop, key, c)
        C = Kop.columns(idx)
        base = spsd.SPSDApprox(C=C, U=None, P_indices=idx)
    else:
        base = spsd.sample_C(Kop, key, c)

    rows = []
    W = Kop.block(base.P_indices, base.P_indices)
    nys = spsd.SPSDApprox(C=base.C, U=spsd.nystrom_U(W),
                          P_indices=base.P_indices)
    rows.append(("nystrom", "-", rel_err(nys)))

    s_kinds = (("uniform", "leverage", "gaussian") if streaming
               else ("uniform", "leverage"))
    for s_kind in s_kinds:
        for m in s_mults:
            s = min(m * c, n_)      # s=40c can exceed tiny --n sizes
            errs = [rel_err(spsd.fast_model_from_C(
                Kop, base.C, jax.random.PRNGKey(100 + i), s,
                P_indices=base.P_indices, s_sketch=s_kind,
                streaming=streaming or None), i)
                for i in range(3)]
            rows.append((f"fast[{s_kind}]", f"s={m}c "
                         f"(s/n={s / n_:.2f})", float(np.mean(errs))))

    proto = spsd.prototype_model(Kop, base.C, base.P_indices)
    rows.append(("prototype", "s=n", rel_err(proto)))

    title = (f"Fig {'4' if adaptive else '3'}: {dataset} n={n_} c={c} "
             f"sigma={sigma:.3f} eta~{eta}"
             f"{' [streaming/hutchinson]' if streaming else ''}")
    print_table(title, ["model", "sketch", "rel err ||K-CUC'||F^2/||K||F^2"],
                [(a, b, f"{e:.5f}") for a, b, e in rows])
    return rows


def run_scaling(ns, seed: int = 0, s_kind: str = "gaussian",
                probes: int = 16):
    """n-scaling sweep: the fast model + streaming metrics at growing n.

    Everything here goes through the single-sweep panel engine — no n×n
    array exists at any point, so n is bounded by O(n·c) memory, not O(n²).
    Each size is timed twice: the PR-1 sequence (model sweep, then a second
    sweep for the Hutchinson error) and the fused ``fast_model_with_error``
    (model + error from ONE pass over the kernel row panels); the ratio is
    the measured speedup of this PR, with kernel-entry counts from
    ``CountingOperator``.
    """
    from repro.core.instrument import CountingOperator
    rows = []
    for n in ns:
        X, _ = make_dataset("letters", seed=seed, n=n)
        # sigma=1 leaves K near-identity on the standardized 16-d mixture
        # (no low-rank structure to capture); 3.0 matches the eta~0.9 regime
        Kop = CountingOperator(RBFKernel(X, sigma=3.0))
        c = max(n // 200, 32)
        s = 4 * c

        t0 = time.perf_counter()
        ap = spsd.fast_model(Kop, jax.random.PRNGKey(seed), c=c, s=s,
                             s_sketch=s_kind, streaming=True)
        jax.block_until_ready(ap.U)
        t_model = time.perf_counter() - t0
        t0 = time.perf_counter()
        err = float(spsd.relative_error(Kop, ap, method="hutchinson",
                                        probes=probes,
                                        key=jax.random.PRNGKey(1)))
        t_err = time.perf_counter() - t0
        entries_sep = Kop.counts["entries"]

        Kop.reset()
        t0 = time.perf_counter()
        ap2, err2 = spsd.fast_model_with_error(
            Kop, jax.random.PRNGKey(seed), c=c, s=s, s_sketch=s_kind,
            probes=probes, error_key=jax.random.PRNGKey(1))
        jax.block_until_ready(ap2.U)
        err2 = float(err2)
        t_fused = time.perf_counter() - t0
        entries_fused = Kop.counts["entries"]

        speedup = (t_model + t_err) / max(t_fused, 1e-9)
        rows.append(dict(n=n, c=c, s=s, model_s=t_model, err_s=t_err,
                         fused_s=t_fused, speedup=speedup, rel_err=err,
                         rel_err_fused=err2, entries_separate=entries_sep,
                         entries_fused=entries_fused))
    print_table(f"n-scaling sweep (fast[{s_kind}], streaming, hutchinson "
                f"q={probes})",
                ["n", "c", "s", "model s", "err s", "fused s", "speedup",
                 "rel err", "rel err (fused)", "#K sep", "#K fused"],
                [(r["n"], r["c"], r["s"], f"{r['model_s']:8.2f}",
                  f"{r['err_s']:8.2f}", f"{r['fused_s']:8.2f}",
                  f"{r['speedup']:5.2f}x", f"{r['rel_err']:.5f}",
                  f"{r['rel_err_fused']:.5f}",
                  f"{r['entries_separate']:>12,}",
                  f"{r['entries_fused']:>12,}") for r in rows])
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*", default=["letters", "pendigit",
                                                     "mushrooms"])
    p.add_argument("--eta", type=float, default=0.9)
    p.add_argument("--adaptive", action="store_true")
    p.add_argument("--n", type=int, default=None)
    p.add_argument("--streaming", action="store_true",
                   help="blockwise operator paths + Hutchinson error metrics")
    p.add_argument("--probes", type=int, default=64)
    p.add_argument("--scaling-ns", nargs="*", type=int, default=None,
                   help="run the streaming n-scaling sweep at these sizes "
                        "instead of the Fig. 3/4 tables (e.g. 5000 20000 50000)")
    args = p.parse_args(argv)
    if args.scaling_ns:
        run_scaling(args.scaling_ns)
        return
    for ds in args.datasets:
        run(ds, args.eta, args.adaptive, n=args.n, streaming=args.streaming,
            probes=args.probes)


if __name__ == "__main__":
    main()
