"""Paper Figures 3 & 4: kernel approximation error vs s/n.

For each dataset: C = ceil(n/100) columns (uniform, or uniform+adaptive^2
with --adaptive, Fig. 4), then the U matrix from
  - the Nystrom method,
  - the fast model (S = uniform / leverage sampling), s in {2c..40c},
  - the prototype model (s = n).
y-axis metric: ||K - C U C^T||_F^2 / ||K||_F^2.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import (DATASETS, calibrate_sigma, make_dataset,
                               print_table)
from repro.core import spsd
from repro.core.adaptive import uniform_adaptive2_indices
from repro.core.kernelop import RBFKernel


def run(dataset: str, eta: float, adaptive: bool, seed: int = 0,
        s_mults=(2, 4, 8, 20, 40), n=None):
    X, _ = make_dataset(dataset, seed=seed, n=n)
    n_ = X.shape[0]
    k = max(n_ // 100, 3)
    sigma = calibrate_sigma(X, eta, k)
    Kop = RBFKernel(X, sigma=sigma)
    c = max(n_ // 100, 8)

    key = jax.random.PRNGKey(seed)
    if adaptive:
        idx = uniform_adaptive2_indices(Kop, key, c)
        C = Kop.columns(idx)
        base = spsd.SPSDApprox(C=C, U=None, P_indices=idx)
    else:
        base = spsd.sample_C(Kop, key, c)

    rows = []
    W = Kop.block(base.P_indices, base.P_indices)
    nys = spsd.SPSDApprox(C=base.C, U=spsd.nystrom_U(W),
                          P_indices=base.P_indices)
    rows.append(("nystrom", "-", float(spsd.relative_error(Kop, nys))))

    for s_kind in ("uniform", "leverage"):
        for m in s_mults:
            errs = [float(spsd.relative_error(Kop, spsd.fast_model_from_C(
                Kop, base.C, jax.random.PRNGKey(100 + i), m * c,
                P_indices=base.P_indices, s_sketch=s_kind)))
                for i in range(3)]
            rows.append((f"fast[{s_kind}]", f"s={m}c "
                         f"(s/n={m * c / n_:.2f})", float(np.mean(errs))))

    proto = spsd.prototype_model(Kop, base.C, base.P_indices)
    rows.append(("prototype", "s=n", float(spsd.relative_error(Kop, proto))))

    title = (f"Fig {'4' if adaptive else '3'}: {dataset} n={n_} c={c} "
             f"sigma={sigma:.3f} eta~{eta}")
    print_table(title, ["model", "sketch", "rel err ||K-CUC'||F^2/||K||F^2"],
                [(a, b, f"{e:.5f}") for a, b, e in rows])
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*", default=["letters", "pendigit",
                                                     "mushrooms"])
    p.add_argument("--eta", type=float, default=0.9)
    p.add_argument("--adaptive", action="store_true")
    p.add_argument("--n", type=int, default=None)
    args = p.parse_args(argv)
    for ds in args.datasets:
        run(ds, args.eta, args.adaptive, n=args.n)


if __name__ == "__main__":
    main()
