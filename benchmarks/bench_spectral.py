"""Paper Figures 11/12: approximate spectral clustering NMI.

CUC^T ~ K as the affinity; degree-normalized Laplacian top-k eigenvectors
(via Lemma 10 on (D^-1/2 C) U (D^-1/2 C)^T), row-normalized, k-means, NMI.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import (calibrate_sigma, kmeans, make_dataset, nmi,
                               print_table)
from repro.core import eig, spsd
from repro.core.kernelop import RBFKernel


def run(dataset: str, k: int, cs=(16, 32, 64), seed=0):
    X, y = make_dataset(dataset, seed=seed)
    sigma = calibrate_sigma(X, 0.9, max(k, 3))
    Kop = RBFKernel(X, sigma=sigma)

    rows = []
    for c in cs:
        base = spsd.sample_C(Kop, jax.random.PRNGKey(seed), c)
        methods = {}
        W = Kop.block(base.P_indices, base.P_indices)
        methods["nystrom"] = (base.C, spsd.nystrom_U(W))
        for m in (4, 8):
            ap = spsd.fast_model_from_C(
                Kop, base.C, jax.random.PRNGKey(seed + m), m * c,
                P_indices=base.P_indices, s_sketch="uniform")
            methods[f"fast s={m}c"] = (ap.C, ap.U)
        proto = spsd.prototype_model(Kop, base.C, base.P_indices)
        methods["prototype"] = (proto.C, proto.U)

        for name, (C, U) in methods.items():
            t0 = time.perf_counter()
            V = eig.spectral_embedding(C, U, k)
            lab = kmeans(np.asarray(V), k, seed=seed)
            dt = time.perf_counter() - t0
            rows.append((dataset, c, name, f"{dt * 1e3:8.1f}",
                         f"{nmi(lab, y):.4f}"))
    print_table(f"Fig 11/12: spectral clustering ({dataset}, k={k})",
                ["dataset", "c", "method", "time ms", "NMI"], rows)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*", default=["pendigit"])
    p.add_argument("--k", type=int, default=8)
    args = p.parse_args(argv)
    for ds in args.datasets:
        run(ds, args.k)


if __name__ == "__main__":
    main()
