"""Paper Figures 11/12: approximate spectral clustering NMI — streaming.

CUC^T ~ K as the affinity; degree-normalized Laplacian top-k eigenvectors
via Lemma 10 on (D^-1/2 C) U (D^-1/2 C)^T, row-normalized, k-means, NMI.

Degree sums d = K1 are *exact and streamed* (one multi-RHS ``matmat`` panel
sweep on the kernel operator), so the normalization does not inherit the
approximation's error; the accuracy-vs-dense reference clusters the top-k
eigenvectors of the degree-normalized operator D^-1/2 K D^-1/2 obtained by
streamed subspace iteration.  ``full()`` is never called (booby-trapped in
``tests/test_workloads.py``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_kpca import SELECTIONS, _methods, make_operator
from benchmarks.common import kmeans, make_dataset, nmi, print_table
from repro.core import eig
from repro.core.kernelop import SPSDOperator


class NormalizedAffinity(SPSDOperator):
    """D^-1/2 K D^-1/2 as a matmat-only operator view for subspace
    iteration — every application streams through the inner operator."""

    def __init__(self, inner, dinv):
        self.inner = inner
        self.dinv = dinv

    @property
    def n(self):
        return self.inner.n

    def matmat(self, V, block_size=None, mesh=None):
        W = self.inner.matmat(self.dinv[:, None] * V,
                              block_size=block_size, mesh=mesh)
        return self.dinv[:, None] * W


def streamed_degrees(Kop) -> jnp.ndarray:
    """Exact degree sums d = K1 in ONE panel sweep."""
    return Kop.matmat(jnp.ones((Kop.n, 1), jnp.float32))[:, 0]


def reference_labels(Kop, dinv, k: int, seed: int = 0):
    """Cluster assignments from the streamed-exact normalized eigvecs."""
    ref = eig.streaming_subspace_eigh(
        NormalizedAffinity(Kop, dinv), k, key=jax.random.PRNGKey(seed),
        power_iters=8)
    V = np.asarray(ref.eigenvectors)
    V = V / np.maximum(np.linalg.norm(V, axis=1, keepdims=True), 1e-9)
    return kmeans(V, k, seed=seed)


def run(dataset: str, k: int, cs=(16, 32, 64), seed=0, n=None,
        selections=SELECTIONS):
    X, y = make_dataset(dataset, seed=seed, n=n)
    Kop = make_operator(X)
    deg = streamed_degrees(Kop)
    dinv = 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-9))
    ref_lab = reference_labels(Kop, dinv, k, seed)
    ref_nmi = nmi(ref_lab, y)

    rows = []
    for c in cs:
        for name, (C, U, dt) in _methods(Kop, jax.random.PRNGKey(seed), c,
                                         selections=selections).items():
            t0 = time.perf_counter()
            V = eig.spectral_embedding(C, U, k, degrees=deg)
            lab = kmeans(np.asarray(V), k, seed=seed)
            rows.append({"dataset": dataset, "n": int(X.shape[0]), "c": c,
                         "k": k, "method": name,
                         "seconds": dt + time.perf_counter() - t0,
                         "nmi": nmi(lab, y),
                         "nmi_dense": ref_nmi,
                         "nmi_vs_dense": nmi(lab, ref_lab)})
    print_table(f"Fig 11/12: spectral clustering ({dataset}, k={k}, "
                f"dense-route NMI {ref_nmi:.4f})",
                ["dataset", "c", "method", "time ms", "NMI",
                 "NMI vs dense"],
                [(r["dataset"], r["c"], r["method"],
                  f"{r['seconds'] * 1e3:8.1f}", f"{r['nmi']:.4f}",
                  f"{r['nmi_vs_dense']:.4f}") for r in rows])
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*", default=["pendigit"])
    p.add_argument("--k", type=int, default=8)
    p.add_argument("--n", type=int, default=None,
                   help="override dataset size (smoke shapes)")
    p.add_argument("--cs", type=int, nargs="*", default=[16, 32, 64])
    args = p.parse_args(argv)
    for ds in args.datasets:
        run(ds, args.k, cs=tuple(args.cs), n=args.n)


if __name__ == "__main__":
    main()
