"""Shared benchmark utilities: synthetic datasets calibrated like the
paper's (Table 6: sigma chosen so the top-1%% spectrum mass eta hits a
target), timing, and table printing.

The paper's LIBSVM datasets are not available offline; we substitute
Gaussian-mixture datasets with matched statistics (n, d, #classes) and
calibrate sigma exactly the way the paper does (eta = ||K_k||_F^2/||K||_F^2
with k = ceil(n/100)).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernelop import RBFKernel
from repro.kernels.pairwise import calibrate as _lib_calibrate

DATASETS = {
    # name: (n, d, classes)  — sized after Table 6/7 but CPU-friendly
    "letters": (1500, 16, 26),
    "pendigit": (1500, 16, 10),
    "cpusmall": (1200, 12, 0),
    "mushrooms": (1200, 24, 2),
    "wine": (1000, 12, 3),
}


def make_dataset(name: str, seed: int = 0, n=None):
    n_, d, k = DATASETS[name]
    n = n or n_
    rng = np.random.default_rng(seed)
    k_eff = max(k, 8)
    centers = rng.normal(size=(k_eff, d)) * 2.0
    labels = rng.integers(0, k_eff, size=n)
    X = centers[labels] + rng.normal(size=(n, d)) * 0.7
    # per-feature scaling like libsvm preprocessing
    X = (X - X.mean(0)) / (X.std(0) + 1e-9)
    return jnp.asarray(X, jnp.float32), labels % max(k, 2)


def eta_of(K: jnp.ndarray, k: int) -> float:
    ev = jnp.linalg.eigvalsh(K)
    ev2 = jnp.sort(ev ** 2)[::-1]
    return float(jnp.sum(ev2[:k]) / jnp.sum(ev2))


def calibrate_sigma(X: jnp.ndarray, eta_target: float = 0.9, k: int = 3,
                    q: float = 0.5) -> float:
    """Bandwidth via the library's per-spec calibration registry.

    Delegates to ``repro.kernels.pairwise.calibrate`` (median-heuristic
    quantile of the streamed pairwise statistic — one n×m gather, no
    ``full()``), so benches and serving agree on σ.  ``eta_target``/``k``
    are accepted for call-site back-compat with the old spectral-mass
    binary search, which survives as :func:`calibrate_sigma_eta` (the
    parity test's oracle); they do not affect the quantile rule.
    """
    del eta_target, k
    spec = _lib_calibrate.calibrate_sigma(jnp.asarray(X, jnp.float32),
                                          "rbf", q=q)
    return float(spec.param("sigma"))


def calibrate_sigma_eta(X: jnp.ndarray, eta_target: float, k: int,
                        lo=0.05, hi=20.0, iters=18) -> float:
    """Binary search sigma so eta(K_sigma) ~ eta_target (paper §6.1).

    The pre-registry rule — kept as the oracle for the calibration parity
    test; it densifies an 800-point sub-kernel, so benches no longer call
    it.
    """
    Xs = X[: min(X.shape[0], 800)]
    for _ in range(iters):
        mid = (lo + hi) / 2
        K = RBFKernel(Xs, sigma=mid).full()
        e = eta_of(K, max(int(np.ceil(Xs.shape[0] / 100)), k))
        if e > eta_target:
            hi = mid          # kernel too smooth -> lower sigma
        else:
            lo = mid
    return (lo + hi) / 2


def timer(fn, *args, repeats: int = 1, **kw):
    fn(*args, **kw)                                   # compile/warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats, out


def print_table(title: str, header, rows):
    print(f"\n== {title} ==")
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(header)]
    print("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    print("-" * (sum(widths) + 2 * len(widths)))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))


def nmi(labels_a, labels_b) -> float:
    """Normalized mutual information (paper §6.4 metric)."""
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    n = a.shape[0]
    ua, ub = np.unique(a), np.unique(b)
    cont = np.zeros((len(ua), len(ub)))
    for i, x in enumerate(ua):
        for j, y in enumerate(ub):
            cont[i, j] = np.sum((a == x) & (b == y))
    pij = cont / n
    pi = pij.sum(1, keepdims=True)
    pj = pij.sum(0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        mi = np.nansum(pij * np.log(pij / (pi @ pj)))
        ha = -np.nansum(pi * np.log(pi))
        hb = -np.nansum(pj * np.log(pj))
    return float(mi / max(np.sqrt(ha * hb), 1e-12))


def kmeans(X, k, seed=0, iters=50):
    rng = np.random.default_rng(seed)
    X = np.asarray(X)
    idx = rng.choice(X.shape[0], k, replace=False)
    C = X[idx]
    for _ in range(iters):
        d = ((X[:, None] - C[None]) ** 2).sum(-1)
        lab = d.argmin(1)
        for j in range(k):
            pts = X[lab == j]
            if len(pts):
                C[j] = pts.mean(0)
    return lab


def knn_classify(train_x, train_y, test_x, k=10):
    d = ((np.asarray(test_x)[:, None] - np.asarray(train_x)[None]) ** 2
         ).sum(-1)
    nn = np.argsort(d, axis=1)[:, :k]
    votes = np.asarray(train_y)[nn]
    out = []
    for row in votes:
        vals, cnt = np.unique(row, return_counts=True)
        out.append(vals[cnt.argmax()])
    return np.asarray(out)
