"""Downstream workload suite: the paper's applications end-to-end.

One row per workload, each with an accuracy-vs-dense number and wall-clock,
so scenario coverage is visible in the perf trajectory
(``BENCH_<tag>.json["workloads"]``):

=========  =============================  ==================================
workload   accuracy vs dense              route
=========  =============================  ==================================
kpca       misalignment (Eq. 10) vs the   ``fast_model`` + SelectionPolicy,
           streamed-exact eigvecs; 10-NN  Lemma-10 ``approx_eigh``; reference
           test error                     via streamed subspace iteration
spectral   NMI agreement with the dense-  degree-normalized Lemma-10 route on
           route clustering (+NMI vs      streamed-exact degree sums d = K1
           labels)
krr        parity vs the dense f64 KRR    ``build_artifact`` (cached Woodbury
           oracle                         solve) → ``serve_kernel_model``
attention  rel err vs exact softmax       ``sketched_attention`` fast-CUR
           attention; decode-path read    with SelectionPolicy landmarks +
           err                            the fused landmark read kernel
=========  =============================  ==================================

All shapes are smoke-sized (CI runs this inside ``run.py --smoke`` and the
``workload-smoke`` job); absolute wall-clock at these shapes is noise — the
accuracy columns and their trajectory are the signal.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import bench_kpca, bench_spectral
from repro.core.sketched_attention import (build_landmark_state,
                                           sketched_attention)
from repro.kernels.landmark_attention import ops as lm_ops
from repro.kernels.pairwise import calibrate as pw_cal
from repro.launch.serve_kernel import synth_problem
from repro.serve.artifact import build_artifact
from repro.serve.engine import (QueryRequest, dense_krr_oracle, parity_gap,
                                serve_kernel_model)

#: the policy each workload row reports (the PR-5 accuracy frontier)
WORKLOAD_SELECTION = "uniform_adaptive2"


def run_kpca(n=400, k=3, c=32, seed=0) -> dict:
    t0 = time.perf_counter()
    mis_rows = bench_kpca.run_misalignment(
        "pendigit", k=k, cs=(c,), seed=seed, n=n,
        selections=(WORKLOAD_SELECTION,))
    knn_rows = bench_kpca.run_knn(
        "pendigit", k=k, c=c, seed=seed, n=n,
        selections=(WORKLOAD_SELECTION,))
    pick = next(r for r in mis_rows
                if r["method"] == f"fast {WORKLOAD_SELECTION}")
    knn = next(r for r in knn_rows
               if r["method"] == f"fast {WORKLOAD_SELECTION}")
    return {"workload": "kpca", "n": n, "c": c, "k": k,
            "selection": WORKLOAD_SELECTION,
            "misalignment": pick["misalignment"],
            "knn_test_err": knn["test_err"],
            "knn_test_err_nystrom": next(
                r["test_err"] for r in knn_rows if r["method"] == "nystrom"),
            "build_seconds": round(pick["seconds"], 4),
            "seconds": round(time.perf_counter() - t0, 3)}


def run_spectral(n=400, k=4, c=32, seed=0) -> dict:
    t0 = time.perf_counter()
    rows = bench_spectral.run("pendigit", k=k, cs=(c,), seed=seed, n=n,
                              selections=(WORKLOAD_SELECTION,))
    pick = next(r for r in rows
                if r["method"] == f"fast {WORKLOAD_SELECTION}")
    return {"workload": "spectral", "n": n, "c": c, "k": k,
            "selection": WORKLOAD_SELECTION,
            "nmi": pick["nmi"], "nmi_dense": pick["nmi_dense"],
            "nmi_vs_dense": pick["nmi_vs_dense"],
            "build_seconds": round(pick["seconds"], 4),
            "seconds": round(time.perf_counter() - t0, 3)}


def run_krr(n=400, d=16, c=48, s=96, nq=64, alpha=1e-2, seed=0) -> dict:
    """Streamed build → cached-Woodbury KRR heads → fused cross serving,
    measured against the dense f64 oracle on held-out queries."""
    X_all, y_all = synth_problem(n + nq, d, seed)
    X, y = X_all[:n], y_all[:n]
    Xq, yq = X_all[n:], y_all[n:]
    spec = pw_cal.calibrate_sigma(X, "rbf")

    t0 = time.perf_counter()
    art = build_artifact(X, y, spec, c=c, s=s, alpha=alpha,
                         selection=WORKLOAD_SELECTION,
                         key=jax.random.PRNGKey(seed))
    build_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = serve_kernel_model(art, [QueryRequest(Xq, "krr")])
    pred = np.asarray(res[0].out)[:, 0]
    query_s = time.perf_counter() - t0

    dense = np.asarray(dense_krr_oracle(art, Xq, y))[:, 0]
    return {"workload": "krr", "n": n, "c": c, "s": s, "nq": nq,
            "selection": WORKLOAD_SELECTION,
            "parity_vs_dense": parity_gap(pred, dense),
            "rmse": float(np.sqrt(np.mean((pred - np.asarray(yq)) ** 2))),
            "rmse_dense": float(
                np.sqrt(np.mean((dense - np.asarray(yq)) ** 2))),
            "build_seconds": round(build_s, 4),
            "query_seconds": round(query_s, 4),
            "seconds": round(build_s + query_s, 3)}


def run_attention(S=256, D=32, c=32, theta=4, seed=0) -> dict:
    """Fast-SPSD attention vs exact softmax attention, with SelectionPolicy
    landmarks, plus the decode-path fused read."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (S, D)) * 0.4
    k = jax.random.normal(ks[1], (S, D)) * 0.4
    v = jax.random.normal(ks[2], (S, D))
    w = jax.nn.softmax((q @ k.T) / np.sqrt(D), axis=-1)
    exact = w @ v
    enorm = float(jnp.linalg.norm(exact))

    def rel_err(out):
        return float(jnp.linalg.norm(out - exact)) / enorm

    t0 = time.perf_counter()
    out = sketched_attention(q, k, v, jax.random.PRNGKey(seed + 1), c=c,
                             theta=theta, mode="fast",
                             selection=WORKLOAD_SELECTION)
    out.block_until_ready()
    fast_s = time.perf_counter() - t0
    err_ny = rel_err(sketched_attention(
        q, k, v, jax.random.PRNGKey(seed + 1), c=c, mode="nystrom",
        selection=WORKLOAD_SELECTION))

    # decode read: prefill state once, fused kernel read for a query block
    state = build_landmark_state(k, v, jax.random.PRNGKey(seed + 2), c=c,
                                 theta=theta, selection=WORKLOAD_SELECTION)
    t0 = time.perf_counter()
    reads = lm_ops.landmark_read(q, state.k_land, state.UV, state.U1,
                                 state.scale)
    reads.block_until_ready()
    read_s = time.perf_counter() - t0

    return {"workload": "attention", "S": S, "D": D, "c": c, "theta": theta,
            "selection": WORKLOAD_SELECTION,
            "rel_err_vs_exact": rel_err(out),
            "rel_err_nystrom": err_ny,
            "decode_rel_err": rel_err(reads),
            "fast_seconds": round(fast_s, 4),
            "decode_read_seconds": round(read_s, 4),
            "seconds": round(fast_s + read_s, 3)}


def run(seed=0) -> list:
    """All four workload rows at smoke shapes."""
    return [run_kpca(seed=seed), run_spectral(seed=seed),
            run_krr(seed=seed), run_attention(seed=seed)]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default=None,
                   help="write {'workloads': rows} to this path (the "
                        "workload-smoke CI leg feeds it to compare_bench)")
    args = p.parse_args(argv)
    rows = run(seed=args.seed)
    for r in rows:
        print(json.dumps(r))
    if args.json:
        out_dir = os.path.dirname(args.json)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"workloads": rows}, f, indent=2)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    main()
