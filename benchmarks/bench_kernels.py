"""Kernel-registry sweep: every registered KernelSpec through the fused path.

The paper's O(n) "#Entries" analysis is kernel-agnostic; this bench proves
the *implementation* is too.  For each registered kernel (rbf, laplacian,
matern32, polynomial, linear, plus anything user-registered) it runs the
fused ``fast_model_with_error`` through a ``CountingOperator`` and reports
wall-clock, measured kernel-entry counts, the sweep route taken
(``pallas_fused`` / ``pallas_fused_sharded`` / ``panel``, with a
``+bf16_f32acc`` suffix under the mixed-precision policy), the l1dist route
(``mxu_signsplit`` / ``vpu_loop``), the Hutchinson relative error, and an
achieved-vs-roofline score for one dedicated timed launch — one row per
kernel, identical machinery for all of them.

    PYTHONPATH=src python -m benchmarks.bench_kernels                # all
    PYTHONPATH=src python -m benchmarks.bench_kernels --kernel laplacian
    PYTHONPATH=src python -m benchmarks.bench_kernels --mesh         # shard
    PYTHONPATH=src python -m benchmarks.bench_kernels --precision bf16_f32acc
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core import spsd
from repro.core.instrument import CountingOperator
from repro.core.kernelop import PairwiseKernel
from repro.kernels.pairwise import specs
from repro.launch import roofline as roofline_lib


def _clustered(seed: int, n: int, d: int = 8, k: int = 8,
               grid: float = 0.5) -> jnp.ndarray:
    """Clustered points snapped to a ``grid`` lattice.

    The quantization mirrors the paper's laplacian evaluation data (letters /
    pendigits / mushrooms are small-integer features) and keeps per-feature
    cardinality within the sign-split segment budget, so the l1dist rows
    exercise the MXU route the way the real workloads would.  ``grid=0``
    disables snapping (continuous data — the VPU reference route).
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    X = centers[rng.integers(0, k, size=n)] + rng.normal(size=(n, d)) * 0.3
    if grid:
        X = np.round(X / grid) * grid
    return jnp.asarray(X, jnp.float32)


def _roofline_row(op: PairwiseKernel, mesh, n: int, d: int,
                  m: int = 128) -> dict:
    """One dedicated timed fused launch, scored against the analytic model.

    ``fast_model_with_error`` interleaves host-side factor algebra with its
    launches, so its wall-clock is not a launch measurement; this times the
    square multi-RHS launch alone (post-warmup) and scores it under
    ``default_profile()`` (CPU-interpret numbers against CPU peaks).
    """
    V = jnp.asarray(np.random.default_rng(0).normal(size=(n, m)), jnp.float32)

    def launch():
        return jax.block_until_ready(op.fused_rows(None, (V,)))

    launch()                                    # compile + warm the cache
    t0 = time.perf_counter()
    launch()
    measured = time.perf_counter() - t0
    edges = op.l1_edges()
    return roofline_lib.achieved_vs_roofline(
        op.spec, (n, n, d), mesh, measured_s=measured, m_total=m,
        l1_route=op.l1_route(),
        segments=0 if edges is None else int(edges.shape[1]) + 1)


def run(kernels=None, n: int = 400, c: int = 16, probes: int = 8,
        seed: int = 0, mesh=None, use_pallas: bool = True,
        precision: str = "f32", with_roofline: bool = True):
    """One fused model+error pass per kernel; returns the per-kernel rows."""
    kernels = list(kernels) if kernels else list(specs.registered_kernels())
    X = _clustered(seed, n)
    rows = []
    for name in kernels:
        # the shared registry-sweep parameterization (entries O(1) on
        # standardized data; custom kernels use their factory defaults)
        spec = specs.suggested_spec(name, X.shape[1])
        spec = spec.with_precision(precision)
        op = PairwiseKernel(X, spec, use_pallas=use_pallas)
        Kc = CountingOperator(op)
        t0 = time.perf_counter()
        ap, err = spsd.fast_model_with_error(
            Kc, jax.random.PRNGKey(seed), c=c, s=4 * c, s_sketch="gaussian",
            probes=probes, mesh=mesh)
        jax.block_until_ready(ap.U)
        dt = time.perf_counter() - t0
        row = dict(kernel=name, seconds=round(dt, 3),
                   entries=Kc.counts["entries"],
                   sweeps=Kc.counts["sweeps"], route=Kc.last_route,
                   precision=precision, l1_route=op.l1_route(),
                   rel_err=float(err))
        if with_roofline and use_pallas:
            row["roofline"] = _roofline_row(op, mesh, n, X.shape[1])
        rows.append(row)
    print_table(
        f"kernel registry sweep (n={n}, c={c}, s={4 * c}, "
        f"precision={precision}, fused model+error)",
        ["kernel", "s", "#K entries", "sweeps", "route", "l1 route",
         "rel err", "roof%"],
        [(r["kernel"], f"{r['seconds']:7.3f}", f"{r['entries']:>12,}",
          r["sweeps"], r["route"], r["l1_route"] or "-",
          f"{r['rel_err']:.5f}",
          f"{100 * r['roofline']['achieved_frac']:.2f}%"
          if "roofline" in r else "-") for r in rows])
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--kernel", nargs="*", default=None,
                   help="subset of the registry (default: every "
                        "registered kernel)")
    p.add_argument("--n", type=int, default=400)
    p.add_argument("--c", type=int, default=16)
    p.add_argument("--probes", type=int, default=8)
    p.add_argument("--mesh", action="store_true",
                   help="shard the sweeps over a ('data',) mesh of all local "
                        "devices (exercises the pallas_fused_sharded route)")
    p.add_argument("--no-pallas", action="store_true",
                   help="force the jnp panel route (baseline)")
    p.add_argument("--precision", default="f32", choices=specs.PRECISIONS,
                   help="tile-evaluation policy for every launch "
                        "(bf16_f32acc: bf16 tiles, f32 accumulators)")
    args = p.parse_args(argv)
    mesh = None
    if args.mesh:
        from repro.distributed import data_parallel_mesh
        mesh = data_parallel_mesh()
    run(kernels=args.kernel, n=args.n, c=args.c, probes=args.probes,
        mesh=mesh, use_pallas=not args.no_pallas, precision=args.precision)
    return 0


if __name__ == "__main__":
    main()
