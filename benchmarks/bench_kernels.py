"""Kernel-registry sweep: every registered KernelSpec through the fused path.

The paper's O(n) "#Entries" analysis is kernel-agnostic; this bench proves
the *implementation* is too.  For each registered kernel (rbf, laplacian,
matern32, polynomial, linear, plus anything user-registered) it runs the
fused ``fast_model_with_error`` through a ``CountingOperator`` and reports
wall-clock, measured kernel-entry counts, the sweep route taken
(``pallas_fused`` / ``pallas_fused_sharded`` / ``panel``), and the Hutchinson
relative error — one row per kernel, identical machinery for all of them.

    PYTHONPATH=src python -m benchmarks.bench_kernels                # all
    PYTHONPATH=src python -m benchmarks.bench_kernels --kernel laplacian
    PYTHONPATH=src python -m benchmarks.bench_kernels --mesh         # shard
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core import spsd
from repro.core.instrument import CountingOperator
from repro.core.kernelop import PairwiseKernel
from repro.kernels.pairwise import specs

def _clustered(seed: int, n: int, d: int = 8, k: int = 8) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    X = centers[rng.integers(0, k, size=n)] + rng.normal(size=(n, d)) * 0.3
    return jnp.asarray(X, jnp.float32)


def run(kernels=None, n: int = 400, c: int = 16, probes: int = 8,
        seed: int = 0, mesh=None, use_pallas: bool = True):
    """One fused model+error pass per kernel; returns the per-kernel rows."""
    kernels = list(kernels) if kernels else list(specs.registered_kernels())
    X = _clustered(seed, n)
    rows = []
    for name in kernels:
        # the shared registry-sweep parameterization (entries O(1) on
        # standardized data; custom kernels use their factory defaults)
        spec = specs.suggested_spec(name, X.shape[1])
        Kc = CountingOperator(PairwiseKernel(X, spec, use_pallas=use_pallas))
        t0 = time.perf_counter()
        ap, err = spsd.fast_model_with_error(
            Kc, jax.random.PRNGKey(seed), c=c, s=4 * c, s_sketch="gaussian",
            probes=probes, mesh=mesh)
        jax.block_until_ready(ap.U)
        dt = time.perf_counter() - t0
        rows.append(dict(kernel=name, seconds=round(dt, 3),
                         entries=Kc.counts["entries"],
                         sweeps=Kc.counts["sweeps"], route=Kc.last_route,
                         rel_err=float(err)))
    print_table(
        f"kernel registry sweep (n={n}, c={c}, s={4 * c}, fused model+error)",
        ["kernel", "s", "#K entries", "sweeps", "route", "rel err"],
        [(r["kernel"], f"{r['seconds']:7.3f}", f"{r['entries']:>12,}",
          r["sweeps"], r["route"], f"{r['rel_err']:.5f}") for r in rows])
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--kernel", nargs="*", default=None,
                   help="subset of the registry (default: every "
                        "registered kernel)")
    p.add_argument("--n", type=int, default=400)
    p.add_argument("--c", type=int, default=16)
    p.add_argument("--probes", type=int, default=8)
    p.add_argument("--mesh", action="store_true",
                   help="shard the sweeps over a ('data',) mesh of all local "
                        "devices (exercises the pallas_fused_sharded route)")
    p.add_argument("--no-pallas", action="store_true",
                   help="force the jnp panel route (baseline)")
    args = p.parse_args(argv)
    mesh = None
    if args.mesh:
        from repro.distributed import data_parallel_mesh
        mesh = data_parallel_mesh()
    run(kernels=args.kernel, n=args.n, c=args.c, probes=args.probes,
        mesh=mesh, use_pallas=not args.no_pallas)
    return 0


if __name__ == "__main__":
    main()
