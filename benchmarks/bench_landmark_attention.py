"""Beyond-paper: the fast-SPSD model as sub-quadratic attention.

Quality (vs exact softmax attention) and FLOP count of the landmark read,
comparing the paper's fast U (mode='fast') against plain Nystrom
(mode='nystrom') at several landmark counts — the LM-side analogue of
Figs 3/4.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core.sketched_attention import (build_landmark_state,
                                           landmark_decode,
                                           sketched_attention)


def _exact(q, k, v):
    w = jax.nn.softmax((q @ k.T) / np.sqrt(q.shape[-1]), axis=-1)
    return w @ v


def run(S=2048, D=64, cs=(16, 32, 64, 128), theta=4, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (S, D)) * 0.4
    k = jax.random.normal(ks[1], (S, D)) * 0.4
    v = jax.random.normal(ks[2], (S, D))
    exact = _exact(q, k, v)

    rows = []
    for c in cs:
        for mode in ("nystrom", "fast"):
            errs = []
            for i in range(3):
                out = sketched_attention(q, k, v,
                                         jax.random.PRNGKey(10 * i + c),
                                         c=c, theta=theta, mode=mode)
                errs.append(float(jnp.linalg.norm(out - exact)
                                  / jnp.linalg.norm(exact)))
            # flops per query token ~ 2*c*D (read) vs 2*S*D exact
            speedup = S / c
            rows.append((c, mode, f"{np.mean(errs):.4f}",
                         f"{speedup:5.1f}x"))
    print_table(f"landmark attention vs exact (S={S}, D={D}, theta={theta})",
                ["c", "U mode", "rel err", "read-FLOP reduction"], rows)

    # decode-path read from a prefill-built state
    state = build_landmark_state(k, v, jax.random.PRNGKey(1), c=128,
                                 theta=theta)
    q1 = jax.random.normal(jax.random.PRNGKey(2), (16, D)) * 0.4
    reads = jax.vmap(lambda qq: landmark_decode(state, qq))(q1)
    err = float(jnp.linalg.norm(reads - _exact(q1, k, v))
                / jnp.linalg.norm(_exact(q1, k, v)))
    print(f"\ndecode read (c=128 landmarks over {S} ctx): rel err {err:.4f}, "
          f"state bytes/token ~ {128 * 2 * D * 4 / S:.1f} vs KV cache "
          f"{2 * D * 2}")
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--seq", type=int, default=2048)
    args = p.parse_args(argv)
    run(S=args.seq)


if __name__ == "__main__":
    main()
