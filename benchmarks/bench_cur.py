"""Paper Figure 2 + Section 5.3: CUR on an image-like matrix.

A synthetic 'natural image' (smooth 2D field + oriented edges + texture,
approximately low-rank like Fig. 2's photo) is decomposed with c=r=100 and
the U matrix computed four ways: optimal (Eq. 8), drineas08 (P_R^T A P_C)^+,
and fast (Eq. 9) at (sc, sr) = (2r, 2c) and (4r, 4c).

``--streaming-selection`` benches the PR-5 selection subsystem instead:
fully streaming C/R selection on an implicit kernel operator (every
registered ``SelectionPolicy`` through ``fast_cur``), reporting wall time,
metered sweeps/entries, and relative error per policy.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core import cur, selection
from repro.core.instrument import CountingOperator
from repro.core.kernelop import RBFKernel


def synth_image(h=960, w=584, seed=0):
    """Smooth low-rank-ish field, like a downscaled natural photo."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    img = (np.sin(yy / 37.0) * np.cos(xx / 53.0)
           + 0.7 * np.sin((xx + 2 * yy) / 91.0)
           + 0.4 * np.cos((3 * xx - yy) / 143.0))
    # a few sharp structures
    img += 1.5 * (np.abs(xx - w * 0.4) < 12)
    img += 1.2 * ((yy - h * 0.6) ** 2 + (xx - w * 0.7) ** 2 < 40 ** 2)
    # mild texture
    u = rng.normal(size=(h, 6))
    v = rng.normal(size=(6, w))
    img += 0.1 * (u @ v)
    return jnp.asarray(img, jnp.float32)


def run(c=100, r=100, seed=0):
    A = synth_image(seed=seed)
    m, n = A.shape
    key = jax.random.PRNGKey(seed)
    rows = []

    t0 = time.perf_counter()
    opt = cur.optimal_cur(A, key, c=c, r=r)
    t_opt = time.perf_counter() - t0
    rows.append(("optimal U (Eq.8)", "-", f"{t_opt * 1e3:9.1f}",
                 f"{float(cur.relative_error(A, opt)):.5f}"))

    C, R, cidx, ridx = cur.select_cur_sketches(A, key, c, r)
    t0 = time.perf_counter()
    U = cur.drineas08_U(A, cidx, ridx)
    t_dri = time.perf_counter() - t0
    rows.append(("drineas08 (Fig 2c)", "sc=r, sr=c", f"{t_dri * 1e3:9.1f}",
                 f"{float(cur.relative_error(A, cur.CURApprox(C=C, U=U, R=R))):.5f}"))

    for mult in (2, 4):
        t0 = time.perf_counter()
        f = cur.fast_cur(A, key, c=c, r=r, sc=mult * r, sr=mult * c,
                         sketch_kind="uniform")
        dt = time.perf_counter() - t0
        rows.append(("fast U (Eq.9)", f"sc={mult}r, sr={mult}c",
                     f"{dt * 1e3:9.1f}",
                     f"{float(cur.relative_error(A, f)):.5f}"))

    print_table(f"Fig 2: CUR on {m}x{n} synthetic image, c=r={c}",
                ["U method", "sketch", "time ms", "rel err"], rows)
    return rows


def run_streaming_selection(n=1500, c=48, sc=96, seed=0, mesh=None):
    """Kernel CUR with streaming C/R selection, one row per policy.

    The operator is an implicit RBF kernel (never densified); each
    registered ``SelectionPolicy`` selects C and R through the operator
    protocol, and ``CountingOperator`` meters the pass budget the policy
    declared.  Relative error is measured against the materialized kernel
    (bench-time only — n is CPU-sized).
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, 8)) * 2.5
    X = jnp.asarray(centers[rng.integers(0, 8, size=n)]
                    + rng.normal(size=(n, 8)) * 0.4, jnp.float32)
    Kd = jnp.asarray(np.asarray(RBFKernel(X, sigma=2.0).full(), np.float32))
    rows = []
    for name in selection.registered_policies():
        pol = selection.get_policy(name)
        Kc = CountingOperator(RBFKernel(X, sigma=2.0))
        t0 = time.perf_counter()
        ap = cur.fast_cur(Kc, jax.random.PRNGKey(seed), c=c, r=c, sc=sc,
                          sr=sc, sketch_kind="gaussian", selection=name,
                          mesh=mesh)
        jax.block_until_ready(ap.U)
        dt = time.perf_counter() - t0
        rows.append(dict(policy=name, seconds=round(dt, 3),
                         sweeps=Kc.counts["sweeps"],
                         declared=1 + 2 * pol.sweep_budget(),
                         entries=Kc.counts["entries"],
                         rel_err=float(cur.relative_error(Kd, ap))))
    print_table(
        f"streaming CUR selection (implicit RBF kernel, n={n}, c=r={c})",
        ["policy", "s", "sweeps", "declared", "#K entries", "rel err"],
        [(r["policy"], f"{r['seconds']:7.3f}", r["sweeps"], r["declared"],
          f"{r['entries']:>12,}", f"{r['rel_err']:.5f}") for r in rows])
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--c", type=int, default=None,
                   help="columns/rows (default 100 for the image CUR, 48 "
                        "for --streaming-selection)")
    p.add_argument("--streaming-selection", action="store_true",
                   help="bench the selection-policy registry on an implicit "
                        "kernel operator instead of the dense image CUR")
    p.add_argument("--n", type=int, default=1500,
                   help="points for --streaming-selection")
    args = p.parse_args(argv)
    if args.streaming_selection:
        c = 48 if args.c is None else args.c
        return run_streaming_selection(n=args.n, c=c, sc=2 * c)
    run(c=100 if args.c is None else args.c,
        r=100 if args.c is None else args.c)


if __name__ == "__main__":
    main()
