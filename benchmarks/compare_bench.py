"""Bench-trajectory regression gate: diff a fresh smoke BENCH payload
against the latest committed ``BENCH_pr*.json`` baseline.

    PYTHONPATH=src python -m benchmarks.compare_bench \
        --fresh results/BENCH_smoke.json            # baseline auto-located

Hard gates (exit 1) — the two numbers the paper's efficiency story rests
on, with generous tolerances because CI runners are noisy:

- scaling rows (matched by ``n``): the fused-sweep ``speedup`` may not drop
  below ``baseline × (1 − tol_speedup)``, and ``rel_err_fused`` may not
  exceed ``baseline × (1 + tol_err) + 1e-6``.
- kernels rows (matched by kernel name): ``rel_err`` under the same bound.

Everything else — wall seconds, routes, serve latency, new/removed rows —
is printed as ADVISORY only: absolute timings at smoke shapes measure the
runner, not the code.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import List, Optional, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def find_baseline(root: str = REPO_ROOT) -> Optional[str]:
    """Latest committed ``BENCH_pr<N>.json`` (highest N), or None."""
    best: Tuple[int, Optional[str]] = (-1, None)
    for path in glob.glob(os.path.join(root, "BENCH_pr*.json")):
        m = re.fullmatch(r"BENCH_pr(\d+)\.json", os.path.basename(path))
        if m and int(m.group(1)) > best[0]:
            best = (int(m.group(1)), path)
    return best[1]


def _index(rows, key) -> dict:
    return {r[key]: r for r in rows if key in r}


def compare(fresh: dict, base: dict, tol_speedup: float = 0.5,
            tol_err: float = 0.5) -> Tuple[List[str], List[str]]:
    """Returns (failures, advisories) as printable strings."""
    failures: List[str] = []
    advisories: List[str] = []

    def err_bound(b: float) -> float:
        return b * (1.0 + tol_err) + 1e-6

    # -- scaling: fused speedup + fused rel-err are the tentpole metrics ----
    f_scale = _index(fresh.get("scaling", []), "n")
    b_scale = _index(base.get("scaling", []), "n")
    for n in sorted(set(f_scale) & set(b_scale)):
        f, b = f_scale[n], b_scale[n]
        floor = b["speedup"] * (1.0 - tol_speedup)
        if f["speedup"] < floor:
            failures.append(
                f"scaling n={n}: fused speedup {f['speedup']:.2f}x < "
                f"{floor:.2f}x floor (baseline {b['speedup']:.2f}x "
                f"- {tol_speedup:.0%})")
        else:
            advisories.append(
                f"scaling n={n}: speedup {b['speedup']:.2f}x -> "
                f"{f['speedup']:.2f}x")
        if f["rel_err_fused"] > err_bound(b["rel_err_fused"]):
            failures.append(
                f"scaling n={n}: rel_err_fused {f['rel_err_fused']:.4g} > "
                f"{err_bound(b['rel_err_fused']):.4g} bound "
                f"(baseline {b['rel_err_fused']:.4g})")
    for n in sorted(set(b_scale) - set(f_scale)):
        advisories.append(f"scaling n={n}: row dropped from fresh payload")

    # -- kernels: per-registry-kernel approximation quality -----------------
    f_k = _index(fresh.get("kernels", []), "kernel")
    b_k = _index(base.get("kernels", []), "kernel")
    for name in sorted(set(f_k) & set(b_k)):
        f, b = f_k[name], b_k[name]
        if f["rel_err"] > err_bound(b["rel_err"]):
            failures.append(
                f"kernels {name}: rel_err {f['rel_err']:.4g} > "
                f"{err_bound(b['rel_err']):.4g} bound "
                f"(baseline {b['rel_err']:.4g})")
        if f.get("route") != b.get("route"):
            advisories.append(
                f"kernels {name}: route {b.get('route')} -> "
                f"{f.get('route')}")
    for name in sorted(set(b_k) - set(f_k)):
        advisories.append(f"kernels {name}: row dropped from fresh payload")

    # -- kernels_bf16: advisory-first (new section; promote once the bf16
    # trajectory has a few PRs of history behind it) ------------------------
    f_bk = _index(fresh.get("kernels_bf16", []), "kernel")
    b_bk = _index(base.get("kernels_bf16", []), "kernel")
    for name in sorted(set(f_bk) & set(b_bk)):
        f, b = f_bk[name], b_bk[name]
        if f["rel_err"] > err_bound(b["rel_err"]):
            advisories.append(
                f"kernels_bf16 {name}: rel_err {f['rel_err']:.4g} > "
                f"{err_bound(b['rel_err']):.4g} bound "
                f"(baseline {b['rel_err']:.4g})")
        if f.get("l1_route") != b.get("l1_route"):
            advisories.append(
                f"kernels_bf16 {name}: l1 route {b.get('l1_route')} -> "
                f"{f.get('l1_route')}")

    # -- roofline: advisory-only (achieved fractions at smoke shapes on CI
    # runners measure the runner; route/profile flips are still worth eyes) -
    f_roof = {(r["kernel"], r["precision"]): r
              for r in fresh.get("roofline", [])}
    b_roof = {(r["kernel"], r["precision"]): r
              for r in base.get("roofline", [])}
    for key in sorted(set(f_roof) & set(b_roof)):
        f, b = f_roof[key], b_roof[key]
        advisories.append(
            f"roofline {key[0]}/{key[1]}: achieved "
            f"{b['achieved_frac']:.3f} -> {f['achieved_frac']:.3f} "
            f"({f['bottleneck']}-bound)")
        if f.get("l1_route") != b.get("l1_route"):
            advisories.append(
                f"roofline {key[0]}/{key[1]}: l1 route "
                f"{b.get('l1_route')} -> {f.get('l1_route')}")

    # -- workloads: advisory-first (downstream accuracy-vs-dense per
    # workload — misalignment / NMI / parity / attention rel err; promote to
    # hard gates once the trajectory has history) ---------------------------
    _WORKLOAD_ACC = ("misalignment", "knn_test_err", "nmi", "nmi_vs_dense",
                     "parity_vs_dense", "rmse", "rel_err_vs_exact",
                     "decode_rel_err")
    f_w = _index(fresh.get("workloads", []), "workload")
    b_w = _index(base.get("workloads", []), "workload")
    for name in sorted(set(f_w) & set(b_w)):
        f, b = f_w[name], b_w[name]
        for m in _WORKLOAD_ACC:
            if m not in f or m not in b:
                continue
            line = f"workloads {name}: {m} {b[m]:.4g} -> {f[m]:.4g}"
            # NMI is a higher-is-better score; everything else is an error
            worse = (f[m] < b[m] * (1.0 - tol_err) - 1e-6
                     if m.startswith("nmi") else f[m] > err_bound(b[m]))
            advisories.append(line + (" [beyond tolerance]" if worse else ""))
    for name in sorted(set(b_w) - set(f_w)):
        advisories.append(f"workloads {name}: row dropped from fresh payload")

    # -- advisory-only sections ---------------------------------------------
    f_serve = _index(fresh.get("serve", []), "clients")
    b_serve = _index(base.get("serve", []), "clients")
    for cl in sorted(set(f_serve) & set(b_serve)):
        advisories.append(
            f"serve clients={cl}: p50 {b_serve[cl]['p50_ms']:.1f} -> "
            f"{f_serve[cl]['p50_ms']:.1f} ms, req/s "
            f"{b_serve[cl]['req_per_s']:.1f} -> "
            f"{f_serve[cl]['req_per_s']:.1f}")
    f_app = _index(fresh.get("serve_append", []), "n")
    b_app = _index(base.get("serve_append", []), "n")
    for nn in sorted(set(f_app) & set(b_app)):
        advisories.append(
            f"serve_append n={nn}: speedup {b_app[nn]['speedup']:.1f}x -> "
            f"{f_app[nn]['speedup']:.1f}x (append p50 "
            f"{b_app[nn]['append_p50_ms']:.1f} -> "
            f"{f_app[nn]['append_p50_ms']:.1f} ms)")
    if fresh.get("total_seconds") and base.get("total_seconds"):
        advisories.append(
            f"smoke wall: {base['total_seconds']:.1f}s -> "
            f"{fresh['total_seconds']:.1f}s")
    return failures, advisories


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fresh", default=os.path.join("results",
                                                   "BENCH_smoke.json"))
    p.add_argument("--baseline", default=None,
                   help="explicit baseline path (default: latest "
                        "BENCH_pr*.json at the repo root)")
    p.add_argument("--tol-speedup", type=float, default=0.5,
                   help="allowed fractional speedup drop (default 0.5)")
    p.add_argument("--tol-err", type=float, default=0.5,
                   help="allowed fractional rel-err growth (default 0.5)")
    args = p.parse_args(argv)

    baseline = args.baseline or find_baseline()
    if baseline is None:
        print("compare_bench: no BENCH_pr*.json baseline found — nothing "
              "to gate (ok)")
        return 0
    if not os.path.exists(args.fresh):
        print(f"compare_bench: fresh payload {args.fresh} missing")
        return 1
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(baseline) as f:
        base = json.load(f)

    print(f"comparing {args.fresh} against {os.path.basename(baseline)}")
    failures, advisories = compare(fresh, base, tol_speedup=args.tol_speedup,
                                   tol_err=args.tol_err)
    for line in advisories:
        print(f"  ADVISORY {line}")
    for line in failures:
        print(f"  FAIL     {line}")
    if failures:
        print(f"compare_bench: {len(failures)} regression(s) beyond "
              f"tolerance")
        return 1
    print("compare_bench: perf trajectory within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
