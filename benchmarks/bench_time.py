"""Paper Table 3: U-matrix time & #entries-of-K scaling.

Measures wall-clock of computing U given C for the three models at growing
n, plus the number of kernel entries each must observe.  With ``--streaming``
the quadratic prototype column is swapped for the gaussian-projection fast
model through the single-sweep panel engine, and the #K columns switch from
the paper's analytic counts to *measured* evaluations via
``CountingOperator`` — the Table-3 metric, observed rather than assumed.
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import make_dataset, print_table
from repro.core import spsd
from repro.core.instrument import CountingOperator
from repro.core.kernelop import RBFKernel


def run(ns=(500, 1000, 2000, 4000), seed=0, streaming: bool = False):
    """``streaming=True`` drops the quadratic prototype column and adds the
    gaussian-projection fast model through blocked K @ S — the configuration
    that stays feasible at n ≫ 10⁴ (pass e.g. --ns 2000 10000 50000)."""
    rows = []
    for n in ns:
        X, _ = make_dataset("letters", seed=seed, n=n)
        Kop = CountingOperator(RBFKernel(X, sigma=1.0))
        c = max(n // 100, 8)
        s = 8 * c
        base = spsd.sample_C(Kop, jax.random.PRNGKey(seed), c)

        Kop.reset()
        t0 = time.perf_counter()
        W = Kop.block(base.P_indices, base.P_indices)
        jax.block_until_ready(spsd.nystrom_U(W))
        t_nys = time.perf_counter() - t0
        k_nys = n * c + Kop.counts["entries"]          # C gather + W block

        Kop.reset()
        t0 = time.perf_counter()
        ap = spsd.fast_model_from_C(Kop, base.C, jax.random.PRNGKey(1), s,
                                    P_indices=base.P_indices,
                                    s_sketch="leverage")
        jax.block_until_ready(ap.U)
        t_fast = time.perf_counter() - t0
        k_fast = n * c + Kop.counts["entries"]

        Kop.reset()
        if streaming:
            t0 = time.perf_counter()
            apg, _ = spsd.fast_model_with_error(
                Kop, jax.random.PRNGKey(2), c=c, s=s, s_sketch="gaussian",
                probes=8)
            jax.block_until_ready(apg.U)
            t_last = time.perf_counter() - t0
            last_cols = (f"{t_last * 1e3:9.1f}",
                         f"{Kop.counts['entries']:>12,}")
        else:
            t0 = time.perf_counter()
            proto = spsd.prototype_model(Kop, base.C, base.P_indices)
            jax.block_until_ready(proto.U)
            t_last = time.perf_counter() - t0
            last_cols = (f"{t_last * 1e3:9.1f}",
                         f"{n * c + Kop.counts['entries']:>12,}")

        rows.append((n, c, s,
                     f"{t_nys * 1e3:9.1f}", f"{k_nys:>10,}",
                     f"{t_fast * 1e3:9.1f}", f"{k_fast:>10,}")
                    + last_cols)
    last_name = "fast[gauss]+err" if streaming else "proto"
    print_table("Table 3: U-matrix cost scaling, measured #K entries"
                + (" [streaming]" if streaming else ""),
                ["n", "c", "s", "nys ms", "nys #K", "fast ms", "fast #K",
                 f"{last_name} ms", f"{last_name} #K"], rows)

    # linear-vs-quadratic check across the n range
    n0, n1 = ns[0], ns[-1]
    f0 = float(rows[0][5])
    f1 = float(rows[-1][5])
    p0 = float(rows[0][7])
    p1 = float(rows[-1][7])
    ref = "gaussian-projection" if streaming else "prototype"
    print(f"\nscaling n x{n1 // n0}: fast x{f1 / max(f0, 1e-9):.1f}, "
          f"{ref} x{p1 / max(p0, 1e-9):.1f} "
          f"(paper: fast ~linear, prototype ~quadratic)")
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--ns", nargs="*", type=int,
                   default=[500, 1000, 2000, 4000])
    p.add_argument("--streaming", action="store_true",
                   help="streaming gaussian fast model instead of the "
                        "quadratic prototype (large-n safe)")
    args = p.parse_args(argv)
    run(tuple(args.ns), streaming=args.streaming)


if __name__ == "__main__":
    main()
