"""Paper Table 3: U-matrix time & #entries-of-K scaling.

Measures wall-clock of computing U given C for the three models at growing
n, plus the number of kernel entries each must observe:
  nystrom: nc | prototype: n^2 | fast: nc + (s-c)^2.
The fast model should scale ~linearly in n; the prototype ~quadratically.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import make_dataset, print_table
from repro.core import spsd
from repro.core.kernelop import RBFKernel


def run(ns=(500, 1000, 2000, 4000), seed=0):
    rows = []
    for n in ns:
        X, _ = make_dataset("letters", seed=seed, n=n)
        Kop = RBFKernel(X, sigma=1.0)
        c = max(n // 100, 8)
        s = 8 * c
        base = spsd.sample_C(Kop, jax.random.PRNGKey(seed), c)

        t0 = time.perf_counter()
        W = Kop.block(base.P_indices, base.P_indices)
        jax.block_until_ready(spsd.nystrom_U(W))
        t_nys = time.perf_counter() - t0

        t0 = time.perf_counter()
        ap = spsd.fast_model_from_C(Kop, base.C, jax.random.PRNGKey(1), s,
                                    P_indices=base.P_indices,
                                    s_sketch="leverage")
        jax.block_until_ready(ap.U)
        t_fast = time.perf_counter() - t0

        t0 = time.perf_counter()
        proto = spsd.prototype_model(Kop, base.C, base.P_indices)
        jax.block_until_ready(proto.U)
        t_proto = time.perf_counter() - t0

        rows.append((n, c, s,
                     f"{t_nys * 1e3:9.1f}", f"{n * c:>10,}",
                     f"{t_fast * 1e3:9.1f}", f"{n * c + (s - c) ** 2:>10,}",
                     f"{t_proto * 1e3:9.1f}", f"{n * n:>12,}"))
    print_table("Table 3: U-matrix cost scaling",
                ["n", "c", "s", "nys ms", "nys #K", "fast ms", "fast #K",
                 "proto ms", "proto #K"], rows)

    # linear-vs-quadratic check across the n range
    n0, n1 = ns[0], ns[-1]
    f0 = float(rows[0][5])
    f1 = float(rows[-1][5])
    p0 = float(rows[0][7])
    p1 = float(rows[-1][7])
    print(f"\nscaling n x{n1 // n0}: fast x{f1 / max(f0, 1e-9):.1f}, "
          f"prototype x{p1 / max(p0, 1e-9):.1f} "
          f"(paper: fast ~linear, prototype ~quadratic)")
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--ns", nargs="*", type=int,
                   default=[500, 1000, 2000, 4000])
    args = p.parse_args(argv)
    run(tuple(args.ns))


if __name__ == "__main__":
    main()
